#!/usr/bin/env python
"""Scan-purity lint: keep host ops out of the engines' jitted scans.

The ≤-1-host-sync-per-revolution contract dies quietly: one
``jax.debug.print`` in a scan body becomes a per-pass host callback, a
``.block_until_ready()`` forces a sync, and a stray ``np.`` call bakes
a host-computed constant into the trace (or crashes on tracers weeks
later).  This lint walks the AST of each engine's device-program
builder (the ``_compiled`` methods, plus :func:`repro.obs.ring.record`
which runs inside them) and fails on the three footguns:

* ``jax.debug.print`` / ``jax.debug.callback`` / ``jax.debug.breakpoint``
* any ``.block_until_ready`` attribute access
* any use of ``np.`` / ``numpy.`` (host NumPy inside a traced scope)

Wired into ``scripts/check.sh``.  Exit 0 = clean, 1 = violations
(printed as ``path:line: message``), 2 = a guarded scope disappeared —
update ``SCOPES`` when refactoring the engines.
"""
from __future__ import annotations

import ast
import sys
from typing import List, Tuple

#: file (repo-relative) -> function/method names whose whole body must
#: stay device-pure (any nesting depth inside them counts)
SCOPES = {
    "src/repro/sim/device_sim.py": ("_compiled",),
    "src/repro/fleet/engine.py": ("_compiled",),
    "src/repro/serve_fleet/engine.py": ("_compiled",),
    "src/repro/obs/ring.py": ("record",),
    # the ISL exchange runs inside the fleet's jitted scan
    "src/repro/isl/exchange.py": ("async_gossip_step", "sync_exchange_step",
                                  "_charge", "_encode_planes",
                                  "_tree_where", "staleness_weight"),
    "src/repro/isl/codec.py": ("encode_delta", "residual_init"),
    "src/repro/isl/link.py": ("open_at", "contact_index", "offset_at"),
}

_DEBUG_ATTRS = {"print", "callback", "breakpoint"}
_NUMPY_NAMES = {"np", "numpy"}


def _dotted(node: ast.AST) -> str:
    """'jax.debug.print' for nested Attribute/Name chains ('' if not)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def check_scope(fn: ast.AST, path: str) -> List[Tuple[str, int, str]]:
    """All violations inside one guarded function's body."""
    hits = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute):
            dotted = _dotted(node)
            head = dotted.split(".", 1)[0]
            if (node.attr in _DEBUG_ATTRS
                    and dotted.startswith(("jax.debug.", "debug."))):
                hits.append((path, node.lineno,
                             f"{dotted} inside a scan body — a per-pass "
                             f"host callback breaks the sync contract"))
            elif node.attr == "block_until_ready":
                hits.append((path, node.lineno,
                             ".block_until_ready() inside a scan body "
                             "forces a device sync"))
            elif head in _NUMPY_NAMES:
                hits.append((path, node.lineno,
                             f"host numpy ({dotted}) inside a traced "
                             f"scope — use jnp, or hoist to __init__"))
    return hits


def lint_file(path: str, scope_names: Tuple[str, ...]
              ) -> Tuple[List[Tuple[str, int, str]], List[str]]:
    """(violations, scope names found) for one file."""
    with open(path) as fh:
        tree = ast.parse(fh.read(), filename=path)
    hits, found = [], []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name in scope_names:
            found.append(node.name)
            hits.extend(check_scope(node, path))
    return hits, found


def main(argv=None) -> int:
    import os

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    all_hits, missing = [], []
    for rel, names in sorted(SCOPES.items()):
        path = os.path.join(root, rel)
        hits, found = lint_file(path, names)
        all_hits.extend(hits)
        missing.extend(f"{rel}:{n}" for n in names if n not in found)
    for path, line, msg in all_hits:
        print(f"{path}:{line}: {msg}")
    if missing:
        print("lint_scan_purity: guarded scopes not found (update SCOPES "
              "after refactoring): " + ", ".join(missing))
        return 2
    if all_hits:
        print(f"lint_scan_purity: {len(all_hits)} violation(s)")
        return 1
    print(f"lint_scan_purity: OK ({len(SCOPES)} files, scan bodies "
          f"host-op-free)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
