#!/usr/bin/env bash
# Tier-1 gate + benchmark smoke: everything a PR must keep green.
#
#   scripts/check.sh           # full tier-1 pytest + quick benchmark smoke
#   scripts/check.sh --fast    # skip the (slow) full test suite, smoke only
#
# The quick benchmark run exercises the jitted problem-(13) solver
# backends (numpy vs jax parity + timing rows) and the on-device
# revolution sweep on small grids, so a regression in the compiled
# solver is caught without paying for a full 1000-sat sweep.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--fast" ]]; then
    echo "== tier-1 pytest =="
    python -m pytest -x -q
fi

echo "== quick benchmark smoke (solver backends + sweep) =="
python -m benchmarks.run --quick

echo "check.sh: OK"
