#!/usr/bin/env bash
# Tier-1 gate + benchmark smoke: everything a PR must keep green.
#
#   scripts/check.sh           # full tier-1 pytest + quick benchmark smoke
#   scripts/check.sh --fast    # skip the (slow) full test suite, smoke only
#
# The quick benchmark run exercises the jitted problem-(13) solver
# backends (numpy vs jax parity + timing rows) and the on-device
# revolution sweep on small grids, so a regression in the compiled
# solver is caught without paying for a full 1000-sat sweep.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# scan-purity lint: no jax.debug.print / .block_until_ready / host
# numpy inside the engines' jitted scan bodies (the sync-contract
# footguns) — cheap, so it runs in both modes, before anything slow
echo "== scan-purity lint (engine scan bodies stay host-op-free) =="
python scripts/lint_scan_purity.py

if [[ "${1:-}" != "--fast" ]]; then
    echo "== tier-1 pytest =="
    python -m pytest -x -q
else
    # --fast skips pytest, so run the standalone host-vs-device parity
    # smoke instead (the full path already covers it twice: the
    # test_device_sim suite and the asserted closed_loop_* bench rows)
    echo "== device-sim smoke (host-vs-device closed-loop parity) =="
    python -c "from repro.sim.device_sim import _smoke; _smoke()"
    # 2-plane x 8-sat fleet smoke on 2 forced CPU devices: join, leave
    # and seeded-failure events entirely on device, <= 1 host sync per
    # revolution, host-vs-fleet parity asserted per plane
    echo "== fleet smoke (2-plane elastic fleet on a 2-device mesh) =="
    python -m repro.fleet
    # degraded-ops smoke: eclipse + one Byzantine slot + epidemic
    # faults with robust aggregation, bit-exact host-prefix action
    # parity, <= 1 host sync per revolution
    echo "== degraded-ops smoke (eclipse + byzantine + epidemic) =="
    python -m repro.fleet --scenario degraded
    # serve-fleet smoke: split-vs-full greedy decode parity, a few
    # hundred requests through real pass-window routing on the split
    # engine, and the fleet serving scan vs its NumPy oracle (f32
    # energy parity on the shared train/serve batteries)
    echo "== serve-fleet smoke (split decode + pass-window serving) =="
    python -m repro.serve_fleet
    # ISL comms smoke: codec bit-metering monotonicity, sync/none ==
    # legacy barrier bit-for-bit, async compressed gossip vs the NumPy
    # host-prefix oracles (actions + every contact row), <= 1 host
    # sync per revolution -- on the forced 2-CPU-device mesh
    echo "== isl smoke (contact-window exchange vs host oracles) =="
    python -m repro.isl
    # flight-recorder smoke: record->flush->render a degraded fleet run
    # + delegated sim + serve fleet under a sync_budget guard; event
    # counts and payloads must match the dense telemetry, and the
    # merged Chrome-trace JSON must validate
    echo "== flight-recorder smoke (rings -> metrics -> timeline) =="
    python -m repro.obs
fi

echo "== quick benchmark smoke (solver backends + sweep + closed loop) =="
python -m benchmarks.run --quick

echo "check.sh: OK"
