import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Kernel-fused memory accounting for §Perf.

The dry-run's jnp attention/scan paths stream their score/decay matrices
through HBM (XLA cost analysis counts every elementwise pass), while the
Pallas kernels keep those tiles in VMEM: the deployed HBM traffic per
attention block is just Q,K,V in + O out (x ~4 for fwd+remat+bwd).

This script, per chosen cell:
  1. micro-compiles the attention op (grad for train) at the cell's
     global shapes/shardings -> measured attention bytes/flops;
  2. computes the kernel's analytic HBM bytes (operands + outputs only);
  3. reports the adjusted memory term = cell_bytes - n_blocks *
     (measured_attn - fused_attn).

Usage: PYTHONPATH=src python scripts/fused_accounting.py
"""
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

sys.path.insert(0, "src")
from repro import configs                                    # noqa: E402
from repro.configs.shapes import SHAPES                      # noqa: E402
from repro.kernels import ops as kops                        # noqa: E402
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16, \
    make_production_mesh                                     # noqa: E402
from repro.models.param import ShardingRules                 # noqa: E402
from repro.utils import hlo as hlo_util                      # noqa: E402

CELLS = [
    ("smollm_360m", "train_4k"),
    ("llama3_8b", "train_4k"),
    ("xlstm_1_3b", "train_4k"),
    ("phi35_moe", "train_4k"),
    ("internlm2_20b", "train_4k"),
    ("granite_3_2b", "prefill_32k"),
]


def measure_attention(cfg, shape, mesh, rules, compute_dtype=jnp.float32):
    """Measured bytes/flops of one attention block op (per device)."""
    B, S = shape.global_batch, shape.seq_len
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    train = shape.kind == "train"

    def struct(shp):
        spec = rules.resolve(("batch",) + (None,) * (len(shp) - 1),
                             mesh, shp)
        return jax.ShapeDtypeStruct(shp, jnp.bfloat16,
                                    sharding=NamedSharding(mesh, spec))

    args = (struct((B, H, S, dh)), struct((B, KV, S, dh)),
            struct((B, KV, S, dh)))

    def op(q, k, v):
        return jnp.sum(kops.flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.window,
            block_q=1024, block_k=1024, use_pallas=False,
            compute_dtype=compute_dtype).astype(jnp.float32))

    prog = jax.grad(jax.checkpoint(op), argnums=(0, 1, 2)) if train else op
    kops.set_inner_unroll(True)
    try:
        comp = jax.jit(prog).lower(*args).compile()
    finally:
        kops.set_inner_unroll(False)
    c = comp.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0]
    return float(c.get("flops", 0)), float(c.get("bytes accessed", 0))


def fused_attention_bytes(cfg, shape, n_chips) -> float:
    """Analytic HBM traffic of the Pallas flash kernel (per device):
    Q,K,V reads + O write; train multiplies by fwd + remat + bwd
    (bwd re-reads Q,K,V,O,dO and writes dQ,dK,dV ~ 3x fwd traffic)."""
    B, S = shape.global_batch, shape.seq_len
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    bytes_fwd = 2.0 * B * S * dh * (H + 2 * KV + H)      # q,k,v in + o out
    mult = 4.0 if shape.kind == "train" else 1.0
    return bytes_fwd * mult / n_chips


def main():
    with open("results/dryrun.json") as f:
        rows = {(r["arch"], r["shape"], r["preset"]): r
                for r in json.load(f) if r["mesh"] == "pod16x16"}
    mesh = make_production_mesh()
    rules = ShardingRules()
    n_chips = mesh.devices.size
    out = []
    for arch, shape_name in CELLS:
        cfg = configs.get(arch)
        shape = SHAPES[shape_name]
        base = rows.get((arch, shape_name, "baseline"))
        if base is None or base.get("status") != "ok":
            continue
        kinds = cfg.block_kinds()
        n_attn = sum(1 for k in kinds if k in ("attn", "shared_attn", "moe"))
        if cfg.enc_dec:
            n_attn += cfg.n_enc_layers + cfg.n_layers  # enc + cross
        if n_attn == 0:
            measured_f = measured_b = fused_b = 0.0
        else:
            with mesh:
                measured_f, measured_b = measure_attention(cfg, shape, mesh,
                                                           rules)
            fused_b = fused_attention_bytes(cfg, shape, n_chips)
        cell_bytes = base["cost"]["bytes_accessed"]
        adj_bytes = max(cell_bytes - n_attn * (measured_b - fused_b),
                        cell_bytes * 0.02)
        rec = {
            "arch": arch, "shape": shape_name,
            "attn_blocks": n_attn,
            "attn_bytes_measured_per_block": measured_b,
            "attn_bytes_fused_per_block": fused_b,
            "cell_bytes_baseline": cell_bytes,
            "cell_bytes_kernel_fused": adj_bytes,
            "memory_s_baseline": cell_bytes / HBM_BW,
            "memory_s_kernel_fused": adj_bytes / HBM_BW,
        }
        out.append(rec)
        print(f"{arch} x {shape_name}: attn {n_attn} blocks | "
              f"measured {measured_b:.3e} B/blk vs fused {fused_b:.3e} | "
              f"memory term {rec['memory_s_baseline']:.2f}s -> "
              f"{rec['memory_s_kernel_fused']:.2f}s")
    with open("results/fused_accounting.json", "w") as f:
        json.dump(out, f, indent=1)
    print("wrote results/fused_accounting.json")


if __name__ == "__main__":
    main()
