"""Inject dry-run/roofline/perf tables into EXPERIMENTS.md placeholders.

Usage: PYTHONPATH=src python scripts/fill_experiments.py
"""
import json
import sys

sys.path.insert(0, "src")
from repro.launch import roofline as rl  # noqa: E402


def dryrun_table(rows):
    hdr = ["arch", "shape", "mesh", "status", "compile[s]",
           "mem/dev[GB]", "flops/dev", "bytes/dev", "coll/dev"]
    out = ["| " + " | ".join(hdr) + " |", "|" + "---|" * len(hdr)]
    key = lambda r: (r["arch"], r["shape"], r["mesh"])
    for r in sorted(rows, key=key):
        if r.get("preset", "baseline") != "baseline":
            continue
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped (sub-quadratic-only cell) | - | - | - | - | - |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR | - | - | - | - | - |")
            continue
        mem = r.get("memory", {}).get("total_per_device_bytes", 0) / 1e9
        c = r.get("cost", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', 0):.0f} | {mem:.2f} | "
            f"{c.get('flops', 0):.2e} | {c.get('bytes_accessed', 0):.2e} | "
            f"{r.get('collective_bytes_per_device', 0):.2e} |")
    return "\n".join(out)


def main():
    with open("results/dryrun.json") as f:
        rows = json.load(f)
    single = [r for r in rows if r["mesh"] == "pod16x16"]
    base = [r for r in single if r.get("preset", "baseline") == "baseline"]

    dr = dryrun_table(rows)
    ro = rl.table(base, md=True)
    adv = rl.advice(base)

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dr)
    text = text.replace("<!-- ROOFLINE_TABLE -->",
                        ro + "\n\n### Bottlenecks and what moves them\n\n"
                        + adv)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    ok = sum(r.get("status") == "ok" for r in rows)
    sk = sum(r.get("status") == "skipped" for r in rows)
    print(f"injected: {ok} ok, {sk} skipped, {len(rows)} total rows")


if __name__ == "__main__":
    main()
