"""Quickstart: the paper in ~60 lines.

1. Build the Table-I constellation and check T_pass.
2. Pick a split point for the autoencoder and solve problem (13).
3. Run three real SL train steps (satellite encoder / ground decoder)
   and account the energy of the pass.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.energy import PassBudget, direct_download_costs
from repro.core.orbits import PAPER_PLANE
from repro.core.resource_opt import solve
from repro.core.sl_step import autoencoder_adapter, make_sl_step
from repro.data.synthetic import ImageryShards

# 1. constellation geometry (paper eqs. 1-5)
print("== constellation ==")
for k, v in PAPER_PLANE.summary().items():
    print(f"  {k:24s} {v:.3f}" if isinstance(v, float) else f"  {k}: {v}")

# 2. split the autoencoder at the latent (cut=5) and optimize the pass
adapter = autoencoder_adapter(cut=5, img=64)
budget = PassBudget(n_items=64)
costs = adapter.costs()
rep = solve(budget, costs)
print("\n== problem (13), autoencoder split ==")
for k, v in rep.allocation.summary().items():
    print(f"  {k:12s} {v}")

dd = direct_download_costs(64 * 64 * 3 * 32, costs.w1_flops + costs.w2_flops)
rep_dd = solve(budget, dd)
print(f"  vs direct download: {rep_dd.allocation.e_total:.4g} J "
      f"({100 * (1 - rep.allocation.e_total / rep_dd.allocation.e_total):.1f}%"
      f" savings)")

# 3. three real SL steps on the satellite's local shard
print("\n== split-learning steps (satellite encoder / ground decoder) ==")
from repro.core.train_state import SLTrainState
from repro.train.optimizer import sgd

pa, pb = adapter.init(jax.random.key(0))
step = make_sl_step(adapter, quantize_boundary=True)   # int8 boundary
shards = ImageryShards(img=64, batch=8)
opt = sgd(lr=1e-2)
state = SLTrainState.create(pa, pb, opt)
for i in range(3):
    batch = jax.tree.map(jnp.asarray, shards.batch_at(0, i))
    res = step(state.params_a, state.params_b, batch)
    state = state.apply_updates(res.grads_a, res.grads_b, opt)
    print(f"  step {i}: loss {float(res.loss):.4f}, boundary "
          f"{res.dtx_bits_down / 8 / 1024:.1f} KiB (int8) each way")
print("done.")
