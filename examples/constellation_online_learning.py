"""End-to-end driver: the paper's system, running.

25-satellite ring (Table I), each with a non-IID local imagery shard,
training the split autoencoder round-robin: satellite runs the encoder,
the ground terminal the decoder; problem (13) allocates (f, p) per pass;
the ISL handoff is an integrity-checked checkpoint; faults and battery
limits exercise the skip/restore policies.

Run:  PYTHONPATH=src python examples/constellation_online_learning.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.core.constellation import ConstellationConfig, ConstellationSim
from repro.core.energy import PassBudget
from repro.core.sl_step import autoencoder_adapter
from repro.data.synthetic import ImageryShards

shards = ImageryShards(img=64, batch=8, n_shards=25)
adapter = autoencoder_adapter(cut=5, img=64)

with tempfile.TemporaryDirectory() as handoff_dir:
    sim = ConstellationSim(
        adapter,
        PassBudget(n_items=64),
        data_for_sat=lambda s, i: jax.tree.map(jnp.asarray,
                                               shards.batch_at(s, i)),
        cfg=ConstellationConfig(
            n_passes=25,                 # one full ring revolution
            batch_size=8,
            optimizer="sgd",             # or "adamw" (LM-track schedule)
            quantize_boundary=True,      # int8 boundary (beyond-paper)
            fail_prob=0.08,              # random satellite failures
            battery_j=2_000.0,
            recharge_w=5.0,
            reserve_j=100.0,
            handoff_dir=handoff_dir,
            join_events={12: 2},         # elastic: 2 sats join at pass 12
        ))
    records = sim.run()

    print(f"{'pass':>4} {'sat':>4} {'action':15s} {'loss':>8} "
          f"{'E_total[J]':>11} {'E_comm[J]':>10} {'D_ISL[Mb]':>10}")
    for r in records:
        loss = f"{r.loss:.4f}" if r.loss is not None else "-"
        print(f"{r.pass_idx:4d} {r.sat_id:4d} {r.action:15s} {loss:>8} "
              f"{r.e_total_j:11.4g} {r.e_comm_j:10.4g} "
              f"{r.d_isl_bits / 1e6:10.2f}")
    print("\nsummary:", sim.summary())
    print(f"planner: {sim.planner.solve_calls} batched solve(s), "
          f"{sim.planner.invalidations} invalidation(s) "
          f"for {len(records)} passes")
