"""Batched serving demo: continuous-batching greedy decode over the KV
cache (full attention; swap --arch mixtral_8x7b for the SWA ring or
xlstm_1_3b for constant-memory recurrent-state decoding).

Run:  PYTHONPATH=src python examples/serve_batched.py --arch smollm_360m
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import DecodeEngine, Request

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="smollm_360m")
ap.add_argument("--requests", type=int, default=6)
ap.add_argument("--slots", type=int, default=3)
ap.add_argument("--new-tokens", type=int, default=10)
args = ap.parse_args()

cfg = configs.get_smoke(args.arch)
params = lm.init(cfg, jax.random.key(0))
engine = DecodeEngine(cfg, params, n_slots=args.slots, s_max=96)

rng = np.random.default_rng(0)
reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)]
t0 = time.time()
out = engine.submit_and_run(reqs)
dt = time.time() - t0
for rid in sorted(out):
    print(f"req {rid}: {out[rid]}")
tok = sum(map(len, out.values()))
print(f"{len(out)} requests, {tok} tokens, {dt:.2f}s "
      f"({tok/dt:.1f} tok/s on {args.slots} slots, arch={cfg.name})")
