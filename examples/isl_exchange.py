"""Compressed, staleness-tolerant inter-plane exchange over a modeled ISL.

A 2-plane fleet trains the split autoencoder twice over the same
revolutions, exchanging checkpoints over the inter-satellite link two
ways:

* **sync / full float** — the classic revolution-boundary barrier
  (``ExchangeConfig(mode="sync")`` with ``scheme="none"``): bit-exact
  with the legacy free averaging, but now *metered* — every exchange
  pays its wire bits and drains ``isl_pw * bits / rate`` joules from
  the pushing satellite's battery;
* **async / top-k 1%** — SFL-LEO-style contact-window gossip
  (``mode="async"``): every ``period`` passes each plane pushes its
  error-feedback-compressed checkpoint delta to the neighbor plane and
  merges what it received with the staleness-discounted weight
  ``mix / (1 + lam * staleness)`` — no barrier, ~60x fewer wire bits,
  and the compressed volume feeds the planner's problem-(13)
  ``d_isl_bits`` term, so the codec changes the *planned* allocation.

Both runs execute inside the fleet's one jitted scan (≤ 1 host sync
per revolution) and replay bit-exactly on the NumPy host-prefix
oracles (``repro.isl.oracle_exchange``), which this script asserts.

Run:  PYTHONPATH=src python examples/isl_exchange.py
      (--revolutions N to train longer; runs on a forced 2-CPU-device
       mesh so the plane axis actually shards)
"""
import argparse
import os

if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2").strip()

import numpy as np  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--revolutions", type=int, default=3)
ap.add_argument("--sats", type=int, default=8)
args = ap.parse_args()

from repro.core.energy import PassBudget               # noqa: E402
from repro.core.orbits import OrbitalPlane             # noqa: E402
from repro.core.sl_step import autoencoder_adapter     # noqa: E402
from repro.fleet import FleetConfig, FleetEngine       # noqa: E402
from repro.isl import (CodecConfig, ContactConfig,     # noqa: E402
                       ExchangeConfig, exchange_events,
                       oracle_exchange)
from repro.obs.timeline import timeline_summary        # noqa: E402
from repro.sim.data import DeviceImageryShards         # noqa: E402

shards = DeviceImageryShards(img=32, batch=4)
adapter = autoencoder_adapter(cut=5, img=32)
budget = PassBudget(plane=OrbitalPlane(n_sats=args.sats), n_items=4e6)
base = dict(n_planes=2, n_revolutions=args.revolutions,
            max_steps_per_pass=2, seed=0)


def final_loss(res):
    return float(np.mean([row[np.isfinite(row)][-1] for row in res.loss]))


runs = {
    "sync full-float barrier": FleetConfig(
        avg_every=1, exchange=ExchangeConfig(mode="sync"), **base),
    "async top-k 1% gossip": FleetConfig(
        avg_every=0, exchange=ExchangeConfig(
            mode="async", codec=CodecConfig("topk", topk_ratio=0.01),
            contact=ContactConfig(period=2), mix=0.5,
            staleness_lam=0.1), **base),
}

for name, cfg in runs.items():
    fleet = FleetEngine(adapter, budget, shards, cfg)
    expect = oracle_exchange(fleet)          # host-prefix replay, first
    res = fleet.run()
    got = exchange_events(fleet.recorder)
    for col in ("t", "slot", "bits", "e_isl_j", "staleness", "weight"):
        np.testing.assert_array_equal(got[col], expect[col], col)
    s = res.summary()
    print(f"\n== {name} ==")
    print(f"  final loss        {final_loss(res):.5f}")
    print(f"  contacts          {int(res.isl_contacts.sum())} "
          f"(oracle parity bit-exact)")
    print(f"  wire bits         {s['ISL_exchange_bits']:.3g}")
    print(f"  ISL energy        {s['ISL_exchange_J']:.3g} J "
          f"(drained from the serving batteries)")
    print(f"  planned d_isl     "
          f"{float(np.asarray(fleet.plan.d_isl_bits).mean()):.4g} "
          f"bits/pass (problem-(13) input)")
    print(f"  host syncs        {fleet.host_syncs} "
          f"(traces={fleet.traces})")
    print("  " + timeline_summary(fleet.recorder.events())
          .replace("\n", "\n  "))
