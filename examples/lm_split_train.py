"""LM track: split-learning a ~360M-class transformer (SmolLM config)
between "satellite" (embedding + lower blocks) and "ground" (upper
blocks + head), plus the plain pjit training driver for comparison.

The full smollm-360m fits the assignment's runnable-driver bill; pass
--smoke to use the reduced config for a fast CPU demo, or --full for
the real 360M shapes (slow on CPU; the dry-run covers the 256-chip
production lowering).

Run:  PYTHONPATH=src python examples/lm_split_train.py --steps 10
"""
import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.energy import PassBudget
from repro.core.resource_opt import solve
from repro.core.sl_step import lm_adapter, make_sl_step
from repro.core.train_state import SLTrainState
from repro.data.synthetic import TokenShards
from repro.train.optimizer import resolve_optimizer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=10)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--cut-units", type=int, default=1)
ap.add_argument("--optimizer", choices=("sgd", "adamw"), default="sgd",
                help="pluggable optimizer; adamw uses the LM lr schedule")
ap.add_argument("--lr", type=float, default=5e-3)
ap.add_argument("--full", action="store_true",
                help="use the real smollm-360m config (slow on CPU)")
args = ap.parse_args()

cfg = configs.get("smollm_360m") if args.full \
    else configs.get_smoke("smollm_360m")
print(f"arch {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
      f"({cfg.param_count()/1e6:.1f}M params)")

adapter = lm_adapter(cfg, cut_units=args.cut_units, seq_len=args.seq)
costs = adapter.plan.costs_at(adapter.cut_index)
rep = solve(PassBudget(n_items=args.batch * args.steps), costs)
print(f"pass allocation: E={rep.allocation.e_total:.4g} J "
      f"feasible={rep.allocation.feasible} "
      f"(W1={costs.w1_flops:.3g} W2={costs.w2_flops:.3g} FLOPs/seq, "
      f"D_tx={costs.dtx_bits/1e6:.2f} Mb/seq)")

pa, pb = adapter.init(jax.random.key(0))
step = make_sl_step(adapter)
shards = TokenShards(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
opt = resolve_optimizer(args.optimizer, lr=args.lr)
state = SLTrainState.create(pa, pb, opt)
batch0 = jax.tree.map(jnp.asarray, shards.batch_at(0, 0))
for i in range(args.steps):
    # memorize one batch: loss must fall
    res = step(state.params_a, state.params_b, batch0)
    state = state.apply_updates(res.grads_a, res.grads_b, opt)
    print(f"  step {i}: loss {float(res.loss):.4f} "
          f"boundary {res.dtx_bits_down/8/1024:.0f} KiB/way")
print(f"done ({opt.name}: loss should be decreasing).")
