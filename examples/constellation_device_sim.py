"""Constellation-scale closed loop, resident on the accelerator.

A 1000-satellite ring trains the split autoencoder for 8 full
revolutions — 8000 passes of [problem-(13) allocation -> reserve-skip
policy -> masked fused SL steps -> battery drain -> solar recharge] —
with the WHOLE loop compiled as one jitted (revolution × ring-slot)
scan: batches are generated inside the scan, the plan never leaves the
device, and the host hears from the constellation exactly once per
revolution (energy telemetry).

The per-pass item budget is scaled so a pass drains ~48 J against 200 J
batteries with slow solar recharge: satellites visibly cycle between
training and reserve-policy skips across revolutions — the paper's
energy-constrained regime, at a scale the host scheduler cannot touch.

Run:  PYTHONPATH=src python examples/constellation_device_sim.py
      (add --small for a fast 64-sat × 4-revolution variant)
"""
import sys
import time

import numpy as np

from repro.core.energy import PassBudget
from repro.core.orbits import OrbitalPlane
from repro.core.sl_step import autoencoder_adapter
from repro.sim.data import DeviceImageryShards
from repro.sim.device_sim import (ACTION_SKIPPED, DeviceConstellationSim,
                                  DeviceSimConfig)

small = "--small" in sys.argv[1:]
n_sats, n_revolutions = (64, 4) if small else (1000, 8)

shards = DeviceImageryShards(img=32, batch=2)
adapter = autoencoder_adapter(cut=5, img=32)
budget = PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=4e6)
cfg = DeviceSimConfig(
    n_revolutions=n_revolutions,
    battery_j=200.0,          # per-sat battery [J]
    recharge_w=1e-4,          # slow solar recharge: skips emerge
    reserve_j=150.0,          # skip threshold
    max_steps_per_pass=2,     # simulated compute cap (alloc is per-item)
)

t0 = time.time()
engine = DeviceConstellationSim(adapter, budget, shards, cfg)
plan = engine.plan.to_host()
print(f"ring: {n_sats} sats x {n_revolutions} revolutions "
      f"({n_sats * n_revolutions} passes)")
print(f"plan (on device, broadcast view): {plan.n_steps[0]} fused "
      f"steps/pass, drain {plan.drain_j[0]:.1f} J/pass, "
      f"E_pass {plan.e_total_j[0]:.1f} J, kept {plan.kept_fraction[0]:.3f}")

print(f"\n{'rev':>4} {'trained':>8} {'skipped':>8} {'mean loss':>10} "
      f"{'battery J (min/med/max)':>24} {'s/rev':>6}")
t_rev = time.time()
last_loss = float("nan")
for rev in range(n_revolutions):
    res = engine.run(1, stream_telemetry=True)   # ONE host sync per rev
    bat = res.energy.battery_j
    trained = res.action != ACTION_SKIPPED
    loss = np.nanmean(res.loss) if trained.any() else float("nan")
    if np.isfinite(loss):
        last_loss = loss
    now = time.time()
    print(f"{rev:4d} {int(trained.sum()):8d} "
          f"{int((~trained).sum()):8d} {loss:10.4f} "
          f"{bat.min():7.1f}/{np.median(bat):7.1f}/{bat.max():7.1f} "
          f"{now - t_rev:6.1f}")
    t_rev = now

es = engine.energy
print(f"\nenergy telemetry after {n_revolutions} revolutions:")
print(f"  fleet spent     {float(np.asarray(es.energy_spent_j).sum()):,.0f} J"
      f" (eq. 11, incl. ground + ISL)")
print(f"  passes served   {int(np.asarray(es.passes_served).sum())}, "
      f"skipped {int(np.asarray(es.passes_skipped).sum())} "
      f"(reserve policy)")
print(f"  batteries       min {float(np.asarray(es.battery_j).min()):.1f} J"
      f" / max {float(np.asarray(es.battery_j).max()):.1f} J")
print(f"  train steps     {int(np.asarray(engine.state.step))} fused "
      f"(last trained-revolution loss {last_loss:.4f})")
print(f"\nhost contact: {engine.traces} jit trace, "
      f"{engine.device_calls} dispatches, {engine.host_syncs} telemetry "
      f"syncs for {n_sats * n_revolutions} passes "
      f"({time.time() - t0:.1f}s total)")
