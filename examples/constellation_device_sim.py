"""Constellation-scale closed loop, resident on the accelerator.

Default: a 1000-satellite ring trains the split autoencoder for 8 full
revolutions — 8000 passes of [problem-(13) allocation -> reserve-skip
policy -> masked fused SL steps -> battery drain -> solar recharge] —
with the WHOLE loop compiled as one jitted (revolution × ring-slot)
scan: batches are generated inside the scan, the plan never leaves the
device, and the host hears from the constellation exactly once per
revolution (energy telemetry).

With ``--planes P`` the same scenario runs as a P-plane *fleet*
(:mod:`repro.fleet`): every plane is its own ring, the (P, N) energy
state and pass plan shard over the plane axis of a device mesh, and the
segment checkpoints are averaged across planes at each revolution
boundary (the paper's inter-plane ISL exchange).  Either way the mesh /
device layout the run actually used is printed.

The per-pass item budget is scaled so a pass drains ~48 J against 200 J
batteries with slow solar recharge: satellites visibly cycle between
training and reserve-policy skips across revolutions — the paper's
energy-constrained regime, at a scale the host scheduler cannot touch.

Run:  PYTHONPATH=src python examples/constellation_device_sim.py
      (--small for a fast 64-sat × 4-revolution variant;
       --planes 2 for the 2-plane fleet — combine with
       XLA_FLAGS=--xla_force_host_platform_device_count=2 to watch it
       shard over two CPU host devices;
       --planes 2 --degraded for the degraded-ops scenario: eclipse
       windows gating recharge, a Byzantine slot corrupting its pass
       updates, and epidemic faults spreading along each ring, defended
       by robust (median/trimmed-mean) inter-plane aggregation)
"""
import argparse
import time

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--small", action="store_true",
                help="64 sats x 4 revolutions (fast CPU variant)")
ap.add_argument("--planes", type=int, default=1,
                help="orbital planes; >1 runs the sharded fleet engine")
ap.add_argument("--degraded", action="store_true",
                help="with --planes >= 2: degraded-ops scenario — "
                     "eclipse windows, one Byzantine slot and epidemic "
                     "faults, robust inter-plane aggregation")
args = ap.parse_args()
if args.degraded and args.planes < 2:
    ap.error("--degraded is a fleet scenario: use --planes >= 2")

import jax  # noqa: E402

from repro.core.energy import PassBudget  # noqa: E402
from repro.core.orbits import OrbitalPlane  # noqa: E402
from repro.core.sl_step import autoencoder_adapter  # noqa: E402
from repro.sim.data import DeviceImageryShards  # noqa: E402
from repro.sim.device_sim import (ACTION_SKIPPED,  # noqa: E402
                                  DeviceConstellationSim, DeviceSimConfig)

n_sats, n_revolutions = (64, 4) if args.small else (1000, 8)
planes = max(1, args.planes)

shards = DeviceImageryShards(img=32, batch=2)
adapter = autoencoder_adapter(cut=5, img=32)
budget = PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=4e6)
energy_knobs = dict(
    battery_j=200.0,          # per-sat battery [J]
    recharge_w=1e-4,          # slow solar recharge: skips emerge
    reserve_j=150.0,          # skip threshold
    max_steps_per_pass=2,     # simulated compute cap (alloc is per-item)
)

t0 = time.time()
if planes > 1:
    from repro.fleet import FleetConfig, FleetEngine

    scenario, aggregate = None, "mean"
    if args.degraded:
        from repro.fleet import (ByzantineConfig, EclipseConfig,
                                 EpidemicConfig, ScenarioConfig)

        # half the orbit in shadow, one lying slot on plane 0, and a
        # transient fault epidemic seeded at slot 0 — defended by the
        # robust inter-plane exchange (trimmed-mean needs > 2 planes)
        scenario = ScenarioConfig(
            eclipse=EclipseConfig(period=4, duty=0.5, stagger=1),
            byzantine=ByzantineConfig(slots={0: [1]}, mode="sign_flip",
                                      scale=1.0),
            epidemic=EpidemicConfig(beta=0.3, ttl=2, init_slots=(0,)))
        aggregate = "trimmed_mean" if planes > 2 else "median"

    engine = FleetEngine(adapter, budget, shards, FleetConfig(
        n_planes=planes, n_revolutions=n_revolutions, avg_every=1,
        scenario=scenario, aggregate=aggregate, **energy_knobs))
    mesh = dict(zip(engine.mesh.axis_names, engine.mesh.devices.shape))
    layout = (f"fleet layout ({planes}, {n_sats}) sharded over mesh "
              f"{mesh}; inter-plane checkpoint averaging every "
              "revolution")
    if args.degraded:
        layout += f" (degraded-ops scenario, aggregate={aggregate})"
else:
    engine = DeviceConstellationSim(adapter, budget, shards,
                                    DeviceSimConfig(
                                        n_revolutions=n_revolutions,
                                        **energy_knobs))
    layout = f"single ring, (1, {n_sats}) layout on the default device"

devs = jax.devices()
print(f"devices: {len(devs)} x {devs[0].platform}  ({layout})")
print(f"ring: {planes} plane(s) x {n_sats} sats x {n_revolutions} "
      f"revolutions ({planes * n_sats * n_revolutions} passes)")
plan = engine.plan
p0 = np.asarray(plan.n_steps).reshape(-1)[0]
print(f"plan (on device, broadcast view): {p0} fused steps/pass, "
      f"drain {np.asarray(plan.drain_j).reshape(-1)[0]:.1f} J/pass, "
      f"E_pass {np.asarray(plan.e_total_j).reshape(-1)[0]:.1f} J, "
      f"kept {np.asarray(plan.kept_fraction).reshape(-1)[0]:.3f}")

print(f"\n{'rev':>4} {'trained':>8} {'skipped':>8} {'mean loss':>10} "
      f"{'battery J (min/med/max)':>24} {'s/rev':>6}")
t_rev = time.time()
last_loss = float("nan")
faulted_total = 0
for rev in range(n_revolutions):
    res = engine.run(1, stream_telemetry=True)   # ONE host sync per rev
    bat = np.asarray(res.energy.battery_j)
    trained = res.action != ACTION_SKIPPED
    if args.degraded:
        from repro.sim.device_sim import ACTION_FAULT
        faulted = res.action == ACTION_FAULT
        faulted_total += int(faulted.sum())
        trained = trained & ~faulted
    loss = np.nanmean(res.loss) if trained.any() else float("nan")
    if np.isfinite(loss):
        last_loss = loss
    now = time.time()
    print(f"{rev:4d} {int(trained.sum()):8d} "
          f"{int((~trained).sum()):8d} {loss:10.4f} "
          f"{bat.min():7.1f}/{np.median(bat):7.1f}/{bat.max():7.1f} "
          f"{now - t_rev:6.1f}")
    t_rev = now

es = engine.energy
print(f"\nenergy telemetry after {n_revolutions} revolutions:")
print(f"  fleet spent     {float(np.asarray(es.energy_spent_j).sum()):,.0f} J"
      f" (eq. 11, incl. ground + ISL)")
print(f"  passes served   {int(np.asarray(es.passes_served).sum())}, "
      f"skipped {int(np.asarray(es.passes_skipped).sum())} "
      f"(reserve policy)")
print(f"  batteries       min {float(np.asarray(es.battery_j).min()):.1f} J"
      f" / max {float(np.asarray(es.battery_j).max()):.1f} J")
print(f"  train steps     {int(np.asarray(engine.state.step).sum())} fused "
      f"(last trained-revolution loss {last_loss:.4f})")
if args.degraded:
    print(f"  degraded ops    {faulted_total} epidemic-faulted passes; "
          f"robust aggregate={engine.cfg.aggregate} over "
          f"{planes} planes")
print(f"\nhost contact: {engine.traces} jit trace, "
      f"{engine.device_calls} dispatches, {engine.host_syncs} telemetry "
      f"syncs for {planes * n_sats * n_revolutions} passes "
      f"({time.time() - t0:.1f}s total)")
