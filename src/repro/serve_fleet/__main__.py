"""Serve-fleet smoke: ``python -m repro.serve_fleet``.

1. Split-vs-full decode parity: the split engine (satellite half +
   boundary downlink + ground half) must generate the exact greedy
   tokens of the unsplit engine.
2. A few hundred synthetic requests, Poisson-drawn per pass window and
   routed FIFO to the satellite overhead, served to completion by the
   real split engine (bulk prefill + continuous batching) — measuring
   one satellite's sustained tokens/sec.
3. The fleet-scale device scan (2 planes x 8 sats) under eclipse +
   concurrent training load, with the NumPy host oracle asserting
   bit-exact f32 energy parity and the [0, capacity] battery clamp.

Exercised by ``scripts/check.sh --fast``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.fleet.scenarios import EclipseConfig
from repro.models import lm
from repro.serve.engine import DecodeEngine, Request
from repro.serve_fleet.engine import (
    FleetServeEngine, ServeFleetConfig, SplitDecodeEngine, TrainLoad,
    assert_host_parity, serve_cost)
from repro.serve_fleet.traffic import PassWindowTraffic, TrafficConfig


def _smoke():
    t0 = time.time()
    cfg = configs.get_smoke("granite_3_2b")
    params = lm.init(cfg, jax.random.key(0))
    cut = max(1, cfg.n_units // 2)

    # -- 1. split decode == full decode (greedy token parity) -------------
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 5).astype(np.int32)
               for _ in range(4)]
    full = DecodeEngine(cfg, params, n_slots=2, s_max=48,
                        act_dtype=jnp.float32)
    split = SplitDecodeEngine(cfg, params, cut_units=cut, n_slots=2,
                              s_max=48, act_dtype=jnp.float32)
    reqs = lambda: [Request(rid=i, prompt=p, max_new_tokens=5)
                    for i, p in enumerate(prompts)]
    assert full.submit_and_run(reqs()) == split.submit_and_run(reqs())
    print(f"[smoke] split-vs-full greedy parity OK (cut={cut})")

    # -- 2. a few hundred requests through real pass-window routing -------
    tcfg = TrafficConfig(users_per_day=25_000.0, prompt_len=5,
                         decode_len=4, peak_utc_s=0.0, seed=1)
    windows = PassWindowTraffic(tcfg, window_s=90.0, n_planes=1)
    eng = SplitDecodeEngine(cfg, params, cut_units=cut, n_slots=8,
                            s_max=32, act_dtype=jnp.float32)
    arrivals = windows.realize(8)[0]            # ~200 requests over 8 windows
    total_req = int(arrivals.sum())
    assert total_req >= 150, f"traffic too thin for the smoke: {total_req}"
    served_tok = 0
    rid = 0
    t1 = time.time()
    for k, n in enumerate(arrivals):
        batch = windows.prompts(0, k, int(n), cfg.vocab)
        out = eng.submit_and_run(
            [Request(rid=rid + i, prompt=batch[i],
                     max_new_tokens=tcfg.decode_len)
             for i in range(int(n))])
        rid += int(n)
        served_tok += sum(len(v) for v in out.values())
    dt = time.time() - t1
    rate = served_tok / dt
    print(f"[smoke] served {total_req} requests / {served_tok} tokens "
          f"through 8 pass windows: {rate:.1f} tok/s")

    # -- 3. fleet scan vs NumPy oracle (f32 energy parity) ----------------
    cost = serve_cost(cfg, params, cut, tokens_per_s=rate)
    scfg = ServeFleetConfig(
        n_planes=2, n_sats=8, n_windows=24, battery_j=60.0,
        recharge_w=0.02, reserve_serve_j=5.0, reserve_train_j=30.0,
        eclipse=EclipseConfig(period=6, duty=0.5), window_s=90.0)
    train = TrainLoad(drain_j=8.0, e_total_j=12.0)
    fleet = FleetServeEngine(scfg, TrafficConfig(
        users_per_day=60_000.0, decode_len=4, seed=2), cost, train=train)
    res = fleet.run()
    assert_host_parity(res, train)
    assert fleet.traces == 1 and fleet.host_syncs == 1
    s = res.summary()
    print(f"[smoke] fleet 2x8, 24 windows: arrivals={s['arrived_requests']} "
          f"served={s['served_requests']:.0f} "
          f"sustained={s['sustained_tokens_per_s']:.2f} tok/s "
          f"p99={s['p99_latency_s']:.1f}s trained={s['trained_passes']} "
          f"skipped={s['skipped_passes']}")
    print("[smoke] host-vs-device f32 energy parity OK "
          f"({time.time() - t0:.1f}s total)")


if __name__ == "__main__":
    _smoke()
