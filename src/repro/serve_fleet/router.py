"""Pass-window-aware request routing for the serving fleet.

An arrival lands on whichever satellite is currently overhead — the
serving-slot rotation the fleet engine computes with its aliveness
cumsum/argmax (``ring[k % n_alive]`` over alive slots, in slot order).
A window that closes before its backlog drains carries the queue over
to the NEXT satellite in the ring: the ground terminal holds the queue,
so routing is simply "the head of the FIFO goes to the current serving
slot, up to its window capacity".

Every function here is ``xp``-agnostic (pass ``numpy`` or
``jax.numpy``): the device engine calls them with ``jnp`` inside its
jitted scan, the NumPy host oracle calls the SAME code with ``np`` —
one implementation, two executions, which is what the f32 energy-parity
assertion leans on (the fleet scenarios module set this pattern).

FIFO latency is reconstructed on the host from per-window
``(arrivals, served)`` telemetry: under FIFO service the ``i``-th
request ever arrived is the ``i``-th ever served, so arrival and
service windows come from two ``searchsorted`` calls on the cumulative
counts — no per-request state in the scan.
"""
from __future__ import annotations

import numpy as np


def serving_slot(member, k, xp=np):
    """Slot currently overhead: ``ring[k % n_alive]`` over alive slots.

    ``member``: bool ``(M,)`` aliveness mask; returns -1 when nobody is
    alive.  Identical semantics (and code shape) to the fleet engine's
    in-scan rotation."""
    member = xp.asarray(member)
    n_alive = member.sum()
    served = n_alive > 0
    rank = xp.where(served, k % xp.maximum(n_alive, 1), 0)
    cums = xp.cumsum(member.astype(xp.int32))
    slot = xp.argmax((cums == rank + 1) & member)
    return xp.where(served, slot, -1).astype(xp.int32)


def drain_queue(backlog, arrivals, capacity, serve_ok, xp=np):
    """One window of FIFO service at the current serving slot.

    ``backlog`` carries over from the previous window (the previous
    satellite's unfinished queue, now routed to this one).  ``serve_ok``
    gates service (battery reserve / eclipse-dead slot): a gated window
    serves nothing and the whole queue carries over.  All f32 scalar
    arithmetic — the NumPy oracle replays it bit-for-bit.

    Returns ``(served, new_backlog)``.
    """
    offered = backlog + arrivals
    served = xp.where(serve_ok, xp.minimum(offered, capacity),
                      xp.float32(0.0))
    return served, offered - served


def fifo_latency_windows(arrivals, served) -> np.ndarray:
    """Per-request queueing delay, in whole windows, under FIFO service.

    ``arrivals`` / ``served`` are per-window counts ``(K,)`` (host
    NumPy).  Request ordinal ``i`` arrives in the first window whose
    cumulative arrivals reach ``i`` and is served in the first window
    whose cumulative served count reaches ``i``; the delay is the window
    difference (0 = served within its arrival window).  Requests still
    in the backlog at the end of the trace are not counted.
    """
    arrivals = np.asarray(arrivals, np.float64)
    served = np.asarray(served, np.float64)
    cum_a = np.cumsum(arrivals)
    cum_s = np.cumsum(served)
    n_served = int(round(cum_s[-1])) if cum_s.size else 0
    if n_served == 0:
        return np.zeros((0,), np.int64)
    idx = np.arange(1, n_served + 1, dtype=np.float64) - 0.5
    arrive_w = np.searchsorted(cum_a, idx)
    serve_w = np.searchsorted(cum_s, idx)
    return (serve_w - arrive_w).astype(np.int64)


def latency_quantile_s(arrivals, served, window_s: float,
                       service_s: float = 0.0, q: float = 0.99) -> float:
    """Latency quantile in seconds over all served requests.

    Window-granular: a request waits ``delay`` whole windows in the
    terminal queue, plus ``service_s`` (its own prefill+decode time on
    the serving satellite).  Returns NaN when nothing was served.
    """
    waits = fifo_latency_windows(arrivals, served)
    if waits.size == 0:
        return float("nan")
    return float(np.quantile(waits * float(window_s) + service_s, q))
