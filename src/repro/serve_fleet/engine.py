"""The constellation as an inference fleet.

Two layers, one battery:

* :class:`SplitDecodeEngine` — continuous-batching greedy decode of the
  SPLIT model: the ground station prefills the prompt (it holds the full
  weights for segment-B work), the satellite half (embedding + units
  ``[0, cut)``) runs per-token decode and the smashed boundary
  activation ``(B, 1, d_model)`` crosses the downlink every generated
  token.  It subclasses :class:`repro.serve.engine.DecodeEngine` —
  slot mechanics, bulk prefill, continuous-batching refill and the
  Pallas decode-attention flag are all inherited; only the jitted
  decode body (:meth:`_decode_fn`) changes, to
  :func:`repro.models.lm.decode_step_split`.

* :class:`FleetServeEngine` — the pass-window serving loop at
  constellation scale, as ONE jitted ``lax.scan`` over windows, vmapped
  over planes (the fleet engine's shape): per window, Poisson arrivals
  (:mod:`repro.serve_fleet.traffic`) are routed to the satellite
  currently overhead (:mod:`repro.serve_fleet.router`), served up to
  the window's token capacity, and the per-token decode energy
  (:class:`ServeCost`) is charged through the SAME
  :class:`repro.sim.energy_state.EnergyState` batteries training
  drains — so the reserve-skip policy, eclipse gating
  (:class:`repro.fleet.scenarios.EclipseConfig`) and train-vs-serve
  contention all act on one battery.  A NumPy host oracle
  (:func:`host_oracle`) replays the full f32 accounting from the
  run's realized arrivals: routing/counting telemetry is bit-exact,
  the joule accumulators match to f32 tolerance (see
  :func:`assert_host_parity`).

Telemetry (arrivals / served / backlog / battery per window) syncs to
the host ONCE per :meth:`FleetServeEngine.run`; sustained tokens/sec
and FIFO p99 latency are derived from it on the host.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import PassBudget, clamp_battery
from repro.core.orbits import OrbitalPlane, PAPER_PLANE
from repro.fleet.scenarios import EclipseConfig
from repro.models import lm
from repro.obs.metrics import (MetricsRegistry, counter_property,
                               global_registry)
from repro.obs.ring import (EV_SERVE, FlightRecorder,
                            record as ring_record, ring_init)
from repro.serve.engine import DecodeEngine, Request
from repro.serve_fleet import router
from repro.serve_fleet.traffic import PassWindowTraffic, TrafficConfig
from repro.sim import energy_state as es


# --------------------------------------------------------------------------
# Split-model decode engine (per-satellite serving capacity).
# --------------------------------------------------------------------------

class SplitDecodeEngine(DecodeEngine):
    """Continuous-batching greedy decode with the model cut at a unit
    boundary: satellite half first, boundary downlink, ground half.

    Numerically identical to the unsplit :class:`DecodeEngine` (two
    sequential unit scans instead of one) — asserted by the parity
    tests — so greedy outputs match while every generated token is
    attributable to a satellite-side FLOP count and a boundary payload.
    """

    def __init__(self, cfg, params, *, cut_units: int, **kw):
        self.cut_units = int(cut_units)
        super().__init__(cfg, params, **kw)
        # validate the cut eagerly (raises on bad cuts / enc-dec)
        lm.split_serve_params(cfg, params, self.cut_units)

    def _decode_fn(self, params, cache, tokens, positions):
        pa, pb = lm.split_serve_params(self.cfg, params, self.cut_units)
        logits, cache, _boundary = lm.decode_step_split(
            self.cfg, pa, pb, cache, tokens, positions, ctx=self.ctx)
        return logits, cache

    @property
    def boundary_bits_per_token(self) -> float:
        """Downlink payload per generated token per request: the smashed
        activation ``(d_model,)`` at the engine's activation dtype."""
        return float(self.cfg.d_model * jnp.dtype(self.act_dtype).itemsize
                     * 8)


def measure_decode_rate(engine: DecodeEngine, *, n_requests: int = 32,
                        prompt_len: int = 6, new_tokens: int = 12,
                        vocab: Optional[int] = None, seed: int = 0,
                        warmup: bool = True) -> float:
    """Sustained generated-tokens/sec of one satellite's engine, measured
    wall-clock over a continuous-batching run (prefill included — it is
    part of the window's work)."""
    vocab = engine.cfg.vocab if vocab is None else vocab
    rng = np.random.default_rng(seed)

    def batch(n, rid0):
        return [Request(rid=rid0 + i,
                        prompt=rng.integers(0, vocab, prompt_len)
                        .astype(np.int32),
                        max_new_tokens=new_tokens) for i in range(n)]

    if warmup:                      # compile prefill + decode step
        engine.submit_and_run(batch(min(2, n_requests), 10_000_000))
    reqs = batch(n_requests, 0)
    t0 = time.perf_counter()
    out = engine.submit_and_run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    return total / dt


# --------------------------------------------------------------------------
# Serving cost model (per generated token, satellite side).
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeCost:
    """What one generated token costs the serving satellite.

    ``tokens_per_s`` is the measured (or assumed) sustained decode rate
    of one satellite — it caps each pass window's service;
    ``e_token_j`` is the battery draw per token (eq.-(7) DVFS compute
    for the satellite half + eq.-(9) downlink energy for the boundary
    activation); ``dtx_bits_token`` is that boundary payload.
    """

    tokens_per_s: float
    e_token_j: float
    dtx_bits_token: float

    def window_capacity_requests(self, window_s: float,
                                 tokens_per_request: float) -> float:
        """Whole requests one pass window can serve (f32 floor — the
        same constant the device scan and the host oracle share)."""
        toks = np.float32(self.tokens_per_s) * np.float32(window_s)
        return float(np.floor(toks / np.float32(tokens_per_request)))


def serve_cost(cfg, params, cut_units: int, *, tokens_per_s: float,
               budget: Optional[PassBudget] = None,
               tx_power_w: float = 2.0,
               act_bits: Optional[int] = None) -> ServeCost:
    """Analytic per-token satellite cost for the split model.

    Per-token decode FLOPs of the satellite half are ``2 x`` its unit
    parameter count (one MAC per weight per token — embedding gather is
    free); compute energy follows the paper's DVFS model at ``f_max``,
    downlink energy the Shannon link at ``tx_power_w`` over the mean
    slant range.
    """
    budget = PassBudget() if budget is None else budget
    pa, _ = lm.split_serve_params(cfg, params, cut_units)
    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(pa["units"]))
    if "shared" in pa:
        n_params += sum(int(np.prod(x.shape))
                        for x in jax.tree.leaves(pa["shared"]))
    flops_tok = 2.0 * n_params
    e_proc = budget.sat_device.proc_energy_j(
        flops_tok, budget.sat_device.f_max_hz, 1.0)
    bits = float(cfg.d_model * (32 if act_bits is None else act_bits))
    e_comm = budget.link.comm_energy_j(bits, tx_power_w,
                                       budget.mean_distance_m)
    return ServeCost(tokens_per_s=float(tokens_per_s),
                     e_token_j=float(e_proc + e_comm),
                     dtx_bits_token=bits)


@dataclasses.dataclass(frozen=True)
class TrainLoad:
    """One planned training pass per window, energy-accounting only.

    The serve engine charges the pass the planner already priced (the
    ``DevicePassPlan`` drain the training fleet executes) so the
    contention telemetry — trained vs reserve-skipped passes — is exact
    with respect to the energy policy without re-running SL training
    inside the serving scan.
    """

    drain_j: float       # satellite-side battery draw per training pass
    e_total_j: float     # full eq.-(11) cost recorded per pass

    @classmethod
    def from_plan(cls, plan) -> "TrainLoad":
        """Mean per-sat load of a ``DevicePassPlan`` (or anything with
        ``drain_j`` / ``e_total_j`` array attributes)."""
        return cls(drain_j=float(np.mean(np.asarray(plan.drain_j))),
                   e_total_j=float(np.mean(np.asarray(plan.e_total_j))))


# --------------------------------------------------------------------------
# Fleet-scale serving scan.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeFleetConfig:
    """Constellation + battery policy for the serving fleet."""

    n_planes: int = 1
    n_sats: int = 8                       # ring slots per plane
    n_windows: int = 64                   # pass windows per run
    battery_j: float = 500.0              # capacity (and initial charge)
    recharge_w: float = 20.0              # solar input while sunlit
    reserve_serve_j: float = 0.0          # serving gate: min charge to serve
    reserve_train_j: float = 0.0          # training gate (reserve-skip)
    eclipse: Optional[EclipseConfig] = None
    plane: OrbitalPlane = PAPER_PLANE
    window_s: Optional[float] = None      # None -> plane.pass_duration_s

    @property
    def pass_window_s(self) -> float:
        return (self.plane.pass_duration_s if self.window_s is None
                else self.window_s)


class ServeTelemetry(NamedTuple):
    """Per-(window, plane) serving telemetry (stacked by the scan)."""

    arrivals: Any         # int32 — Poisson arrivals this window
    served: Any           # f32   — requests served this window
    backlog: Any          # f32   — queue carried to the next satellite
    tokens: Any           # f32   — generated tokens this window
    battery_j: Any        # f32   — serving slot's charge, post-recharge
    slot: Any             # int32 — which satellite was overhead
    trained: Any          # int32 — 1 trained / 0 reserve-skipped / -1 n/a


@dataclasses.dataclass
class ServeFleetResult:
    """One run's synced telemetry, ``(P, K)`` host arrays."""

    cfg: ServeFleetConfig
    cost: ServeCost
    traffic: PassWindowTraffic
    arrivals: np.ndarray
    served: np.ndarray
    backlog: np.ndarray
    tokens: np.ndarray
    battery_j: np.ndarray
    slot: np.ndarray
    trained: np.ndarray
    energy: es.EnergyState          # final (P, M) state, host arrays
    run_s: float = float("nan")

    @property
    def window_s(self) -> float:
        return self.cfg.pass_window_s

    def sustained_tokens_per_s(self) -> float:
        """Fleet-wide generated tokens per wall-second of orbit time."""
        K = self.arrivals.shape[1]
        return float(self.tokens.sum() / (K * self.window_s))

    def request_service_s(self) -> float:
        """One request's own decode time on the serving satellite."""
        return float(self.traffic.cfg.decode_len / self.cost.tokens_per_s)

    def p99_latency_s(self, q: float = 0.99) -> float:
        """FIFO latency quantile over every served request, all planes."""
        waits = [router.fifo_latency_windows(self.arrivals[p],
                                             self.served[p])
                 for p in range(self.arrivals.shape[0])]
        waits = np.concatenate(waits) if waits else np.zeros((0,))
        if waits.size == 0:
            return float("nan")
        lat = waits * self.window_s + self.request_service_s()
        return float(np.quantile(lat, q))

    def summary(self) -> Dict[str, Any]:
        trained = self.trained[self.trained >= 0]
        return {
            "n_planes": self.cfg.n_planes,
            "n_sats": self.cfg.n_sats,
            "n_windows": int(self.arrivals.shape[1]),
            "window_s": self.window_s,
            "offered_users_per_day": self.traffic.cfg.users_per_day,
            "arrived_requests": int(self.arrivals.sum()),
            "served_requests": float(self.served.sum()),
            "final_backlog_requests": float(self.backlog[:, -1].sum()),
            "sustained_tokens_per_s": self.sustained_tokens_per_s(),
            "p99_latency_s": self.p99_latency_s(),
            "serve_energy_spent_j": float(
                np.sum(self.energy.energy_spent_j)),
            "trained_passes": int(trained.sum()) if trained.size else None,
            "skipped_passes": (int((trained == 0).sum())
                               if trained.size else None),
            "min_battery_j": float(self.battery_j.min())
            if self.battery_j.size else float("nan"),
        }


class FleetServeEngine:
    """Device-resident pass-window serving loop (chainable runs).

    The whole (window x plane) loop is ONE jitted ``lax.scan``:
    arrivals are realized eagerly by the traffic host twin
    (``realize(K, start=k)`` — ``fold_in`` on the absolute window
    index, so chained runs continue the same stream) and fed to the
    scan as inputs (the NumPy oracle replays the bit-identical array),
    the serving slot is the ring rotation, service is FIFO up to the
    window's token capacity, and every joule moves through
    ``EnergyState`` — ``apply_serve`` for decode drain, ``apply_pass``
    for the optional concurrent :class:`TrainLoad` (reserve-skip reads
    the post-serve battery: that is the contention), eclipse-gated
    ``recharge`` last.  ``traces`` / ``device_calls`` / ``host_syncs``
    count as in the sim/fleet engines (registry-backed, namespace
    ``serve_fleet``): one trace per distinct window count, one host
    sync per run.  Every window also records an ``EV_SERVE`` event into
    a per-plane :class:`~repro.obs.ring.TelemetryRing` on the carry,
    flushed into ``self.recorder`` at that same sync.
    """

    traces = counter_property("traces")
    device_calls = counter_property("device_calls")
    host_syncs = counter_property("host_syncs")

    def __init__(self, cfg: ServeFleetConfig, traffic: TrafficConfig,
                 cost: ServeCost, *, train: Optional[TrainLoad] = None):
        self.cfg = cfg
        self.cost = cost
        self.train = train
        self.traffic = PassWindowTraffic(traffic, cfg.pass_window_s,
                                         cfg.n_planes)
        P, M = cfg.n_planes, cfg.n_sats
        self.energy = es.EnergyState(
            battery_j=jnp.full((P, M), cfg.battery_j, jnp.float32),
            energy_spent_j=jnp.zeros((P, M), jnp.float32),
            passes_served=jnp.zeros((P, M), jnp.int32),
            passes_skipped=jnp.zeros((P, M), jnp.int32))
        self.backlog = jnp.zeros((P,), jnp.float32)
        self.k = 0
        self.metrics = MetricsRegistry("serve_fleet",
                                       parent=global_registry())
        self.metrics.gauge("n_planes").set(P)
        self.metrics.gauge("n_sats").set(M)
        self.recorder = FlightRecorder(self.metrics)
        self._fns: Dict[int, Any] = {}
        # f32 constants shared verbatim with the host oracle
        self._c = serve_constants(cfg, self.traffic, cost, train)

    # ------------------------------------------------------------- compile
    def _compiled(self, n_windows: int):
        if n_windows in self._fns:
            return self._fns[n_windows]
        cfg, train = self.cfg, self.train
        P, M = cfg.n_planes, cfg.n_sats
        c = self._c
        eclipse = cfg.eclipse
        plane_ids = jnp.arange(P, dtype=jnp.int32)
        member = jnp.ones((M,), bool)     # static ring: everyone alive

        def closed_loop(backlog, energy, k0, ring, arrivals):
            # side effect fires at trace time
            self.metrics.inc("traces")

            def plane_window(plane, backlog_p, energy_p, ring_p, k, a_i):
                slot = router.serving_slot(member, k, xp=jnp)
                serve_ok = energy_p.battery_j[slot] >= c["reserve_serve"]
                served, backlog_p = router.drain_queue(
                    backlog_p, a_i.astype(jnp.float32), c["cap_req"],
                    serve_ok, xp=jnp)
                tokens = served * c["tok_per_req"]
                energy_p = es.apply_serve(energy_p, slot,
                                          tokens * c["e_token"],
                                          c["capacity"])
                if train is not None:
                    # contention: the reserve-skip gate reads the
                    # POST-serve battery — serving drain is what flips
                    # a trained pass into a skip
                    trains = (energy_p.battery_j[slot]
                              >= c["reserve_train"])
                    energy_p = es.apply_pass(
                        energy_p, slot, c["train_drain"],
                        c["train_e_total"], c["capacity"], trains)
                    trained_i = trains.astype(jnp.int32)
                else:
                    trained_i = jnp.int32(-1)
                sunlit = (None if eclipse is None
                          else eclipse.sunlit(k, plane))
                energy_p = es.recharge(energy_p, c["recharge"],
                                       c["capacity"], sunlit=sunlit)
                telem = ServeTelemetry(
                    arrivals=a_i, served=served, backlog=backlog_p,
                    tokens=tokens, battery_j=energy_p.battery_j[slot],
                    slot=slot, trained=trained_i)
                # flight recorder: one EV_SERVE per (plane, window),
                # absolute window index k
                ring_p = ring_record(
                    ring_p, EV_SERVE, k, slot,
                    (a_i.astype(jnp.float32), telem.battery_j,
                     served, backlog_p, tokens,
                     trained_i.astype(jnp.float32),
                     (jnp.float32(1.0) if sunlit is None
                      else sunlit.astype(jnp.float32)),
                     c["cap_req"]))
                return backlog_p, energy_p, ring_p, telem

            vwin = jax.vmap(plane_window, in_axes=(0, 0, 0, 0, None, 0))

            def body(carry, a_k):
                backlog, energy, k, ring = carry
                backlog, energy, ring, telem = vwin(plane_ids, backlog,
                                                    energy, ring, k, a_k)
                return (backlog, energy, k + 1, ring), telem

            (backlog, energy, k, ring), telem = jax.lax.scan(
                body, (backlog, energy, k0, ring), arrivals)
            return backlog, energy, k, ring, telem

        fn = jax.jit(closed_loop, donate_argnums=(0, 1, 3))
        self._fns[n_windows] = fn
        return fn

    # ----------------------------------------------------------------- run
    def run(self, n_windows: Optional[int] = None) -> ServeFleetResult:
        K = self.cfg.n_windows if n_windows is None else n_windows
        if K < 1:
            raise ValueError("need at least one pass window")
        fn = self._compiled(K)
        # realize the traffic eagerly (host twin, absolute window
        # offset) and feed it to the scan: the oracle replays the
        # bit-identical array
        arrivals = jnp.asarray(
            self.traffic.realize(K, start=self.k).T)   # (K, P) scan xs
        # one EV_SERVE per (plane, window): capacity K per plane's ring
        ring = ring_init(K, batch=(self.cfg.n_planes,))
        t0 = time.perf_counter()
        self.metrics.inc("device_calls")
        backlog, energy, k, ring, telem = fn(self.backlog, self.energy,
                                             jnp.int32(self.k), ring,
                                             arrivals)
        telem = jax.tree.map(np.asarray, telem)        # ONE host sync
        self.metrics.inc("host_syncs")
        dt = time.perf_counter() - t0
        self.metrics.histogram("dispatch_s").record(dt)
        # ring flush rides the same sync boundary — no extra sync
        self.recorder.ingest(ring)
        self.backlog, self.energy, self.k = backlog, energy, int(k)
        host = jax.tree.map(np.asarray, energy)
        # scan stacks (K, P); results read (P, K)
        return ServeFleetResult(
            cfg=self.cfg, cost=self.cost, traffic=self.traffic,
            arrivals=telem.arrivals.T, served=telem.served.T,
            backlog=telem.backlog.T, tokens=telem.tokens.T,
            battery_j=telem.battery_j.T, slot=telem.slot.T,
            trained=telem.trained.T,
            energy=es.EnergyState(*host), run_s=dt)


# --------------------------------------------------------------------------
# NumPy host oracle (f32 energy parity).
# --------------------------------------------------------------------------

def serve_constants(cfg: ServeFleetConfig, traffic: PassWindowTraffic,
                    cost: ServeCost,
                    train: Optional[TrainLoad]) -> Dict[str, np.float32]:
    """Every scalar the serving scan folds into its f32 arithmetic,
    pre-rounded to f32 ONCE so the device scan and the NumPy oracle
    consume bit-identical constants."""
    w = traffic.window_s
    c = {
        "capacity": cfg.battery_j,
        "recharge": cfg.recharge_w * w,
        "reserve_serve": cfg.reserve_serve_j,
        "reserve_train": cfg.reserve_train_j,
        "tok_per_req": traffic.cfg.tokens_per_request,
        "e_token": cost.e_token_j,
        "cap_req": cost.window_capacity_requests(
            w, traffic.cfg.tokens_per_request),
        "train_drain": 0.0 if train is None else train.drain_j,
        "train_e_total": 0.0 if train is None else train.e_total_j,
    }
    return {k: np.float32(v) for k, v in c.items()}


def host_oracle(cfg: ServeFleetConfig, traffic: PassWindowTraffic,
                cost: ServeCost, train: Optional[TrainLoad],
                n_windows: int,
                arrivals: Optional[np.ndarray] = None
                ) -> Dict[str, np.ndarray]:
    """Replay ``n_windows`` serving windows from a fresh fleet in NumPy
    f32 scalars — same arrivals, same constants
    (:func:`serve_constants`), same operation order — and return the
    telemetry the device scan must reproduce (bit-exact for
    routing/counting, f32-tolerance for the fused joule accumulators —
    see :func:`assert_host_parity`).

    ``arrivals`` defaults to the traffic host twin from window 0
    (``traffic.realize(n_windows)`` — what a fresh fleet's first run
    consumes); pass an explicit array to replay a different stream,
    e.g. a chained run's ``result.arrivals``.
    """
    P, M = cfg.n_planes, cfg.n_sats
    c = serve_constants(cfg, traffic, cost, train)
    arr = (traffic.realize(n_windows) if arrivals is None
           else np.asarray(arrivals, np.int32))        # (P, K) int32
    f32 = np.float32
    battery = np.full((P, M), f32(cfg.battery_j), f32)
    spent = np.zeros((P, M), f32)
    srv = np.zeros((P, M), np.int32)
    skp = np.zeros((P, M), np.int32)
    backlog = np.zeros((P,), f32)
    t_served = np.zeros((P, n_windows), f32)
    t_backlog = np.zeros((P, n_windows), f32)
    t_tokens = np.zeros((P, n_windows), f32)
    t_battery = np.zeros((P, n_windows), f32)
    t_trained = np.full((P, n_windows), -1, np.int32)
    for k in range(n_windows):
        for p in range(P):
            slot = int(router.serving_slot(np.ones((M,), bool), k))
            ok = battery[p, slot] >= c["reserve_serve"]
            served, backlog[p] = router.drain_queue(
                backlog[p], f32(arr[p, k]), c["cap_req"], ok, xp=np)
            tokens = f32(served * c["tok_per_req"])
            drain = f32(tokens * c["e_token"])
            battery[p, slot] = clamp_battery_f32(
                f32(battery[p, slot] - drain), c["capacity"])
            spent[p, slot] = f32(spent[p, slot] + drain)
            if train is not None:
                trains = battery[p, slot] >= c["reserve_train"]
                if trains:
                    battery[p, slot] = clamp_battery_f32(
                        f32(battery[p, slot] - c["train_drain"]),
                        c["capacity"])
                    spent[p, slot] = f32(spent[p, slot]
                                         + c["train_e_total"])
                    srv[p, slot] += 1
                else:
                    skp[p, slot] += 1
                t_trained[p, k] = int(trains)
            sunlit = (True if cfg.eclipse is None
                      else bool(cfg.eclipse.sunlit(k, p)))
            if sunlit:
                battery[p] = np.minimum(
                    np.maximum(battery[p] + c["recharge"], f32(0.0)),
                    c["capacity"])
            t_served[p, k] = served
            t_backlog[p, k] = backlog[p]
            t_tokens[p, k] = tokens
            t_battery[p, k] = battery[p, slot]
    return {"arrivals": arr, "served": t_served, "backlog": t_backlog,
            "tokens": t_tokens, "battery_j": t_battery,
            "trained": t_trained, "final_battery_j": battery,
            "energy_spent_j": spent, "passes_served": srv,
            "passes_skipped": skp}


def clamp_battery_f32(battery: np.float32, capacity: np.float32):
    """f32 scalar twin of :func:`repro.core.energy.clamp_battery`
    (``jnp.clip`` = max-then-min, replayed in NumPy f32)."""
    return np.minimum(np.maximum(battery, np.float32(0.0)), capacity)


def assert_host_parity(result: ServeFleetResult,
                       train: Optional[TrainLoad]) -> Dict[str, np.ndarray]:
    """Assert the host-vs-device parity contract for a fresh-fleet run
    and return the oracle telemetry.

    Routing and counting are BIT-exact (arrivals — the engine and the
    oracle consume the same realized array by construction —
    served/backlog/token counts (all integer-valued f32), the
    trained/skipped decisions and the pass counters).  The joule
    accumulators (battery trajectory, ``energy_spent_j``) are asserted
    at f32 tolerance: XLA fuses the scan's multiply-accumulate chains
    into FMAs whose single rounding the NumPy scalar replay cannot
    reproduce, so these agree to ~1 ulp per window rather than
    bit-for-bit.  Battery trajectories must also sit in
    ``[0, capacity]`` — the clamp policy's invariant.
    """
    K = result.arrivals.shape[1]
    o = host_oracle(result.cfg, result.traffic, result.cost, train, K)
    np.testing.assert_array_equal(result.arrivals, o["arrivals"])
    np.testing.assert_array_equal(result.served, o["served"])
    np.testing.assert_array_equal(result.tokens, o["tokens"])
    np.testing.assert_array_equal(result.backlog, o["backlog"])
    np.testing.assert_array_equal(result.trained, o["trained"])
    np.testing.assert_array_equal(np.asarray(result.energy.passes_served),
                                  o["passes_served"])
    np.testing.assert_array_equal(np.asarray(result.energy.passes_skipped),
                                  o["passes_skipped"])
    tol = dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(result.battery_j, o["battery_j"], **tol)
    np.testing.assert_allclose(np.asarray(result.energy.battery_j),
                               o["final_battery_j"], **tol)
    np.testing.assert_allclose(np.asarray(result.energy.energy_spent_j),
                               o["energy_spent_j"], **tol)
    assert float(result.battery_j.min()) >= 0.0
    assert float(result.battery_j.max()) <= result.cfg.battery_j
    return o
