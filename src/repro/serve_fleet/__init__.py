"""The constellation as an inference fleet: pass-window-routed
continuous-batching serving of the split model, on the same batteries
training drains.

``python -m repro.serve_fleet`` runs the smoke: split-vs-full decode
parity, a few hundred synthetic requests routed through pass windows on
a small ring, and the host-vs-device f32 energy-parity assertion.
"""
from repro.serve_fleet.engine import (
    FleetServeEngine,
    ServeCost,
    ServeFleetConfig,
    ServeFleetResult,
    SplitDecodeEngine,
    TrainLoad,
    assert_host_parity,
    host_oracle,
    measure_decode_rate,
    serve_cost,
)
from repro.serve_fleet.traffic import PassWindowTraffic, TrafficConfig

__all__ = [
    "FleetServeEngine",
    "PassWindowTraffic",
    "ServeCost",
    "ServeFleetConfig",
    "ServeFleetResult",
    "SplitDecodeEngine",
    "TrafficConfig",
    "TrainLoad",
    "assert_host_parity",
    "host_oracle",
    "measure_decode_rate",
    "serve_cost",
]
