"""Seeded synthetic ground traffic for the serving fleet.

Millions of users hitting a ground terminal are modeled as Poisson
request arrivals with a diurnal (24 h sinusoid) intensity profile,
realized PER PASS WINDOW: window ``k`` of plane ``p`` receives
``Poisson(lam_p(k))`` requests, where ``lam_p(k)`` follows the daily
cycle evaluated at the window's wall-clock time.  Parameterization is
in **users/day** (scaled to millions — the ROADMAP north star) with a
per-user daily request rate; the fleet splits the offered load evenly
across its planes (one ground terminal per plane, each seeing whichever
satellite of its plane is overhead — the paper's time-window geometry).

In the style of :class:`repro.sim.data.DeviceImageryShards`, the
arrival draw is a pure function of ``(seed, plane, window)`` built on
``jax.random.fold_in``: ``__call__`` composes under ``jit``/``scan``
and, called eagerly, IS the NumPy host twin — :meth:`realize` returns
the counts as a host array.  The serving fleet engine feeds
``realize`` output to its device scan as inputs rather than calling
``__call__`` in-trace: at millions-scale rates XLA fuses the traced
intensity arithmetic into FMAs whose lambda sits 1 ulp from the eager
twin's, and one flipped Poisson rejection round yields a completely
different (same-distribution) draw — realizing once and sharing the
array makes host-vs-device arrival parity exact by construction.
:meth:`prompts` derives per-window token batches for the real
split-decode engine from the same stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Offered load: ``users_per_day`` users, each issuing
    ``requests_per_user_day`` requests/day on average, with requests of
    ``prompt_len`` prompt tokens decoding ``decode_len`` new tokens."""

    users_per_day: float = 1.0e6
    requests_per_user_day: float = 1.0
    prompt_len: int = 8
    decode_len: int = 16
    diurnal_amp: float = 0.5        # peak deviation from the mean rate
    peak_utc_s: float = 43_200.0    # daily peak (noon by default)
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.diurnal_amp <= 1.0:
            raise ValueError(f"diurnal_amp must be in [0, 1], "
                             f"got {self.diurnal_amp}")

    @property
    def tokens_per_request(self) -> float:
        return float(self.decode_len)

    def mean_rate_per_s(self, n_planes: int = 1) -> float:
        """Mean fleet arrival rate split over ``n_planes`` terminals."""
        return (self.users_per_day * self.requests_per_user_day
                / 86_400.0 / n_planes)


@dataclasses.dataclass(frozen=True)
class PassWindowTraffic:
    """Traceable ``(plane, k) -> arrival count`` for pass window ``k``.

    ``window_s`` is the pass-window duration (the plane's
    ``pass_duration_s``); ``n_planes`` divides the configured offered
    load across terminals.  ``traceable = True`` advertises the
    device-scan contract (same flag as the sim data providers).
    """

    cfg: TrafficConfig = TrafficConfig()
    window_s: float = 228.0
    n_planes: int = 1

    traceable = True

    # ------------------------------------------------------------- intensity
    def rate(self, k):
        """Mean arrivals in window ``k`` (pure arithmetic: works on
        Python ints, NumPy arrays and traced JAX values alike)."""
        c = self.cfg
        base = c.mean_rate_per_s(self.n_planes) * self.window_s
        t = (jnp.asarray(k, jnp.float32) + 0.5) * self.window_s
        day = 2.0 * jnp.pi * (t - c.peak_utc_s) / 86_400.0
        return base * (1.0 + c.diurnal_amp * jnp.cos(day))

    # -------------------------------------------------------------- arrivals
    def __call__(self, plane, k):
        """Poisson arrival count for ``(plane, window k)`` — int32."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.cfg.seed),
                               jnp.asarray(plane, jnp.uint32)),
            jnp.asarray(k, jnp.uint32))
        return jax.random.poisson(key, self.rate(k)).astype(jnp.int32)

    def realize(self, n_windows: int, start: int = 0) -> np.ndarray:
        """Host twin: arrival counts for windows ``[start, start +
        n_windows)`` of every plane as a ``(n_planes, n_windows)``
        NumPy array — one eager vmapped call of the identical pure
        function.  This array IS the serving fleet's traffic: the
        engine feeds it to its scan and the NumPy oracle replays it,
        so both consume exactly the same draws."""
        planes = jnp.arange(self.n_planes, dtype=jnp.uint32)
        ks = jnp.arange(start, start + n_windows, dtype=jnp.uint32)
        grid = jax.vmap(lambda p: jax.vmap(lambda k: self(p, k))(ks))(planes)
        return np.asarray(grid)

    # --------------------------------------------------------------- prompts
    def prompts(self, plane: int, k: int, n: int, vocab: int) -> np.ndarray:
        """``(n, prompt_len)`` int32 prompt batch for window ``k`` —
        seeded from the same stream (host-eager; feeds the real
        split-decode engine in the measured path and the smoke)."""
        key = jax.random.fold_in(
            jax.random.fold_in(
                jax.random.fold_in(jax.random.key(self.cfg.seed),
                                   jnp.uint32(plane)),
                jnp.uint32(k)), jnp.uint32(0xB0B))
        toks = jax.random.randint(
            key, (n, self.cfg.prompt_len), 0, vocab, dtype=jnp.int32)
        return np.asarray(toks)
