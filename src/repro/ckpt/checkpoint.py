"""Atomic, integrity-checked pytree checkpoints (npz + msgpack manifest).

Fault-tolerance contract (the 1000-node story):
  * writes go to ``<dir>/tmp.<step>.<pid>`` then os.replace() — a crash
    mid-write never corrupts the latest checkpoint;
  * every array is sha256-hashed into the manifest; restore verifies
    before returning, so a torn/bit-rotted file fails loudly;
  * ``latest_step`` scans for the newest *complete* checkpoint — restart
    after failure is "call restore(latest_step())";
  * the SL ring handoff reuses the same machinery (``save_handoff``):
    the segment-A weights a satellite ships over the ISL *are* a
    checkpoint, so a satellite loss mid-pass degrades to "next satellite
    restores the last handoff" — the paper's skip-and-continue policy.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.utils.treeutil import tree_flatten_with_names

_CKPT_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for name, leaf in tree_flatten_with_names(tree):
        flat[name] = np.asarray(leaf)
    return flat


def _manifest(flat: Dict[str, np.ndarray], meta: Optional[Dict]) -> bytes:
    entries = {}
    for k, v in flat.items():
        entries[k] = {
            "shape": list(v.shape),
            "dtype": str(v.dtype),
            "sha256": hashlib.sha256(np.ascontiguousarray(v).tobytes())
            .hexdigest(),
        }
    return msgpack.packb({"arrays": entries, "meta": meta or {}})


def save(directory: str, step: int, tree, meta: Optional[Dict] = None) -> str:
    """Atomically write checkpoint ``<directory>/step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    final = os.path.join(directory, f"step_{step}")
    tmp = tempfile.mkdtemp(prefix=f".tmp.{step}.", dir=directory)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(_manifest(flat, meta))
        if os.path.isdir(final):
            # never overwrite silently; keep the existing complete ckpt
            import shutil
            shutil.rmtree(tmp)
            return final
        os.replace(tmp, final)
    except BaseException:
        import shutil
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _load_verified(path: str) -> Tuple[Dict[str, np.ndarray], Dict]:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    for k, info in manifest["arrays"].items():
        if k not in flat:
            raise IOError(f"checkpoint {path}: missing array {k}")
        h = hashlib.sha256(np.ascontiguousarray(flat[k]).tobytes()).hexdigest()
        if h != info["sha256"]:
            raise IOError(f"checkpoint {path}: integrity failure on {k}")
    return flat, manifest.get("meta", {})


def restore(directory: str, step: int, like) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a pytree of arrays)."""
    path = os.path.join(directory, f"step_{step}")
    flat, meta = _load_verified(path)
    names = [n for n, _ in tree_flatten_with_names(like)]
    leaves, treedef = jax.tree.flatten(like)
    out = []
    for name, leaf in zip(names, leaves):
        if name not in flat:
            raise IOError(f"checkpoint {path}: missing {name}")
        arr = flat[name]
        want = np.asarray(leaf)
        if tuple(arr.shape) != tuple(want.shape):
            raise IOError(f"{name}: shape {arr.shape} != {want.shape}")
        out.append(jnp.asarray(arr, dtype=want.dtype))
    return jax.tree.unflatten(treedef, out), meta


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for entry in os.listdir(directory):
        m = _CKPT_RE.match(entry)
        if m and os.path.exists(os.path.join(directory, entry,
                                             "manifest.msgpack")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


# --------------------------------------------------------------------------
# SL ring handoff = checkpoint of the satellite segment.
# --------------------------------------------------------------------------

def save_handoff(directory: str, pass_idx: int, segment_tree,
                 meta: Optional[Dict] = None) -> Tuple[str, int]:
    """Persist the segment-A weights shipped over the ISL; returns
    (path, payload_bytes) — the bytes are exactly the paper's D_ISL."""
    flat = _flatten(segment_tree)
    payload = sum(v.nbytes for v in flat.values())
    path = save(directory, pass_idx, segment_tree,
                meta=dict(meta or {}, payload_bytes=payload))
    return path, payload


def restore_handoff(directory: str, like, pass_idx: Optional[int] = None
                    ) -> Tuple[Any, Dict, int]:
    """Restore the most recent (or given) handoff; returns
    (tree, meta, pass_idx). Raises FileNotFoundError if none exists."""
    step = pass_idx if pass_idx is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no handoff in {directory}")
    tree, meta = restore(directory, step, like)
    return tree, meta, step
