"""Checkpointing: atomic pytree save/restore with integrity hashes."""
from repro.ckpt.checkpoint import (latest_step, restore, save,
                                   save_handoff, restore_handoff)
