"""Zamba2-1.2B — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

38 blocks: Mamba2 backbone with a single global shared attention+FFN
block (weights shared across its occurrences — counted once in params
and in the paper's D_ISL handoff payload) interleaved every 6th block.
Hybrid SSM => sub-quadratic, eligible for long_500k.
"""
import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=36,                    # 6 units of (5 mamba2 + 1 shared attn)
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    d_head=64,
    ssm_state=64,
    pattern=("mamba2",) * 5 + ("shared_attn",),
    rope_theta=10_000.0,
    sub_quadratic=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=256, ssm_state=16,
        pattern=("mamba2", "shared_attn"))
