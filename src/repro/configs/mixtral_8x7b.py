"""Mixtral-8x7B — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

SWA (window 4096) bounds the decode KV cache, making the 500k-context
decode cell sub-quadratic in memory — eligible for long_500k.
"""
import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    d_head=128,
    n_experts=8,
    top_k=2,
    window=4096,
    rope_theta=1_000_000.0,
    sub_quadratic=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=96, vocab=256, n_experts=4, top_k=2,
        window=32)
