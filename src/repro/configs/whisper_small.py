"""Whisper-small — encoder-decoder audio [arXiv:2212.04356].

12 encoder + 12 decoder layers at d_model=768, 12 heads (MHA: kv=12).
The conv frontend is a STUB per the assignment: input_specs provides
precomputed frame embeddings (B, 1500, d_model) — the output length of
whisper's 2x conv stem on 30 s of audio. Decoder = causal self-attention
+ cross-attention to the encoder states. Full attention => long_500k is
skipped (and whisper's source context is 30 s anyway); decode shapes run
against the decoder self-attn cache.
"""
import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,                    # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    d_head=64,
    causal=True,
    enc_dec=True,
    n_enc_layers=12,
    frontend="audio",
    frontend_len=1500,
    tie_embeddings=True,
    mlp_kind="gelu",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=256, n_enc_layers=2,
        frontend_len=32)
