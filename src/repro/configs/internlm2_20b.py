"""InternLM2-20B — dense GQA [arXiv:2403.17297]."""
import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    d_head=128,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="internlm2-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_head=16, d_ff=256, vocab=256)
