"""Architecture configs: the 10 assigned archs + the paper's own models.

Each ``<id>.py`` exposes ``CONFIG: ArchConfig`` with the exact published
hyper-parameters, plus ``smoke_config()`` returning a reduced same-family
config for CPU tests.  ``get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Dict, List, Optional, Tuple

# Block kinds understood by repro.models.lm:
#   attn      — GQA attention + SwiGLU FFN (pre-RMSNorm residual block)
#   moe       — GQA attention + top-k MoE FFN
#   mamba2    — Mamba-2 (SSD) block, no separate FFN
#   mlstm     — xLSTM matrix-LSTM block (projected, gated)
#   slstm     — xLSTM scalar-LSTM block (recurrent scan)
#   shared_attn — zamba2 global shared attention+FFN block (weights shared
#                 across all occurrences; counted once in params/D_ISL)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_experts: int = 0
    top_k: int = 0
    d_head: Optional[int] = None
    ssm_state: int = 0
    causal: bool = True
    window: Optional[int] = None            # sliding-window attention (Mixtral)
    # Repeating block pattern; scanned as units of len(pattern) blocks.
    # None => all-"attn" (or all-"moe" if n_experts>0).
    pattern: Optional[Tuple[str, ...]] = None
    rope_theta: float = 500_000.0
    mrope: bool = False                     # Qwen2-VL multimodal RoPE
    enc_dec: bool = False                   # Whisper
    n_enc_layers: int = 0
    frontend: Optional[str] = None          # "audio" | "vision" (stub embeds)
    frontend_len: int = 0                   # stub embedding sequence length
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    sub_quadratic: bool = False             # eligible for long_500k
    capacity_factor: float = 1.25           # MoE dispatch capacity
    moe_every: int = 1                      # MoE FFN every k-th layer (1=all)
    mlp_kind: str = "swiglu"                # swiglu (3 matmuls) | gelu (2)

    # ---------------------------------------------------------------- helpers
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        """Inner width of mamba2/mlstm blocks (2x expansion)."""
        return 2 * self.d_model

    def block_kinds(self) -> List[str]:
        if self.pattern is None:
            kind = "moe" if self.n_experts else "attn"
            return [kind] * self.n_layers
        reps = math.ceil(self.n_layers / len(self.pattern))
        return (list(self.pattern) * reps)[: self.n_layers]

    def pattern_unit(self) -> Tuple[str, ...]:
        """The repeating unit scanned over by the model."""
        if self.pattern is None:
            return ("moe",) if self.n_experts else ("attn",)
        return self.pattern

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern_unit())

    # ------------------------------------------------------- param accounting
    def block_param_count(self, kind: str) -> float:
        d, dh = self.d_model, self.head_dim
        if kind in ("attn", "shared_attn"):
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * d
            n_mm = 3 if self.mlp_kind == "swiglu" else 2
            ffn = n_mm * d * self.d_ff if self.d_ff else 0
            return attn + ffn + 2 * d
        if kind == "moe":
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * d
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            return attn + ffn + 2 * d
        if kind == "mamba2":
            di, n = self.d_inner, self.ssm_state or 64
            return (d * 2 * di + di * 4            # in_proj + conv1d(k=4)
                    + di * (2 * n)                 # B, C proj
                    + di                           # dt proj (per-channel)
                    + di * d + 2 * d)              # out_proj + norms
        if kind == "mlstm":
            di = self.d_inner
            return d * 3 * di + 3 * di + di * d + 2 * d
        if kind == "slstm":
            return 2 * d * 4 * d + 4 * d + 2 * d
        raise ValueError(kind)

    def block_active_param_count(self, kind: str) -> float:
        if kind == "moe":
            d = self.d_model
            dh = self.head_dim
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * d
            ffn = self.top_k * 3 * d * self.d_ff + d * self.n_experts
            return attn + ffn + 2 * d
        return self.block_param_count(kind)

    def param_count(self) -> float:
        kinds = self.block_kinds()
        shared_done = False
        total = 0.0
        for k in kinds:
            if k == "shared_attn":
                if shared_done:
                    continue
                shared_done = True
            total += self.block_param_count(k)
        total += self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        if self.enc_dec:
            enc = self.n_enc_layers * self.block_param_count("attn")
            cross = self.n_layers * (2 * self.d_model * self.n_heads * self.head_dim
                                     + 2 * self.d_model)
            total += enc + cross
        total += self.d_model  # final norm
        return total

    def active_param_count(self) -> float:
        kinds = self.block_kinds()
        shared_done = False
        total = 0.0
        for k in kinds:
            if k == "shared_attn":
                if shared_done:
                    continue
                shared_done = True
            total += self.block_active_param_count(k)
        total += self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        if self.enc_dec:
            total += self.n_enc_layers * self.block_param_count("attn")
            total += self.n_layers * (2 * self.d_model * self.n_heads * self.head_dim
                                      + 2 * self.d_model)
        total += self.d_model
        return total


ASSIGNED = [
    "xlstm_1_3b", "granite_3_2b", "llama3_8b", "smollm_360m", "internlm2_20b",
    "phi35_moe", "mixtral_8x7b", "qwen2_vl_7b", "zamba2_1_2b", "whisper_small",
]

PAPER_MODELS = ["resnet18", "autoencoder"]


def get(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


def get_smoke(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.smoke_config()


def all_assigned() -> Dict[str, ArchConfig]:
    return {n: get(n) for n in ASSIGNED}
