"""Qwen2-VL-7B backbone — M-RoPE, dynamic resolution [arXiv:2409.12191].

Per the assignment spec the modality frontend (ViT) is a STUB: input_specs
provides precomputed patch embeddings of length ``frontend_len`` which the
model splices in front of the token embeddings. M-RoPE (temporal/height/
width split of the rotary dims) is implemented for the backbone.
"""
import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    d_head=128,
    rope_theta=1_000_000.0,
    mrope=True,
    frontend="vision",
    frontend_len=256,               # one 512x512 image ~ 256 merged patches
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2vl-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256, frontend_len=8)
