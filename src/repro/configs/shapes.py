"""Assigned input-shape sets + ShapeDtypeStruct stand-ins for the dry-run.

LM transformer shapes are (seq_len x global_batch); ``decode_*`` /
``long_*`` lower ``serve_step`` (one new token against a KV cache of
seq_len) rather than ``train_step``; ``long_500k`` only applies to
sub-quadratic archs (xlstm / zamba2 SSM state, mixtral SWA) — skips are
recorded in DESIGN.md and surfaced by :func:`applicable`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    """long_500k needs sub-quadratic attention (SSM/hybrid/SWA archs)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    if applicable(cfg, shape):
        return None
    return (f"{cfg.name} is pure full-attention: a 512k-token decode KV cache "
            f"is outside the regime this arch targets (sub-quadratic archs "
            f"xlstm/zamba2/mixtral run this cell; see DESIGN.md §4)")


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                act_dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   {tokens, labels}                       (B, S) int32
    prefill: {tokens}                               (B, S) int32
    decode:  {tokens}                               (B, 1) int32 + cache built
             separately by the step builder (cache lives in donated state).
    Frontends (vlm/audio) add precomputed stub embeddings per the spec.
    """
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    emb = lambda s: jax.ShapeDtypeStruct(s, act_dtype)

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = tok((B, S))
        specs["labels"] = tok((B, S))
    elif shape.kind == "prefill":
        specs["tokens"] = tok((B, S))
    else:  # decode: one new token, cache of length S handled by serve_step
        specs["tokens"] = tok((B, 1))
        specs["positions"] = tok((B,))

    if cfg.frontend == "vision" and shape.kind != "decode":
        specs["frontend_embed"] = emb((B, cfg.frontend_len, cfg.d_model))
    if cfg.frontend == "audio":
        # encoder always sees the (stub) frame embeddings, even at decode
        specs["enc_frames"] = emb((B, cfg.frontend_len, cfg.d_model))
    return specs


def cell_list(arch_names: List[str]) -> List[tuple]:
    """All runnable (arch, shape) dry-run cells, in a stable order."""
    from repro import configs
    cells = []
    for a in arch_names:
        cfg = configs.get(a)
        for s in SHAPES.values():
            if applicable(cfg, s):
                cells.append((a, s.name))
    return cells
