"""xLSTM-1.3B — sLSTM + mLSTM blocks [arXiv:2405.04517].

48 blocks at d_model=2048, 4 heads. The 1.3B xLSTM[7:1] recipe interleaves
one sLSTM block per seven mLSTM blocks; d_ff=0 (the projected mLSTM block
carries its own 2x up/down projection instead of a separate FFN).
Linear recurrence => sub-quadratic, eligible for long_500k.
"""
import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    ssm_state=0,
    pattern=("mlstm",) * 7 + ("slstm",),
    sub_quadratic=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="xlstm-smoke", n_layers=4, d_model=64, n_heads=2,
        n_kv_heads=2, vocab=128, pattern=("mlstm", "mlstm", "mlstm", "slstm"))
