"""SmolLM-360M — llama-architecture small [hf:HuggingFaceTB/SmolLM-360M].

Assigned spec: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
Also the config used by the runnable end-to-end training driver.
"""
import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    d_head=64,
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="smollm-smoke", n_layers=2, d_model=96, n_heads=3,
        n_kv_heads=1, d_head=32, d_ff=256, vocab=512)
