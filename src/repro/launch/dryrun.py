import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on
first init, and the production meshes need 512 placeholder host devices.
Only this entry point does that — tests and benches see one device.

Per cell this produces:
  * proof of compile (sharding-coherent pjit program on the target mesh),
  * memory_analysis() (fits-per-device evidence),
  * cost_analysis() FLOPs/bytes (roofline compute & memory terms),
  * collective op census from the post-SPMD HLO (collective term).

Usage:
  python -m repro.launch.dryrun --arch llama3_8b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results/dryrun.json
  python -m repro.launch.dryrun --all --preset baseline
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.shapes import SHAPES, applicable, input_specs, skip_reason
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import lm
from repro.models.param import ShardingRules, partition_specs, shape_structs
from repro.train.step import (TrainConfig, make_decode_step,
                              make_prefill_step, make_train_step)
from repro.utils import hlo as hlo_util


# --------------------------------------------------------------------------
# Sharding / step presets (the §Perf hillclimb knobs).
# --------------------------------------------------------------------------

PRESETS: Dict[str, Dict[str, Any]] = {
    # paper-faithful baseline: batch over (pod, data); megatron TP over
    # model; ZeRO over data; full remat.
    "baseline": {},
    # sequence-sharded activations for the long cells
    "seqshard": {"rules": {"seq": "model"}},
    # no remat (memory for compute)
    "noremat": {"tcfg": {"remat": "none"}},
    "dots": {"tcfg": {"remat": "dots"}},
    # expert parallelism for MoE: experts over model axis
    "ep": {"rules": {"experts": "model", "mlp": None}},
    # bigger attention tiles
    "bigblocks": {"tcfg": {"block_q": 1024, "block_k": 1024}},
    # fp32 activations (ablation)
    "fp32act": {"tcfg": {"act_dtype": jnp.float32}},
    # bf16 streamed attention operands (halves score-tensor HBM traffic)
    "bf16attn": {"tcfg": {"attn_compute_dtype": jnp.bfloat16}},
    # pad attention heads to the model-axis multiple (Megatron practice;
    # fixes smollm 15-head / qwen 28-head replication). zero-init pad head
    # at deployment keeps the function identical.
    "padheads": {"cfg": {"pad_heads": True}},
    # smaller mlstm chunk: intra-chunk work scales with L, state I/O is
    # VMEM-resident in the fused kernel
    "chunk128": {"tcfg": {"mlstm_chunk": 128}},
    "chunk64": {"tcfg": {"mlstm_chunk": 64}},
    "opt_xlstm": {"tcfg": {"mlstm_chunk": 64, "remat": "dots"}},
    # small models don't want TP-16: batch over BOTH axes (256-way DP),
    # weights replicated, optimizer state ZeRO'd over all chips
    "puredp": {"rules": {"batch": ("pod", "data", "model"), "heads": None,
                         "kv_heads": None, "mlp": None, "vocab": None,
                         "inner": None, "zero": ("data", "model")}},
    # combination winners (see EXPERIMENTS.md §Perf)
    "opt": {"rules": {"batch": ("pod", "data", "model"), "heads": None,
                      "kv_heads": None, "mlp": None, "vocab": None,
                      "inner": None, "zero": ("data", "model")},
            "tcfg": {"attn_compute_dtype": jnp.bfloat16}},
    "opt_moe": {"rules": {"experts": "model", "mlp": None},
                "tcfg": {"attn_compute_dtype": jnp.bfloat16}},
    # batch-local MoE dispatch: per-row buffers, zero dispatch collectives
    "moelocal": {"tcfg": {"moe_dispatch": "batch_local"}},
    "opt_moe2": {"tcfg": {"moe_dispatch": "batch_local",
                          "attn_compute_dtype": jnp.bfloat16}},
}


def build_rules(overrides: Dict[str, Any]) -> ShardingRules:
    return dataclasses.replace(ShardingRules(), **overrides)


def build_tcfg(overrides: Dict[str, Any]) -> TrainConfig:
    return dataclasses.replace(TrainConfig(), **overrides)


# --------------------------------------------------------------------------
# Cell lowering.
# --------------------------------------------------------------------------

def _with_sharding(structs: Dict, mesh, rules: ShardingRules) -> Dict:
    from jax.sharding import NamedSharding
    out = {}
    for k, s in structs.items():
        spec = rules.resolve(("batch",) + (None,) * (len(s.shape) - 1),
                             mesh, s.shape)
        out[k] = jax.ShapeDtypeStruct(s.shape, s.dtype,
                                      sharding=NamedSharding(mesh, spec))
    return out


def _mem_report(compiled) -> Dict[str, float]:
    out = {}
    try:
        m = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(m, attr, None)
            if v is not None:
                out[attr] = float(v)
        out["total_per_device_bytes"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0))
    except Exception as e:                                  # CPU backend gaps
        out["error"] = str(e)
    return out


def _cost_report(compiled) -> Dict[str, float]:
    out = {}
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        for k in ("flops", "bytes accessed", "transcendentals",
                  "optimal_seconds"):
            if k in c:
                out[k.replace(" ", "_")] = float(c[k])
    except Exception as e:
        out["error"] = str(e)
    return out


def _model_flops(cfg, shape) -> Dict[str, float]:
    """6·N·D (train) / 2·N·D (inference) with N = active non-embedding
    params + head; plus the analytic full-graph estimate (incl. attention)."""
    from repro.core.splitting import lm_plan
    n_active = cfg.active_param_count() - cfg.vocab * cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:
        tokens = shape.global_batch * 1
        mult = 2.0
    plan = lm_plan(cfg, shape.seq_len if shape.kind != "decode" else 1)
    analytic = (sum(l.fwd_flops * (l.active_param_count / max(l.param_count, 1))
                    for l in plan.layers)
                + plan.gs_fixed_fwd_flops)
    analytic *= shape.global_batch * (3.0 if shape.kind == "train" else 1.0)
    return {"model_flops_6nd": mult * n_active * tokens,
            "analytic_flops": analytic}


def _scan_topup(cfg, shape, mesh, rules, tcfg) -> Dict[str, Any]:
    """Per-trip body cost of recurrent-scan ops (mamba2 / mlstm / slstm).

    These stay `lax.scan` (while loops) in the cost variants — unrolling
    them explodes compile time — so the main measurement counts each
    body ONCE per block. Here each op is micro-compiled alone at the
    cell's global shapes/shardings with unroll k=1 and k=2; the diff is
    exactly one trip's body (fwd [+ remat + bwd for train]), and the
    top-up adds (n_trips - 1) x n_blocks_of_kind bodies.
    """
    from collections import Counter
    from jax.sharding import NamedSharding
    from repro.kernels import ops as kops
    from repro.models.layers import mamba_dims

    kinds = Counter(k for k in cfg.block_kinds()
                    if k in ("mamba2", "mlstm", "slstm"))
    out = {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "detail": {}}
    if not kinds or shape.kind == "decode":
        return out
    B, S = shape.global_batch, shape.seq_len
    train = shape.kind == "train"
    act = tcfg.act_dtype

    def struct(shp, dtype):
        spec = rules.resolve(("batch",) + (None,) * (len(shp) - 1),
                             mesh, shp)
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=NamedSharding(mesh, spec))

    def rep(shp, dtype=jnp.float32):
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=NamedSharding(
                mesh, rules.resolve((None,) * len(shp), mesh, shp)))

    def measure(opfn, args, n_diff):
        def run(k):
            def scalar(*a):
                return jnp.sum(opfn(*a, unroll=k).astype(jnp.float32))
            if train:
                prog = jax.grad(jax.checkpoint(scalar),
                                argnums=tuple(range(n_diff)))
            else:
                prog = scalar
            comp = jax.jit(prog).lower(*args).compile()
            c = comp.cost_analysis()
            if isinstance(c, (list, tuple)):
                c = c[0]
            return (float(c.get("flops", 0.0)),
                    float(c.get("bytes accessed", 0.0)),
                    hlo_util.collective_bytes(comp.as_text()))
        f1, b1, cb1 = run(1)
        f2, b2, cb2 = run(2)
        # clamp: XLA may fuse across the two unrolled bodies making a
        # diff slightly negative; a body cost is necessarily >= 0
        return max(f2 - f1, 0.0), max(b2 - b1, 0.0), max(cb2 - cb1, 0.0)

    for kind, n_blocks in kinds.items():
        if kind == "mamba2":
            di, H, P, N = mamba_dims(cfg)
            chunk = tcfg.mamba_chunk
            n_trips = -(-S // chunk)
            opfn = lambda x, dt, b, c, al, unroll=1, _ck=chunk: \
                kops.mamba_scan(x, dt, al, b, c, chunk=_ck,
                                use_pallas=False, unroll=unroll)[0]
            args = (struct((B, S, H, P), act), struct((B, S, H), jnp.float32),
                    struct((B, S, N), act), struct((B, S, N), act),
                    rep((H,)))
            n_diff = 4
        elif kind == "mlstm":
            H = cfg.n_heads
            P = cfg.d_inner // H
            chunk = tcfg.mlstm_chunk
            n_trips = -(-S // chunk)
            opfn = lambda q, k, v, i, f, unroll=1, _ck=chunk: \
                kops.mlstm_scan(q, k, v, i, f, chunk=_ck,
                                use_pallas=False, unroll=unroll)[0]
            args = tuple(struct((B, S, H, P), act) for _ in range(3)) + \
                tuple(struct((B, S, H), jnp.float32) for _ in range(2))
            n_diff = 5
        else:  # slstm
            d = cfg.d_model
            n_trips = S
            opfn = lambda xp, wh, unroll=1: kops.slstm_scan(
                xp, wh, jnp.zeros((B, d)), jnp.zeros((B, d)),
                jnp.zeros((B, d)), jnp.full((B, d), -1e30),
                unroll=unroll)[0]
            args = (struct((B, S, 4 * d), jnp.float32),
                    rep((d, 4 * d)))
            n_diff = 2
        df, db, dc = measure(opfn, args, n_diff)
        mult = (n_trips - 1) * n_blocks
        out["flops"] += mult * df
        out["bytes"] += mult * db
        out["coll"] += mult * dc
        out["detail"][kind] = {"body_flops": df, "body_bytes": db,
                               "body_coll": dc, "n_trips": n_trips,
                               "n_blocks": n_blocks}
    return out


def _compile_variant(cfg, shape, mesh, rules, tcfg, batch, unroll: int):
    """Lower + compile one variant; returns (compiled, t_lower, t_compile)."""
    t0 = time.time()
    if shape.kind == "train":
        tc = dataclasses.replace(tcfg, scan_unroll=unroll)
        step, _, _, init_state = make_train_step(cfg, mesh, rules, tc)
        state_struct = jax.eval_shape(init_state, jax.random.key(0))
        lowered = step.lower(state_struct, batch)
    elif shape.kind == "prefill":
        step, _ = make_prefill_step(
            cfg, mesh, rules, act_dtype=tcfg.act_dtype,
            block_q=tcfg.block_q, block_k=tcfg.block_k, unroll=unroll)
        pstruct = shape_structs(lm.abstract_params(cfg))
        lowered = step.lower(pstruct, batch)
    else:  # decode
        step, _, _, cache_struct = make_decode_step(
            cfg, mesh, rules, batch=shape.global_batch,
            s_max=shape.seq_len, act_dtype=tcfg.act_dtype, unroll=unroll)
        pstruct = shape_structs(lm.abstract_params(cfg))
        lowered = step.lower(pstruct, cache_struct,
                             batch["tokens"], batch["positions"])
    t_lower = time.time() - t0
    compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0 - t_lower


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               preset: str = "baseline", verbose: bool = True,
               cost_pass: bool = True) -> Dict[str, Any]:
    """One dry-run cell.

    Production compile (scanned units, streaming inner scans) proves the
    sharding and yields memory_analysis. XLA's cost analysis counts a
    while body ONCE regardless of trip count, so flops/bytes/collectives
    are measured on two cost variants with the inner scans unrolled and
    the unit scan unrolled k=1 and k=2: per-unit cost = m2 - m1 exactly,
    total = m1 + (n_units - 1) * (m2 - m1).
    """
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    over_cfg = PRESETS[preset].get("cfg", {})
    if over_cfg.get("pad_heads"):
        axis = 16
        pad = (-cfg.n_heads) % axis
        if pad and (cfg.n_heads + pad) % cfg.n_kv_heads == 0:
            cfg = dataclasses.replace(cfg, n_heads=cfg.n_heads + pad)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "preset": preset,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
    }
    if not applicable(cfg, shape):
        result["status"] = "skipped"
        result["reason"] = skip_reason(cfg, shape)
        return result

    over = PRESETS[preset]
    rules = build_rules(over.get("rules", {}))
    tcfg = build_tcfg(over.get("tcfg", {}))
    if shape.seq_len >= 32768 and "tcfg" not in over:
        tcfg = dataclasses.replace(tcfg, block_q=2048, block_k=2048)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    n_units = cfg.n_units

    with mesh:
        specs = input_specs(cfg, shape, act_dtype=tcfg.act_dtype)
        batch = _with_sharding(specs, mesh, rules)

        # 1) production artifact: compile proof + memory analysis
        compiled, t_lower, t_compile = _compile_variant(
            cfg, shape, mesh, rules, tcfg, batch, unroll=1)
        mem = _mem_report(compiled)

        # 2) cost variants (inner scans unrolled; unit scan k=1, k=2)
        from repro.kernels import ops as kops
        def _measure(c):
            cost = _cost_report(c)
            text = c.as_text()
            coll = hlo_util.collective_stats(text)
            return (cost.get("flops", 0.0), cost.get("bytes_accessed", 0.0),
                    sum(v["bytes"] for v in coll.values()), coll)

        if cost_pass:
            kops.set_inner_unroll(True)
            try:
                c1, _, tc1 = _compile_variant(cfg, shape, mesh, rules, tcfg,
                                              batch, unroll=1)
                f1, b1, cb1, coll1 = _measure(c1)
                del c1
                c2, _, tc2 = _compile_variant(cfg, shape, mesh, rules, tcfg,
                                              batch, unroll=2)
                f2, b2, cb2, coll2 = _measure(c2)
                del c2
            finally:
                kops.set_inner_unroll(False)
            # per-unit deltas; XLA occasionally fuses ACROSS the two
            # unrolled bodies making a delta slightly negative - clamp to
            # the k1 floor rather than extrapolating an artifact
            flops_dev = max(f1 + (n_units - 1) * (f2 - f1), f1)
            bytes_dev = max(b1 + (n_units - 1) * (b2 - b1), b1)
            coll_bytes = max(cb1 + (n_units - 1) * (cb2 - cb1), cb1)
            topup = _scan_topup(cfg, shape, mesh, rules, tcfg)
            flops_dev += topup["flops"]
            bytes_dev += topup["bytes"]
            coll_bytes += topup["coll"]
            coll = {op: {"count": coll1[op]["count"]
                         + (n_units - 1) * (coll2[op]["count"]
                                            - coll1[op]["count"]),
                         "bytes": coll1[op]["bytes"]
                         + (n_units - 1) * (coll2[op]["bytes"]
                                            - coll1[op]["bytes"])}
                    for op in coll1}
            cost = {"flops": flops_dev, "bytes_accessed": bytes_dev,
                    "k1": {"flops": f1, "bytes": b1, "coll": cb1},
                    "k2": {"flops": f2, "bytes": b2, "coll": cb2},
                    "scan_topup": topup,
                    "cost_compile_s": round(tc1 + tc2, 1)}
        else:
            cost = _cost_report(compiled)
            coll = hlo_util.collective_stats(compiled.as_text())
            coll_bytes = sum(v["bytes"] for v in coll.values())
            flops_dev = cost.get("flops", 0.0)
            bytes_dev = cost.get("bytes_accessed", 0.0)

    mf = _model_flops(cfg, shape)
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    useful_s = mf["model_flops_6nd"] / (n_chips * PEAK_FLOPS_BF16)
    bound_s = max(terms.values())
    result.update({
        "status": "ok",
        "n_chips": n_chips,
        "n_units": n_units,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": cost,
        "collectives": coll,
        "collective_bytes_per_device": coll_bytes,
        **mf,
        "roofline": {
            **{k: v for k, v in terms.items()},
            "dominant": dominant,
            "useful_s": useful_s,
            "bound_s": bound_s,
            "roofline_fraction": useful_s / bound_s if bound_s > 0 else 0.0,
            "flops_ratio_useful":
                mf["model_flops_6nd"] / (flops_dev * n_chips)
                if flops_dev else 0.0,
        },
    })
    if verbose:
        r = result["roofline"]
        print(f"[{result['mesh']}:{preset}] {arch} x {shape_name}: "
              f"compile {t_compile:.1f}s | flops/dev {flops_dev:.3e} "
              f"bytes/dev {bytes_dev:.3e} coll/dev {coll_bytes:.3e} | "
              f"T(comp/mem/coll) {compute_s:.4f}/{memory_s:.4f}/"
              f"{collective_s:.4f}s -> {dominant} | "
              f"roofline {r['roofline_fraction']:.3f}")
        print("  memory_analysis:", {k: f"{v:.3e}" for k, v in mem.items()
                                     if isinstance(v, float)})
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--preset", default="baseline", choices=sorted(PRESETS))
    ap.add_argument("--no-cost-pass", action="store_true",
                    help="compile proof + memory only (multi-pod sweep)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in configs.ASSIGNED:
            for s in SHAPES:
                cells.append((a, s))
    elif args.arch and not args.shape:
        cells = [(args.arch, s) for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch [--shape] or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    failures = 0
    for mp in meshes:
        for a, s in cells:
            try:
                results.append(lower_cell(a, s, multi_pod=mp,
                                          preset=args.preset,
                                          cost_pass=not args.no_cost_pass))
            except Exception:
                failures += 1
                traceback.print_exc()
                results.append({"arch": a, "shape": s,
                                "mesh": "pod2x16x16" if mp else "pod16x16",
                                "preset": args.preset, "status": "error",
                                "error": traceback.format_exc()[-2000:]})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        # replace same-key entries
        key = lambda r: (r["arch"], r["shape"], r["mesh"], r["preset"])
        merged = {key(r): r for r in existing}
        for r in results:
            merged[key(r)] = r
        with open(args.out, "w") as f:
            json.dump(list(merged.values()), f, indent=1)
        print(f"wrote {len(results)} cells -> {args.out}")
    ok = sum(r.get("status") == "ok" for r in results)
    sk = sum(r.get("status") == "skipped" for r in results)
    print(f"dry-run: {ok} ok, {sk} skipped, {failures} failed, "
          f"{len(results)} total")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
