"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model) — a TPU v5e pod.
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the pod
axis is pure data parallelism over the inter-pod (DCN/optical) links —
in the paper's terms, independent orbital planes training replicas whose
gradients all-reduce over inter-plane ISLs.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has (tests / examples): (n//m, m)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_fleet_mesh(planes: int):
    """A 1-D ``("plane",)`` mesh for the fleet engine's plane axis.

    The axis size is the largest divisor of ``planes`` this host's
    device count supports, so a ``(P, N)``-laid-out fleet always shards
    evenly: 4 planes on 2 CPU host devices -> 2-way plane sharding, any
    plane count on 1 device -> a trivial (replicated) mesh.  In the
    paper's terms each mesh slot carries one or more orbital planes;
    inter-plane checkpoint averaging all-reduces over this axis (the
    inter-plane ISL exchange).
    """
    n = len(jax.devices())
    planes = max(1, int(planes))
    size = max(d for d in range(1, min(planes, n) + 1) if planes % d == 0)
    return jax.make_mesh((size,), ("plane",))


def plane_sharding(mesh, axis: str = "plane"):
    """``NamedSharding`` splitting leading-axis-(P,) arrays over ``axis``.

    Works with :func:`make_fleet_mesh` (axis ``"plane"``) or any other
    mesh that carries a suitable axis (e.g. :func:`make_host_mesh`'s
    ``"data"`` axis for CPU-device tests); trailing dims replicate.
    """
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(axis))


# TPU v5e roofline constants (per chip) — §Roofline hardware targets.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (~45 GB/s usable)
