"""Roofline report: turn results/dryrun.json into the EXPERIMENTS.md
§Roofline table + per-cell bottleneck advice.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline results/dryrun.json
  PYTHONPATH=src python -m repro.launch.roofline results/dryrun.json --md
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

ADVICE = {
    "memory_s": ("fuse the attention/scan inner loops (the Pallas kernels "
                 "keep score matrices in VMEM; the jnp dry-run path streams "
                 "them through HBM) and drop fp32 intermediates to bf16"),
    "compute_s": ("reduce recompute (remat policy) and replicated compute "
                  "(head-count vs model-axis divisibility); shard attention "
                  "over head_dim when heads don't divide the axis"),
    "collective_s": ("reorder shardings to turn all-gathers into "
                     "reduce-scatters, overlap DP grad reduction with the "
                     "backward scan, or compress gradients (topk/int8)"),
}


def load(path: str, mesh: str = "pod16x16", preset: str = None) -> List[Dict]:
    with open(path) as f:
        rows = json.load(f)
    out = [r for r in rows if r.get("mesh") == mesh]
    if preset is not None:
        out = [r for r in out if r.get("preset") == preset]
    return out


def _fmt(x, digits=3):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e4:
        return f"{x:.{digits}e}"
    return f"{x:.{digits}g}"


def table(rows: List[Dict], md: bool = False) -> str:
    hdr = ["arch", "shape", "preset", "T_comp[s]", "T_mem[s]", "T_coll[s]",
           "dominant", "6ND[s]", "MODEL/HLO", "roofline"]
    lines = []
    if md:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(",".join(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r.get("preset", ""))):
        if r.get("status") == "skipped":
            row = [r["arch"], r["shape"], r.get("preset", ""), "-", "-", "-",
                   "skipped", "-", "-", "-"]
        elif r.get("status") != "ok":
            row = [r["arch"], r["shape"], r.get("preset", ""), "-", "-", "-",
                   "ERROR", "-", "-", "-"]
        else:
            rf = r["roofline"]
            row = [r["arch"], r["shape"], r.get("preset", ""),
                   _fmt(rf["compute_s"]), _fmt(rf["memory_s"]),
                   _fmt(rf["collective_s"]),
                   rf["dominant"].replace("_s", ""),
                   _fmt(rf["useful_s"]),
                   _fmt(rf["flops_ratio_useful"], 2),
                   _fmt(rf["roofline_fraction"], 3)]
        if md:
            lines.append("| " + " | ".join(map(str, row)) + " |")
        else:
            lines.append(",".join(map(str, row)))
    return "\n".join(lines)


def advice(rows: List[Dict]) -> str:
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        lines.append(f"- {r['arch']} x {r['shape']}: {rf['dominant']} "
                     f"dominates ({_fmt(rf[rf['dominant']])} s vs useful "
                     f"{_fmt(rf['useful_s'])} s) -> "
                     f"{ADVICE[rf['dominant']]}.")
    return "\n".join(lines)


def interesting_cells(rows: List[Dict]) -> Dict[str, Dict]:
    """The three hillclimb picks: worst roofline fraction, most
    collective-bound, most representative of the paper's technique."""
    ok = [r for r in rows if r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    # "most representative": the runnable SL driver arch at train shape
    rep = next((r for r in ok if r["arch"] == "smollm_360m"
                and r["shape"] == "train_4k"), ok[0])
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--preset", default=None)
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--advice", action="store_true")
    args = ap.parse_args()
    rows = load(args.path, args.mesh, args.preset)
    print(table(rows, md=args.md))
    if args.advice:
        print()
        print(advice(rows))
        picks = interesting_cells(rows)
        print("\nhillclimb picks:")
        for k, r in picks.items():
            print(f"  {k}: {r['arch']} x {r['shape']} "
                  f"(fraction {r['roofline']['roofline_fraction']:.4f})")


if __name__ == "__main__":
    main()
