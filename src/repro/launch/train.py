"""End-to-end LM training driver (runs on whatever devices exist).

Trains an assigned arch (full or smoke config) with the pjit train step:
synthetic token shards, prefetch, checkpoint/restart, optional gradient
compression. This is the runnable counterpart of the train_4k dry-run
cells; ``--smoke`` uses the reduced config so a few hundred steps fit on
CPU (examples/lm_split_train.py drives the ~100M-class run).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt as ckptlib
from repro import configs
from repro.data.synthetic import TokenShards, prefetch
from repro.launch.mesh import make_host_mesh
from repro.models.param import ShardingRules
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainConfig, TrainState, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    mesh = make_host_mesh(model=args.model_parallel)
    rules = ShardingRules()
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=max(args.steps, 1)),
        remat=args.remat, compression=args.compression)

    step, state_sh, _, init_state = make_train_step(cfg, mesh, rules, tcfg)
    with mesh:
        state = init_state(jax.random.key(args.seed))

    start = 0
    if args.ckpt_dir:
        last = ckptlib.latest_step(args.ckpt_dir)
        if last is not None:
            restored, meta = ckptlib.restore(args.ckpt_dir, last, state)
            state = TrainState(*restored) if isinstance(restored, (list, tuple)) \
                else restored
            start = int(meta.get("step", last))
            print(f"restored checkpoint step {last} (resuming at {start})")

    shards = TokenShards(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                         seed=args.seed)
    it = prefetch(shards.iterate(shard=0, start=start))

    losses = []
    t0 = time.time()
    with mesh:
        for i in range(start, args.steps):
            batch = next(it)
            kw = {}
            if cfg.frontend == "vision":
                kw["frontend_embed"] = jnp.zeros(
                    (args.batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
            if cfg.frontend == "audio":
                kw["enc_frames"] = jnp.zeros(
                    (args.batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
            state, metrics = step(state, {**batch, **kw})
            losses.append(float(metrics["loss"]))
            if (i + 1) % args.log_every == 0:
                dt = time.time() - t0
                print(f"step {i+1:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"({dt/args.log_every:.2f}s/step)")
                t0 = time.time()
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                ckptlib.save(args.ckpt_dir, i + 1, state,
                             meta={"step": i + 1, "arch": cfg.name})

    if losses:
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    else:
        print("no steps to run (checkpoint already at target step)")
    return losses


if __name__ == "__main__":
    main()
