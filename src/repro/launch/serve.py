"""Serving driver: batched greedy decoding with the continuous-batching
engine (serve/engine.py) over any arch's smoke config.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m \
      --requests 6 --new-tokens 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import DecodeEngine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill", choices=("bulk", "loop"), default="bulk",
                    help="prompt ingestion: one prefill forward + cache "
                    "splice (bulk) or the legacy token-by-token loop")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route decode attention through the Pallas "
                    "flash-decode kernel")
    ap.add_argument("--cut", type=int, default=None,
                    help="serve the SPLIT model cut at this unit boundary "
                    "(satellite half + boundary downlink + ground half)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    params = lm.init(cfg, jax.random.key(args.seed))
    kw = dict(n_slots=args.slots, s_max=args.s_max, prefill=args.prefill,
              use_pallas=args.use_pallas)
    if args.cut is None:
        engine = DecodeEngine(cfg, params, **kw)
    else:
        from repro.serve_fleet.engine import SplitDecodeEngine
        engine = SplitDecodeEngine(cfg, params, cut_units=args.cut, **kw)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len)
                    .astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    out = engine.submit_and_run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    for rid in sorted(out):
        print(f"req {rid}: {out[rid]}")
    mode = f"{args.prefill} prefill"
    if args.use_pallas:
        mode += ", pallas decode"
    if args.cut is not None:
        mode += f", split at unit {args.cut}"
    print(f"served {len(out)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, {args.slots} slots, {mode})")
    return out


if __name__ == "__main__":
    main()
