"""Serving driver: batched greedy decoding with the continuous-batching
engine (serve/engine.py) over any arch's smoke config.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m \
      --requests 6 --new-tokens 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import DecodeEngine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    params = lm.init(cfg, jax.random.key(args.seed))
    engine = DecodeEngine(cfg, params, n_slots=args.slots, s_max=args.s_max)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len)
                    .astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    out = engine.submit_and_run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    for rid in sorted(out):
        print(f"req {rid}: {out[rid]}")
    print(f"served {len(out)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, {args.slots} slots)")
    return out


if __name__ == "__main__":
    main()
