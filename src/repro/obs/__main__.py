"""``python -m repro.obs``: flight-recorder smoke + timeline render CLI.

Default (no args) runs the record→flush→render smoke exercised by
``scripts/check.sh --fast``:

1. a 2-plane × 8-sat degraded fleet run (eclipse + epidemic) under a
   :func:`~repro.obs.metrics.sync_budget` guard, asserting every pass
   produced exactly one ring event whose payload matches the dense
   telemetry bit for bit;
2. a delegated ``ConstellationSim.run(engine="device")`` asserting the
   recorder event count matches the host-facing ``PassRecord`` list;
3. a serve-fleet run asserting one ``EV_SERVE`` event per
   (plane, window);
4. a merged Chrome-trace render, structurally validated.

``python -m repro.obs render`` runs a fresh fleet (optionally with the
degraded scenario and/or a concurrent serve fleet) and writes the
Perfetto/Chrome-trace JSON — the acceptance path is::

    python -m repro.obs render --planes 4 --sats 256 \\
        --scenario degraded --serve --out trace.json

Env knobs for the smoke (small-machine CI): ``REPRO_OBS_SMOKE_SATS``
(default 8), ``REPRO_OBS_SMOKE_PLANES`` (2), ``REPRO_OBS_SMOKE_REVS``
(2).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _fleet_engine(n_planes: int, n_sats: int, n_revolutions: int,
                  scenario: str, seed: int = 0):
    from repro.core.energy import PassBudget
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.fleet.engine import FleetConfig, FleetEngine
    from repro.fleet.scenarios import (EclipseConfig, EpidemicConfig,
                                       ScenarioConfig)
    from repro.sim.data import DeviceImageryShards

    scn = None
    if scenario == "degraded":
        scn = ScenarioConfig(
            eclipse=EclipseConfig(period=4, duty=0.5, stagger=1),
            epidemic=EpidemicConfig(beta=0.6, ttl=2, init_slots=(0,),
                                    start=0))
    cfg = FleetConfig(
        n_planes=n_planes, n_revolutions=n_revolutions,
        battery_j=200.0, recharge_w=0.02, reserve_j=180.0,
        max_steps_per_pass=2, seed=seed, avg_every=1, scenario=scn,
        aggregate="median" if scn is not None and n_planes > 1 else "mean")
    return FleetEngine(autoencoder_adapter(cut=5, img=32),
                       PassBudget(plane=OrbitalPlane(n_sats=n_sats),
                                  n_items=4e6),
                       DeviceImageryShards(img=32, batch=4), cfg)


def _serve_engine(n_planes: int, n_sats: int, n_windows: int,
                  seed: int = 2):
    from repro.fleet.scenarios import EclipseConfig
    from repro.serve_fleet.engine import (FleetServeEngine, ServeCost,
                                          ServeFleetConfig, TrainLoad)
    from repro.serve_fleet.traffic import TrafficConfig

    cost = ServeCost(tokens_per_s=400.0, e_token_j=0.05,
                     dtx_bits_token=16_384.0)
    scfg = ServeFleetConfig(
        n_planes=n_planes, n_sats=n_sats, n_windows=n_windows,
        battery_j=60.0, recharge_w=0.02, reserve_serve_j=5.0,
        reserve_train_j=30.0, eclipse=EclipseConfig(period=6, duty=0.5),
        window_s=90.0)
    train = TrainLoad(drain_j=8.0, e_total_j=12.0)
    return FleetServeEngine(scfg, TrafficConfig(users_per_day=60_000.0,
                                                decode_len=4, seed=seed),
                            cost, train=train)


def _smoke() -> None:
    import numpy as np

    from repro.obs.metrics import sync_budget
    from repro.obs.ring import EV_EXCHANGE, EV_PASS, EV_SERVE, merge_events
    from repro.obs.timeline import (timeline_summary, validate_chrome_trace,
                                    write_chrome_trace)

    n_sats = int(os.environ.get("REPRO_OBS_SMOKE_SATS", "8"))
    n_planes = int(os.environ.get("REPRO_OBS_SMOKE_PLANES", "2"))
    n_revs = int(os.environ.get("REPRO_OBS_SMOKE_REVS", "2"))
    t0 = time.time()

    # -- 1. degraded fleet run under a sync budget ------------------------
    fleet = _fleet_engine(n_planes, n_sats, n_revs, "degraded")
    with sync_budget(n_revs, registry=fleet.metrics):
        res = fleet.run(stream_telemetry=True)
    ev = fleet.recorder.events()
    n_pass = int((ev["kind"] == EV_PASS).sum())
    assert n_pass == res.action.size, (n_pass, res.action.shape)
    assert fleet.recorder.dropped == 0
    # payload actions must match the dense telemetry bit for bit
    for p in range(n_planes):
        sel = (ev["kind"] == EV_PASS) & (ev["plane"] == p)
        order = np.argsort(ev["t"][sel])
        np.testing.assert_array_equal(
            ev["payload"][sel][order][:, 0].astype(np.int32),
            res.action[p])
    n_exch = int((ev["kind"] == EV_EXCHANGE).sum())
    print(f"[obs] fleet {n_planes}x{n_sats}x{n_revs}: {n_pass} pass "
          f"events + {n_exch} exchange markers, payload==telemetry, "
          f"host_syncs={fleet.host_syncs}<= {n_revs} ({time.time() - t0:.1f}s)")

    # -- 2. delegated sim run: events must match PassRecords --------------
    t1 = time.time()
    from repro.core.constellation import (ConstellationConfig,
                                          ConstellationSim)
    from repro.core.energy import PassBudget
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.sim.data import DeviceImageryShards

    sim = ConstellationSim(
        autoencoder_adapter(cut=5, img=32),
        PassBudget(plane=OrbitalPlane(n_sats=4), n_items=4e6),
        DeviceImageryShards(img=32, batch=4),
        ConstellationConfig(n_passes=8, batch_size=4, battery_j=200.0,
                            recharge_w=0.01, reserve_j=150.0,
                            max_steps_per_pass=4))
    sim.run(engine="device")
    eng = sim.device_engine
    assert len(eng.recorder) == len(sim.records), \
        (len(eng.recorder), len(sim.records))
    sim_ev = eng.recorder.events()
    from repro.sim.device_sim import ACTION_NAMES
    code = {v: k for k, v in ACTION_NAMES.items()}
    rec_act = np.array([code[r.action] for r in sim.records], np.int32)
    np.testing.assert_array_equal(
        sim_ev["payload"][:, 0].astype(np.int32), rec_act)
    print(f"[obs] delegated sim: {len(eng.recorder)} events == "
          f"{len(sim.records)} PassRecords ({time.time() - t1:.1f}s)")

    # -- 3. serve fleet: one EV_SERVE per (plane, window) -----------------
    t2 = time.time()
    serve = _serve_engine(n_planes, n_sats, n_windows=24)
    with sync_budget(1, registry=serve.metrics):
        sres = serve.run()
    sev = serve.recorder.events()
    n_serve = int((sev["kind"] == EV_SERVE).sum())
    assert n_serve == sres.arrivals.size, (n_serve, sres.arrivals.shape)
    print(f"[obs] serve fleet: {n_serve} serve events == "
          f"{sres.arrivals.size} windows ({time.time() - t2:.1f}s)")

    # -- 4. merged render -------------------------------------------------
    import tempfile
    merged = merge_events(ev, sev)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "trace.json")
        write_chrome_trace(path, merged, window_s=90.0)
        with open(path) as fh:
            validate_chrome_trace(json.load(fh))
    print(timeline_summary(merged))
    print(f"[obs] smoke OK: render valid ({time.time() - t0:.1f}s total)")


def _render(argv) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs render",
        description="run a fleet (optionally + serving) and write the "
                    "mission timeline as Chrome-trace/Perfetto JSON")
    ap.add_argument("--planes", type=int, default=2)
    ap.add_argument("--sats", type=int, default=8)
    ap.add_argument("--revolutions", type=int, default=1)
    ap.add_argument("--windows", type=int, default=24,
                    help="serve windows (with --serve)")
    ap.add_argument("--scenario", choices=("none", "degraded"),
                    default="none")
    ap.add_argument("--serve", action="store_true",
                    help="also run a serve fleet on the same plane "
                         "layout and merge its windows into the trace")
    ap.add_argument("--window-s", type=float, default=90.0,
                    help="seconds of trace time per pass/window index")
    ap.add_argument("--out", default="trace.json")
    ap.add_argument("--events", default=None,
                    help="also save the raw event table (.npz)")
    args = ap.parse_args(argv)

    from repro.obs.ring import merge_events
    from repro.obs.timeline import (timeline_summary, validate_chrome_trace,
                                    write_chrome_trace)

    t0 = time.time()
    fleet = _fleet_engine(args.planes, args.sats, args.revolutions,
                          args.scenario)
    fleet.run()
    tables = [fleet.recorder.events()]
    recorders = [fleet.recorder]
    print(f"[render] fleet {args.planes}x{args.sats}x{args.revolutions} "
          f"({args.scenario}): {len(fleet.recorder)} events, "
          f"host_syncs={fleet.host_syncs} ({time.time() - t0:.1f}s)")
    if args.serve:
        t1 = time.time()
        serve = _serve_engine(args.planes, args.sats, args.windows)
        serve.run()
        tables.append(serve.recorder.events())
        recorders.append(serve.recorder)
        print(f"[render] serve fleet {args.planes}x{args.sats}, "
              f"{args.windows} windows: {len(serve.recorder)} events "
              f"({time.time() - t1:.1f}s)")

    merged = merge_events(*tables)
    trace = write_chrome_trace(args.out, merged, window_s=args.window_s)
    validate_chrome_trace(trace)
    assert sum(r.dropped for r in recorders) == 0
    if args.events:
        import numpy as np
        np.savez(args.events, dropped=np.int64(0), **merged)
        print(f"[render] event table -> {args.events}")
    print(timeline_summary(merged))
    print(f"[render] {len(trace['traceEvents'])} trace events -> "
          f"{args.out} (open in ui.perfetto.dev or chrome://tracing)")


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if argv and argv[0] == "render":
        _render(argv[1:])
    elif not argv:
        _smoke()
    else:
        raise SystemExit("usage: python -m repro.obs [render ...]")


if __name__ == "__main__":
    main()
