"""In-scan telemetry rings: the device half of the flight recorder.

The engines (:mod:`repro.sim.device_sim`, :mod:`repro.fleet.engine`,
:mod:`repro.serve_fleet.engine`) run entire (revolution × pass) and
(window × plane) loops as single jitted scans; anything they want to
tell the host has to either ride the scan outputs or break the
≤-1-host-sync-per-revolution contract.  A :class:`TelemetryRing` is the
first option made first-class: a fixed-size structured event buffer
(kind / time / slot / float32 payload row) plus a monotonic cursor,
carried through the scan like any other state and **flushed at the
existing revolution-boundary sync** — the ring arrays come home inside
the same host read as the dense telemetry, so recording events costs
zero extra syncs (asserted via the metrics registry's ``host_syncs``
counter, see :mod:`repro.obs.metrics`).

Device API (traceable, vmap-safe — the fleet engine records into a
``(P, ...)``-leading ring under its plane ``vmap``):

* :func:`ring_init` — allocate a ring of ``capacity`` event slots;
* :func:`record` — write one event at the cursor (a ``mask=False``
  record is a no-op: same trace, nothing written).  When the ring is
  full the cursor keeps counting but the write wraps — newest events
  overwrite the oldest, and the overflow is reported as ``dropped`` at
  flush time, never silently.

Host API:

* :func:`flush` — one host copy of the ring, unwrapped into
  chronological event arrays (+ the dropped-event count);
* :class:`FlightRecorder` — accumulates flushed rings across
  dispatches/planes into one event table (feeding the engine's metrics
  registry), ready for :mod:`repro.obs.timeline` to render.

Event payload rows are plain float32; the *meaning* of each column is
fixed per event kind (``PASS_FIELDS`` / ``SERVE_FIELDS``) so the host
side can name them without the device side carrying strings.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------- schema

EV_PASS = 0          # one training pass (sim + fleet engines)
EV_SERVE = 1         # one serving window (serve-fleet engine)
EV_EXCHANGE = 2      # inter-plane ISL checkpoint exchange

EVENT_NAMES = {EV_PASS: "pass", EV_SERVE: "serve", EV_EXCHANGE: "exchange"}

#: float32 payload columns, fixed per event kind (unused tail = 0)
PASS_FIELDS = ("action", "battery_j", "loss", "n_steps", "kept_fraction",
               "fault", "sunlit", "n_infected")
SERVE_FIELDS = ("arrivals", "battery_j", "served", "backlog", "tokens",
                "trained", "sunlit", "capacity_req")
EXCHANGE_FIELDS = ("aggregate", "bits", "e_isl_j", "staleness", "weight")
FIELDS_BY_KIND = {EV_PASS: PASS_FIELDS, EV_SERVE: SERVE_FIELDS,
                  EV_EXCHANGE: EXCHANGE_FIELDS}

#: every ring row is this wide — the max any kind needs
PAYLOAD_WIDTH = 8


class TelemetryRing(NamedTuple):
    """Fixed-size structured event buffer riding a scan carry.

    All fields are arrays (a pytree by NamedTuple construction), so a
    ring vmaps/shards/donates like any other carry leaf.  ``cursor``
    counts every recorded event monotonically; the write index is
    ``cursor % capacity``, so ``cursor > capacity`` means the oldest
    ``cursor - capacity`` events were overwritten.
    """

    kind: Any        # (C,)   int32  EV_* code
    t: Any           # (C,)   int32  pass / window index
    slot: Any        # (C,)   int32  ring slot (satellite), -1 = plane-wide
    payload: Any     # (C, W) float32 columns named by FIELDS_BY_KIND
    cursor: Any      # ()     int32  total events recorded (monotonic)

    @property
    def capacity(self) -> int:
        return self.kind.shape[-1]


def ring_init(capacity: int, payload_width: int = PAYLOAD_WIDTH,
              batch: Tuple[int, ...] = ()) -> TelemetryRing:
    """A fresh ring of ``capacity`` event slots (``batch`` adds leading
    axes — the fleet engine allocates one ring per plane as
    ``batch=(P,)`` and records under its plane ``vmap``)."""
    if capacity < 1:
        raise ValueError(f"ring capacity must be >= 1, got {capacity}")
    return TelemetryRing(
        kind=jnp.full(batch + (capacity,), -1, jnp.int32),
        t=jnp.zeros(batch + (capacity,), jnp.int32),
        slot=jnp.zeros(batch + (capacity,), jnp.int32),
        payload=jnp.zeros(batch + (capacity, payload_width), jnp.float32),
        cursor=jnp.zeros(batch, jnp.int32))


def record(ring: TelemetryRing, kind, t, slot, payload,
           mask=True) -> TelemetryRing:
    """Write one event at the cursor; traceable, called INSIDE scans.

    ``payload`` is a sequence/array of up to ``PAYLOAD_WIDTH`` float32
    scalars (shorter rows are zero-padded); ``mask=False`` leaves the
    ring bit-identical (the event never happened — same trace either
    way, so conditional events cost nothing).  Must stay jnp-pure: it
    runs inside the engines' jitted scan bodies, where a stray host op
    would break the sync contract (``scripts/lint_scan_purity.py``
    guards this function alongside the scan bodies themselves).
    """
    cap = ring.kind.shape[-1]
    width = ring.payload.shape[-1]
    pay = jnp.asarray(payload, jnp.float32).reshape(-1)
    if pay.shape[0] > width:
        raise ValueError(f"payload has {pay.shape[0]} columns; the ring "
                         f"holds {width}")
    if pay.shape[0] < width:
        pay = jnp.concatenate(
            [pay, jnp.zeros((width - pay.shape[0],), jnp.float32)])
    m = jnp.asarray(mask, bool)
    idx = ring.cursor % cap
    return TelemetryRing(
        kind=ring.kind.at[idx].set(
            jnp.where(m, jnp.asarray(kind, jnp.int32), ring.kind[idx])),
        t=ring.t.at[idx].set(
            jnp.where(m, jnp.asarray(t, jnp.int32), ring.t[idx])),
        slot=ring.slot.at[idx].set(
            jnp.where(m, jnp.asarray(slot, jnp.int32), ring.slot[idx])),
        payload=ring.payload.at[idx].set(
            jnp.where(m, pay, ring.payload[idx])),
        cursor=ring.cursor + m.astype(jnp.int32))


# ------------------------------------------------------------- host side

class RingEvents(NamedTuple):
    """One flushed ring, chronological, host arrays."""

    kind: np.ndarray      # (n,) int32
    t: np.ndarray         # (n,) int32
    slot: np.ndarray      # (n,) int32
    payload: np.ndarray   # (n, W) float32
    dropped: int          # events overwritten before this flush


def flush(ring: TelemetryRing) -> RingEvents:
    """One device→host copy of a (flat) ring, unwrapped oldest-first.

    Call it where the engine already syncs telemetry — the ring comes
    home inside the same host read, so flushing adds no sync of its
    own.  Rings with leading batch axes (one per plane) are flushed
    per plane by :meth:`FlightRecorder.ingest`.
    """
    host = TelemetryRing(*[np.asarray(a) for a in ring])
    if host.cursor.ndim != 0:
        raise ValueError("flush() takes a flat ring; index the plane axis "
                         "first (FlightRecorder.ingest does)")
    cap = host.kind.shape[-1]
    cursor = int(host.cursor)
    n = min(cursor, cap)
    if cursor <= cap:
        order = np.arange(n)
    else:                       # wrapped: oldest event sits at cursor % cap
        start = cursor % cap
        order = np.concatenate([np.arange(start, cap), np.arange(start)])
    return RingEvents(kind=host.kind[order], t=host.t[order],
                      slot=host.slot[order], payload=host.payload[order],
                      dropped=cursor - n)


_EVENT_COLUMNS = ("kind", "t", "slot", "plane", "payload")


class FlightRecorder:
    """Host-side accumulator of flushed rings — the mission's black box.

    Engines own one recorder each and call :meth:`ingest` right where
    they sync telemetry (one call per dispatch).  The recorder splits
    plane-batched rings, tags every event with its plane, feeds the
    engine's metrics registry (``events_recorded`` / ``events_dropped``
    counters) and serves the merged, time-ordered event table to
    :mod:`repro.obs.timeline`.
    """

    def __init__(self, metrics=None):
        self.metrics = metrics
        self.dropped = 0
        self._chunks = []          # list of per-ingest column dicts

    def __len__(self) -> int:
        return sum(int(c["kind"].shape[0]) for c in self._chunks)

    def ingest(self, ring: TelemetryRing, *, t_offset: int = 0) -> int:
        """Flush ``ring`` (flat, or plane-batched ``(P, ...)``) into the
        event table; returns the number of events ingested.

        ``t_offset`` shifts event times into the run's absolute
        timeline for engines that record dispatch-local indices (the
        sim engine's ``t`` restarts at 0 every dispatch; the fleet and
        serve engines record absolute indices and pass 0).
        """
        host = TelemetryRing(*[np.asarray(a) for a in ring])
        planes = ([None] if host.cursor.ndim == 0
                  else range(host.cursor.shape[0]))
        n_total = 0
        for p in planes:
            r = host if p is None else TelemetryRing(
                *[a[p] for a in host])
            ev = flush(r)
            n = ev.kind.shape[0]
            n_total += n
            self.dropped += ev.dropped
            self._chunks.append({
                "kind": ev.kind, "t": ev.t + np.int32(t_offset),
                "slot": ev.slot,
                "plane": np.full((n,), 0 if p is None else p, np.int32),
                "payload": ev.payload})
        if self.metrics is not None:
            self.metrics.inc("events_recorded", n_total)
            if self.dropped:
                self.metrics.counter("events_dropped").set(self.dropped)
        return n_total

    def events(self) -> Dict[str, np.ndarray]:
        """The merged event table, stably sorted by (t, plane)."""
        if not self._chunks:
            return {"kind": np.zeros((0,), np.int32),
                    "t": np.zeros((0,), np.int32),
                    "slot": np.zeros((0,), np.int32),
                    "plane": np.zeros((0,), np.int32),
                    "payload": np.zeros((0, PAYLOAD_WIDTH), np.float32)}
        cols = {k: np.concatenate([c[k] for c in self._chunks])
                for k in _EVENT_COLUMNS}
        order = np.lexsort((cols["plane"], cols["t"]))
        return {k: v[order] for k, v in cols.items()}

    # --------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """One ``.npz`` with the merged table (+ dropped count) — what
        ``python -m repro.obs render --events`` re-renders offline."""
        ev = self.events()
        np.savez(path, dropped=np.int64(self.dropped), **ev)

    @staticmethod
    def load(path: str) -> "FlightRecorder":
        data = np.load(path)
        rec = FlightRecorder()
        rec.dropped = int(data["dropped"])
        rec._chunks.append({k: data[k] for k in _EVENT_COLUMNS})
        return rec


def merge_events(*tables: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Merge event tables (e.g. a train fleet's and a serve fleet's)
    into one, stably sorted by (t, plane)."""
    tables = [t for t in tables if t["kind"].shape[0]]
    if not tables:
        return FlightRecorder().events()
    cols = {k: np.concatenate([t[k] for t in tables])
            for k in _EVENT_COLUMNS}
    order = np.lexsort((cols["plane"], cols["t"]))
    return {k: v[order] for k, v in cols.items()}


def payload_column(events: Dict[str, np.ndarray], kind: int,
                   field: str) -> np.ndarray:
    """The named payload column of every ``kind`` event (host helper)."""
    fields = FIELDS_BY_KIND[kind]
    mask = events["kind"] == kind
    return events["payload"][mask][:, fields.index(field)]
