"""Unified host-side metrics layer: counters, gauges, histograms.

Before this module the repo's observability was three ad-hoc ints on
``DeviceConstellationSim`` (``traces`` / ``device_calls`` /
``host_syncs``) plus per-row benchmark prints.  The registry keeps the
same cheap integer semantics but makes them *uniform* (every engine
exposes the same counter names under its own namespace), *aggregable*
(child registries propagate into a process-global parent, which
``benchmarks/run.py`` serialises as the BENCH ``metrics`` block) and
*assertable* (:func:`sync_budget` turns the ≤-1-host-sync-per-revolution
contract into a context manager any test can wrap around a run).

Compat: the engines keep their old attribute API via
:func:`counter_property` — ``sim.host_syncs`` reads (and ``+= 1``
writes) go straight through to the registry counter, so every existing
test, benchmark and example keeps working unchanged.

Everything here is host-side Python — nothing in this module is ever
traced, and incrementing a counter never touches a device.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, List, Optional


class Counter:
    """Monotonic-by-convention integer metric (``inc``/``add``/``set``).

    Deltas propagate to the owning registry's parent chain, so a fleet
    engine bumping ``fleet.host_syncs`` also bumps the global
    aggregate — which is what :func:`sync_budget` watches by default.
    """

    kind = "counter"

    def __init__(self, name: str, registry: "MetricsRegistry"):
        self.name = name
        self._registry = registry
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.add(n)

    def add(self, n: int) -> None:
        self.value += n
        self._registry._propagate(self.name, n)

    def set(self, value: int) -> None:
        """Absolute write (the compat-property setter needs it; the
        delta still propagates so parent aggregates stay consistent)."""
        self.add(value - self.value)

    def to_value(self):
        return self.value


class Gauge:
    """Last-write-wins scalar (mesh shape, plane count, battery floor…).

    Gauges do NOT aggregate to the parent — summing "n_planes" across
    engines is meaningless — but they do *appear* in the parent's
    ``to_dict`` under their qualified name, via registry traversal.
    """

    kind = "gauge"

    def __init__(self, name: str, registry: "MetricsRegistry"):
        self.name = name
        self.value: Any = None

    def set(self, value) -> None:
        self.value = value

    def to_value(self):
        return self.value


class Histogram:
    """Streaming summary of a float series (dispatch latencies, window
    throughputs): count / sum / min / max plus power-of-two buckets.

    Buckets are ``le`` upper bounds in a fixed geometric ladder — good
    enough to eyeball a latency distribution in a BENCH JSON without
    storing samples.
    """

    kind = "histogram"

    #: geometric bucket upper bounds (seconds-ish scale); +inf implied
    BOUNDS = tuple(2.0 ** e for e in range(-10, 7))

    def __init__(self, name: str, registry: "MetricsRegistry"):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets = [0] * (len(self.BOUNDS) + 1)

    def record(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        for i, bound in enumerate(self.BOUNDS):
            if x <= bound:
                self.buckets[i] += 1
                return
        self.buckets[-1] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_value(self):
        if not self.count:
            return {"count": 0}
        out = {"count": self.count, "sum": self.sum, "mean": self.mean,
               "min": self.min, "max": self.max}
        nonzero = {f"le_{bound:g}": n
                   for bound, n in zip(self.BOUNDS, self.buckets) if n}
        if self.buckets[-1]:
            nonzero["le_inf"] = self.buckets[-1]
        out["buckets"] = nonzero
        return out


_METRIC_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """A namespaced bag of metrics with get-or-create accessors.

    Engines build one per instance, parented to the process-global
    registry::

        self.metrics = MetricsRegistry("fleet", parent=global_registry())
        self.metrics.inc("traces")            # counter shorthand
        self.metrics.histogram("dispatch_s").record(dt)

    Counter deltas roll up the parent chain under the child's qualified
    name (``fleet.traces``), so the global registry is always the sum
    over every live engine — that aggregate is what lands in BENCH
    JSONs and what :func:`sync_budget` guards by default.
    """

    def __init__(self, namespace: str = "",
                 parent: Optional["MetricsRegistry"] = None):
        self.namespace = namespace
        self.parent = parent
        self._metrics: Dict[str, Any] = {}

    # ----------------------------------------------------- accessors
    def _get(self, kind: str, name: str):
        m = self._metrics.get(name)
        if m is None:
            m = _METRIC_TYPES[kind](name, self)
            self._metrics[name] = m
        elif m.kind != kind:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get("counter", name)

    def gauge(self, name: str) -> Gauge:
        return self._get("gauge", name)

    def histogram(self, name: str) -> Histogram:
        return self._get("histogram", name)

    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    # --------------------------------------------------- aggregation
    def _qualify(self, name: str) -> str:
        return f"{self.namespace}.{name}" if self.namespace else name

    def _propagate(self, name: str, delta: int) -> None:
        if self.parent is not None and delta:
            self.parent.counter(self._qualify(name)).add(delta)

    def counters_matching(self, suffix: str) -> List[Counter]:
        """Every counter whose name is ``suffix`` or ends with
        ``.suffix`` — how :func:`sync_budget` finds host-sync counters
        from any engine namespace."""
        return [m for name, m in sorted(self._metrics.items())
                if m.kind == "counter"
                and (name == suffix or name.endswith("." + suffix))]

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready snapshot (the BENCH ``metrics`` block)."""
        return {name: m.to_value()
                for name, m in sorted(self._metrics.items())}


# ------------------------------------------------------ global registry

_GLOBAL: MetricsRegistry = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    """The process-wide aggregate every engine parents to."""
    return _GLOBAL


def reset_global() -> MetricsRegistry:
    """Fresh global registry (benchmark entry points call this so one
    process's runs don't bleed into the next BENCH JSON).  Engines
    created *before* the reset keep propagating into the old registry;
    construct engines after resetting."""
    global _GLOBAL
    _GLOBAL = MetricsRegistry()
    return _GLOBAL


# --------------------------------------------------------- sync budget

class SyncBudgetExceeded(AssertionError):
    """A guarded region performed more device→host syncs than allowed."""


@contextlib.contextmanager
def sync_budget(max_syncs: int, registry: Optional[MetricsRegistry] = None,
                counter: str = "host_syncs"):
    """Assert that the wrapped region performs ≤ ``max_syncs`` telemetry
    syncs — the ≤-1-per-revolution contract as a context manager::

        with sync_budget(cfg.n_revolutions, registry=fleet.metrics):
            fleet.run()

    Watches every counter named ``counter`` (or ``*.{counter}``) in
    ``registry`` (default: the global registry, i.e. all engines at
    once) and raises :class:`SyncBudgetExceeded` with the offending
    delta.  Counters created *inside* the region are picked up too —
    the before-snapshot treats unseen counters as 0.
    """
    reg = registry if registry is not None else global_registry()
    before = {c.name: c.value for c in reg.counters_matching(counter)}
    yield reg
    after = {c.name: c.value for c in reg.counters_matching(counter)}
    spent = sum(after.values()) - sum(before.get(k, 0) for k in after)
    if spent > max_syncs:
        detail = ", ".join(f"{k}: +{v - before.get(k, 0)}"
                           for k, v in sorted(after.items())
                           if v - before.get(k, 0))
        raise SyncBudgetExceeded(
            f"sync budget exceeded: {spent} host syncs > allowed "
            f"{max_syncs} ({detail})")


# ------------------------------------------------------- compat shim

def counter_property(name: str):
    """A class-level property backing an old-style ``self.<attr>`` int
    against ``self.metrics.counter(name)``.

    Keeps the pre-registry API alive verbatim: reads return the counter
    value, ``engine.traces += 1`` and ``engine.host_syncs = 0`` both
    work (augmented assignment reads then sets; the set propagates the
    delta).  Engines declare::

        traces = counter_property("traces")
    """

    def _get(self):
        return self.metrics.counter(name).value

    def _set(self, value):
        self.metrics.counter(name).set(int(value))

    return property(_get, _set, doc=f"compat view of metrics counter "
                                    f"{name!r}")
