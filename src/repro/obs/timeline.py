"""Mission-timeline export: flushed rings → Chrome-trace / Perfetto JSON.

Renders a :class:`~repro.obs.ring.FlightRecorder` event table as the
kind of dense per-pass timeline SFL-LEO / LEO-Split evaluate with: one
process per orbital plane, one thread per ring slot, a complete-event
("X") span per training pass (named by its action: trained / shed /
reserve-skip / failed / fault) or serving window, eclipse shading and
ISL exchange markers on dedicated tracks, and battery / backlog counter
("C") series.  The JSON loads directly in ``ui.perfetto.dev`` or
``chrome://tracing``; :func:`timeline_summary` gives the same story as
plain text for terminals and smoke logs.

Event times are pass/window *indices*; :func:`to_chrome_trace` maps
index ``t`` to ``t * window_s`` seconds of trace time (trace
timestamps are microseconds), so the timeline's x-axis is mission time
under the configured pass cadence.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List

import numpy as np

from .ring import (EV_EXCHANGE, EV_PASS, EV_SERVE, EXCHANGE_FIELDS,
                   FIELDS_BY_KIND, PASS_FIELDS, SERVE_FIELDS)

# Synthetic tids for plane-wide tracks (real slots are small ints).
_TID_ECLIPSE = 9000
_TID_EXCHANGE = 9001
_TID_SERVE_BASE = 5000     # serve slot m renders at tid 5000 + m


def _action_names() -> Dict[int, str]:
    # Lazy import: device_sim imports repro.obs, so a top-level import
    # here would be circular.
    from repro.sim.device_sim import ACTION_NAMES
    return dict(ACTION_NAMES)


def _row(ev: Dict[str, np.ndarray], i: int) -> Dict[str, float]:
    fields = FIELDS_BY_KIND.get(int(ev["kind"][i]), ())
    pay = ev["payload"][i]
    return {f: float(pay[j]) for j, f in enumerate(fields)}


def to_chrome_trace(events: Dict[str, np.ndarray],
                    window_s: float = 1.0) -> Dict[str, Any]:
    """Event table (from ``FlightRecorder.events`` / ``merge_events``)
    → Chrome-trace JSON object (``{"traceEvents": [...]}``)."""
    actions = _action_names()
    us = window_s * 1e6
    out: List[Dict[str, Any]] = []
    seen_procs = set()
    seen_threads = set()

    def meta_proc(pid: int, name: str) -> None:
        if pid not in seen_procs:
            seen_procs.add(pid)
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": name}})

    def meta_thread(pid: int, tid: int, name: str) -> None:
        if (pid, tid) not in seen_threads:
            seen_threads.add((pid, tid))
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": name}})

    # Eclipse shading: consecutive sunlit==0 passes on one plane merge
    # into a single span on the plane's eclipse track.
    eclipse_open: Dict[int, List[float]] = {}   # plane -> [start_ts, end_ts]

    def close_eclipse(pid: int) -> None:
        span = eclipse_open.pop(pid, None)
        if span is not None:
            out.append({"ph": "X", "name": "eclipse", "cat": "eclipse",
                        "pid": pid, "tid": _TID_ECLIPSE,
                        "ts": span[0], "dur": span[1] - span[0], "args": {}})

    n = int(events["kind"].shape[0])
    for i in range(n):
        kind = int(events["kind"][i])
        t = int(events["t"][i])
        slot = int(events["slot"][i])
        pid = int(events["plane"][i])
        ts = t * us
        args = _row(events, i)
        meta_proc(pid, f"plane {pid}")

        if kind == EV_PASS:
            meta_thread(pid, slot, f"slot {slot}")
            name = actions.get(int(args.get("action", -1)),
                               f"action {int(args.get('action', -1))}")
            out.append({"ph": "X", "name": name, "cat": "train",
                        "pid": pid, "tid": slot, "ts": ts, "dur": us,
                        "args": args})
            out.append({"ph": "C", "name": f"battery slot {slot}",
                        "pid": pid, "tid": slot, "ts": ts,
                        "args": {"J": args.get("battery_j", 0.0)}})
            if "sunlit" in args:
                meta_thread(pid, _TID_ECLIPSE, "eclipse")
                if args["sunlit"] < 0.5:
                    span = eclipse_open.setdefault(pid, [ts, ts])
                    span[1] = ts + us
                else:
                    close_eclipse(pid)
        elif kind == EV_SERVE:
            tid = _TID_SERVE_BASE + max(slot, 0)
            meta_thread(pid, tid, f"serve slot {slot}")
            out.append({"ph": "X", "name": "serve", "cat": "serve",
                        "pid": pid, "tid": tid, "ts": ts, "dur": us,
                        "args": args})
            out.append({"ph": "C", "name": f"backlog slot {slot}",
                        "pid": pid, "tid": tid, "ts": ts,
                        "args": {"tok": args.get("backlog", 0.0)}})
        elif kind == EV_EXCHANGE:
            meta_thread(pid, _TID_EXCHANGE, "isl exchange")
            out.append({"ph": "i", "name": "plane exchange",
                        "cat": "exchange", "pid": pid,
                        "tid": _TID_EXCHANGE, "ts": ts, "s": "p",
                        "args": args})

    for pid in list(eclipse_open):
        close_eclipse(pid)
    return {"traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"window_s": window_s, "n_events": n}}


def write_chrome_trace(path: str, events: Dict[str, np.ndarray],
                       window_s: float = 1.0) -> Dict[str, Any]:
    trace = to_chrome_trace(events, window_s=window_s)
    with open(path, "w") as fh:
        json.dump(trace, fh)
    return trace


def validate_chrome_trace(obj: Any) -> None:
    """Raise ``ValueError`` unless ``obj`` is a loadable Chrome-trace
    object (what the acceptance criterion means by "valid")."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a chrome trace: missing 'traceEvents'")
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for key in ("ph", "pid", "tid", "name"):
            if key not in ev:
                raise ValueError(f"traceEvents[{i}] missing {key!r}")
        if ev["ph"] in ("X", "C", "i") and "ts" not in ev:
            raise ValueError(f"traceEvents[{i}] ({ev['ph']}) missing 'ts'")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"traceEvents[{i}] (X) missing 'dur'")


def timeline_summary(events: Dict[str, np.ndarray]) -> str:
    """Plain-text digest of an event table (for smokes / terminals)."""
    actions = _action_names()
    kind = events["kind"]
    lines = [f"flight recorder: {kind.shape[0]} events, "
             f"planes {sorted(set(events['plane'].tolist())) or '-'}"]
    pass_mask = kind == EV_PASS
    if pass_mask.any():
        acts = events["payload"][pass_mask][:, PASS_FIELDS.index("action")]
        acts = acts.astype(np.int32)
        counts = ", ".join(
            f"{actions.get(int(a), int(a))}={int((acts == a).sum())}"
            for a in np.unique(acts))
        batt = events["payload"][pass_mask][:, PASS_FIELDS.index("battery_j")]
        finite = batt[np.isfinite(batt)]
        lines.append(f"  passes: {int(pass_mask.sum())} ({counts})")
        if finite.size:
            lines.append(f"  battery J: min {finite.min():.1f} / "
                         f"mean {finite.mean():.1f} / max {finite.max():.1f}")
        sun = events["payload"][pass_mask][:, PASS_FIELDS.index("sunlit")]
        if (sun < 0.5).any():
            lines.append(f"  eclipsed passes: {int((sun < 0.5).sum())}")
    serve_mask = kind == EV_SERVE
    if serve_mask.any():
        pay = events["payload"][serve_mask]
        served = pay[:, SERVE_FIELDS.index("served")]
        tokens = pay[:, SERVE_FIELDS.index("tokens")]
        backlog = pay[:, SERVE_FIELDS.index("backlog")]
        lines.append(f"  serve windows: {int(serve_mask.sum())}, "
                     f"served {served.sum():.0f} req / "
                     f"{tokens.sum():.0f} tok, final backlog "
                     f"{backlog[-1]:.0f} req")
    n_ex = int((kind == EV_EXCHANGE).sum())
    if n_ex:
        pay = events["payload"][kind == EV_EXCHANGE]
        bits = pay[:, EXCHANGE_FIELDS.index("bits")]
        e_isl = pay[:, EXCHANGE_FIELDS.index("e_isl_j")]
        stale = pay[:, EXCHANGE_FIELDS.index("staleness")]
        line = f"  plane exchanges: {n_ex}"
        if bits.sum() > 0:      # metered (repro.isl); legacy barrier = 0
            line += (f", {bits.sum():.3g} bits / {e_isl.sum():.3g} J "
                     f"over ISL, max staleness {stale.max():.0f}")
        lines.append(line)
    return "\n".join(lines)
