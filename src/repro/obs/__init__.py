"""Flight recorder: in-scan telemetry rings, metrics, timeline export.

See :mod:`repro.obs.ring` (device-side event rings +
:class:`FlightRecorder`), :mod:`repro.obs.metrics` (registry +
``sync_budget`` guard) and :mod:`repro.obs.timeline` (Chrome-trace /
Perfetto rendering).  ``python -m repro.obs`` runs the record→flush→
render smoke; ``python -m repro.obs render`` produces a trace JSON from
a fresh fleet run.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      SyncBudgetExceeded, counter_property,
                      global_registry, reset_global, sync_budget)
from .ring import (EV_EXCHANGE, EV_PASS, EV_SERVE, EVENT_NAMES,
                   EXCHANGE_FIELDS, FIELDS_BY_KIND, PASS_FIELDS,
                   PAYLOAD_WIDTH, SERVE_FIELDS, FlightRecorder,
                   RingEvents, TelemetryRing, flush, merge_events,
                   payload_column, record, ring_init)
from .timeline import (timeline_summary, to_chrome_trace,
                       validate_chrome_trace, write_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "SyncBudgetExceeded", "counter_property", "global_registry",
    "reset_global", "sync_budget",
    "EV_EXCHANGE", "EV_PASS", "EV_SERVE", "EVENT_NAMES",
    "EXCHANGE_FIELDS", "FIELDS_BY_KIND", "PASS_FIELDS", "PAYLOAD_WIDTH",
    "SERVE_FIELDS", "FlightRecorder", "RingEvents", "TelemetryRing",
    "flush", "merge_events", "payload_column", "record", "ring_init",
    "timeline_summary", "to_chrome_trace", "validate_chrome_trace",
    "write_chrome_trace",
]
