"""HLO inspection: collective-traffic accounting from the compiled
(post-SPMD, per-device) module text.

``collective_bytes(compiled.as_text())`` sums the bytes each device
moves through all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops. Conventions per op (bytes that actually cross
links, per device, ring-algorithm steady state ~ payload size):

  all-reduce        operand bytes (2(N-1)/N ~ 2x payload; we report 1x
                    payload and fold algorithm factors into link_bw)
  all-gather        result bytes  (what the device must receive)
  reduce-scatter    operand bytes (what the device must send)
  all-to-all        operand bytes
  collective-permute operand bytes
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops where the *result* is the received payload
_USE_RESULT = {"all-gather"}


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_instr(line: str, op: str) -> Tuple[str, str]:
    """Return (result_text, operand_text) for '%x = <res> op(<args>)'."""
    key = f" {op}("
    pos = line.find(key)
    if pos < 0:
        key = f"= {op}("
        pos = line.find(key)
        if pos < 0:
            return "", ""
        res_text = ""
    else:
        eq = line.find(" = ")
        res_text = line[eq + 3: pos] if eq >= 0 else ""
    start = line.find("(", pos)
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return res_text, line[start + 1: end]


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind {count, bytes} from a (post-SPMD) HLO module text."""
    stats: Dict[str, Dict[str, float]] = {
        op: {"count": 0, "bytes": 0.0} for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        for op in _COLLECTIVES:
            # match the op as an instruction, not a metadata mention
            if f" {op}(" in s or f"= {op}(" in s:
                if f"{op}-start" in s and op + "-start(" not in s:
                    pass
                res_text, arg_text = _split_instr(s, op)
                if not arg_text and not res_text:
                    continue
                payload = _shapes_bytes(
                    res_text if op in _USE_RESULT else arg_text)
                # async pairs (-start/-done) would double count; the
                # "= op(" match only hits the sync or -start form once.
                stats[op]["count"] += 1
                stats[op]["bytes"] += payload
                break
        else:
            # async forms: all-gather-start etc.
            for op in _COLLECTIVES:
                if f" {op}-start(" in s or f"= {op}-start(" in s:
                    res_text, arg_text = _split_instr(s, f"{op}-start")
                    payload = _shapes_bytes(
                        res_text if op in _USE_RESULT else arg_text)
                    stats[op]["count"] += 1
                    stats[op]["bytes"] += payload
                    break
    return stats


def collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"=\s+(?:\([^)]*\)\s+)?{re.escape(opname)}\(",
                          hlo_text))
