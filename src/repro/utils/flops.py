"""Analytic FLOPs / bytes accounting (fvcore-equivalent, pure python).

Conventions (match fvcore's flop_count and the roofline spec):
  * one multiply-add = 2 FLOPs,
  * ``fwd`` counts the forward pass per *item* (image / sequence),
  * training work = fwd + bwd ≈ 3 × fwd (bwd wrt inputs + wrt weights),
  * MODEL_FLOPS for LM rooflines = 6 · N_params · tokens (dense) or
    6 · N_active · tokens (MoE), per the Kaplan/Chinchilla convention.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

TRAIN_MULT = 3.0           # fwd + bwd(inputs) + bwd(weights)


def matmul_flops(m: float, k: float, n: float) -> float:
    """C[m,n] = A[m,k] @ B[k,n]: 2*m*k*n FLOPs."""
    return 2.0 * m * k * n


def conv2d_flops(h_out: float, w_out: float, c_in: float, c_out: float,
                 kh: int, kw: int, groups: int = 1) -> float:
    """Per-image conv2d forward FLOPs (2 per MAC)."""
    return 2.0 * h_out * w_out * c_out * (c_in / groups) * kh * kw


def attention_flops(seq_q: float, seq_kv: float, n_heads: float,
                    d_head: float, causal: bool = False,
                    window: Optional[int] = None) -> float:
    """QK^T + AV matmul FLOPs for one sequence (logits+probs ignored)."""
    if window is not None and window < seq_kv:
        # sliding window: each query attends to <= window keys
        eff = seq_q * min(window, seq_kv)
    elif causal and seq_q == seq_kv:
        eff = seq_q * seq_kv / 2.0
    else:
        eff = seq_q * seq_kv
    return 2.0 * 2.0 * n_heads * eff * d_head   # 2 matmuls x 2 FLOP/MAC


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """One cuttable layer of a sequential model (splitting.py consumes)."""

    name: str
    fwd_flops: float            # per item, forward only
    param_bytes: float          # segment-handoff payload contribution
    out_bits: float             # boundary activation bits per item if cut AFTER this layer
    # Active params actually touched per item (== param count for dense,
    # top_k/E fraction for MoE). Used for MODEL_FLOPS.
    active_param_count: float = 0.0
    param_count: float = 0.0


def total_fwd_flops(layers: Sequence[LayerCost]) -> float:
    return sum(l.fwd_flops for l in layers)


def total_param_bytes(layers: Sequence[LayerCost]) -> float:
    return sum(l.param_bytes for l in layers)


# --------------------------------------------------------------------------
# Paper models: autoencoder (Fig. 3 top) and ResNet-18 (Fig. 3 bottom).
# --------------------------------------------------------------------------

def autoencoder_layer_costs(img: int = 224, base: int = 16,
                            latent_ch: int = 3, act_bits: int = 32) -> List[LayerCost]:
    """Conv autoencoder 224x224x3 -> 7x7xlatent_ch (paper §V-A geometry).

    Encoder: 5 stride-2 conv stages 224->112->56->28->14->7;
    decoder mirrors with transposed convs. The 7x7xlatent latent at 32 bit
    = 4.7 kbit matches the paper's D_tx.
    """
    layers: List[LayerCost] = []
    chans = [3, base, base * 2, base * 4, base * 8, latent_ch]
    res = img
    for i in range(5):
        c_in, c_out = chans[i], chans[i + 1]
        res = res // 2
        f = conv2d_flops(res, res, c_in, c_out, 3, 3)
        p = (c_in * c_out * 9 + c_out) * 4.0
        layers.append(LayerCost(
            name=f"enc{i}", fwd_flops=f, param_bytes=p,
            out_bits=res * res * c_out * act_bits,
            param_count=c_in * c_out * 9 + c_out,
            active_param_count=c_in * c_out * 9 + c_out))
    dchans = [latent_ch, base * 8, base * 4, base * 2, base, 3]
    for i in range(5):
        c_in, c_out = dchans[i], dchans[i + 1]
        res = res * 2
        f = conv2d_flops(res, res, c_in, c_out, 3, 3)
        p = (c_in * c_out * 9 + c_out) * 4.0
        layers.append(LayerCost(
            name=f"dec{i}", fwd_flops=f, param_bytes=p,
            out_bits=res * res * c_out * act_bits,
            param_count=c_in * c_out * 9 + c_out,
            active_param_count=c_in * c_out * 9 + c_out))
    return layers


def resnet18_layer_costs(img: int = 224, n_classes: int = 1000,
                         act_bits: int = 32) -> List[LayerCost]:
    """ResNet-18 stages as cuttable units (stem, 4 stages x 2 blocks, head).

    The paper's Table II cut points l1/l2/l3 correspond to cutting after
    stage1 / stage2 / stage3 (out_bits 6.42 / 3.21 / 1.61 Mbit at 32-bit
    activations: 56*56*64=200704 datum -> x32 = 6.42 Mb, etc.).
    """
    layers: List[LayerCost] = []

    def block(name, res, c_in, c_out, stride, downsample):
        f = conv2d_flops(res, res, c_in, c_out, 3, 3)
        f += conv2d_flops(res, res, c_out, c_out, 3, 3)
        p = (c_in * c_out + c_out * c_out) * 9 * 4.0 + 4 * c_out * 4.0
        if downsample:
            f += conv2d_flops(res, res, c_in, c_out, 1, 1)
            p += c_in * c_out * 4.0
        n_params = p / 4.0
        layers.append(LayerCost(name=name, fwd_flops=f, param_bytes=p,
                                out_bits=res * res * c_out * act_bits,
                                param_count=n_params, active_param_count=n_params))

    r = img // 2                       # stem: 7x7/2 conv + maxpool/2
    f_stem = conv2d_flops(r, r, 3, 64, 7, 7)
    layers.append(LayerCost("stem", f_stem, (3 * 64 * 49 + 2 * 64) * 4.0,
                            (img // 4) ** 2 * 64 * act_bits,
                            param_count=3 * 64 * 49, active_param_count=3 * 64 * 49))
    r = img // 4
    block("s1b1", r, 64, 64, 1, False)
    block("s1b2", r, 64, 64, 1, False)
    r //= 2
    block("s2b1", r, 64, 128, 2, True)
    block("s2b2", r, 128, 128, 1, False)
    r //= 2
    block("s3b1", r, 128, 256, 2, True)
    block("s3b2", r, 256, 256, 1, False)
    r //= 2
    block("s4b1", r, 256, 512, 2, True)
    block("s4b2", r, 512, 512, 1, False)
    layers.append(LayerCost("head", 2.0 * 512 * n_classes, 512 * n_classes * 4.0,
                            n_classes * act_bits,
                            param_count=512 * n_classes,
                            active_param_count=512 * n_classes))
    return layers


# --------------------------------------------------------------------------
# LM architectures: per-block analytic FLOPs from an ArchConfig-like object.
# --------------------------------------------------------------------------

def lm_block_fwd_flops(d_model: int, n_heads: int, n_kv_heads: int,
                       d_ff: int, seq: int, block_kind: str = "attn",
                       n_experts: int = 0, top_k: int = 0,
                       d_head: Optional[int] = None,
                       ssm_state: int = 64, causal: bool = True,
                       window: Optional[int] = None,
                       mlp_kind: str = "swiglu") -> float:
    """Forward FLOPs for one block processing a whole sequence of length seq."""
    dh = d_head or (d_model // n_heads)
    f = 0.0
    if block_kind in ("attn", "attn_dense", "shared_attn"):
        # projections: q (H*dh), k,v (KV*dh), o (H*dh)
        f += matmul_flops(seq, d_model, (2 * n_heads + 2 * n_kv_heads) * dh)
        f += attention_flops(seq, seq, n_heads, dh, causal=causal, window=window)
    elif block_kind == "mamba2":
        d_inner = 2 * d_model
        f += matmul_flops(seq, d_model, 2 * d_inner)          # in_proj (x, z)
        f += 2.0 * seq * d_inner * 4                          # conv1d k=4
        f += matmul_flops(seq, d_inner, 2 * ssm_state + 1)    # B, C, dt
        f += 6.0 * seq * d_inner * ssm_state                  # selective scan
        f += matmul_flops(seq, d_inner, d_model)              # out_proj
        return f                                              # no separate FFN
    elif block_kind == "mlstm":
        d_inner = 2 * d_model
        f += matmul_flops(seq, d_model, 3 * d_inner)          # q,k,v proj
        f += 6.0 * seq * d_inner * dh                         # matrix-memory update
        f += matmul_flops(seq, d_inner, d_model)
        return f
    elif block_kind == "slstm":
        f += matmul_flops(seq, d_model, 4 * d_model) * 2      # gates in+rec
        f += 10.0 * seq * d_model
        return f
    # FFN part
    if n_experts and top_k:
        f += matmul_flops(seq, d_model, n_experts)            # router
        f += top_k * 3.0 * matmul_flops(seq, d_model, d_ff)   # gate/up/down per active expert
    elif d_ff:
        n_mm = 3.0 if mlp_kind == "swiglu" else 2.0
        f += n_mm * matmul_flops(seq, d_model, d_ff)          # SwiGLU / GELU MLP
    return f


def lm_embed_head_fwd_flops(d_model: int, vocab: int, seq: int) -> float:
    """Output head matmul (embedding lookup is a gather ~0 FLOPs)."""
    return matmul_flops(seq, d_model, vocab)
