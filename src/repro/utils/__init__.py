from repro.utils.treeutil import (
    tree_bytes,
    tree_count_params,
    tree_flatten_with_names,
    tree_global_norm,
)
