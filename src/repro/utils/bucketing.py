"""Shared size-bucketing schedule for jit recompile control.

One copy of the padding schedule used by both the fused pass engine
(scan step counts, :mod:`repro.core.sl_step`) and the JAX solver
backend (batch sizes, :mod:`repro.core.resource_opt_jax`): exact powers
of two up to 16, then 1/8-octave granularity.  Keeping it in one place
keeps the two engines' recompile-count guarantees in sync.
"""
from __future__ import annotations


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


def bucket_size(n: int) -> int:
    """Padded size: powers of two up to 16, then 1/8-octave steps.

    Pure pow2 bucketing wastes up to ~2x compute on padding (n=65 would
    pad to 128).  Above 16 we round up to a multiple of next_pow2(n)/8
    instead: still O(1) distinct compilations per octave, but padding is
    bounded at 25% worst-case (typically <12%).
    """
    if n <= 16:
        return next_pow2(n)
    gran = next_pow2(n) // 8
    return -(-n // gran) * gran
