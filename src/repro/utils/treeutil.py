"""Pytree helpers used across the framework (no flax/optax available)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_flatten_with_names(tree, prefix: str = ""):
    """Yield (dotted_name, leaf) pairs for a nested dict/list pytree."""
    out = []

    def _walk(node, path):
        if node is None:                      # empty subtree (jax semantics)
            return
        if isinstance(node, dict):
            for k in sorted(node.keys()):
                _walk(node[k], f"{path}.{k}" if path else str(k))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                _walk(v, f"{path}[{i}]")
        else:
            out.append((path, node))

    _walk(tree, prefix)
    return out


def tree_count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def tree_bytes(tree) -> int:
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)
