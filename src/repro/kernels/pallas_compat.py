"""Version-compat shims for the Pallas TPU API.

jax renamed the TPU lowering-parameter dataclass across releases:

  * jax <= 0.4.x:  ``jax.experimental.pallas.tpu.TPUCompilerParams``
  * jax >= 0.5.x:  ``jax.experimental.pallas.tpu.CompilerParams``

Every kernel in this package goes through :func:`tpu_compiler_params`
instead of naming either class directly, so the same source runs on the
pinned toolchain (0.4.37, where only ``TPUCompilerParams`` exists) and
on newer jax without edits.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    _COMPILER_PARAMS_CLS = pltpu.CompilerParams
else:                                       # jax 0.4.x spelling
    _COMPILER_PARAMS_CLS = pltpu.TPUCompilerParams


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params object for ``pl.pallas_call``.

    Accepts the keyword arguments common to both spellings
    (``dimension_semantics=...`` etc.) and forwards them to whichever
    class this jax version provides.
    """
    return _COMPILER_PARAMS_CLS(**kwargs)
