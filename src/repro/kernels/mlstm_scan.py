"""xLSTM mLSTM chunkwise-parallel scan — Pallas TPU kernel.

The mLSTM matrix memory C_t = f_t C_{t-1} + i_t k_t v_t^T (xLSTM,
arXiv:2405.04517) is computed in its chunkwise-parallel form: within a
(chunk x P) VMEM tile the recurrence becomes a decay-masked (L x L)
attention matrix (two MXU matmuls), and the (P x P) matrix memory plus
its (P,) normalizer and scalar stabilizer are carried across the
sequential chunk axis in VMEM scratch.

Exact stabilization: unrolling the sequential stabilizer
m_t = max(lf_t + m_{t-1}, li_t) gives m_t = max(b_t + m_0,
max_{s<=t}(b_t - b_s + li_s)) with b = cumsum(log f) — so the chunkwise
row stabilizers equal the sequential ones exactly and the kernel is
bit-faithful (up to fp) to the paper's recurrence, including the
max(|den|, exp(-m_t)) normalizer.

Grid: (B, H, n_chunks), chunks sequential. VMEM note: the (P x P)
memory tile bounds P at ~512 for fp32 scratch; larger head dims tile the
value dimension (n_v_tiles grid axis would be added) — the assigned
xlstm-1.3b (P=1024) runs the jnp chunked path at train shapes and this
kernel validates the algorithm at P<=512.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref,
                  h_ref, cout_ref, nout_ref, mout_ref,
                  c_s, n_s, m_s, *, chunk: int, n_chunks: int,
                  seq_len: int, scale: float):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_s[...] = jnp.zeros_like(c_s)
        n_s[...] = jnp.zeros_like(n_s)
        m_s[...] = jnp.full_like(m_s, NEG_INF)

    qc = q_ref[0, :, 0, :].astype(jnp.float32) * scale    # (L, P)
    kc = k_ref[0, :, 0, :].astype(jnp.float32)
    vc = v_ref[0, :, 0, :].astype(jnp.float32)
    li = i_ref[0, :, 0].astype(jnp.float32)[:, None]      # (L, 1)
    lf = -jax.nn.softplus(-f_ref[0, :, 0].astype(jnp.float32))[:, None]

    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    valid = pos < seq_len
    lf = jnp.where(valid, lf, 0.0)                        # pad: f=1, i=0
    li = jnp.where(valid, li, NEG_INF)
    qc = jnp.where(valid, qc, 0.0)                        # zero OOB tails
    kc = jnp.where(valid, kc, 0.0)
    vc = jnp.where(valid, vc, 0.0)

    b = jnp.cumsum(lf, axis=0)                            # (L, 1) inclusive
    m_prev = m_s[0, 0]
    c_prev, n_prev = c_s[...], n_s[...]                   # (P,P), (P,1)

    # D_{ts} = b_t - b_s + li_s for s <= t
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    dmat = jnp.where(tri, b - b.T + li.T, NEG_INF)        # (L, L)

    m_intra = jnp.max(dmat, axis=1, keepdims=True)        # (L, 1)
    m_inter = b + m_prev
    m_row = jnp.maximum(m_intra, m_inter)                 # == sequential m_t

    s_intra = jax.lax.dot_general(qc, kc, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    w = jnp.exp(dmat - m_row)                             # (L, L)
    sw = s_intra * w
    inter_scale = jnp.exp(m_inter - m_row)                # (L, 1)

    num = (jax.lax.dot_general(sw, vc, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + inter_scale * jax.lax.dot_general(
               qc, c_prev, (((1,), (0,)), ((), ())),
               preferred_element_type=jnp.float32))       # (L, P)
    den = (jnp.sum(sw, axis=1, keepdims=True)
           + inter_scale * jax.lax.dot_general(
               qc, n_prev, (((1,), (0,)), ((), ())),
               preferred_element_type=jnp.float32))       # (L, 1)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_row))
    h_ref[0, :, 0, :] = (num / den).astype(h_ref.dtype)

    # carry update to end-of-chunk state
    btot = b[-1:, :]                                      # (1, 1)
    m_new = m_row[-1, 0]                                  # sequential m at L-1
    wk = jnp.exp(btot - b + li - m_new)                   # (L, 1)
    decay = jnp.exp(btot[0, 0] + m_prev - m_new)
    c_s[...] = decay * c_prev + jax.lax.dot_general(
        kc * wk, vc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_s[...] = decay * n_prev + jax.lax.dot_general(
        kc * wk, jnp.ones((chunk, 1), jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[0, 0] = m_new

    @pl.when(ci == n_chunks - 1)
    def _final():
        cout_ref[0, 0] = c_s[...]
        nout_ref[0, 0, :, 0] = n_s[:, 0]
        mout_ref[0, 0] = m_s[0, 0]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk_scan(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                     interpret: bool = True):
    """q,k,v: (B,S,H,P); i_pre,f_pre: (B,S,H).

    Returns (h: (B,S,H,P), (C: (B,H,P,P), n: (B,H,P,1), m: (B,H))).
    """
    B, S, H, P = q.shape
    chunk = min(chunk, S)
    n_chunks = pl.cdiv(S, chunk)
    scale = 1.0 / math.sqrt(P)

    kernel = functools.partial(_mlstm_kernel, chunk=chunk,
                               n_chunks=n_chunks, seq_len=S, scale=scale)
    h, c, n, m = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, hh, ci: (b, ci, hh, 0)),
            pl.BlockSpec((1, chunk, 1, P), lambda b, hh, ci: (b, ci, hh, 0)),
            pl.BlockSpec((1, chunk, 1, P), lambda b, hh, ci: (b, ci, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, ci: (b, ci, hh)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, ci: (b, ci, hh)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, hh, ci: (b, ci, hh, 0)),
            pl.BlockSpec((1, 1, P, P), lambda b, hh, ci: (b, hh, 0, 0)),
            pl.BlockSpec((1, 1, P, 1), lambda b, hh, ci: (b, hh, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, hh, ci: (b, hh)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), q.dtype),
            jax.ShapeDtypeStruct((B, H, P, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((P, P), jnp.float32),
            pltpu.VMEM((P, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, i_pre, f_pre)
    return h, (c, n, m)
