"""Mamba-2 SSD chunked selective scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm (Mamba-2, arXiv:2405.21060): the
sequence is tiled into (chunk x P) VMEM blocks; within a chunk the scan
is re-expressed as two MXU matmuls (an (L x L) decay-masked "attention"
for the intra-chunk term and an (L x N) x (N x P) contraction for the
inter-chunk term), while the (P x N) recurrent state is carried across
the sequential chunk axis in VMEM scratch — the HBM traffic is exactly
one pass over x/dt/B/C plus one (P x N) state, which is what makes long
sequences memory-optimal.

Recurrence (per head): h_t = exp(a·dt_t) h_{t-1} + dt_t · x_t ⊗ b_t;
y_t = h_t c_t, with a = −exp(a_log) < 0 so every decay factor is ≤ 1
(no stabilizer needed).

Grid: (B, H, n_chunks), chunks sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, hout_ref,
                h_ref, *, chunk: int, n_chunks: int, seq_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xc = x_ref[0, :, 0, :].astype(jnp.float32)            # (L, P)
    dtc = dt_ref[0, :, 0].astype(jnp.float32)[:, None]    # (L, 1)
    bc = b_ref[0].astype(jnp.float32)                     # (L, N)
    cc = c_ref[0].astype(jnp.float32)                     # (L, N)
    a = -jnp.exp(alog_ref[0, 0].astype(jnp.float32))      # scalar < 0

    # tail padding: zero dt => identity decay, zero update; zero the data
    # tensors too (pallas pads OOB tail blocks with undefined values)
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    valid = pos < seq_len
    dtc = jnp.where(valid, dtc, 0.0)
    xc = jnp.where(valid, xc, 0.0)
    bc = jnp.where(valid, bc, 0.0)
    cc = jnp.where(valid, cc, 0.0)

    ad = a * dtc                                          # (L,1) log-decays
    cum = jnp.cumsum(ad, axis=0)                          # b_t = sum_{s<=t} ad_s

    # intra-chunk: M_{ts} = exp(b_t - b_s) (c_t . b_s) dt_s for s <= t
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    decay = jnp.exp(cum - cum.T)                          # (L, L)
    scores = jax.lax.dot_general(cc, bc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    m = jnp.where(tri, decay * scores * dtc.T, 0.0)       # (L, L)
    y = jax.lax.dot_general(m, xc, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y_t += exp(b_t) c_t . h_prev^T
    h_prev = h_ref[...]                                   # (P, N)
    y = y + jnp.exp(cum) * jax.lax.dot_general(
        cc, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h_new = exp(b_L) h_prev + x^T (b * dt * exp(b_L - b_s))
    total = cum[-1:, :]                                   # (1,1)
    w = jnp.exp(total - cum) * dtc                        # (L,1)
    h_ref[...] = (jnp.exp(total) * h_prev
                  + jax.lax.dot_general(xc, bc * w, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_chunk_scan(x, dt, a_log, b, c, *, chunk: int = 128,
                     interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); b,c: (B,S,N).

    Returns (y: (B,S,H,P), h_final: (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    n_chunks = pl.cdiv(S, chunk)
    alog2d = a_log.reshape(H, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks,
                               seq_len=S)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, h, ci: (bi, ci, h)),
            pl.BlockSpec((1, 1), lambda bi, h, ci: (h, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, N), lambda bi, h, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, h, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, alog2d, b, c)
    return y, h
