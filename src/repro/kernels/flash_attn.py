"""Flash attention forward — Pallas TPU kernel.

TPU-native adaptation (DESIGN.md §5): instead of a CUDA warp-level
softmax, the kernel streams (block_k x head_dim) K/V tiles HBM->VMEM over
the innermost ("arbitrary") grid axis while the (block_q x head_dim) Q
tile and the fp32 accumulator stay resident in VMEM; the two matmuls hit
the MXU with 128-aligned dims. GQA is handled in the *index maps* — the
K/V BlockSpecs map query-head h to kv-head h // group, so grouped heads
re-stream the same KV tiles without materializing a repeated KV tensor.

Grid: (B, H, n_q_blocks, n_kv_blocks), kv innermost sequential.
Scratch (VMEM): m (block_q,1) row max, l (block_q,1) row sum,
acc (block_q, head_dim) fp32 output accumulator.

Causal / sliding-window masking is positional (iota compare); fully
masked KV blocks are skipped with pl.when so the sequential axis does no
work outside the band — the same work-skipping the paper's deadline
optimizer assumes when it budgets W(ℓ).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale: float, causal: bool, window: Optional[int],
                block_q: int, block_k: int, n_kv: int, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Band check: skip KV blocks fully outside the (causal, window) band.
    in_band = True
    if causal:
        in_band = k_start <= q_start + block_q - 1
    if window is not None:
        in_band = jnp.logical_and(
            in_band, k_start + block_k - 1 > q_start - window)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
        # zero OOB tail rows: pallas pads the last block with undefined
        # values, and 0 * garbage in the PV matmul would poison the acc
        kv_valid = (k_start
                    + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)
                    < seq_kv)
        k = jnp.where(kv_valid, k, 0.0)
        v = jnp.where(kv_valid, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_kv                              # tail padding
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)         # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = True):
    """q: (B, H, Sq, D); k, v: (B, KV, Skv, D). Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    assert H % KV == 0
    group = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)

    n_q = pl.cdiv(Sq, block_q)
    n_kv = pl.cdiv(Skv, block_k)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv=n_kv, seq_kv=Skv)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
