"""Flash-decode attention — Pallas TPU kernel for the memory-bound cells.

decode_32k / long_500k lower a single new token against a (possibly
huge) KV cache: arithmetic intensity ~ O(1) FLOP/byte, so the kernel's
job is purely to stream the cache HBM->VMEM once at line rate. The
(1 x head_dim) query and the fp32 (m, l, acc) running softmax state stay
in VMEM across the sequential kv-block axis; per-batch valid cache
lengths mask the tail.

Grid: (B, H, n_kv_blocks), kv innermost sequential. GQA via index maps
(h -> h // group), same as flash_attn.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_k: int, n_kv: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * block_k
    valid_len = len_ref[0, 0]

    @pl.when(k_start < valid_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        kv_valid = (k_start
                    + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)
                    < valid_len)
        k = jnp.where(kv_valid, k, 0.0)
        v = jnp.where(kv_valid, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(kpos < valid_len, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, block_k: int = 512,
                     interpret: bool = True):
    """q: (B, H, 1, D); k, v: (B, KV, S, D); lengths: (B,) valid cache len."""
    B, H, _, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    group = H // KV
    block_k = min(block_k, S)
    n_kv = pl.cdiv(S, block_k)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_k=block_k, n_kv=n_kv)
    lengths2d = lengths.reshape(B, 1).astype(jnp.int32)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ki: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths2d, q, k, v)
