"""SL-boundary int8 quantization — Pallas TPU kernel (beyond-paper).

The split-learning boundary payload (activations down, gradients up) is
the paper's D_tx; quantizing it int8 cuts comm energy ~4x (eq. 9). The
kernel fuses the per-row abs-max reduction with the scale/round/clip in
one VMEM pass so the boundary tensor is read from HBM exactly once —
on the satellite's power budget, memory traffic is energy.

Grid: (n_row_blocks,), each block (block_rows x d) resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_rows(x, *, block_rows: int = 256, interpret: bool = True):
    """x: (rows, d) -> (q int8 (rows, d), scale fp32 (rows, 1))."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    n = pl.cdiv(rows, block_rows)
    return pl.pallas_call(
        _quant_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
