"""Public kernel ops: jit'd wrappers with platform dispatch.

Two execution paths per op:
  * Pallas kernel (TPU target; interpret=True on CPU in tests) — the
    deployment fast path,
  * an algorithm-equivalent chunked ``lax.scan`` jnp path — runs anywhere,
    is differentiable (custom_vjp flash backward for attention), and is
    what the multi-pod dry-run lowers so the compiled HLO's byte/flop
    traffic matches the kernel's streaming behavior rather than a naive
    O(S^2)-materialized oracle.

``use_pallas=None`` auto-selects: pallas iff the default backend is TPU.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attn as _decode_pallas
from repro.kernels import flash_attn as _flash_pallas
from repro.kernels import mamba_scan as _mamba_pallas
from repro.kernels import mlstm_scan as _mlstm_pallas
from repro.kernels import split_quant as _quant_pallas

NEG_INF = -1e30

# Dry-run cost-measurement mode: unroll the internal lax.scans so XLA's
# cost analysis (which counts a while body once) sees the true work.
_INNER_UNROLL = False


def set_inner_unroll(flag: bool):
    global _INNER_UNROLL
    _INNER_UNROLL = bool(flag)


def _inner_unroll():
    return True if _INNER_UNROLL else 1


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_seq(x, axis: int, block: int):
    s = x.shape[axis]
    pad = (-s) % block
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ==========================================================================
# Flash attention (training / prefill): chunked, triangular-skipping,
# custom_vjp with flash-style recomputing backward.
# ==========================================================================

def _attn_fwd_blocks(qr, kb, vb, q_start, k_starts, *, scale, causal,
                     window, seq_kv, compute_dtype=jnp.float32):
    """Online-softmax over a list of KV blocks for one Q block.

    qr: (B, KV, g, Lq, D); kb/vb: (n, B, KV, Lk, D) stacked blocks.
    Returns (o, lse) with lse = m + log l. ``compute_dtype`` sets the
    streamed-operand precision (bf16 halves the HBM traffic of the
    score/probability tensors; accumulation stays fp32).
    """
    B, KV, g, Lq, D = qr.shape
    Lk = kb.shape[3]
    qr = qr.astype(compute_dtype)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, k_start = inp
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qr, kblk.astype(compute_dtype),
                       preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (Lq, Lk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (Lq, Lk), 1)
        mask = kpos < seq_kv
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(compute_dtype),
            vblk.astype(compute_dtype),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, g, Lq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, g, Lq, 1), jnp.float32)
    a0 = jnp.zeros((B, KV, g, Lq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, k_starts),
                                  unroll=_inner_unroll())
    l = jnp.maximum(l, 1e-30)
    return acc / l, m + jnp.log(l)


def _kv_range(qi: int, n_kv: int, *, causal: bool, window: Optional[int],
              block_q: int, block_k: int) -> Tuple[int, int]:
    """Static KV block range [lo, hi) in-band for Q block qi."""
    hi = n_kv
    if causal:
        hi = min(n_kv, ((qi + 1) * block_q + block_k - 1) // block_k)
    lo = 0
    if window is not None:
        lo = max(0, (qi * block_q - window + 1) // block_k)
    return lo, hi


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _chunked_attention(q, k, v, causal, window, block_q, block_k,
                       compute_dtype):
    o, _ = _chunked_attention_fwd_impl(q, k, v, causal, window,
                                       block_q, block_k, compute_dtype)
    return o


def _chunked_attention_fwd_impl(q, k, v, causal, window, block_q, block_k,
                                compute_dtype=jnp.float32):
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    n_q = -(-Sq // block_q)
    n_kv = -(-Skv // block_k)

    qp = _pad_seq(q, 2, block_q).reshape(B, KV, g, n_q, block_q, D)
    kp = _pad_seq(k, 2, block_k).reshape(B, KV, n_kv, block_k, D)
    vp = _pad_seq(v, 2, block_k).reshape(B, KV, n_kv, block_k, D)
    k_starts_all = jnp.arange(n_kv, dtype=jnp.int32) * block_k

    os, lses = [], []
    for qi in range(n_q):                       # static triangular skipping
        lo, hi = _kv_range(qi, n_kv, causal=causal, window=window,
                           block_q=block_q, block_k=block_k)
        kb = jnp.moveaxis(kp[:, :, lo:hi], 2, 0)
        vb = jnp.moveaxis(vp[:, :, lo:hi], 2, 0)
        o_qi, lse_qi = _attn_fwd_blocks(
            qp[:, :, :, qi], kb, vb, qi * block_q, k_starts_all[lo:hi],
            scale=scale, causal=causal, window=window, seq_kv=Skv,
            compute_dtype=compute_dtype)
        os.append(o_qi)
        lses.append(lse_qi)
    o = jnp.stack(os, axis=3)                   # (B,KV,g,n_q,bq,D)
    lse = jnp.stack(lses, axis=3)
    o = o.reshape(B, H, n_q * block_q, D)[:, :, :Sq].astype(q.dtype)
    lse = lse.reshape(B, H, n_q * block_q, 1)[:, :, :Sq]
    return o, lse


def _chunked_attention_fwd(q, k, v, causal, window, block_q, block_k,
                           compute_dtype):
    o, lse = _chunked_attention_fwd_impl(q, k, v, causal, window,
                                         block_q, block_k, compute_dtype)
    return o, (q, k, v, o, lse)


def _chunked_attention_bwd(causal, window, block_q, block_k, compute_dtype,
                           res, do):
    q, k, v, o, lse = res
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    n_q = -(-Sq // bq)
    n_kv = -(-Skv // bk)

    cd = compute_dtype
    qp = _pad_seq(q, 2, bq).reshape(B, KV, g, n_q, bq, D).astype(cd)
    kp = _pad_seq(k, 2, bk).reshape(B, KV, n_kv, bk, D).astype(cd)
    vp = _pad_seq(v, 2, bk).reshape(B, KV, n_kv, bk, D).astype(cd)
    dop = _pad_seq(do, 2, bq).reshape(B, KV, g, n_q, bq, D).astype(cd)
    op = _pad_seq(o, 2, bq).reshape(B, KV, g, n_q, bq, D).astype(cd)
    lsep = _pad_seq(lse, 2, bq).reshape(B, KV, g, n_q, bq, 1)
    delta = jnp.sum(dop.astype(jnp.float32) * op.astype(jnp.float32),
                    axis=-1, keepdims=True)             # (B,KV,g,nq,bq,1)

    dq = jnp.zeros(qp.shape, jnp.float32)
    dk = jnp.zeros(kp.shape, jnp.float32)
    dv = jnp.zeros(vp.shape, jnp.float32)

    for qi in range(n_q):
        lo, hi = _kv_range(qi, n_kv, causal=causal, window=window,
                           block_q=bq, block_k=bk)
        q_qi = qp[:, :, :, qi]
        do_qi = dop[:, :, :, qi]
        lse_qi = lsep[:, :, :, qi]
        delta_qi = delta[:, :, :, qi]

        def body(dq_acc, inp):
            kblk, vblk, k_start = inp
            s = jnp.einsum("bkgqd,bkcd->bkgqc", q_qi, kblk,
                           preferred_element_type=jnp.float32) * scale
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = kpos < Skv
            if causal:
                mask = mask & (kpos <= qpos)
            if window is not None:
                mask = mask & (kpos > qpos - window)
            s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lse_qi).astype(cd)             # (B,KV,g,bq,bk)
            dp = jnp.einsum("bkgqd,bkcd->bkgqc", do_qi, vblk,
                            preferred_element_type=jnp.float32)
            ds = (p.astype(jnp.float32) * (dp - delta_qi) * scale).astype(cd)
            dq_acc = dq_acc + jnp.einsum("bkgqc,bkcd->bkgqd", ds, kblk,
                                         preferred_element_type=jnp.float32)
            dkb = jnp.einsum("bkgqc,bkgqd->bkcd", ds, q_qi,
                             preferred_element_type=jnp.float32)
            dvb = jnp.einsum("bkgqc,bkgqd->bkcd", p, do_qi,
                             preferred_element_type=jnp.float32)
            return dq_acc, (dkb, dvb)

        kb = jnp.moveaxis(kp[:, :, lo:hi], 2, 0)
        vb = jnp.moveaxis(vp[:, :, lo:hi], 2, 0)
        k_starts = (jnp.arange(lo, hi, dtype=jnp.int32)) * bk
        dq_qi, (dkbs, dvbs) = jax.lax.scan(
            body, jnp.zeros(q_qi.shape, jnp.float32), (kb, vb, k_starts),
            unroll=_inner_unroll())
        dq = dq.at[:, :, :, qi].set(dq_qi)
        dk = dk.at[:, :, lo:hi].add(jnp.moveaxis(dkbs, 0, 2))
        dv = dv.at[:, :, lo:hi].add(jnp.moveaxis(dvbs, 0, 2))

    dq = dq.reshape(B, H, n_q * bq, D)[:, :, :Sq].astype(q.dtype)
    dk = dk.reshape(B, KV, n_kv * bk, D)[:, :, :Skv].astype(k.dtype)
    dv = dv.reshape(B, KV, n_kv * bk, D)[:, :, :Skv].astype(v.dtype)
    return dq, dk, dv


_chunked_attention.defvjp(_chunked_attention_fwd, _chunked_attention_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512,
                    use_pallas: Optional[bool] = None,
                    compute_dtype=jnp.float32):
    """q: (B,H,Sq,D); k,v: (B,KV,Skv,D) -> (B,H,Sq,D)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _flash_pallas.flash_attention_fwd(
            q, k, v, causal=causal, window=window,
            interpret=not _on_tpu())
    return _chunked_attention(q, k, v, causal, window, block_q, block_k,
                              compute_dtype)


# ==========================================================================
# Decode attention (one token vs a KV cache).
# ==========================================================================

def decode_attention(q, k, v, lengths, *,
                     use_pallas: Optional[bool] = None):
    """q: (B,H,1,D); k,v: (B,KV,S,D); lengths: (B,) -> (B,H,1,D).

    The jnp path is a single masked pass over the cache — the op is
    memory-bound (one read of K and V), which the HLO then reflects.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _decode_pallas.decode_attention(
            q, k, v, lengths, interpret=not _on_tpu())
    B, H, _, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, KV, g, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qr, k.astype(jnp.float32)) * scale
    kpos = jnp.arange(S)[None, None, None, :]
    s = jnp.where(kpos < lengths[:, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, 1, D).astype(q.dtype)


# ==========================================================================
# Mamba-2 SSD chunked scan.
# ==========================================================================

def mamba_scan(x, dt, a_log, b, c, *, chunk: int = 128,
               use_pallas: Optional[bool] = None, unroll: int = 1):
    """Returns (y: (B,S,H,P), h_final: (B,H,P,N)). Differentiable jnp path."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _mamba_pallas.mamba_chunk_scan(
            x, dt, a_log, b, c, chunk=chunk, interpret=not _on_tpu())
    return _mamba_chunked_jnp(x, dt, a_log, b, c, chunk=chunk, unroll=unroll)


def _mamba_chunked_jnp(x, dt, a_log, b, c, *, chunk: int, unroll: int = 1):
    B, S, H, P = x.shape
    N = b.shape[-1]
    L = min(chunk, S)
    n = -(-S // L)
    xf = _pad_seq(x.astype(jnp.float32), 1, L).reshape(B, n, L, H, P)
    dtf = _pad_seq(dt.astype(jnp.float32), 1, L).reshape(B, n, L, H)
    bf = _pad_seq(b.astype(jnp.float32), 1, L).reshape(B, n, L, N)
    cf = _pad_seq(c.astype(jnp.float32), 1, L).reshape(B, n, L, N)
    a = -jnp.exp(a_log.astype(jnp.float32))                 # (H,)

    tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])

    def body(h, inp):
        xc, dtc, bc, cc = inp                               # (B,L,H,P),(B,L,H),(B,L,N),(B,L,N)
        ad = dtc * a                                        # (B,L,H)
        cum = jnp.cumsum(ad, axis=1)                        # (B,L,H)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,L,L,H)
        scores = jnp.einsum("btn,bsn->bts", cc, bc)         # (B,L,L)
        m = jnp.where(tri[None, :, :, None],
                      decay * scores[..., None] * dtc[:, None], 0.0)
        y = jnp.einsum("btsh,bshp->bthp", m, xc)
        y = y + jnp.exp(cum)[..., None] * jnp.einsum("btn,bhpn->bthp",
                                                     cc, h)
        total = cum[:, -1:, :]                              # (B,1,H)
        w = jnp.exp(total - cum) * dtc                      # (B,L,H)
        h = (jnp.exp(total)[:, 0, :, None, None] * h
             + jnp.einsum("bshp,bsn,bsh->bhpn", xc, bc, w))
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    hT, ys = jax.lax.scan(body, h0, xs, unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, n * L, H, P)[:, :S]
    return y.astype(x.dtype), hT


def mamba_decode_step(h, x_t, dt_t, a_log, b_t, c_t):
    """Single-token state update. h: (B,H,P,N); returns (y_t, h_new)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(a[None] * dt_t.astype(jnp.float32))     # (B,H)
    upd = (dt_t[..., None, None] * x_t[..., None].astype(jnp.float32)
           * b_t[:, None, None, :].astype(jnp.float32))
    h = h * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, c_t.astype(jnp.float32))
    return y.astype(x_t.dtype), h


# ==========================================================================
# mLSTM chunkwise scan.
# ==========================================================================

def mlstm_scan(q, k, v, i_pre, f_pre, *, chunk: int = 256,
               use_pallas: Optional[bool] = None, unroll: int = 1):
    """Returns (h: (B,S,H,P), state (C, n, m)). Differentiable jnp path."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        h, (C, n, m) = _mlstm_pallas.mlstm_chunk_scan(
            q, k, v, i_pre, f_pre, chunk=chunk, interpret=not _on_tpu())
        return h, (C, n[..., 0], m)
    return _mlstm_chunked_jnp(q, k, v, i_pre, f_pre, chunk=chunk,
                              unroll=unroll)


def _mlstm_chunked_jnp(q, k, v, i_pre, f_pre, *, chunk: int,
                       unroll: int = 1):
    B, S, H, P = q.shape
    L = min(chunk, S)
    n_chunks = -(-S // L)
    scale = 1.0 / math.sqrt(P)

    def blk(t):
        return _pad_seq(t.astype(jnp.float32), 1, L)

    qf = blk(q).reshape(B, n_chunks, L, H, P) * scale
    kf = blk(k).reshape(B, n_chunks, L, H, P)
    vf = blk(v).reshape(B, n_chunks, L, H, P)
    lif = blk(i_pre).reshape(B, n_chunks, L, H)
    pad = (-S) % L
    if pad:   # padded tail: i = -inf (no update), f = 1 (identity decay)
        tail_mask = jnp.arange(n_chunks * L).reshape(n_chunks, L) < S
        lif = jnp.where(tail_mask[None, :, :, None], lif, NEG_INF)
    lff = -jax.nn.softplus(-blk(f_pre).reshape(B, n_chunks, L, H))
    if pad:
        lff = jnp.where(tail_mask[None, :, :, None], lff, 0.0)

    tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])

    def body(carry, inp):
        C, n, m = carry                                      # (B,H,P,P),(B,H,P),(B,H)
        qc, kc, vc, li, lf = inp
        bcum = jnp.cumsum(lf, axis=1)                        # (B,L,H)
        dmat = jnp.where(tri[None, :, :, None],
                         bcum[:, :, None, :] - bcum[:, None, :, :]
                         + li[:, None, :, :], NEG_INF)       # (B,L,L,H)
        m_intra = jnp.max(dmat, axis=2)                      # (B,L,H)
        m_inter = bcum + m[:, None, :]
        m_row = jnp.maximum(m_intra, m_inter)                # (B,L,H)
        s = jnp.einsum("bthp,bshp->btsh", qc, kc)
        w = jnp.exp(dmat - m_row[:, :, None, :])
        sw = s * w
        inter = jnp.exp(m_inter - m_row)                     # (B,L,H)
        num = (jnp.einsum("btsh,bshp->bthp", sw, vc)
               + inter[..., None] * jnp.einsum("bthp,bhpv->bthv", qc, C))
        den = (jnp.sum(sw, axis=2)
               + inter * jnp.einsum("bthp,bhp->bth", qc, n))
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_row))
        h = num / den[..., None]

        btot = bcum[:, -1, :]                                # (B,H)
        m_new = m_row[:, -1, :]                              # sequential m
        wk = jnp.exp(btot[:, None, :] - bcum + li)           # (B,L,H)
        wk = wk * jnp.exp(-m_new)[:, None, :]
        decay = jnp.exp(btot + m - m_new)                    # (B,H)
        C = (decay[..., None, None] * C
             + jnp.einsum("bshp,bshv->bhpv", kc * wk[..., None], vc))
        n = decay[..., None] * n + jnp.sum(kc * wk[..., None], axis=1)
        return (C, n, m_new), h

    C0 = jnp.zeros((B, H, P, P), jnp.float32)
    n0 = jnp.zeros((B, H, P), jnp.float32)
    m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qf, kf, vf, lif, lff))
    (CT, nT, mT), hs = jax.lax.scan(body, (C0, n0, m0), xs,
                                    unroll=unroll)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, n_chunks * L, H, P)[:, :S]
    return h.astype(q.dtype), (CT, nT, mT)


def mlstm_decode_step(state, q_t, k_t, v_t, i_t, f_t):
    """Single-token mLSTM update. state = (C, n, m); q_t..: (B,H,P)."""
    C, n, m = state
    P = q_t.shape[-1]
    scale = 1.0 / math.sqrt(P)
    qf = q_t.astype(jnp.float32) * scale
    kf, vf = k_t.astype(jnp.float32), v_t.astype(jnp.float32)
    li = i_t.astype(jnp.float32)
    lf = -jax.nn.softplus(-f_t.astype(jnp.float32))
    m_new = jnp.maximum(lf + m, li)
    fs = jnp.exp(lf + m - m_new)
    iz = jnp.exp(li - m_new)
    C = fs[..., None, None] * C + iz[..., None, None] * (
        kf[..., None] * vf[..., None, :])
    n = fs[..., None] * n + iz[..., None] * kf
    num = jnp.einsum("bhkv,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q_t.dtype)
    return h, (C, n, m_new)


# ==========================================================================
# sLSTM sequential scan (true recurrence; lives here so the dry-run can
# micro-measure its per-step body cost with unroll extrapolation).
# ==========================================================================

def slstm_scan(xproj, wh, c0, n0, h0, m0, *, unroll: int = 1):
    """xproj: (B,S,4d) precomputed input projections (+bias); wh: (d,4d).

    Stabilized exponential-gating sLSTM. Returns (h: (B,S,d), carry).
    """
    def step(carry, xp):
        c, n, h, m = carry
        g = xp + h @ wh
        zt, it, ft, ot = jnp.split(g, 4, axis=-1)
        z = jnp.tanh(zt)
        o = jax.nn.sigmoid(ot)
        lf = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(lf + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(lf + m - m_new)
        c = f_s * c + i_s * z
        n = jnp.maximum(f_s * n + i_s, jnp.exp(-m_new))
        h = o * (c / n)
        return (c, n, h, m_new), h

    carry, hs = jax.lax.scan(step, (c0, n0, h0, m0),
                             jnp.moveaxis(xproj, 1, 0), unroll=unroll)
    return jnp.moveaxis(hs, 0, 1), carry


# ==========================================================================
# SL boundary quantization (straight-through for training).
# ==========================================================================

def quantize_boundary(x, *, use_pallas: Optional[bool] = None):
    """Per-row int8 quantization of a 2D-flattenable tensor."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        q, s = _quant_pallas.quantize_rows(x2, interpret=not _on_tpu())
    else:
        from repro.kernels import ref
        q, s = ref.quantize_rows(x2)
    return q.reshape(shape), s.reshape(shape[:-1] + (1,))


def dequantize_boundary(q, s, dtype=jnp.float32):
    return (q.astype(jnp.float32) * s).astype(dtype)


@jax.custom_vjp
def ste_quantize(x):
    """Quantize-dequantize with straight-through gradients (training)."""
    q, s = quantize_boundary(x, use_pallas=False)
    return dequantize_boundary(q, s, x.dtype)


def _ste_fwd(x):
    return ste_quantize(x), None


def _ste_bwd(_, g):
    return (g,)


ste_quantize.defvjp(_ste_fwd, _ste_bwd)
