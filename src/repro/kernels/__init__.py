"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three layers:
  <name>.py  - pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target;
               validated on CPU via interpret=True),
  ops.py     - public jit'd wrappers; dispatch pallas-on-TPU vs an
               algorithm-equivalent chunked lax.scan jnp path on CPU so the
               dry-run HLO reflects the kernel's streaming behavior,
  ref.py     - pure-jnp naive oracles for allclose sweeps.
"""
