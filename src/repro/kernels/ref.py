"""Pure-jnp naive oracles for every kernel (full materialization /
sequential scans, fp32). These define correctness; kernels and the
chunked ops paths are asserted allclose against these in tests.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Attention (flash_attn / decode_attn oracle).
# --------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None,
              kv_len: Optional[jnp.ndarray] = None,
              q_offset: int | jnp.ndarray = 0):
    """Naive softmax attention with GQA.

    q: (B, H, Sq, D); k, v: (B, KV, Skv, D) with H % KV == 0.
    ``q_offset``: absolute position of q[0] (for decode: cache length).
    ``kv_len``: (B,) valid cache lengths (decode masking); None = all valid.
    """
    B, H, Sq, D = q.shape
    KV = k.shape[1]
    Skv = k.shape[2]
    g = H // KV
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / math.sqrt(D)

    qpos = jnp.arange(Sq)[:, None] + q_offset          # (Sq, 1) or (B,Sq,1)
    kpos = jnp.arange(Skv)[None, :]
    if jnp.ndim(q_offset) > 0:                          # per-batch offsets
        qpos = jnp.arange(Sq)[None, :, None] + jnp.reshape(q_offset, (-1, 1, 1))
        kpos = jnp.arange(Skv)[None, None, :]
    mask = jnp.ones((Sq, Skv), bool) if jnp.ndim(qpos) == 2 else \
        jnp.ones((B, Sq, Skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    if kv_len is not None:
        mask = mask & (kpos < jnp.reshape(kv_len, (-1, 1, 1)))
    if mask.ndim == 2:
        mask = mask[None]
    s = jnp.where(mask[:, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)                 # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


# --------------------------------------------------------------------------
# Mamba-2 SSD (mamba_scan oracle): sequential recurrence, fp32.
# --------------------------------------------------------------------------

def mamba_ssd(x, dt, a_log, b, c, h0=None):
    """h_t = exp(a*dt_t) h_{t-1} + dt_t * (b_t ⊗ x_t);  y_t = h_t c_t.

    x:  (B, S, H, P)   per-head channels
    dt: (B, S, H)      positive step sizes
    a_log: (H,)        A = -exp(a_log) (negative decay rate)
    b, c: (B, S, N)    shared across heads (n_groups=1)
    h0: (B, H, P, N) initial state. Returns (y, h_final).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))             # (H,)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    bf, cf = b.astype(jnp.float32), c.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                            # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(a[None] * dtt)                   # (B,H)
        upd = (dtt[..., None, None] * xt[..., None]
               * bt[:, None, None, :])                   # (B,H,P,N)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hT


# --------------------------------------------------------------------------
# xLSTM mLSTM (mlstm_scan oracle): sequential stabilized recurrence.
# --------------------------------------------------------------------------

def mlstm(q, k, v, i_pre, f_pre, state=None):
    """Stabilized mLSTM recurrence (xLSTM eq. 19-27).

    q,k,v: (B, S, H, P); i_pre,f_pre: (B, S, H) pre-activations.
    state: (C, n, m) with C (B,H,P,P), n (B,H,P), m (B,H). Returns (h, state).
    """
    B, S, H, P = q.shape
    scale = 1.0 / math.sqrt(P)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    log_i = i_pre.astype(jnp.float32)
    log_f = -jax.nn.softplus(-f_pre.astype(jnp.float32))   # log sigmoid

    if state is None:
        C0 = jnp.zeros((B, H, P, P), jnp.float32)           # keys x values
        n0 = jnp.zeros((B, H, P), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, li, lf = inp                             # (B,H,P)x3,(B,H)x2
        m_new = jnp.maximum(lf + m, li)
        fs = jnp.exp(lf + m - m_new)[..., None]
        iz = jnp.exp(li - m_new)[..., None]
        C = fs[..., None] * C + iz[..., None] * (kt[..., None] * vt[..., None, :])
        n = fs * n + iz * kt
        num = jnp.einsum("bhkv,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qf, kf, vf, log_i, log_f))
    (CT, nT, mT), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype), (CT, nT, mT)


# --------------------------------------------------------------------------
# SL boundary int8 quantization (split_quant oracle).
# --------------------------------------------------------------------------

def quantize_rows(x):
    """Per-row symmetric int8: returns (q int8, scale fp32 per row)."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_rows(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)
