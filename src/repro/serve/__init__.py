"""Serving: KV-cache decode engine with batched requests."""
from repro.serve.engine import DecodeEngine, Request
