"""A small batched serving engine: continuous-batching decode over the
LM's KV cache (full / sliding-window / SSM-state, per architecture).

Slots hold independent requests; finished slots are refilled from the
queue without stopping the batch (continuous batching a la Orca/vLLM,
adapted to the static-shape jit step).

Prefill is BULK by default: the prompt runs through ``forward`` in
prefill mode (one call, full sequence), its cache is converted with
``cache_from_prefill`` and spliced into the slot's batch row — the
other live slots' caches are untouched.  The legacy token-by-token
loop (``prefill="loop"``) is kept only as a parity reference: it ran
one full-batch jitted step per prompt token AND wrote a zero-token
entry into every *other* live slot's cache position, which is merely
wasteful for attention rings (the garbage row is overwritten at that
slot's next real write) but corrupts recurrent state (mamba / xLSTM)
for any concurrently-live slot.

Decode attention can be routed through the Pallas flash-decode kernel
(``kernels/decode_attn.py``) with ``use_pallas=True``; the default is
the reference jnp path (``Ctx.use_pallas=False``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.layers import Ctx


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


class DecodeEngine:
    """Greedy decoding over ``n_slots`` concurrent requests."""

    def __init__(self, cfg, params, *, n_slots: int = 4, s_max: int = 512,
                 act_dtype=jnp.bfloat16, use_pallas: bool = False,
                 prefill: str = "bulk"):
        if prefill not in ("bulk", "loop"):
            raise ValueError(f"prefill must be 'bulk' or 'loop', "
                             f"got {prefill!r}")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.s_max = s_max
        self.act_dtype = act_dtype
        self.prefill_mode = prefill
        self.ctx = Ctx(cfg=cfg, mode="decode", act_dtype=act_dtype,
                       use_pallas=use_pallas)
        self.cache = lm.init_cache(cfg, n_slots, s_max, act_dtype)
        self.positions = np.zeros((n_slots,), np.int32)
        self.budget = np.zeros((n_slots,), np.int32)
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.live: List[Optional[Request]] = [None] * n_slots
        self._step = jax.jit(self._step_fn, donate_argnums=(1,))
        # jit caches one executable per distinct prompt length
        self._prefill = jax.jit(self._prefill_fn)

    # ---------------------------------------------------------------- jitted
    def _decode_fn(self, params, cache, tokens, positions):
        """One batched decode step -> (logits (B,1,V), cache). Subclasses
        (the split-serving engine) override this to change the model
        path while keeping all slot mechanics."""
        return lm.decode_step(self.cfg, params, cache, tokens,
                              positions, ctx=self.ctx)

    def _step_fn(self, params, cache, tokens, positions):
        logits, cache = self._decode_fn(params, cache, tokens, positions)
        return jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32), cache

    def _prefill_fn(self, params, tokens):
        """Bulk prefill of one prompt (1, S) -> (next_token, decode cache
        of batch 1)."""
        pctx = dataclasses.replace(self.ctx, mode="prefill")
        logits, _, caches = lm.forward(self.cfg, params, tokens, ctx=pctx,
                                       remat="none")
        cache1 = lm.cache_from_prefill(self.cfg, caches, self.s_max,
                                       self.act_dtype)
        return jnp.argmax(logits[0, -1]).astype(jnp.int32), cache1

    # ---------------------------------------------------------------- slots
    def _prefill_into_slot(self, slot: int, req: Request):
        req.out_tokens = []
        self.live[slot] = req
        self.budget[slot] = req.max_new_tokens
        if self.prefill_mode == "loop":
            self._prefill_into_slot_loop(slot, req)
            return
        nxt, cache1 = self._prefill(self.params,
                                    jnp.asarray(req.prompt)[None, :])
        # splice the single-request cache into this slot's batch row;
        # every cache leaf is (n_units, batch, ...)
        self.cache = jax.tree.map(
            lambda full, one: full.at[:, slot].set(
                one[:, 0].astype(full.dtype)),
            self.cache, cache1)
        self.positions[slot] = len(req.prompt)
        self.last_tok[slot] = int(nxt)

    def _prefill_into_slot_loop(self, slot: int, req: Request):
        """Legacy token-by-token prefill — parity reference ONLY.

        Runs one full-batch decode step per prompt token; each step also
        pushes a zero token through every other live slot, which writes
        garbage into their attention ring rows (harmless: overwritten at
        that position's next real write) and advances their recurrent
        states (NOT harmless — do not use with concurrently-live slots
        on mamba/mlstm/slstm architectures).
        """
        pos = 0
        for t in req.prompt:
            toks = np.zeros((self.n_slots, 1), np.int32)
            toks[slot, 0] = int(t)
            posv = self.positions.copy()
            posv[slot] = pos
            nxt, self.cache = self._step(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(posv))
            pos += 1
        self.positions[slot] = pos
        self.last_tok[slot] = int(np.asarray(nxt)[slot])

    # ------------------------------------------------------------------ run
    def submit_and_run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve all requests to completion; returns rid -> generated ids.

        Requests are served FIFO (slot refill order = submission order).
        ``max_new_tokens <= 0`` completes immediately with ``[]``; a
        prompt of length >= ``s_max`` cannot fit the cache alongside a
        generated token and raises ``ValueError`` up front.
        """
        done: Dict[int, List[int]] = {}
        queue: List[Request] = []
        for req in requests:
            if len(req.prompt) >= self.s_max:
                raise ValueError(
                    f"request {req.rid}: prompt length {len(req.prompt)} "
                    f">= s_max={self.s_max} (no cache room to decode)")
            if req.max_new_tokens <= 0:
                req.out_tokens = []
                done[req.rid] = req.out_tokens
            else:
                queue.append(req)

        for slot in range(self.n_slots):
            if queue:
                self._prefill_into_slot(slot, queue.pop(0))

        while any(r is not None for r in self.live):
            toks = self.last_tok.reshape(-1, 1).astype(np.int32)
            nxt, self.cache = self._step(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.positions))
            nxt = np.asarray(nxt)
            for slot, req in enumerate(self.live):
                if req is None:
                    continue
                req.out_tokens.append(int(toks[slot, 0]))
                self.positions[slot] += 1
                self.budget[slot] -= 1
                self.last_tok[slot] = int(nxt[slot])
                if self.budget[slot] <= 0 or \
                        self.positions[slot] >= self.s_max - 1:
                    done[req.rid] = req.out_tokens
                    self.live[slot] = None
                    if queue:                    # continuous batching refill
                        self._prefill_into_slot(slot, queue.pop(0))
        return done
