"""A small batched serving engine: continuous-batching decode over the
LM's KV cache (full / sliding-window / SSM-state, per architecture).

Slots hold independent requests; finished slots are refilled from the
queue without stopping the batch (continuous batching a la Orca/vLLM,
adapted to the static-shape jit step). Prefill runs per-request via
``forward`` in prefill mode and its cache is spliced into the slot.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.layers import Ctx


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


class DecodeEngine:
    """Greedy decoding over ``n_slots`` concurrent requests."""

    def __init__(self, cfg, params, *, n_slots: int = 4, s_max: int = 512,
                 act_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.s_max = s_max
        self.ctx = Ctx(cfg=cfg, mode="decode", act_dtype=act_dtype)
        self.cache = lm.init_cache(cfg, n_slots, s_max, act_dtype)
        self.positions = np.zeros((n_slots,), np.int32)
        self.budget = np.zeros((n_slots,), np.int32)
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.live: List[Optional[Request]] = [None] * n_slots

        def step(params, cache, tokens, positions):
            logits, cache = lm.decode_step(cfg, params, cache, tokens,
                                           positions, ctx=self.ctx)
            return jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32), \
                cache
        self._step = jax.jit(step, donate_argnums=(1,))

    # ---------------------------------------------------------------- slots
    def _prefill_into_slot(self, slot: int, req: Request):
        """Run the prompt through decode steps to build the slot cache.

        (Token-by-token prefill keeps the engine single-program; the
        prefill_step path exists for bulk prefill benchmarking.)
        """
        req.out_tokens = []
        self.live[slot] = req
        self.budget[slot] = req.max_new_tokens
        pos = 0
        for t in req.prompt:
            toks = np.zeros((self.n_slots, 1), np.int32)
            toks[slot, 0] = int(t)
            posv = self.positions.copy()
            posv[slot] = pos
            nxt, self.cache = self._step(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(posv))
            pos += 1
        self.positions[slot] = pos
        self.last_tok[slot] = int(np.asarray(nxt)[slot])

    def submit_and_run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve all requests to completion; returns rid -> generated ids."""
        queue = list(requests)
        done: Dict[int, List[int]] = {}
        for slot in range(self.n_slots):
            if queue:
                self._prefill_into_slot(slot, queue.pop(0))

        while any(r is not None for r in self.live):
            toks = self.last_tok.reshape(-1, 1).astype(np.int32)
            nxt, self.cache = self._step(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.positions))
            nxt = np.asarray(nxt)
            for slot, req in enumerate(self.live):
                if req is None:
                    continue
                req.out_tokens.append(int(toks[slot, 0]))
                self.positions[slot] += 1
                self.budget[slot] -= 1
                self.last_tok[slot] = int(nxt[slot])
                if self.budget[slot] <= 0 or \
                        self.positions[slot] >= self.s_max - 1:
                    done[req.rid] = req.out_tokens
                    self.live[slot] = None
                    if queue:                    # continuous batching refill
                        self._prefill_into_slot(slot, queue.pop(0))
        return done
