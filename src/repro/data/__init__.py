"""Deterministic synthetic data pipeline (tokens + satellite imagery)."""
from repro.data.synthetic import (ImageryShards, TokenShards, prefetch)
