"""Deterministic synthetic data shards.

The Native-SMEC setting (paper §II) has each satellite capturing a
*local, non-IID* shard: we model that with per-satellite seeded
generators whose class/token distributions differ by shard, so the
constellation's round-robin SL training sees genuine data heterogeneity
(the thing the cyclical handoff must average over).

Everything is reproducible from (seed, shard_id, batch_idx) — a restart
resumes mid-epoch without state files. ``prefetch`` overlaps host
generation with device compute (double buffering via device_put).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenShards:
    """Zipf-ish token streams; shard-dependent unigram tilt => non-IID."""

    vocab: int
    seq_len: int
    batch: int
    n_shards: int = 1
    seed: int = 0

    def _rng(self, shard: int, idx: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, shard, idx]))

    def batch_at(self, shard: int, idx: int) -> Dict[str, np.ndarray]:
        rng = self._rng(shard, idx)
        # shard-tilted zipf: rank permutation differs per shard
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        perm = np.random.default_rng(
            np.random.SeedSequence([self.seed, shard])).permutation(self.vocab)
        p = p[np.argsort(perm)]
        p /= p.sum()
        toks = rng.choice(self.vocab, size=(self.batch, self.seq_len + 1),
                          p=p).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, shard: int = 0, start: int = 0) -> Iterator[Dict]:
        idx = start
        while True:
            yield self.batch_at(shard, idx)
            idx += 1


@dataclasses.dataclass(frozen=True)
class ImageryShards:
    """Synthetic "satellite imagery": gaussian blobs + per-shard class
    prior tilt (non-IID across the orbital ring)."""

    img: int = 224
    channels: int = 3
    n_classes: int = 10
    batch: int = 16
    n_shards: int = 25
    seed: int = 0

    def _class_prior(self, shard: int) -> np.ndarray:
        g = np.random.default_rng(np.random.SeedSequence([self.seed, shard]))
        alpha = g.dirichlet(np.full(self.n_classes, 0.5))
        return alpha

    def batch_at(self, shard: int, idx: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, shard, idx]))
        labels = rng.choice(self.n_classes, size=self.batch,
                            p=self._class_prior(shard)).astype(np.int32)
        xs = np.linspace(-1, 1, self.img, dtype=np.float32)
        xx, yy = np.meshgrid(xs, xs)
        imgs = np.empty((self.batch, self.img, self.img, self.channels),
                        np.float32)
        for i, lab in enumerate(labels):
            g = np.random.default_rng(
                np.random.SeedSequence([self.seed, shard, idx, i]))
            cx, cy = g.uniform(-0.5, 0.5, 2)
            sx = 0.15 + 0.04 * (lab % 5)
            blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * sx ** 2)))
            phase = 2 * math.pi * lab / self.n_classes
            for c in range(self.channels):
                imgs[i, :, :, c] = blob * math.cos(phase + c) \
                    + 0.05 * g.standard_normal((self.img, self.img))
        return {"images": imgs, "labels": labels}

    def iterate(self, shard: int = 0, start: int = 0) -> Iterator[Dict]:
        idx = start
        while True:
            yield self.batch_at(shard, idx)
            idx += 1


def prefetch(it: Iterator[Dict], size: int = 2,
             sharding=None) -> Iterator[Dict]:
    """Double-buffer host batches onto device ahead of compute."""
    import collections
    buf = collections.deque()

    def put(b):
        if sharding is None:
            return jax.tree.map(jnp.asarray, b)
        return jax.tree.map(
            lambda a: jax.device_put(a, sharding), b)

    try:
        for _ in range(size):
            buf.append(put(next(it)))
        while True:
            out = buf.popleft()
            buf.append(put(next(it)))
            yield out
    except StopIteration:
        while buf:
            yield buf.popleft()
