"""Device-resident degraded-ops scenario engine: eclipses, Byzantine
satellites, robust aggregation, and epidemic fault propagation.

The fleet engine (:mod:`repro.fleet.engine`) assumed cooperative,
healthy satellites under uninterrupted sunlight: recharge never paused,
every update was honest, and failures were independent seeded draws
that went silent beyond the precomputed horizon.  This module composes
the degraded-ops space — ROADMAP item 4 — INSIDE the one jitted scan:

* **Eclipse windows** (:class:`EclipseConfig`) — per-plane periodic
  shadow intervals.  ``sunlit(k, plane)`` is pure arithmetic on the
  pass index, so it traces inside the scan and stays correct beyond
  any precomputed horizon; it gates solar recharge through
  :func:`repro.sim.energy_state.recharge`'s ``sunlit=`` argument, and
  eclipse-depleted batteries flow straight into the reserve-skip
  policy (the planner "sees" the eclipse through the battery).
* **Byzantine satellites** (:class:`ByzantineConfig`) — a static
  ``(P, M)`` corruption mask.  When a Byzantine slot serves, the
  update its pass produced is corrupted at the pass-kernel boundary:
  ``sign_flip`` replaces the pass delta ``Δ`` with ``-scale·Δ``,
  ``scaled_noise`` adds ``scale·N(0, 1)`` to every float param leaf.
  The inter-plane exchange survives via :func:`aggregate_planes` —
  coordinate-wise ``trimmed_mean`` / ``median`` over the plane axis,
  with plain ``mean`` kept as the parity default.
* **Epidemic faults** (:class:`EpidemicConfig`) — transient faults
  that spread to ring-slot neighbors with probability ``beta`` per
  pass and recover after ``ttl`` passes.  The per-(plane, pass, slot)
  spread draws are precomputed on the host for the configured horizon
  (:func:`build_scenario_schedule`, bit-exact booleans — the host
  oracle below replays them), and refreshed from ``jax.random``
  *inside* the scan beyond it, so chained runs stay fault-active.

Host-prefix parity: :func:`oracle_actions` replays the full degraded
decision loop (membership → failure draw → epidemic fault → reserve
skip → drain → eclipse-gated recharge) in NumPy scalars against the
same precomputed schedules, producing the exact ``ACTION_*`` sequence
the device engine must emit for the precomputed prefix.  Byzantine
corruption never changes an action (only losses), so the oracle covers
every scenario combination.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, NamedTuple, Optional, Sequence, Tuple

import numpy as np

#: entropy tags appended to the run seed so scenario streams can never
#: collide with the membership/failure streams of the same seed
_EPIDEMIC_TAG = 0xEC1D

#: aggregation modes accepted by :func:`aggregate_planes` (and
#: ``FleetConfig.aggregate``)
AGGREGATION_MODES = ("mean", "median", "trimmed_mean")


# --------------------------------------------------------------------------
# Scenario configuration
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EclipseConfig:
    """Periodic orbital shadow windows, per plane.

    Pass ``k`` of plane ``p`` is in eclipse iff
    ``(k + phase + p * stagger) % period < round(duty * period)`` — the
    shadow sits at the start of each ``period``-pass cycle.  ``stagger``
    offsets the planes against each other (different RAAN ⇒ different
    shadow phase); ``duty`` is the eclipse fraction of the cycle
    (``duty=1`` ⇒ permanent shadow, recharge never fires).
    """

    period: int                 # eclipse cycle length, in passes
    duty: float                 # fraction of the cycle spent in shadow
    stagger: int = 0            # per-plane phase offset, in passes
    phase: int = 0              # global phase offset, in passes

    def __post_init__(self):
        if self.period < 1:
            raise ValueError(f"eclipse period must be >= 1, got {self.period}")
        if not 0.0 <= self.duty <= 1.0:
            raise ValueError(f"eclipse duty must be in [0, 1], got {self.duty}")

    @property
    def eclipse_passes(self) -> int:
        return int(round(self.duty * self.period))

    def sunlit(self, k, plane=0):
        """Is plane ``plane`` in sunlight at pass ``k``?  Pure modular
        arithmetic — works on Python ints, NumPy arrays and traced JAX
        scalars alike, so the same expression serves the host oracle
        and the device scan (and any pass index beyond the horizon)."""
        pos = (k + self.phase + plane * self.stagger) % self.period
        return pos >= self.eclipse_passes


@dataclasses.dataclass(frozen=True)
class ByzantineConfig:
    """Which slots lie, and how.

    ``planes`` marks every slot of the listed planes Byzantine (the
    acceptance scenario: one whole plane of four); ``slots`` marks
    individual ``plane -> [slot, ...]`` entries.  ``mode``:

    * ``"sign_flip"`` — the pass update ``Δ`` becomes ``-scale · Δ``
      (a radiation-flipped / adversarial gradient);
    * ``"scaled_noise"`` — ``scale · N(0, 1)`` is added to every float
      parameter leaf after the pass (garbled transmission).
    """

    planes: Tuple[int, ...] = ()
    slots: Mapping[int, Sequence[int]] = dataclasses.field(
        default_factory=dict)
    mode: str = "sign_flip"
    scale: float = 1.0

    def __post_init__(self):
        if self.mode not in ("sign_flip", "scaled_noise"):
            raise ValueError(f"unknown Byzantine mode {self.mode!r}; "
                             "expected 'sign_flip' or 'scaled_noise'")

    def mask(self, n_planes: int, n_slots: int) -> np.ndarray:
        """The static ``(P, M)`` corruption mask."""
        byz = np.zeros((n_planes, n_slots), bool)
        for p in self.planes:
            byz[int(p) % n_planes, :] = True
        for p, ms in self.slots.items():
            for m in ([ms] if isinstance(ms, (int, np.integer)) else ms):
                byz[int(p) % n_planes, int(m) % n_slots] = True
        return byz


@dataclasses.dataclass(frozen=True)
class EpidemicConfig:
    """Transient faults spreading along the slot ring.

    At pass ``start`` the ``init_slots`` of every plane become faulted
    for ``ttl`` passes.  Each pass, a healthy slot adjacent (slot-index
    ring, modulo M) to a faulted slot catches the fault with
    probability ``beta`` (one Bernoulli draw per slot per pass); a
    faulted slot recovers ``ttl`` passes after infection.  A faulted
    slot stays in the serving rotation but its pass is a masked no-op
    (``ACTION_FAULT``) — transient, unlike the permanent seeded
    failures.  Fault dynamics are autonomous: they depend only on the
    draws, never on membership or training state, which is what lets
    :func:`epidemic_oracle` replay them exactly.
    """

    beta: float = 0.3
    ttl: int = 3
    init_slots: Tuple[int, ...] = (0,)
    start: int = 0

    def __post_init__(self):
        if not 0.0 <= self.beta <= 1.0:
            raise ValueError(f"beta must be in [0, 1], got {self.beta}")
        if self.ttl < 1:
            raise ValueError(f"ttl must be >= 1, got {self.ttl}")


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """The composable degraded-ops scenario: any subset of the three
    stressors, all executing inside the fleet's one jitted scan."""

    eclipse: Optional[EclipseConfig] = None
    byzantine: Optional[ByzantineConfig] = None
    epidemic: Optional[EpidemicConfig] = None

    @property
    def degraded(self) -> bool:
        return (self.eclipse is not None or self.byzantine is not None
                or self.epidemic is not None)


class ScenarioSchedule(NamedTuple):
    """Host-precomputed device arrays for one scenario horizon.

    ``spread_draw[p, k, m]`` — the epidemic Bernoulli draws for the
    precomputed prefix, realized as booleans on the host (per-plane
    streams spawned via ``np.random.SeedSequence([seed, tag])`` so they
    can never collide with the membership/failure streams); shape
    ``(P, 1, M)`` all-False when no epidemic is configured.
    ``byz_mask[p, m]`` — the static Byzantine mask.
    ``init_mask[m]`` — the epidemic seed slots.
    """

    spread_draw: np.ndarray       # (P, K, M) bool
    byz_mask: np.ndarray          # (P, M) bool
    init_mask: np.ndarray         # (M,) bool


def build_scenario_schedule(scn: Optional[ScenarioConfig], n_planes: int,
                            n_slots: int, n_passes: int,
                            seed: int = 0) -> ScenarioSchedule:
    """Precompute the scenario's host-side draws for ``n_passes``."""
    P, M, K = int(n_planes), int(n_slots), int(n_passes)
    byz = np.zeros((P, M), bool)
    init = np.zeros((M,), bool)
    spread = np.zeros((P, 1, M), bool)
    if scn is not None:
        if scn.byzantine is not None:
            byz = scn.byzantine.mask(P, M)
        if scn.epidemic is not None:
            ep = scn.epidemic
            for m in ep.init_slots:
                init[int(m) % M] = True
            streams = np.random.SeedSequence(
                [int(seed), _EPIDEMIC_TAG]).spawn(P)
            spread = np.stack([
                np.random.default_rng(s).random((K, M)) < ep.beta
                for s in streams])
    return ScenarioSchedule(spread_draw=spread, byz_mask=byz,
                            init_mask=init)


# --------------------------------------------------------------------------
# Robust inter-plane aggregation (the ISL exchange, hardened)
# --------------------------------------------------------------------------

def aggregate_planes(tree, mode: str = "mean", trim: int = 1):
    """Inter-plane checkpoint aggregation over the leading plane axis.

    Float leaves are replaced by a robust center (broadcast back, so
    shapes/shardings are preserved — under the fleet mesh the ``mean``
    mode lowers to an all-reduce over the ``plane`` axis); integer
    leaves (step counters, lr schedules) stay per-plane.

    Modes (coordinate-wise over the plane axis):

    * ``"mean"``          — plain average, the host-parity default;
    * ``"median"``        — robust to ``< P/2`` corrupted planes;
    * ``"trimmed_mean"``  — drop the ``trim`` largest and smallest
      values per coordinate, average the rest (needs ``P > 2·trim``).
    """
    import jax
    import jax.numpy as jnp

    if mode not in AGGREGATION_MODES:
        raise ValueError(f"unknown aggregation mode {mode!r}; expected "
                         f"one of {AGGREGATION_MODES}")

    def center(x):
        if mode == "mean":
            return jnp.mean(x, axis=0, keepdims=True)
        if mode == "median":
            return jnp.median(x, axis=0, keepdims=True)
        P = x.shape[0]
        if P <= 2 * trim:
            raise ValueError(
                f"trimmed_mean(trim={trim}) needs more than {2 * trim} "
                f"planes, got {P}")
        return jnp.mean(jnp.sort(x, axis=0)[trim:P - trim], axis=0,
                        keepdims=True)

    def agg(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.broadcast_to(center(x), x.shape)
        return x

    return jax.tree.map(agg, tree)


# --------------------------------------------------------------------------
# Host oracles (NumPy replays of the device dynamics, prefix only)
# --------------------------------------------------------------------------

def epidemic_step(ttl: np.ndarray, spread_k: np.ndarray, k: int,
                  ep: EpidemicConfig, init_mask: np.ndarray,
                  xp=np):
    """One pass of the epidemic dynamics — THE update rule, shared
    verbatim (via ``xp=jnp``) by the device scan and the NumPy oracle.

    Order: (1) spread from the previous pass's faulted set to ring-slot
    neighbors gated by this pass's draws, (2) inject the initial
    infection at ``start`` — so seed slots begin spreading the *next*
    pass, (3) the returned ``faulted`` mask gates this pass, (4) the
    returned ``ttl`` is already decremented for the next pass.
    """
    infected_prev = ttl > 0
    neigh = xp.roll(infected_prev, 1) | xp.roll(infected_prev, -1)
    new_inf = ~infected_prev & neigh & spread_k
    ttl = xp.where(new_inf, ep.ttl, ttl)
    ttl = xp.where((k == ep.start) & init_mask, xp.maximum(ttl, ep.ttl),
                   ttl)
    faulted = ttl > 0
    return faulted, xp.maximum(ttl - 1, 0)


def epidemic_oracle(scn: ScenarioConfig, sched: ScenarioSchedule,
                    n_passes: Optional[int] = None) -> np.ndarray:
    """Replay the epidemic prefix on the host: ``(P, K, M)`` bool —
    which slots are faulted at each pass.  All-False when the scenario
    has no epidemic."""
    P, K_pre, M = sched.spread_draw.shape
    K = K_pre if n_passes is None else min(int(n_passes), K_pre)
    out = np.zeros((P, K, M), bool)
    if scn is None or scn.epidemic is None:
        return out
    for p in range(P):
        ttl = np.zeros((M,), np.int64)
        for k in range(K):
            out[p, k], ttl = epidemic_step(
                ttl, sched.spread_draw[p, k], k, scn.epidemic,
                sched.init_mask)
    return out


def oracle_actions(fleet, return_slots: bool = False):
    """Host-prefix parity oracle: the exact ``(P, K)`` ``ACTION_*``
    sequence a fresh :class:`~repro.fleet.engine.FleetEngine` must emit
    over its precomputed horizon.

    Replays the full degraded decision loop in NumPy scalars —
    membership (join/leave/permanent failures), the seeded failure
    stream, epidemic faults (via :func:`epidemic_step` on the same
    precomputed draws), the reserve-skip policy against the planned
    per-slot drains, eclipse-gated membership-aware recharge, and the
    ISL exchange's per-push battery charge when the fleet wires a
    :class:`repro.isl.ExchangeConfig` (an exchange-drained battery
    reaches the reserve-skip policy on both engines identically).
    Byzantine corruption perturbs losses, never actions, so the oracle
    is exact for every scenario combination.  Call it on a fleet that
    has not run yet (it reads the initial battery/failure state).

    ``return_slots=True`` additionally returns the ``(P, K)`` serving
    slot per pass (−1 where the ring was empty) — what
    :func:`repro.isl.exchange.oracle_exchange` replays contact payers
    from.
    """
    from repro.core.energy import clamp_battery
    from repro.sim.device_sim import (ACTION_FAILED, ACTION_FAULT,
                                      ACTION_SHED, ACTION_SKIPPED,
                                      ACTION_TRAINED)

    sched, scn = fleet.schedule, fleet.cfg.scenario
    ssched = fleet.scenario_schedule
    P, M, K = sched.n_planes, sched.n_slots, sched.n_passes
    cfg = fleet.cfg
    drain = np.asarray(fleet.plan.drain_j, np.float32)
    kept = np.asarray(fleet.plan.kept_fraction, np.float32)
    battery = np.asarray(fleet.energy.battery_j, np.float32).copy()
    failed = np.asarray(fleet._failed, bool).copy()
    recharge_j = np.float32(cfg.recharge_w
                            * fleet.budget.plane.pass_duration_s)
    reserve = np.float32(cfg.reserve_j)
    has_epi = scn is not None and scn.epidemic is not None
    # ISL exchange charge (repro.isl): same order as the device scan —
    # train drain, recharge, then the contact push's transmit energy
    exch = getattr(fleet, "exchange", None)
    ex_on = bool(getattr(fleet, "_ex_on", False))
    e_isl = np.float32(getattr(fleet, "_ex_energy_j", 0.0))
    L, avg_every = fleet.rev_len, int(cfg.avg_every)

    actions = np.zeros((P, K), np.int32)
    slots = np.full((P, K), -1, np.int32)
    for p in range(P):
        ttl = np.zeros((M,), np.int64)
        for k in range(K):
            faulted_m = np.zeros((M,), bool)
            if has_epi:
                faulted_m, ttl = epidemic_step(
                    ttl, ssched.spread_draw[p, k], k, scn.epidemic,
                    ssched.init_mask)
            member = sched.member_at(k, failed[p])
            n_alive = int(member.sum())
            served = n_alive > 0
            slot = (np.flatnonzero(member)[k % n_alive] if served else 0)
            fail = served and bool(sched.fail_mask[p, k])
            fault = served and not fail and bool(faulted_m[slot])
            skip = battery[p, slot] < reserve
            trains = served and not fail and not fault and not skip
            if not served or fail:
                actions[p, k] = ACTION_FAILED
            elif fault:
                actions[p, k] = ACTION_FAULT
            elif skip:
                actions[p, k] = ACTION_SKIPPED
            else:
                actions[p, k] = (ACTION_SHED if kept[p, slot] < 1.0
                                 else ACTION_TRAINED)
            if served:
                slots[p, k] = slot
            if fail:
                failed[p, slot] = True
            if trains:
                battery[p, slot] = clamp_battery(
                    battery[p, slot] - drain[p, slot],
                    np.float32(cfg.battery_j))
            sunlit = (scn is None or scn.eclipse is None
                      or bool(scn.eclipse.sunlit(k, p)))
            if sunlit:
                gain = np.where(member & ~failed[p],
                                recharge_j, np.float32(0.0))
                battery[p] = clamp_battery(battery[p] + gain,
                                           np.float32(cfg.battery_j))
            if ex_on and served and not fail:
                if exch.mode == "async":
                    push = bool(exch.contact.open_at(k))
                else:
                    push = (avg_every > 0 and (k + 1) % L == 0
                            and ((k + 1) // L) % avg_every == 0)
                if push:
                    battery[p, slot] = clamp_battery(
                        battery[p, slot] - e_isl,
                        np.float32(cfg.battery_j))
    return (actions, slots) if return_slots else actions


# --------------------------------------------------------------------------
# CI smoke: python -m repro.fleet --scenario degraded
# --------------------------------------------------------------------------

def _smoke_degraded(n_sats: int = 8, n_planes: int = 2,
                    n_revolutions: int = 2) -> None:  # pragma: no cover
    """The degraded-ops smoke: a 2-plane fleet under eclipse + one
    Byzantine slot + epidemic faults, aggregated with trimmed-mean
    (falls back to median for fleets too small to trim).  Asserts the
    loss stays finite on the honest planes, the device action sequence
    matches the host-prefix oracle bit for bit, and the
    ≤-1-sync-per-revolution contract holds."""
    import time

    import numpy as np

    from repro.core.energy import PassBudget
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.fleet.engine import FleetConfig, FleetEngine
    from repro.sim.data import DeviceImageryShards
    from repro.sim.device_sim import ACTION_FAULT, ACTION_SKIPPED

    shards = DeviceImageryShards(img=32, batch=4)
    adapter = autoencoder_adapter(cut=5, img=32)
    budget = PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=4e6)
    # tuned against the autoencoder plan's energy scale (~48 J drain
    # per served pass, ~4.5 J recharge per sunlit pass at 0.02 W): a
    # slot's first serve drains it below the 180 J reserve, and the
    # 50%-duty eclipse halves the recovery rate so second serves skip
    scn = ScenarioConfig(
        eclipse=EclipseConfig(period=4, duty=0.5, stagger=1),
        byzantine=ByzantineConfig(slots={0: [1]}, mode="sign_flip",
                                  scale=1.0),
        epidemic=EpidemicConfig(beta=0.6, ttl=2, init_slots=(0,),
                                start=0))
    aggregate = "trimmed_mean" if n_planes > 2 else "median"
    cfg = FleetConfig(
        n_planes=n_planes, n_revolutions=n_revolutions,
        battery_j=200.0, recharge_w=0.02, reserve_j=180.0,
        max_steps_per_pass=2, seed=0, avg_every=1,
        scenario=scn, aggregate=aggregate)

    t0 = time.time()
    fleet = FleetEngine(adapter, budget, shards, cfg)
    expect = oracle_actions(fleet)
    res = fleet.run(stream_telemetry=True)
    t1 = time.time()
    import jax
    print(f"degraded-ops: {n_planes} planes x {n_sats} sats x "
          f"{n_revolutions} revolutions on {len(jax.devices())} device(s), "
          f"eclipse+byzantine+epidemic, aggregate={aggregate} "
          f"({t1 - t0:.1f}s)")
    print(f"  {res.summary()}")
    print(f"  traces={fleet.traces} device_calls={fleet.device_calls} "
          f"host_syncs={fleet.host_syncs} (<=1/revolution)")
    assert fleet.traces == 1 and fleet.host_syncs <= n_revolutions

    np.testing.assert_array_equal(res.action, expect)
    finite = res.loss[np.isfinite(res.loss)]
    assert finite.size > 0 and np.isfinite(finite).all()
    assert (res.action == ACTION_FAULT).sum() > 0, \
        "epidemic never faulted a serving slot"
    assert (res.action == ACTION_SKIPPED).sum() > 0, \
        "eclipse never depleted a battery into the reserve-skip policy"
    assert res.n_infected.max() > 1, "epidemic never spread"
    print("  host-prefix action parity OK; loss finite; "
          f"max infected={int(res.n_infected.max())}/{fleet.n_slots}")
