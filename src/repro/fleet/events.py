"""Precomputed membership / failure schedules for the fleet engine.

The host :class:`~repro.core.constellation.ConstellationSim` mutates its
ring from Python — ``join_events`` append ``SatelliteState``s,
``leave_events`` and seeded ``fail_prob`` draws flip ``alive`` flags —
which is exactly why elastic runs used to be forced back to the host
oracle.  A device program cannot reshape arrays mid-scan, but it does
not have to: every membership event is either *statically known*
(join/leave schedules are plain config dicts) or *seeded* (the failure
draw consumes one ``numpy`` ``Generator.random()`` per pass, a stream
that is precomputable to the last bit).  This module folds all of it
into an :class:`EventSchedule` of fixed-shape arrays:

* ``join_pass[m]``  — the pass at which slot ``m`` becomes a ring
  member (0 for the initial ring; joiner slots are appended in event
  order, mirroring the host's ``len(self.sats)`` id assignment);
* ``leave_pass[m]`` — the pass at which slot ``m`` is removed
  (``NEVER`` = int32 max, so membership persists for chained runs
  beyond the horizon; the host's ``sid % len(sats)`` resolution is
  replayed against the join schedule, so ids match exactly);
* ``fail_mask[p, k]`` — plane ``p``'s seeded Bernoulli failure stream:
  ``default_rng(seed + p).random(K) < fail_prob``, the *same* stream
  the host oracle consumes one draw at a time (``Generator.random()``
  sequential draws equal one array draw), realized as booleans on the
  host so f32/f64 threshold rounding can never flip a decision.
  ``legacy_streams=False`` replaces the ``seed + p`` derivation with
  ``np.random.SeedSequence(seed).spawn(n_planes)`` — ``seed + p``
  collides across runs ((seed=0, plane=1) is bit-identical to
  (seed=1, plane=0)), which spawned sequences can never do.  Legacy
  stays the default because the host oracle is a per-plane
  ``ConstellationSim(seed=seed + p)``; spawned streams have no host
  counterpart (scalar ``Generator``s cannot consume them draw-by-draw
  with the same arithmetic), so parity-checked runs keep legacy and
  fleet-only studies opt into collision-free streams.

Inside the scan, slot ``m`` is alive at pass ``k`` iff
``join_pass[m] <= k < leave_pass[m]`` and it has not failed (the
``failed`` mask rides the scan carry); the serving slot is the
``k mod n_alive``-th member in slot order — precisely the host's
``ring[k % len(ring)]``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence

import numpy as np

#: ``leave_pass`` sentinel for "never leaves" — far beyond any horizon,
#: so chained runs past the precomputed schedule keep their membership
#: (only *failures* stop firing there: the seeded stream is finite).
NEVER = np.iinfo(np.int32).max


@dataclasses.dataclass(frozen=True)
class EventSchedule:
    """Membership + failure events for ``n_passes`` passes over
    ``n_slots`` slots (initial ring + every joiner), per plane."""

    n_initial: int                  # slots alive at pass 0
    n_slots: int                    # M = n_initial + total joins
    n_passes: int                   # K, the precomputed horizon
    join_pass: np.ndarray           # (M,) int32
    leave_pass: np.ndarray          # (M,) int32; NEVER = never leaves
    fail_mask: np.ndarray           # (P, K) bool, seeded per plane
    fail_prob: float
    seed: int
    legacy_streams: bool = True     # seed+p streams (host-parity) vs
                                    # SeedSequence.spawn (collision-free)

    @property
    def n_planes(self) -> int:
        return self.fail_mask.shape[0]

    def member_at(self, k: int, failed: Optional[np.ndarray] = None
                  ) -> np.ndarray:
        """Host-side membership oracle (tests): alive slots at pass ``k``."""
        member = (self.join_pass <= k) & (k < self.leave_pass)
        if failed is not None:
            member = member & ~np.asarray(failed)
        return member


def leave_ids(value) -> list:
    """Normalize one ``leave_events`` value — a single satellite id or a
    sequence of them — into a list of ints (host + device engines share
    this, so a multi-leave pass resolves identically in both)."""
    if isinstance(value, (int, np.integer)):
        return [int(value)]
    return [int(v) for v in value]


def build_event_schedule(n_initial: int, n_passes: int, *,
                         join_events: Optional[Mapping[int, int]] = None,
                         leave_events: Optional[Mapping[int, Any]] = None,
                         fail_prob: float = 0.0, n_planes: int = 1,
                         seed: int = 0,
                         legacy_streams: bool = True) -> EventSchedule:
    """Replay the host scheduler's event semantics into fixed arrays.

    Mirrors ``ConstellationSim.run`` pass for pass: at pass ``k`` joins
    are appended first (slot id = current total count), then each leave
    event — a single id or a sequence of ids (``Mapping[int, int |
    Sequence[int]]``) — resolves ``sid % <total count>``, so a leave
    targeting a yet-to-join slot id behaves identically in both
    engines.  With ``legacy_streams=True`` plane ``p``'s failure stream
    is drawn from ``default_rng(seed + p)``, one draw per pass whether
    or not it fires — matching the host oracle's per-pass
    ``rng.random()`` consumption exactly (the host sim for plane ``p``
    must therefore run with ``seed + p``); ``legacy_streams=False``
    draws each plane from a ``SeedSequence(seed).spawn(n_planes)``
    child, which no other (seed, plane) pair can collide with.
    """
    join_events = dict(join_events or {})
    leave_events = dict(leave_events or {})
    join_pass = [0] * int(n_initial)
    leaves = []
    for k in range(int(n_passes)):
        for _ in range(int(join_events.get(k, 0))):
            join_pass.append(k)
        if k in leave_events:
            for sid in leave_ids(leave_events[k]):
                leaves.append((k, sid % len(join_pass)))
    n_slots = len(join_pass)
    leave_pass = np.full((n_slots,), NEVER, np.int32)
    for k, sid in leaves:
        leave_pass[sid] = min(int(leave_pass[sid]), k)
    if legacy_streams:
        streams = [seed + p for p in range(int(n_planes))]
    else:
        streams = np.random.SeedSequence(int(seed)).spawn(int(n_planes))
    fail_mask = np.stack([
        np.random.default_rng(s).random(int(n_passes)) < fail_prob
        for s in streams])
    return EventSchedule(
        n_initial=int(n_initial), n_slots=n_slots, n_passes=int(n_passes),
        join_pass=np.asarray(join_pass, np.int32), leave_pass=leave_pass,
        fail_mask=fail_mask, fail_prob=float(fail_prob), seed=int(seed),
        legacy_streams=bool(legacy_streams))


def static_schedule(n_sats: int, n_passes: int,
                    n_planes: int = 1, seed: int = 0) -> EventSchedule:
    """A steady-state schedule: no events, no failures."""
    return build_event_schedule(n_sats, n_passes, n_planes=n_planes,
                                seed=seed)
