"""Sharded elastic fleet engine: a constellation on a device mesh.

The PR-4 device engine (:mod:`repro.sim.device_sim`) runs ONE static
ring on ONE device; elastic membership and random failures stayed
host-oracle features, and multi-plane constellations meant multiple
independent runs.  This module is the path from "one ring on one chip"
to "a constellation on a mesh":

* **Elastic + faults on device** — the scan carry grows a per-slot
  ``failed`` mask; combined with the precomputed join/leave schedule
  (:mod:`repro.fleet.events`) it yields each pass's aliveness mask, and
  the serving slot is computed *inside* the scan as the host's
  ``ring[k % len(ring)]``.  A seeded failure stream (the host oracle's
  own ``numpy`` draws, realized per plane) flips slots dead mid-run; a
  dead or absent slot's pass masks through the shared step kernel
  (``SLTrainState.apply_updates(where=)``), so the successor trains
  through unchanged — checkpoint restoration is the carry itself.
* **Plane-sharded execution** — a :class:`FleetConfig` of P planes × N
  sats lays the :class:`~repro.sim.energy_state.EnergyState`, the
  :class:`~repro.sim.device_sim.DevicePassPlan` and the per-plane data
  cursors out as ``(P, ...)`` arrays sharded over a
  ``launch/mesh.make_fleet_mesh`` plane axis
  (``jax.sharding.NamedSharding``); every plane runs its ring's closed
  loop under one ``vmap``, so the whole fleet advances as ONE jitted
  (revolution × pass) scan with ≤ 1 telemetry sync per revolution.
* **Inter-plane ISL exchange** — at revolution boundaries
  (``avg_every``) the segment checkpoints are averaged across the
  plane axis (:func:`average_planes`, an all-reduce over the mesh) —
  the paper's inter-plane ISL checkpoint exchange.
* **Heterogeneous planning** — all P×M problem-(13) instances are shed
  and solved in one device call
  (:func:`~repro.sim.device_sim.plan_ring_passes` with a ``(P, M)``
  row shape), with per-satellite measured ``dtx_bits`` rows (e.g. from
  :func:`~repro.core.sl_step.ring_boundary_bits`) planning mixed
  payloads in the same solve.

The host :class:`~repro.core.constellation.ConstellationSim` stays the
parity oracle: one host sim per plane (seeded ``seed + p``) must
reproduce the fleet's action/skip/fail sequences, losses and battery
trajectories — ``ConstellationSim.run(engine="device")`` now delegates
elastic runs here (P=1) instead of refusing them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import PassBudget, clamp_battery
from repro.obs.metrics import (MetricsRegistry, counter_property,
                               global_registry)
from repro.obs.ring import (EV_EXCHANGE, EV_PASS, FlightRecorder,
                            record as ring_record, ring_init)
from repro.core.sl_step import (SplitAdapter, dedupe_state_buffers,
                                make_pass_step)
from repro.core.train_state import SLTrainState
from repro.fleet.events import EventSchedule, build_event_schedule
from repro.fleet.scenarios import (ScenarioConfig, aggregate_planes,
                                   build_scenario_schedule,
                                   epidemic_step as scn_epidemic_step)
from repro.isl.codec import delta_payload_bits
from repro.isl.exchange import (ExchangeConfig, async_gossip_step,
                                exchange_init, null_exchange_state,
                                sync_exchange_step)
from repro.launch.mesh import make_fleet_mesh, plane_sharding
from repro.sim import energy_state as es_mod
from repro.sim.device_sim import (ACTION_FAILED, ACTION_FAULT, ACTION_SHED,
                                  ACTION_SKIPPED, ACTION_TRAINED,
                                  DevicePassPlan, measure_and_plan)
from repro.sim.energy_state import EnergyState
from repro.train.optimizer import resolve_optimizer


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs of a P-plane elastic constellation run.

    The steady-state fields mirror
    :class:`~repro.sim.device_sim.DeviceSimConfig`; the elastic fields
    mirror the host :class:`~repro.core.constellation
    .ConstellationConfig` (``join_events`` / ``leave_events`` /
    ``fail_prob`` / ``join_battery_frac``) — the SAME schedules drive
    both engines, which is what makes the host the parity oracle.
    Plane ``p``'s failure stream is seeded ``seed + p``.
    """

    n_planes: int = 1
    n_revolutions: int = 1
    lr: float = 1e-2
    optimizer: Any = "sgd"
    quantize_boundary: bool = False
    battery_j: float = 5_000.0
    recharge_w: float = 20.0
    reserve_j: float = 100.0
    max_steps_per_pass: Optional[int] = 128
    min_fraction: float = 0.05
    seed: int = 0
    # ---- elastic membership / fault injection (host-oracle parity) ----
    fail_prob: float = 0.0
    join_events: Dict[int, int] = dataclasses.field(default_factory=dict)
    leave_events: Dict[int, int] = dataclasses.field(default_factory=dict)
    join_battery_frac: float = 1.0
    # seed+p failure streams (host-parity default) vs collision-free
    # SeedSequence.spawn streams — see fleet/events.py
    legacy_streams: bool = True
    # ---- fleet structure ----------------------------------------------
    # passes per revolution (telemetry/streaming/averaging granularity);
    # None = the initial ring size
    passes_per_revolution: Optional[int] = None
    # inter-plane checkpoint averaging period, in revolutions; 0 = off
    avg_every: int = 1
    # ---- degraded-ops scenario (fleet/scenarios.py) -------------------
    # eclipse windows + Byzantine slots + epidemic faults; None = the
    # cooperative, permanently-sunlit baseline (host-parity default)
    scenario: Optional[ScenarioConfig] = None
    # inter-plane aggregation: "mean" (parity default) | "median" |
    # "trimmed_mean" — see fleet/scenarios.aggregate_planes
    aggregate: str = "mean"
    # ---- ISL comms subsystem (repro.isl) ------------------------------
    # modeled inter-plane exchange: contact windows, compressed deltas,
    # metered bits/joules charged to the shared batteries and priced
    # into the problem-(13) plan.  None = the free, instantaneous
    # legacy barrier above (host-parity default).
    exchange: Optional[ExchangeConfig] = None


class FleetTelemetry(NamedTuple):
    """Per-pass scan outputs; stacked to (R, L, P) by the nested scan."""

    action: Any               # int32 ACTION_* code
    sat: Any                  # int32 serving slot id (-1: ring empty)
    loss: Any                 # float32 mean loss (NaN unless trained)
    battery_j: Any            # float32 serving sat battery at pass end
    n_steps: Any              # int32 fused steps executed
    n_infected: Any           # int32 epidemic-faulted slots this pass


def average_planes(tree):
    """Inter-plane checkpoint averaging over the leading plane axis —
    the ``mode="mean"`` case of
    :func:`repro.fleet.scenarios.aggregate_planes` (kept as the named
    parity default; robust runs select ``median`` / ``trimmed_mean``
    via ``FleetConfig.aggregate``)."""
    return aggregate_planes(tree, "mean")


@dataclasses.dataclass
class FleetResult:
    """Host-side view of one fleet run (synced telemetry).

    Per-pass arrays are ``(P, K)`` — plane-major, pass index within the
    plane's own K-pass timeline; per-slot arrays are ``(P, M)``.
    """

    action: np.ndarray        # (P, K) int32 ACTION_* codes
    sat: np.ndarray           # (P, K) serving slot (-1: ring empty)
    loss: np.ndarray          # (P, K) NaN unless trained
    battery_j: np.ndarray     # (P, K) serving sat battery at pass end
    n_steps: np.ndarray       # (P, K)
    n_infected: np.ndarray    # (P, K) epidemic-faulted slots per pass
    plan: DevicePassPlan      # (P, M) host copies
    energy: EnergyState       # (P, M) final fleet state, host copies
    failed: np.ndarray        # (P, M) final failure mask
    fault_ttl: np.ndarray     # (P, M) final epidemic recovery counters
    state: Any                # final SLTrainState, (P, ...) leaves
    isl_bits: Optional[np.ndarray] = None      # (P,) pushed wire bits
    isl_e_j: Optional[np.ndarray] = None       # (P,) ISL transmit joules
    isl_contacts: Optional[np.ndarray] = None  # (P,) successful pushes

    def summary(self) -> Dict[str, Any]:
        """Fleet-wide roll-up, same shape as ``ConstellationSim.summary``
        (loss_first/loss_last are time-ordered across the fleet)."""
        trained = (self.action == ACTION_TRAINED) | \
                  (self.action == ACTION_SHED)
        # time-major flatten so first/last match the host's pass order
        t_order = trained.T.reshape(-1)
        losses = self.loss.T.reshape(-1)[t_order]
        p_idx, k_idx = np.nonzero(trained)
        sats = self.sat[p_idx, k_idx]
        return {
            "passes": int(self.action.size),
            "trained": int(trained.sum()),
            "skipped": int((self.action == ACTION_SKIPPED).sum()),
            "failed": int((self.action == ACTION_FAILED).sum()),
            "faulted": int((self.action == ACTION_FAULT).sum()),
            "loss_first": float(losses[0]) if losses.size else None,
            "loss_last": float(losses[-1]) if losses.size else None,
            "E_total_J": float(self.plan.e_total_j[p_idx, sats].sum()),
            "E_comm_J": float(self.plan.e_comm_j[p_idx, sats].sum()),
            "E_proc_J": float(self.plan.e_proc_j[p_idx, sats].sum()),
            "E_isl_J": float(self.plan.e_isl_j[p_idx, sats].sum()),
            # measured exchange meter (repro.isl) — 0 when the legacy
            # free barrier (exchange=None) ran
            "ISL_exchange_bits": (float(self.isl_bits.sum())
                                  if self.isl_bits is not None else 0.0),
            "ISL_exchange_J": (float(self.isl_e_j.sum())
                               if self.isl_e_j is not None else 0.0),
        }


class FleetEngine:
    """P orbital planes × an elastic M-slot ring each, as ONE program.

    ``batch_fn(sat, idx) -> batch`` must be traceable (the same
    contract as :class:`~repro.sim.device_sim.DeviceConstellationSim`);
    plane ``p``'s slot ``m`` reads global satellite id ``p * M + m``,
    so a per-plane host oracle is simply the same provider with its sat
    ids offset.  ``state`` is a *single-copy*
    :class:`~repro.core.train_state.SLTrainState`; the engine
    replicates it to a ``(P, ...)``-leading fleet state sharded over
    the plane mesh axis.

    Observability: every pass records an ``EV_PASS`` event (and every
    inter-plane exchange an ``EV_EXCHANGE`` marker) into a per-plane
    :class:`~repro.obs.ring.TelemetryRing` sharded with the carry,
    flushed into ``self.recorder`` at the existing telemetry sync.
    The ``traces`` / ``device_calls`` / ``host_syncs`` counters live on
    ``self.metrics`` (namespace ``fleet``) with the same
    ≤-1-sync-per-revolution contract as the static engine.
    """

    traces = counter_property("traces")
    device_calls = counter_property("device_calls")
    host_syncs = counter_property("host_syncs")

    def __init__(self, adapter: SplitAdapter, budget: PassBudget,
                 batch_fn: Callable[[Any, Any], Dict],
                 cfg: Optional[FleetConfig] = None, *,
                 state: Optional[SLTrainState] = None,
                 plan: Optional[DevicePassPlan] = None,
                 dtx_bits=None, schedule: Optional[EventSchedule] = None,
                 mesh=None, plane_axis: str = "plane",
                 battery0=None, failed0=None):
        cfg = FleetConfig() if cfg is None else cfg
        self.adapter = adapter
        self.budget = budget
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.n_planes = int(cfg.n_planes)
        # slot layout follows the schedule (a chained delegation's ring
        # may already carry joiners beyond the configured plane); the
        # eq.-(5) ISL physics below stays pinned to budget.plane.n_sats
        self.n_initial = (budget.plane.n_sats if schedule is None
                          else schedule.n_initial)
        self.rev_len = (self.n_initial if cfg.passes_per_revolution is None
                        else int(cfg.passes_per_revolution))
        self.n_passes = cfg.n_revolutions * self.rev_len

        if schedule is None:
            schedule = build_event_schedule(
                self.n_initial, self.n_passes,
                join_events=cfg.join_events, leave_events=cfg.leave_events,
                fail_prob=cfg.fail_prob, n_planes=self.n_planes,
                seed=cfg.seed, legacy_streams=cfg.legacy_streams)
        if schedule.n_planes != self.n_planes:
            raise ValueError(f"schedule covers {schedule.n_planes} planes "
                             f"but the fleet has {self.n_planes}")
        self.schedule = schedule
        self.n_slots = schedule.n_slots
        P, M = self.n_planes, self.n_slots
        aggregate_planes({}, cfg.aggregate)   # validate the mode early
        self.scenario_schedule = build_scenario_schedule(
            cfg.scenario, P, M, schedule.n_passes, seed=cfg.seed)

        self.optimizer = resolve_optimizer(cfg.optimizer, lr=cfg.lr)
        if state is None:
            pa, pb = adapter.init(jax.random.key(cfg.seed))
            state = SLTrainState.create(pa, pb, self.optimizer)

        # ---- ISL exchange statics (repro.isl) --------------------------
        # wire bits, contact capacity and per-push transmit energy are
        # shape-static, so they are Python floats baked into the trace;
        # a payload over the contact capacity disables the exchange
        # outright (hard bandwidth limit, not a price), and the
        # amortized per-pass bit volume feeds the problem-(13) planner
        # below so the codec choice changes the planned allocation
        exch = cfg.exchange
        self.exchange = exch
        self._ex_bits = 0.0
        self._ex_energy_j = 0.0
        self._ex_cap_bits = float("inf")
        self._ex_fits = False
        isl_extra_bits = 0.0
        if exch is not None:
            ptree = (state.params_a, state.params_b)
            self._ex_bits = delta_payload_bits(ptree, exch.codec)
            self._ex_cap_bits = exch.contact.capacity_bits(budget.isl,
                                                           budget.link)
            self._ex_fits = self._ex_bits <= self._ex_cap_bits
            if self._ex_fits:
                self._ex_energy_j = exch.contact.tx_energy_j(
                    self._ex_bits, budget.isl, budget.link)
                isl_extra_bits = (self._ex_bits
                                  * exch.mean_contacts_per_pass(
                                      self.rev_len, int(cfg.avg_every)))
        self._ex_on = exch is not None and self._ex_fits and P > 1

        # measured costs + plan + scan sizing via the construction block
        # shared with the single-ring engine; all P*M problem-(13)
        # instances shed + solve in ONE device call, with eq. (5)
        # priced off the configured plane (host parity)
        self.dtx_bits = dtx_bits
        self.batch_size, self.costs, self.plan, self._scan_steps = \
            measure_and_plan(adapter, budget, batch_fn,
                             quantize_boundary=cfg.quantize_boundary,
                             params_a=state.params_a, n_sats=(P, M),
                             ring_n=budget.plane.n_sats, dtx_bits=dtx_bits,
                             max_steps_per_pass=cfg.max_steps_per_pass,
                             min_fraction=cfg.min_fraction, plan=plan,
                             isl_extra_bits=isl_extra_bits)
        if tuple(self.plan.n_steps.shape) != (P, M):
            raise ValueError(f"plan shape {self.plan.n_steps.shape} != "
                             f"fleet layout ({P}, {M})")

        # ---- mesh + (P, ...) layout ------------------------------------
        self.mesh = make_fleet_mesh(P) if mesh is None else mesh
        axis_size = dict(zip(self.mesh.axis_names,
                             self.mesh.devices.shape))[plane_axis]
        if P % axis_size:
            raise ValueError(
                f"{P} planes cannot shard evenly over the {axis_size}-way "
                f"'{plane_axis}' mesh axis; use make_fleet_mesh({P})")
        self._shard = plane_sharding(self.mesh, plane_axis)
        put = lambda t: jax.device_put(t, self._shard)    # noqa: E731

        self.state = put(jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.asarray(x)[None],
                                       (P,) + jnp.shape(x)), state))
        battery = np.full((P, M), cfg.battery_j, np.float32)
        battery[:, self.n_initial:] = clamp_battery(
            cfg.battery_j * cfg.join_battery_frac, cfg.battery_j)
        if battery0 is not None:
            battery[:, :self.n_initial] = np.broadcast_to(
                np.asarray(battery0, np.float32), (P, self.n_initial))
        self.energy = put(EnergyState(
            battery_j=jnp.asarray(battery),
            energy_spent_j=jnp.zeros((P, M), jnp.float32),
            passes_served=jnp.zeros((P, M), jnp.int32),
            passes_skipped=jnp.zeros((P, M), jnp.int32)))
        failed = np.zeros((P, M), bool)
        if failed0 is not None:
            failed[:, :self.n_initial] = np.broadcast_to(
                np.asarray(failed0, bool), (P, self.n_initial))
        self._failed = put(jnp.asarray(failed))
        self._fail_mask = put(jnp.asarray(schedule.fail_mask))
        self._batch_idx = put(jnp.zeros((P,), jnp.int32))
        self._pass_idx = jnp.zeros((), jnp.int32)
        # epidemic recovery counters ride the carry; the precomputed
        # spread draws and the static Byzantine mask ship as sharded
        # inputs so the scan reads its own plane's rows
        self._ttl = put(jnp.zeros((P, M), jnp.int32))
        self._spread = put(jnp.asarray(self.scenario_schedule.spread_draw))
        self._byz = put(jnp.asarray(self.scenario_schedule.byz_mask))
        self.plan = put(self.plan)
        # exchange carry: anchors/residuals/meters ride the scan like
        # any other state (empty trees when the exchange is off, so the
        # scan signature never changes shape)
        self._ex_state = put(
            exchange_init((self.state.params_a, self.state.params_b), P)
            if self._ex_on else null_exchange_state(P))

        self._pass_step = make_pass_step(
            adapter, self.optimizer,
            quantize_boundary=cfg.quantize_boundary)
        # stateless streams for beyond-horizon draws: fold_in on the
        # pass index (and plane) means chained runs need no RNG carry.
        # Built here, not inside the traced program — the scan bodies
        # stay host-op-free (scripts/lint_scan_purity.py).
        base_key = jax.random.key(np.uint32(cfg.seed))
        self._fail_key = jax.random.fold_in(base_key, 1)
        self._spread_key = jax.random.fold_in(base_key, 2)
        self._noise_key = jax.random.fold_in(base_key, 3)
        self._fns: Dict[int, Any] = {}
        self.metrics = MetricsRegistry("fleet", parent=global_registry())
        self.metrics.gauge("n_planes").set(P)
        self.metrics.gauge("n_slots").set(M)
        self.recorder = FlightRecorder(self.metrics)

    # ------------------------------------------------------- the program
    def _compiled(self, n_revolutions: int):
        """The jitted (revolution × pass) fleet loop for R revolutions,
        vmapped over planes; cached per R."""
        fn = self._fns.get(n_revolutions)
        if fn is not None:
            return fn

        cfg = self.cfg
        P, M, L = self.n_planes, self.n_slots, self.rev_len
        K = self._scan_steps
        pass_step = self._pass_step
        batch_fn = self.batch_fn
        avg_every = int(cfg.avg_every)
        horizon = self.schedule.n_passes
        recharge_j = jnp.float32(cfg.recharge_w
                                 * self.budget.plane.pass_duration_s)
        reserve = jnp.float32(cfg.reserve_j)
        cap = jnp.float32(cfg.battery_j)
        step_ids = jnp.arange(K, dtype=jnp.int32)
        plane_ids = jnp.arange(P, dtype=jnp.int32)
        join_pass = jnp.asarray(self.schedule.join_pass, jnp.int32)
        leave_pass = jnp.asarray(self.schedule.leave_pass, jnp.int32)
        # static scenario structure (Python-level: absent stressors are
        # dead code, so a scenario-free fleet compiles to the same
        # program as before)
        scn = cfg.scenario
        eclipse = None if scn is None else scn.eclipse
        byz_cfg = None if scn is None else scn.byzantine
        epidemic = None if scn is None else scn.epidemic
        init_mask = jnp.asarray(self.scenario_schedule.init_mask)
        fail_prob = float(cfg.fail_prob)
        fail_key = self._fail_key
        spread_key = self._spread_key
        noise_key = self._noise_key
        # ISL exchange statics: an inactive exchange (off / over
        # capacity / single plane) is dead code, so the program matches
        # the legacy one exactly
        exch = self.exchange if self._ex_on else None
        ex_async = exch is not None and exch.mode == "async"
        ex_sync = exch is not None and exch.mode == "sync"
        ex_bits = float(self._ex_bits)
        ex_e_j = float(self._ex_energy_j)
        battery_cap = float(cfg.battery_j)

        def corrupt_params(new_tree, old_tree, lie, plane, k, salt):
            """Byzantine injection at the pass kernel: where ``lie``,
            replace the pass delta Δ with -scale·Δ (sign_flip) or add
            scale·N(0,1) per float leaf (scaled_noise)."""
            scale = jnp.float32(byz_cfg.scale)
            leaves, treedef = jax.tree.flatten(new_tree)
            old_leaves = jax.tree.leaves(old_tree)
            out = []
            for i, (new, old) in enumerate(zip(leaves, old_leaves)):
                if not jnp.issubdtype(new.dtype, jnp.floating):
                    out.append(new)
                    continue
                if byz_cfg.mode == "sign_flip":
                    bad = old - scale * (new - old)
                else:       # scaled_noise
                    kk = jax.random.fold_in(jax.random.fold_in(
                        jax.random.fold_in(noise_key, k), plane),
                        2 * i + salt)
                    bad = new + scale * jax.random.normal(
                        kk, new.shape, new.dtype)
                out.append(jnp.where(lie, bad, new))
            return jax.tree.unflatten(treedef, out)

        def closed_loop(state, energy, failed, ttl, bidx, k, ring, ex,
                        plan, fail_mask, spread, byz):
            # side effect fires at trace time
            self.metrics.inc("traces")

            def plane_pass(plane, fail_k, spread_k, byz_row, state,
                           energy, failed, ttl, bidx, ring, plan, k):
                # epidemic dynamics first: faults spread along the slot
                # ring gated by the precomputed prefix draws, or by
                # in-scan jax.random draws beyond the horizon — chained
                # runs stay fault-active
                faulted_m = jnp.zeros((M,), bool)
                if epidemic is not None:
                    live = jax.random.uniform(
                        jax.random.fold_in(
                            jax.random.fold_in(spread_key, k), plane),
                        (M,)) < epidemic.beta
                    draw = jnp.where(k < horizon, spread_k, live)
                    faulted_m, ttl = scn_epidemic_step(
                        ttl, draw, k, epidemic, init_mask, xp=jnp)

                # membership next, exactly like the host scheduler:
                # joins and leaves apply at pass start, then the serving
                # slot is ring[k % len(ring)] over the alive slots in
                # slot order
                member = (join_pass <= k) & (k < leave_pass) & ~failed
                n_alive = member.sum()
                served = n_alive > 0
                rank = jnp.where(served, k % jnp.maximum(n_alive, 1), 0)
                cums = jnp.cumsum(member.astype(jnp.int32))
                slot = jnp.argmax((cums == rank + 1)
                                  & member).astype(jnp.int32)

                # the host's decision order: seeded failure draw, then
                # the transient epidemic fault, then the reserve-skip
                # policy, then the planned masked pass
                fail = served & fail_k
                fault = served & ~fail & faulted_m[slot]
                skip = energy.battery_j[slot] < reserve
                trains = served & ~fail & ~fault & ~skip
                n_valid = jnp.where(trains,
                                    jnp.minimum(plan.n_steps[slot], K), 0)

                def step_body(st, j):
                    return pass_step(st,
                                     batch_fn(plane * M + slot, bidx + j),
                                     j < n_valid)

                old_state = state
                state, losses = jax.lax.scan(step_body, state, step_ids)
                valid = step_ids < n_valid
                loss = jnp.where(
                    trains,
                    jnp.where(valid, losses, 0.0).sum()
                    / jnp.maximum(n_valid, 1).astype(jnp.float32),
                    jnp.nan)

                if byz_cfg is not None:
                    # a Byzantine serving slot corrupts the update its
                    # pass just produced (params only; its optimizer
                    # state stays the honest trajectory's)
                    lie = byz_row[slot] & trains
                    state = state.replace(
                        params_a=corrupt_params(
                            state.params_a, old_state.params_a, lie,
                            plane, k, 0),
                        params_b=corrupt_params(
                            state.params_b, old_state.params_b, lie,
                            plane, k, 1))

                failed = failed.at[slot].set(failed[slot] | fail)
                energy = es_mod.apply_pass(
                    energy, slot, plan.drain_j[slot],
                    plan.e_total_j[slot], cap, trains,
                    skipped=served & ~fail & ~fault & skip)
                # recharge this pass's members that are still alive (a
                # slot that just failed collects nothing — it is dead);
                # an eclipsed plane harvests nothing at all, which is
                # how orbital shadow reaches the reserve-skip policy
                sunlit = (None if eclipse is None
                          else eclipse.sunlit(k, plane))
                energy = es_mod.recharge(energy, recharge_j, cap,
                                         member_mask=member & ~failed,
                                         sunlit=sunlit)
                bidx = bidx + n_valid
                action = jnp.where(
                    ~served | fail, ACTION_FAILED,
                    jnp.where(fault, ACTION_FAULT,
                              jnp.where(skip, ACTION_SKIPPED,
                                        jnp.where(
                                            plan.kept_fraction[slot] < 1.0,
                                            ACTION_SHED, ACTION_TRAINED)))
                ).astype(jnp.int32)
                telem = FleetTelemetry(
                    action=action,
                    sat=jnp.where(served, slot, -1).astype(jnp.int32),
                    loss=loss,
                    battery_j=jnp.where(served, energy.battery_j[slot],
                                        jnp.nan),
                    n_steps=n_valid,
                    n_infected=faulted_m.sum().astype(jnp.int32))
                # flight recorder: one EV_PASS per (plane, pass) into
                # this plane's ring (t is the absolute pass index, so
                # chained runs land on one timeline with no rebasing)
                ring = ring_record(
                    ring, EV_PASS, k, telem.sat,
                    (action.astype(jnp.float32), telem.battery_j, loss,
                     n_valid.astype(jnp.float32),
                     plan.kept_fraction[slot],
                     (fail | fault).astype(jnp.float32),
                     (jnp.float32(1.0) if sunlit is None
                      else sunlit.astype(jnp.float32)),
                     faulted_m.sum().astype(jnp.float32)))
                return (state, energy, failed, ttl, bidx, ring), telem

            vpass = jax.vmap(
                plane_pass,
                in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, None))

            def pass_body(carry, _):
                state, energy, failed, ttl, bidx, k, ring, ex = carry
                # scheduled failures fire inside the precomputed prefix
                # (bit-parity with the host oracle); beyond it the
                # stream refreshes from jax.random so chained runs keep
                # drawing failures at the same rate
                fail_k = (jnp.take(fail_mask,
                                   jnp.minimum(k, horizon - 1), axis=1)
                          & (k < horizon))
                if fail_prob > 0.0:
                    live = jax.random.uniform(
                        jax.random.fold_in(fail_key, k), (P,)) < fail_prob
                    fail_k = fail_k | (live & (k >= horizon))
                spread_k = jnp.take(
                    spread, jnp.minimum(k, spread.shape[1] - 1), axis=1)
                (state, energy, failed, ttl, bidx, ring), telem = vpass(
                    plane_ids, fail_k, spread_k, byz, state, energy,
                    failed, ttl, bidx, ring, plan, k)
                if ex_async:
                    # contact-window gossip (repro.isl): compressed
                    # delta push + staleness-discounted merge + battery
                    # charge, every pass the window opens — no barrier
                    state, ex, energy, ring = async_gossip_step(
                        exch, state, ex, energy, ring, k, telem.sat,
                        telem.action, wire_bits=ex_bits, e_push_j=ex_e_j,
                        battery_cap=battery_cap, n_planes=P,
                        action_failed=ACTION_FAILED)
                return (state, energy, failed, ttl, bidx, k + 1,
                        ring, ex), telem

            def rev_body(carry, _):
                carry, telem = jax.lax.scan(pass_body, carry, None,
                                            length=L)
                state, energy, failed, ttl, bidx, k, ring, ex = carry
                if ex_sync and avg_every > 0:
                    # the revolution-boundary exchange, codec'd and
                    # metered (repro.isl): compressed delta
                    # reconstructions cross the link, the pushing slot
                    # pays the transmit energy
                    do = (k // L) % avg_every == 0
                    state, ex, energy, ring = sync_exchange_step(
                        exch, cfg.aggregate, state, ex, energy, ring, k,
                        telem.sat[-1], telem.action[-1], do,
                        wire_bits=ex_bits, e_push_j=ex_e_j,
                        battery_cap=battery_cap, n_planes=P,
                        action_failed=ACTION_FAILED)
                elif cfg.exchange is None and avg_every > 0 and P > 1:
                    # inter-plane ISL exchange at the revolution
                    # boundary — robust modes (median / trimmed_mean)
                    # are what survive Byzantine planes
                    do = (k // L) % avg_every == 0
                    state = jax.tree.map(
                        lambda a, o: jnp.where(do, a, o),
                        aggregate_planes(state, cfg.aggregate), state)
                    ring = jax.vmap(
                        lambda r: ring_record(r, EV_EXCHANGE, k, -1,
                                              (1.0,), mask=do))(ring)
                return (state, energy, failed, ttl, bidx, k, ring,
                        ex), telem

            carry, telem = jax.lax.scan(
                rev_body,
                (state, energy, failed, ttl, bidx, k, ring, ex),
                None, length=n_revolutions)
            return carry + (telem,)

        fn = jax.jit(closed_loop, donate_argnums=(0, 1, 2, 3, 4, 6, 7))
        self._fns[n_revolutions] = fn
        return fn

    # --------------------------------------------------------------- run
    def run(self, n_revolutions: Optional[int] = None, *,
            stream_telemetry: bool = False) -> FleetResult:
        """Run R fleet revolutions; chainable (state/aliveness persist).

        ``stream_telemetry=True`` dispatches one revolution at a time
        and syncs its telemetry (exactly one host sync per revolution);
        the default runs all R revolutions in one dispatch with a
        single sync at the end.
        """
        cfg = self.cfg
        R = cfg.n_revolutions if n_revolutions is None else n_revolutions
        if R < 1:
            raise ValueError("need at least one revolution")
        self.state._require_live("fleet closed loop")
        state = dedupe_state_buffers(self.state)
        self.state.mark_consumed()
        energy, failed = self.energy, self._failed
        ttl, bidx, k = self._ttl, self._batch_idx, self._pass_idx
        ex = self._ex_state

        chunks = []
        r_chunk = 1 if stream_telemetry else R
        fn = self._compiled(r_chunk)
        # ring capacity: L passes + exchange markers (one per boundary,
        # or one per contact window when gossiping), per plane —
        # nothing ever drops
        n_ex = (self.rev_len // self.exchange.contact.period + 1
                if self._ex_on and self.exchange.mode == "async" else 1)
        ring_cap = r_chunk * (self.rev_len + n_ex)
        for _ in range(R if stream_telemetry else 1):
            ring = jax.device_put(
                ring_init(ring_cap, batch=(self.n_planes,)), self._shard)
            t0 = time.perf_counter()
            state, energy, failed, ttl, bidx, k, ring, ex, telem = fn(
                state, energy, failed, ttl, bidx, k, ring, ex, self.plan,
                self._fail_mask, self._spread, self._byz)
            # commit the carry per dispatch: an interrupted streaming
            # study keeps every completed revolution and stays chainable
            self.state, self.energy, self._failed = state, energy, failed
            self._ttl, self._batch_idx, self._pass_idx = ttl, bidx, k
            self._ex_state = ex
            self.metrics.inc("device_calls")
            chunks.append(jax.tree.map(np.asarray, telem))  # the ONE sync
            self.metrics.inc("host_syncs")
            self.metrics.histogram("dispatch_s").record(
                time.perf_counter() - t0)
            # ring flush rides the same sync boundary — no extra sync
            # (events carry absolute pass indices, no rebasing needed)
            self.recorder.ingest(ring)

        telem = jax.tree.map(lambda *xs: np.concatenate(xs), *chunks)
        # (R, L, P) -> (P, R*L): plane-major per-pass timelines
        flat = lambda x: np.transpose(x, (2, 0, 1)).reshape(   # noqa: E731
            self.n_planes, -1)
        return FleetResult(
            action=flat(telem.action), sat=flat(telem.sat),
            loss=flat(telem.loss), battery_j=flat(telem.battery_j),
            n_steps=flat(telem.n_steps),
            n_infected=flat(telem.n_infected),
            plan=DevicePassPlan(*[np.asarray(a) for a in self.plan]),
            energy=EnergyState(*[np.asarray(a) for a in energy]),
            failed=np.asarray(failed), fault_ttl=np.asarray(ttl),
            state=state,
            isl_bits=np.asarray(ex.bits), isl_e_j=np.asarray(ex.e_j),
            isl_contacts=np.asarray(ex.n_contacts))


def _smoke(n_sats: int = 8, n_planes: int = 2,
           n_revolutions: int = 2) -> None:       # pragma: no cover
    """``python -m repro.fleet``: host-vs-fleet closed-loop parity with
    join, leave and seeded-failure events, for CI.

    Each plane's host oracle is a :class:`ConstellationSim` with the
    same event schedule and failure seed (``seed + p``), same model
    init and its data ids offset to the plane's global range; the fleet
    must reproduce every action (trained/shed/skip/**failed**), serving
    sat id, loss and battery reading, with ≤ 1 host sync per
    revolution.
    """
    import time

    from repro.core.constellation import (ConstellationConfig,
                                          ConstellationSim)
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.sim.data import DeviceImageryShards
    from repro.sim.device_sim import ACTION_NAMES

    shards = DeviceImageryShards(img=32, batch=4)
    adapter = autoencoder_adapter(cut=5, img=32)
    budget = PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=4e6)
    events = dict(join_events={3: 1}, leave_events={5: 1})
    cfg = FleetConfig(
        n_planes=n_planes, n_revolutions=n_revolutions,
        battery_j=200.0, recharge_w=0.01, reserve_j=150.0,
        max_steps_per_pass=2, fail_prob=0.2, seed=0, avg_every=0,
        **events)

    t0 = time.time()
    fleet = FleetEngine(adapter, budget, shards, cfg)
    M, K = fleet.n_slots, fleet.n_passes
    res = fleet.run(stream_telemetry=True)
    t1 = time.time()
    devs = len(jax.devices())
    print(f"fleet: {n_planes} planes x {n_sats}(+{M - n_sats} join) sats "
          f"x {n_revolutions} revolutions on {devs} device(s), mesh "
          f"{dict(zip(fleet.mesh.axis_names, fleet.mesh.devices.shape))} "
          f"({t1 - t0:.1f}s)")
    print(f"  {res.summary()}")
    print(f"  traces={fleet.traces} device_calls={fleet.device_calls} "
          f"host_syncs={fleet.host_syncs} (<=1/revolution)")
    assert fleet.traces == 1 and fleet.host_syncs <= n_revolutions

    mism = 0
    for p in range(n_planes):
        hcfg = ConstellationConfig(
            n_passes=K, batch_size=4, battery_j=200.0, recharge_w=0.01,
            reserve_j=150.0, max_steps_per_pass=2, fail_prob=0.2,
            seed=cfg.seed + p, **events)
        host = ConstellationSim(
            adapter, budget, lambda s, i, p=p: shards(p * M + s, i), hcfg)
        host.state = SLTrainState.create(
            *adapter.init(jax.random.key(cfg.seed)), host.optimizer)
        host.run()
        h_act = [r.action for r in host.records]
        d_act = [ACTION_NAMES[int(a)] for a in res.action[p]]
        assert h_act == d_act, (p, h_act, d_act)
        assert [r.sat_id for r in host.records] == list(res.sat[p])
        for hr, dl, db in zip(host.records, res.loss[p], res.battery_j[p]):
            if hr.loss is not None:
                mism += abs(dl - hr.loss) > 2e-4 * abs(hr.loss) + 2e-5
            np.testing.assert_allclose(db, hr.battery_j, rtol=1e-5,
                                       atol=0.05)
    assert mism == 0
    s = res.summary()
    assert s["failed"] > 0 and s["skipped"] > 0 and s["trained"] > 0, s
    print(f"  host-vs-fleet parity OK for all {n_planes} planes "
          f"({time.time() - t1:.1f}s host oracle)")


if __name__ == "__main__":                          # pragma: no cover
    _smoke()
