"""Sharded elastic fleet engine: multi-plane constellations on a mesh.

See :mod:`repro.fleet.engine` for the closed loop,
:mod:`repro.fleet.events` for the precomputed membership/failure
schedules that make elastic runs device-resident while keeping the host
:class:`~repro.core.constellation.ConstellationSim` as the parity
oracle, and :mod:`repro.fleet.scenarios` for the degraded-ops scenario
engine (eclipse windows, Byzantine satellites + robust aggregation,
epidemic fault propagation) composing inside the same jitted scan.
"""
from repro.fleet.engine import (FleetConfig, FleetEngine, FleetResult,
                                FleetTelemetry, average_planes)
from repro.fleet.events import (EventSchedule, build_event_schedule,
                                leave_ids, static_schedule)
from repro.fleet.scenarios import (ByzantineConfig, EclipseConfig,
                                   EpidemicConfig, ScenarioConfig,
                                   ScenarioSchedule, aggregate_planes,
                                   build_scenario_schedule,
                                   epidemic_oracle, oracle_actions)

__all__ = [
    "FleetConfig", "FleetEngine", "FleetResult", "FleetTelemetry",
    "average_planes", "EventSchedule", "build_event_schedule",
    "leave_ids", "static_schedule",
    "ByzantineConfig", "EclipseConfig", "EpidemicConfig",
    "ScenarioConfig", "ScenarioSchedule", "aggregate_planes",
    "build_scenario_schedule", "epidemic_oracle", "oracle_actions",
]
