"""Sharded elastic fleet engine: multi-plane constellations on a mesh.

See :mod:`repro.fleet.engine` for the closed loop and
:mod:`repro.fleet.events` for the precomputed membership/failure
schedules that make elastic runs device-resident while keeping the host
:class:`~repro.core.constellation.ConstellationSim` as the parity
oracle.
"""
from repro.fleet.engine import (FleetConfig, FleetEngine, FleetResult,
                                FleetTelemetry, average_planes)
from repro.fleet.events import (EventSchedule, build_event_schedule,
                                static_schedule)

__all__ = [
    "FleetConfig", "FleetEngine", "FleetResult", "FleetTelemetry",
    "average_planes", "EventSchedule", "build_event_schedule",
    "static_schedule",
]
