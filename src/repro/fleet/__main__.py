"""``python -m repro.fleet``: the fleet smoke on a >=2-CPU-device host.

Forces a 2-device CPU topology (when no accelerator/topology is already
configured) BEFORE jax initializes, so the 2-plane smoke actually
exercises plane sharding over a real multi-device mesh — the CI proof
that join/leave/failure events run on device across the mesh with <= 1
host sync per revolution.

Env knobs (small-machine CI): ``REPRO_FLEET_SMOKE_SATS`` (default 8),
``REPRO_FLEET_SMOKE_PLANES`` (default 2), ``REPRO_FLEET_SMOKE_REVS``
(default 2).
"""
import os

if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2").strip()

from repro.fleet.engine import _smoke  # noqa: E402  (after XLA_FLAGS)

_smoke(n_sats=int(os.environ.get("REPRO_FLEET_SMOKE_SATS", "8")),
       n_planes=int(os.environ.get("REPRO_FLEET_SMOKE_PLANES", "2")),
       n_revolutions=int(os.environ.get("REPRO_FLEET_SMOKE_REVS", "2")))
