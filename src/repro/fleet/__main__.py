"""``python -m repro.fleet``: the fleet smokes on a >=2-CPU-device host.

Forces a 2-device CPU topology (when no accelerator/topology is already
configured) BEFORE jax initializes, so the 2-plane smoke actually
exercises plane sharding over a real multi-device mesh — the CI proof
that join/leave/failure events run on device across the mesh with <= 1
host sync per revolution.

``--scenario degraded`` runs the degraded-ops smoke instead: eclipse
windows + one Byzantine slot + epidemic faults with robust aggregation,
asserting finite losses and bit-exact host-prefix action parity
(:func:`repro.fleet.scenarios._smoke_degraded`).

Env knobs (small-machine CI): ``REPRO_FLEET_SMOKE_SATS`` (default 8),
``REPRO_FLEET_SMOKE_PLANES`` (default 2), ``REPRO_FLEET_SMOKE_REVS``
(default 2).
"""
import os
import sys

if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2").strip()

args = sys.argv[1:]
scenario = "baseline"
if args:
    if args[0] != "--scenario" or len(args) != 2 \
            or args[1] not in ("baseline", "degraded"):
        raise SystemExit("usage: python -m repro.fleet "
                         "[--scenario baseline|degraded]")
    scenario = args[1]

kw = dict(
    n_sats=int(os.environ.get("REPRO_FLEET_SMOKE_SATS", "8")),
    n_planes=int(os.environ.get("REPRO_FLEET_SMOKE_PLANES", "2")),
    n_revolutions=int(os.environ.get("REPRO_FLEET_SMOKE_REVS", "2")))

if scenario == "degraded":
    from repro.fleet.scenarios import _smoke_degraded  # noqa: E402

    _smoke_degraded(**kw)
else:
    from repro.fleet.engine import _smoke  # noqa: E402

    _smoke(**kw)
