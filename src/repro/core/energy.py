"""Per-pass energy / latency assembly — paper eqs. (11)-(12).

A :class:`PassBudget` bundles everything that is *fixed* during one
satellite pass (split plan, link distances, device specs); the decision
variables of problem (13) enter as the four per-phase *times*
``(t_proc_sat, t_comm_down, t_proc_gs, t_comm_up)`` in the convex
time-domain reformulation (DESIGN.md §3), or equivalently as the raw
``(f_leo, f_gs, p_leo, p_gs)`` of the paper.

Phase naming follows Fig. 1/2 of the paper with the first split on the
satellite:

  sat-forward  (E_proc at LEO, W1)          ── downlink activations D_tx
  gs-forward+backward (E_proc at GS, W2)    ── uplink boundary grads D_tx
  sat-backward (folded into W1 by the FLOPs accounting of splitting.py)
  ISL handoff of segment-A weights D_ISL    (fixed-rate link, eq. 10)

The paper's eq. (11) has exactly one E_proc and one E_comm per side plus
E_ISL; we keep that structure: ``W1`` already contains forward+backward
work of segment A and ``D_tx`` is transmitted twice (activations down,
gradients up), matching the paper's symmetric-payload assumption
("with the same size assumed for the gradients in the uplink").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.compute_model import DeviceComputeSpec, PAPER_DEVICE
from repro.core.linkbudget import ISLConfig, LinkConfig, PAPER_GS_LINK, PAPER_ISL
from repro.core.orbits import OrbitalPlane, PAPER_PLANE


def clamp_battery(battery, capacity_j):
    """THE battery clamp: charge lives in ``[0, capacity_j]``.

    The single battery policy shared by the host scheduler
    (:mod:`repro.core.constellation`, scalar floats — returns a plain
    float) and the device constellation engine
    (:mod:`repro.sim.energy_state`, ``(N,)`` arrays — returns an
    array); every battery mutation in the repo routes through here.  A
    pass whose allocation would overdraw the battery leaves it empty,
    not negative (the energy *accounting* still records the full
    eq.-(11) cost); solar recharge never exceeds capacity.
    """
    if isinstance(battery, (float, int)):
        return min(max(float(battery), 0.0), float(capacity_j))
    import jax.numpy as jnp

    return jnp.clip(battery, 0.0, capacity_j)


def solar_recharge_j(recharge_w: float, duration_s: float,
                     sunlit: bool = True) -> float:
    """Energy harvested between passes: panel power × pass duration,
    exactly 0 J while the plane is in eclipse.

    The host-side counterpart of the device engine's ``sunlit`` gate in
    :func:`repro.sim.energy_state.recharge` — both add either the full
    ``recharge_w * duration_s`` or a literal 0.0 before clamping, so an
    eclipse window can never perturb host/device battery parity by a
    rounding step.  Shadow geometry (which passes are eclipsed) lives
    in :class:`repro.fleet.scenarios.EclipseConfig`.
    """
    return float(recharge_w) * float(duration_s) * (1.0 if sunlit else 0.0)


@dataclasses.dataclass(frozen=True)
class SplitCosts:
    """The four orbit-aware cost terms of a split plan at one cut point.

    ``w1_flops``/``w2_flops`` are *per item* (fvcore convention, eq. 6);
    ``dtx_bits`` is the boundary payload per item in ONE direction
    (the paper assumes the gradient payload equals the activation
    payload); ``d_isl_bits`` is the segment-A parameter payload shipped
    once per pass over the ISL.
    """

    w1_flops: float          # satellite segment, fwd+bwd FLOPs per item
    w2_flops: float          # ground segment, fwd+bwd FLOPs per item
    dtx_bits: float          # boundary activation bits per item (one way)
    d_isl_bits: float        # segment-A weights in bits (per pass)
    name: str = "split"

    def scaled_boundary(self, factor: float) -> "SplitCosts":
        """Boundary compression (e.g. int8 => factor 0.25) — beyond-paper."""
        return dataclasses.replace(self, dtx_bits=self.dtx_bits * factor,
                                   name=f"{self.name}+q{factor:g}")


@dataclasses.dataclass(frozen=True)
class PassBudget:
    """Everything fixed during one satellite pass (problem 13 constants)."""

    plane: OrbitalPlane = PAPER_PLANE
    link: LinkConfig = PAPER_GS_LINK
    isl: ISLConfig = PAPER_ISL
    sat_device: DeviceComputeSpec = PAPER_DEVICE
    gs_device: DeviceComputeSpec = PAPER_DEVICE
    n_items: float = 400.0            # images processed per pass (Table I)

    @property
    def mean_distance_m(self) -> float:
        return self.plane.mean_slant_range_m()

    @property
    def t_prop_s(self) -> float:
        """One-way GS<->LEO propagation delay at mean distance."""
        return self.plane.mean_prop_delay_s

    def fixed_overhead_s(self, costs: SplitCosts) -> float:
        """Time not controlled by (f, p): 2×propagation + ISL transfer.

        eq. (12): T_prop appears twice (activations down, gradients up);
        the ISL handoff runs at a fixed rate so it is a constant too.
        """
        return 2.0 * self.t_prop_s + self.isl.time_s(costs.d_isl_bits) \
            + self.plane.isl_prop_delay_s

    def time_budget_s(self, costs: SplitCosts) -> float:
        """T_budget = T_pass − fixed overhead, available to the 4 phases."""
        return self.plane.pass_duration_s - self.fixed_overhead_s(costs)

    def isl_energy_j(self, costs: SplitCosts) -> float:
        return self.isl.energy_j(costs.d_isl_bits)


@dataclasses.dataclass(frozen=True)
class Allocation:
    """A solution of problem (13): per-phase times + implied (f, p)."""

    t_proc_sat: float
    t_comm_down: float       # boundary activations, LEO -> GS
    t_proc_gs: float
    t_comm_up: float         # boundary gradients,   GS -> LEO
    f_sat_hz: float
    f_gs_hz: float
    p_down_w: float
    p_up_w: float
    e_proc_sat: float
    e_comm_down: float
    e_proc_gs: float
    e_comm_up: float
    e_isl: float
    t_fixed: float
    feasible: bool = True

    @property
    def e_total(self) -> float:
        """eq. (11)."""
        return (self.e_proc_sat + self.e_comm_down + self.e_proc_gs
                + self.e_comm_up + self.e_isl)

    @property
    def t_total(self) -> float:
        """eq. (12)."""
        return (self.t_proc_sat + self.t_comm_down + self.t_proc_gs
                + self.t_comm_up + self.t_fixed)

    def summary(self) -> dict:
        return {
            "feasible": self.feasible,
            "E_total_J": self.e_total,
            "T_total_s": self.t_total,
            "E_proc_J": self.e_proc_sat + self.e_proc_gs,
            "E_comm_J": self.e_comm_down + self.e_comm_up + self.e_isl,
            "f_sat_MHz": self.f_sat_hz / 1e6,
            "f_gs_MHz": self.f_gs_hz / 1e6,
            "p_down_W": self.p_down_w,
            "p_up_W": self.p_up_w,
        }


def evaluate_raw(budget: PassBudget, costs: SplitCosts,
                 f_sat_hz: float, f_gs_hz: float,
                 p_down_w: float, p_up_w: float) -> Allocation:
    """Evaluate eqs. (11)-(12) for raw decision variables (paper form).

    Each D_tx payload is ``n_items * dtx_bits`` (the whole batch crosses
    the boundary once per pass in each direction).
    """
    n = budget.n_items
    d = budget.mean_distance_m
    down_bits = n * costs.dtx_bits
    up_bits = n * costs.dtx_bits

    t_ps = budget.sat_device.proc_time_s(costs.w1_flops, f_sat_hz, n)
    t_pg = budget.gs_device.proc_time_s(costs.w2_flops, f_gs_hz, n)
    t_cd = budget.link.comm_time_s(down_bits, p_down_w, d) if down_bits else 0.0
    t_cu = budget.link.comm_time_s(up_bits, p_up_w, d) if up_bits else 0.0

    return Allocation(
        t_proc_sat=t_ps, t_comm_down=t_cd, t_proc_gs=t_pg, t_comm_up=t_cu,
        f_sat_hz=f_sat_hz, f_gs_hz=f_gs_hz, p_down_w=p_down_w, p_up_w=p_up_w,
        e_proc_sat=budget.sat_device.proc_energy_j(costs.w1_flops, f_sat_hz, n),
        e_comm_down=budget.link.comm_energy_j(down_bits, p_down_w, d) if down_bits else 0.0,
        e_proc_gs=budget.gs_device.proc_energy_j(costs.w2_flops, f_gs_hz, n),
        e_comm_up=budget.link.comm_energy_j(up_bits, p_up_w, d) if up_bits else 0.0,
        e_isl=budget.isl_energy_j(costs),
        t_fixed=budget.fixed_overhead_s(costs),
        feasible=True,
    )


def allocation_from_times(budget: PassBudget, costs: SplitCosts,
                          t_proc_sat: float, t_comm_down: float,
                          t_proc_gs: float, t_comm_up: float,
                          feasible: bool = True) -> Allocation:
    """Build an Allocation from the time-domain variables (solver output)."""
    n = budget.n_items
    d = budget.mean_distance_m
    down_bits = n * costs.dtx_bits
    up_bits = n * costs.dtx_bits

    def _f(dev: DeviceComputeSpec, w: float, t: float) -> float:
        return dev.freq_for_time(w, t, n) if w > 0 else 0.0

    def _p(bits: float, t: float) -> float:
        return budget.link.power_for_time(bits, t, d) if bits > 0 else 0.0

    f_sat = _f(budget.sat_device, costs.w1_flops, t_proc_sat)
    f_gs = _f(budget.gs_device, costs.w2_flops, t_proc_gs)
    p_down = _p(down_bits, t_comm_down)
    p_up = _p(up_bits, t_comm_up)

    return Allocation(
        t_proc_sat=t_proc_sat if costs.w1_flops > 0 else 0.0,
        t_comm_down=t_comm_down if down_bits > 0 else 0.0,
        t_proc_gs=t_proc_gs if costs.w2_flops > 0 else 0.0,
        t_comm_up=t_comm_up if up_bits > 0 else 0.0,
        f_sat_hz=f_sat, f_gs_hz=f_gs, p_down_w=p_down, p_up_w=p_up,
        e_proc_sat=budget.sat_device.energy_for_time(costs.w1_flops, t_proc_sat, n),
        e_comm_down=budget.link.energy_for_time(down_bits, t_comm_down, d) if down_bits > 0 else 0.0,
        e_proc_gs=budget.gs_device.energy_for_time(costs.w2_flops, t_proc_gs, n),
        e_comm_up=budget.link.energy_for_time(up_bits, t_comm_up, d) if up_bits > 0 else 0.0,
        e_isl=budget.isl_energy_j(costs),
        t_fixed=budget.fixed_overhead_s(costs),
        feasible=feasible,
    )


def direct_download_costs(raw_bits_per_item: float, total_work_flops: float,
                          name: str = "direct-download") -> SplitCosts:
    """Fig. 3 (top) baseline: no split — raw data down, all compute on GS.

    W1 = 0 (satellite does no model work), D_tx = raw image bits, no ISL
    handoff (there is no on-sat model segment to move).  The gradient
    uplink payload is 0 in this baseline; we model that by halving via
    a dedicated flag — instead we simply fold it: direct download sends
    raw data one way only, so we encode dtx as *half* the round payload.
    To keep eq. (11) structure (which charges dtx twice), we pass
    dtx_bits = raw/2 so the total transmitted volume equals raw.
    """
    return SplitCosts(w1_flops=0.0, w2_flops=total_work_flops,
                      dtx_bits=raw_bits_per_item / 2.0, d_isl_bits=0.0,
                      name=name)
