"""The split-learning train step (paper Fig. 1 steps 1-8, on real models).

One SL step over a batch at the current satellite:

  (1-2) satellite forward on segment A          -> boundary activations z
  (3)   downlink z (optionally int8-quantized)           [D_tx, eq. 8-9]
  (4-5) ground forward+loss+backward on segment B
  (6)   uplink boundary gradient dz (optionally quantized)
  (7)   satellite backward through segment A (jax.vjp)
  (8)   both sides apply SGD; at pass end segment A ships over the ISL.

The step is built once per (model, cut) via an adapter; the actual
boundary tensors and their exact bit-counts are returned so the energy
accounting (core/energy) charges what the model really transmitted, not
a spec-sheet estimate.

Boundary quantization (beyond-paper) uses the split_quant kernel's STE
wrapper so training remains end-to-end differentiable.

Pass engine (the per-pass hot path)
-----------------------------------
:func:`make_sl_step` runs ONE step per jitted call; a pass that the
problem-(13) allocation budgets for k steps used to pay k Python
dispatches plus k eager optimizer updates.  :func:`make_sl_pass` fuses
the whole pass into a single jitted ``jax.lax.scan``: one
:class:`~repro.core.train_state.SLTrainState` (both segments' params +
optimizer states + step counter) threads through the scan carry
(buffers donated, so segment weights update in place across the pass;
the input state is marked consumed), batches are stacked along the scan
axis, and the per-step losses come back as one (k,) array.  The
optimizer is pluggable (:class:`~repro.train.optimizer.Optimizer` —
SGD or AdamW with its lr schedule) and updates inside the scan body.
Step counts are bucketed to the next power of two with a per-step
validity mask — padded steps leave the carry untouched — so
recompilation is O(log k) over a constellation run instead of one
compile per distinct allocation.  The scanned step applies exactly the
same grads + optimizer update as the scalar path, so k scanned SGD
steps match k sequential ``make_sl_step`` + ``sgd_update`` calls
loss-for-loss.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import SplitCosts
from repro.core.splitting import SplitPlan
from repro.kernels import ops
# padded step counts share the repo-wide bucketing schedule (pow2 up to
# 16, then 1/8-octave) with the solver backend's batch padding
from repro.utils.bucketing import bucket_size as _bucket_size


@dataclasses.dataclass(frozen=True)
class SplitAdapter:
    """Model-agnostic view of a cut model."""

    name: str
    init: Callable[[Any], Tuple[Any, Any]]          # rng -> (params_a, params_b)
    forward_a: Callable[[Any, Dict], jnp.ndarray]   # (params_a, batch) -> z
    loss_b: Callable[[Any, jnp.ndarray, Dict], jnp.ndarray]
    plan: SplitPlan
    cut_index: int

    def costs(self, act_bits: int = 32) -> SplitCosts:
        return self.plan.costs_at(self.cut_index)


@dataclasses.dataclass
class SLStepResult:
    loss: jnp.ndarray
    grads_a: Any
    grads_b: Any
    dtx_bits_down: int                  # measured boundary payload (one way)
    dtx_bits_up: int


def _make_sl_grads(adapter: SplitAdapter, quantize_boundary: bool):
    """The traced body shared by make_sl_step and make_sl_pass:
    (params_a, params_b, batch) -> (loss, g_a, g_b, payload_bits)."""

    q_bits = 8 if quantize_boundary else 32

    def sl_grads(params_a, params_b, batch):
        # satellite forward, with vjp closure kept for step (7)
        z, vjp_a = jax.vjp(lambda pa: adapter.forward_a(pa, batch), params_a)
        z_tx = ops.ste_quantize(z) if quantize_boundary else z

        # ground: loss + backward wrt segment B and wrt the boundary
        def ground(pb, zz):
            return adapter.loss_b(pb, zz, batch)

        loss, (g_b, g_z) = jax.value_and_grad(ground, argnums=(0, 1))(
            params_b, z_tx)

        # uplink gradient (quantized the same way on the return path)
        g_z_tx = ops.ste_quantize(g_z) if quantize_boundary else g_z
        (g_a,) = vjp_a(g_z_tx.astype(z.dtype))

        payload = z.size * q_bits
        return loss, g_a, g_b, payload

    return sl_grads


def make_sl_step(adapter: SplitAdapter, *, quantize_boundary: bool = False):
    """Returns jit'd sl_step(params_a, params_b, batch) -> SLStepResult."""

    jitted = jax.jit(_make_sl_grads(adapter, quantize_boundary))

    def run(params_a, params_b, batch) -> SLStepResult:
        loss, g_a, g_b, payload = jitted(params_a, params_b, batch)
        return SLStepResult(loss=loss, grads_a=g_a, grads_b=g_b,
                            dtx_bits_down=int(payload),
                            dtx_bits_up=int(payload))

    return run


def boundary_bits(adapter: SplitAdapter, batch,
                  quantize_boundary: bool = False) -> int:
    """Exact one-way boundary payload (bits) for ``batch`` — shape-only.

    Uses ``jax.eval_shape`` on the satellite segment, so measuring the
    payload for the energy model costs no FLOPs (the old protocol ran a
    full probe train step just to read off ``z.size``).
    """
    params_shape = jax.eval_shape(adapter.init, jax.random.key(0))[0]
    z = jax.eval_shape(adapter.forward_a, params_shape, batch)
    return z.size * (8 if quantize_boundary else 32)


def _batch_shape_key(batch):
    return (jax.tree_util.tree_structure(batch),
            tuple((x.shape, str(x.dtype)) for x in jax.tree.leaves(batch)))


def make_boundary_meter(adapter: SplitAdapter,
                        quantize_boundary: bool = False):
    """A :func:`boundary_bits` memoized per batch shape.

    The shared payload cache for the pass engine and the constellation
    scheduler: steady-state passes (constant batch shapes) trace the
    satellite segment exactly once.
    """
    cache: Dict[Any, int] = {}

    def measure(batch) -> int:
        key = _batch_shape_key(batch)
        bits = cache.get(key)
        if bits is None:
            bits = boundary_bits(adapter, batch, quantize_boundary)
            cache[key] = bits
        return bits

    return measure


def ring_boundary_bits(adapter: SplitAdapter, batches: Sequence[Dict],
                       quantize_boundary: bool = False) -> np.ndarray:
    """Per-satellite boundary payloads (bits, one way) as ONE array.

    ``batches`` holds one representative batch per ring member (their
    shapes may differ — non-IID shards, ragged tails); the result is the
    array feed for the device-resident planner
    (:func:`repro.core.mission.sweep_revolutions` ``dtx_bits=`` or the
    per-satellite instance lists of ``plan_revolution``) instead of a
    Python-int-at-a-time protocol.  Shape-only via ``jax.eval_shape``,
    memoized per distinct shape.
    """
    meter = make_boundary_meter(adapter, quantize_boundary)
    return np.asarray([float(meter(b)) for b in batches], dtype=np.float64)


# --------------------------------------------------------------------------
# The scan-fused pass engine.
# --------------------------------------------------------------------------

def make_pass_step(adapter: SplitAdapter, optimizer, *,
                   quantize_boundary: bool = False):
    """The shared masked SL step kernel: one traced train step.

    ``pass_step(state, batch, valid) -> (new_state, loss)``

    Runs one split-learning step (both grads + the optimizer update on
    an :class:`~repro.core.train_state.SLTrainState`) and gates it on
    ``valid``: an invalid step passes the whole carry through untouched
    and reports NaN loss.  This is THE scan body of the repo — used by
    :func:`make_sl_pass` (padded / planner-masked steps of one fused
    pass) and by the device constellation engine
    (:mod:`repro.sim.device_sim`, where skip-below-reserve passes and
    beyond-allocation steps mask the same way) — so host and device
    closed loops train through literally the same kernel.
    """
    sl_grads = _make_sl_grads(adapter, quantize_boundary)

    def pass_step(state, batch, valid):
        loss, g_a, g_b, _ = sl_grads(state.params_a, state.params_b, batch)
        state = state.apply_updates(g_a, g_b, optimizer, where=valid)
        return state, jnp.where(valid, loss, jnp.nan)

    return pass_step


def dedupe_state_buffers(state):
    """Copy leaves that alias the same buffer (e.g. a tied LM embedding
    shared between segments A and B): XLA rejects donating one buffer
    twice, and the segments diverge after the first update anyway.
    Shared by every donating engine (fused pass, device sim)."""
    seen = set()

    def uniq(x):
        if id(x) in seen:
            return jnp.copy(x)
        seen.add(id(x))
        return x

    return jax.tree.map(uniq, state)


@dataclasses.dataclass
class SLPassResult:
    """One whole pass: k fused SL steps + optimizer updates, as a state.

    ``state`` is the :class:`~repro.core.train_state.SLTrainState` after
    the pass; the ``params_a``/``params_b``/``opt_a``/``opt_b``
    properties are read-only conveniences over it.

    When the pass ran with a device-side ``n_valid`` (planner-driven
    step count), ``losses`` still has static length k but entries at or
    beyond the allocated count are NaN — aggregate with ``nanmean``.
    """

    losses: jnp.ndarray                 # (k,) per-step training loss
    state: Any                          # SLTrainState after the pass
    n_steps: int
    dtx_bits_down: int                  # boundary payload per step (one way)
    dtx_bits_up: int

    @property
    def params_a(self):
        return self.state.params_a

    @property
    def params_b(self):
        return self.state.params_b

    @property
    def opt_a(self):
        return self.state.opt_a

    @property
    def opt_b(self):
        return self.state.opt_b




def make_sl_pass(adapter: SplitAdapter, *, quantize_boundary: bool = False,
                 optimizer=None, lr: float = 1e-2, grad_clip: float = 1.0,
                 donate: bool = True, bucket: bool = True):
    """Returns a fused pass executor running k SL steps in one jitted call.

    ``sl_pass(state, batches) -> SLPassResult``

    ``state`` is an :class:`~repro.core.train_state.SLTrainState`; it
    rides the ``lax.scan`` carry and (with ``donate=True``) its buffers
    are donated to the call, so a pass updates segment weights in place
    instead of round-tripping k times through Python.  The input state
    is marked *consumed* — chain ``result.state`` forward; reusing a
    consumed state raises instead of crashing on freed buffers.

    ``optimizer`` is an :class:`~repro.train.optimizer.Optimizer`, a
    registered name (``"sgd"``/``"adamw"``), or None for SGD built from
    the legacy ``lr``/``grad_clip`` kwargs.  Any optimizer whose state
    is a pytree works — the update runs inside the scan body.

    ``batches`` is either a list of k per-step batch dicts (shapes may
    vary between steps — consecutive same-shape groups are scanned and
    chained) or one pytree whose leaves carry a leading scan axis of
    length k.  With ``bucket=True`` k is padded to a bucketed step count
    (powers of two up to 16, then 1/8-octave granularity, see
    ``_bucket_size``) with masked no-op steps — the carry passes through
    unchanged — keeping recompiles rare at <=25% worst-case padded
    compute.

    ``sl_pass(state, batches, n_valid=...)`` accepts a *device* integer
    scalar bounding how many of the k steps actually train — the raw
    output of the on-device revolution planner
    (:meth:`~repro.core.mission.RevolutionSweep.steps_for`).  Steps at
    index >= n_valid are carry passthroughs and report NaN loss, so the
    planner's allocation drives the pass with no host synchronization.
    """
    from repro.core.train_state import SLTrainState
    from repro.train.optimizer import resolve_optimizer

    opt = resolve_optimizer(optimizer, lr=lr, grad_clip=grad_clip)
    # padded steps leave the whole carry (params, opt, step) untouched —
    # the masking lives inside the shared kernel (make_pass_step)
    step_kernel = make_pass_step(adapter, opt,
                                 quantize_boundary=quantize_boundary)
    measure_payload = make_boundary_meter(adapter, quantize_boundary)

    def one_step(state, xs):
        batch, valid = xs
        return step_kernel(state, batch, valid)

    def scan_pass(state, batches, valid):
        return jax.lax.scan(one_step, state, (batches, valid))

    jitted = jax.jit(scan_pass, donate_argnums=(0,) if donate else ())

    def run_state(state, batches: Union[Sequence[Dict], Dict],
                  n_valid=None) -> SLPassResult:
        # even a donate=False pass must reject a consumed state: its
        # buffers may already be freed by the pass that consumed it
        state._require_live("pass")
        if isinstance(batches, (list, tuple)):
            if not batches:
                raise ValueError("a pass needs at least one batch")
            keys = [_batch_shape_key(b) for b in batches]
            if any(key != keys[0] for key in keys):
                if n_valid is not None:
                    raise ValueError("n_valid requires same-shape batches "
                                     "(one fused scan)")
                # ragged pass (e.g. a partial final shard batch): scan
                # consecutive same-shape groups, chaining the donated
                # state between them.  Payload is reported for the first
                # group's step shape.
                results = []
                i = 0
                while i < len(batches):
                    j = i + 1
                    while j < len(batches) and keys[j] == keys[i]:
                        j += 1
                    r = run_state(state, list(batches[i:j]))
                    state = r.state
                    results.append(r)
                    i = j
                return SLPassResult(
                    losses=jnp.concatenate([r.losses for r in results]),
                    state=state, n_steps=len(batches),
                    dtx_bits_down=results[0].dtx_bits_down,
                    dtx_bits_up=results[0].dtx_bits_up)
            batches = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
        k = jax.tree.leaves(batches)[0].shape[0]
        if k == 0:
            raise ValueError("a pass needs at least one batch")
        payload = measure_payload(jax.tree.map(lambda x: x[0], batches))
        kb = _bucket_size(k) if bucket else k
        if kb > k:
            # pad the scan axis by repeating the last batch; the validity
            # mask turns those steps into carry passthroughs.
            batches = jax.tree.map(
                lambda x: jnp.concatenate(
                    [x, jnp.repeat(x[-1:], kb - k, axis=0)]), batches)
        if n_valid is None:
            valid = jnp.arange(kb) < k
        else:
            # device-resident step budget (e.g. RevolutionSweep.steps_for):
            # the comparison runs on device — no host sync of the plan
            valid = jnp.arange(kb) < jnp.minimum(
                jnp.asarray(n_valid, jnp.int32), k)
        call_state = dedupe_state_buffers(state) if donate else state
        new_state, losses = jitted(call_state, batches, valid)
        if donate:
            state.mark_consumed()
        return SLPassResult(losses=losses[:k], state=new_state, n_steps=k,
                            dtx_bits_down=payload, dtx_bits_up=payload)

    def run(state, batches, n_valid=None) -> SLPassResult:
        if not isinstance(state, SLTrainState):
            raise TypeError(
                "sl_pass(state, batches) expects an SLTrainState (the old "
                "4-tuple (params_a, params_b, opt_a, opt_b, batches) call "
                "was removed; build one with SLTrainState.create), got "
                f"{type(state).__name__}")
        return run_state(state, batches, n_valid=n_valid)

    return run


# --------------------------------------------------------------------------
# Adapters for the paper's models and the LM track.
# --------------------------------------------------------------------------

def autoencoder_adapter(cut: int = 5, img: int = 64, base: int = 16,
                        latent_ch: int = 3) -> SplitAdapter:
    """Encoder (satellite) / decoder (ground) — paper §V-A (cut=5)."""
    from repro.core.splitting import autoencoder_plan
    from repro.models import vision
    from repro.models.param import init_params

    names = vision.ae_stage_names()

    def _init(rng):
        p = init_params(vision.ae_abstract_params(base, latent_ch), rng)
        pa = {k: p[k] for k in names[:cut]}
        pb = {k: p[k] for k in names[cut:]}
        return pa, pb

    def fa(pa, batch):
        return vision.ae_apply_range(pa, batch["images"], 0, cut)

    def lb(pb, z, batch):
        recon = vision.ae_apply_range(pb, z, cut, len(names))
        return jnp.mean(jnp.square(recon.astype(jnp.float32)
                                   - batch["images"].astype(jnp.float32)))

    return SplitAdapter("autoencoder", _init, fa, lb,
                        plan=autoencoder_plan(img=img, base=base,
                                              latent_ch=latent_ch),
                        cut_index=cut)


def resnet18_adapter(cut: int = 5, img: int = 64,
                     n_classes: int = 10) -> SplitAdapter:
    """ResNet-18 classification, Table II cuts l1/l2/l3 = 3/5/7."""
    from repro.core.splitting import resnet18_plan
    from repro.models import vision
    from repro.models.param import init_params

    names = vision.RESNET_STAGES

    def _init(rng):
        p = init_params(vision.resnet18_abstract_params(n_classes), rng)
        pa = {k: p[k] for k in names[:cut]}
        pb = {k: p[k] for k in names[cut:]}
        return pa, pb

    def fa(pa, batch):
        return vision.resnet18_apply_range(pa, batch["images"], 0, cut)

    def lb(pb, z, batch):
        logits = vision.resnet18_apply_range(pb, z, cut, len(names))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, batch["labels"][:, None],
                                 axis=-1)[:, 0]
        return jnp.mean(lse - ll)

    return SplitAdapter("resnet18", _init, fa, lb,
                        plan=resnet18_plan(img=img, n_classes=n_classes),
                        cut_index=cut)


def lm_adapter(cfg, cut_units: int, seq_len: int) -> SplitAdapter:
    """LM split at a pattern-unit boundary: embed+units[:u] on-sat."""
    from repro.core.splitting import lm_plan
    from repro.models import lm
    from repro.models.layers import Ctx

    pat_len = len(cfg.pattern_unit())
    cut_blocks = cut_units * pat_len
    ctx = Ctx(cfg=cfg, act_dtype=jnp.float32)

    def _init(rng):
        p = lm.init(cfg, rng)
        pa = {"embed": p["embed"],
              "units": jax.tree.map(lambda t: t[:cut_units], p["units"])}
        pb = {"units": jax.tree.map(lambda t: t[cut_units:], p["units"]),
              "final_norm": p["final_norm"]}
        if "head" in p:
            pb["head"] = p["head"]
        else:
            pb["head_tied"] = p["embed"]     # ground needs the head copy
        if "shared" in p:
            pa["shared"] = p["shared"]
            pb["shared"] = p["shared"]
        return pa, pb

    def fa(pa, batch):
        return lm.forward_segment(cfg, pa, None, 0, cut_blocks, ctx=ctx,
                                  tokens=batch["tokens"])

    def lb(pb, z, batch):
        pfull = dict(pb)
        if "head_tied" in pb:
            pfull = {k: v for k, v in pb.items() if k != "head_tied"}
            pfull["embed"] = pb["head_tied"]
            cfg_b = cfg
        else:
            cfg_b = cfg
        logits = lm.forward_segment(
            cfg_b, pfull, z, cut_blocks, lm.n_blocks(cfg), ctx=ctx,
            unit_offset=cut_units)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    return SplitAdapter(cfg.name, _init, fa, lb,
                        plan=lm_plan(cfg, seq_len), cut_index=cut_blocks)
