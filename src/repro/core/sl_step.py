"""The split-learning train step (paper Fig. 1 steps 1-8, on real models).

One SL step over a batch at the current satellite:

  (1-2) satellite forward on segment A          -> boundary activations z
  (3)   downlink z (optionally int8-quantized)           [D_tx, eq. 8-9]
  (4-5) ground forward+loss+backward on segment B
  (6)   uplink boundary gradient dz (optionally quantized)
  (7)   satellite backward through segment A (jax.vjp)
  (8)   both sides apply SGD; at pass end segment A ships over the ISL.

The step is built once per (model, cut) via an adapter; the actual
boundary tensors and their exact bit-counts are returned so the energy
accounting (core/energy) charges what the model really transmitted, not
a spec-sheet estimate.

Boundary quantization (beyond-paper) uses the split_quant kernel's STE
wrapper so training remains end-to-end differentiable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.energy import SplitCosts
from repro.core.splitting import SplitPlan
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SplitAdapter:
    """Model-agnostic view of a cut model."""

    name: str
    init: Callable[[Any], Tuple[Any, Any]]          # rng -> (params_a, params_b)
    forward_a: Callable[[Any, Dict], jnp.ndarray]   # (params_a, batch) -> z
    loss_b: Callable[[Any, jnp.ndarray, Dict], jnp.ndarray]
    plan: SplitPlan
    cut_index: int

    def costs(self, act_bits: int = 32) -> SplitCosts:
        return self.plan.costs_at(self.cut_index)


@dataclasses.dataclass
class SLStepResult:
    loss: jnp.ndarray
    grads_a: Any
    grads_b: Any
    dtx_bits_down: int                  # measured boundary payload (one way)
    dtx_bits_up: int


def make_sl_step(adapter: SplitAdapter, *, quantize_boundary: bool = False):
    """Returns jit'd sl_step(params_a, params_b, batch) -> SLStepResult."""

    q_bits = 8 if quantize_boundary else 32

    def sl_step(params_a, params_b, batch):
        # satellite forward, with vjp closure kept for step (7)
        z, vjp_a = jax.vjp(lambda pa: adapter.forward_a(pa, batch), params_a)
        z_tx = ops.ste_quantize(z) if quantize_boundary else z

        # ground: loss + backward wrt segment B and wrt the boundary
        def ground(pb, zz):
            return adapter.loss_b(pb, zz, batch)

        loss, (g_b, g_z) = jax.value_and_grad(ground, argnums=(0, 1))(
            params_b, z_tx)

        # uplink gradient (quantized the same way on the return path)
        g_z_tx = ops.ste_quantize(g_z) if quantize_boundary else g_z
        (g_a,) = vjp_a(g_z_tx.astype(z.dtype))

        payload = z.size * q_bits
        return loss, g_a, g_b, payload

    jitted = jax.jit(sl_step)

    def run(params_a, params_b, batch) -> SLStepResult:
        loss, g_a, g_b, payload = jitted(params_a, params_b, batch)
        return SLStepResult(loss=loss, grads_a=g_a, grads_b=g_b,
                            dtx_bits_down=int(payload),
                            dtx_bits_up=int(payload))

    return run


# --------------------------------------------------------------------------
# Adapters for the paper's models and the LM track.
# --------------------------------------------------------------------------

def autoencoder_adapter(cut: int = 5, img: int = 64, base: int = 16,
                        latent_ch: int = 3) -> SplitAdapter:
    """Encoder (satellite) / decoder (ground) — paper §V-A (cut=5)."""
    from repro.core.splitting import autoencoder_plan
    from repro.models import vision
    from repro.models.param import init_params

    names = vision.ae_stage_names()

    def _init(rng):
        p = init_params(vision.ae_abstract_params(base, latent_ch), rng)
        pa = {k: p[k] for k in names[:cut]}
        pb = {k: p[k] for k in names[cut:]}
        return pa, pb

    def fa(pa, batch):
        return vision.ae_apply_range(pa, batch["images"], 0, cut)

    def lb(pb, z, batch):
        recon = vision.ae_apply_range(pb, z, cut, len(names))
        return jnp.mean(jnp.square(recon.astype(jnp.float32)
                                   - batch["images"].astype(jnp.float32)))

    return SplitAdapter("autoencoder", _init, fa, lb,
                        plan=autoencoder_plan(img=img, base=base,
                                              latent_ch=latent_ch),
                        cut_index=cut)


def resnet18_adapter(cut: int = 5, img: int = 64,
                     n_classes: int = 10) -> SplitAdapter:
    """ResNet-18 classification, Table II cuts l1/l2/l3 = 3/5/7."""
    from repro.core.splitting import resnet18_plan
    from repro.models import vision
    from repro.models.param import init_params

    names = vision.RESNET_STAGES

    def _init(rng):
        p = init_params(vision.resnet18_abstract_params(n_classes), rng)
        pa = {k: p[k] for k in names[:cut]}
        pb = {k: p[k] for k in names[cut:]}
        return pa, pb

    def fa(pa, batch):
        return vision.resnet18_apply_range(pa, batch["images"], 0, cut)

    def lb(pb, z, batch):
        logits = vision.resnet18_apply_range(pb, z, cut, len(names))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, batch["labels"][:, None],
                                 axis=-1)[:, 0]
        return jnp.mean(lse - ll)

    return SplitAdapter("resnet18", _init, fa, lb,
                        plan=resnet18_plan(img=img, n_classes=n_classes),
                        cut_index=cut)


def lm_adapter(cfg, cut_units: int, seq_len: int) -> SplitAdapter:
    """LM split at a pattern-unit boundary: embed+units[:u] on-sat."""
    from repro.core.splitting import lm_plan
    from repro.models import lm
    from repro.models.layers import Ctx

    pat_len = len(cfg.pattern_unit())
    cut_blocks = cut_units * pat_len
    ctx = Ctx(cfg=cfg, act_dtype=jnp.float32)

    def _init(rng):
        p = lm.init(cfg, rng)
        pa = {"embed": p["embed"],
              "units": jax.tree.map(lambda t: t[:cut_units], p["units"])}
        pb = {"units": jax.tree.map(lambda t: t[cut_units:], p["units"]),
              "final_norm": p["final_norm"]}
        if "head" in p:
            pb["head"] = p["head"]
        else:
            pb["head_tied"] = p["embed"]     # ground needs the head copy
        if "shared" in p:
            pa["shared"] = p["shared"]
            pb["shared"] = p["shared"]
        return pa, pb

    def fa(pa, batch):
        return lm.forward_segment(cfg, pa, None, 0, cut_blocks, ctx=ctx,
                                  tokens=batch["tokens"])

    def lb(pb, z, batch):
        pfull = dict(pb)
        if "head_tied" in pb:
            pfull = {k: v for k, v in pb.items() if k != "head_tied"}
            pfull["embed"] = pb["head_tied"]
            cfg_b = cfg
        else:
            cfg_b = cfg
        logits = lm.forward_segment(
            cfg_b, pfull, z, cut_blocks, lm.n_blocks(cfg), ctx=ctx,
            unit_offset=cut_units)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    return SplitAdapter(cfg.name, _init, fa, lb,
                        plan=lm_plan(cfg, seq_len), cut_index=cut_blocks)
