"""Orbital mechanics of the LEO constellation — paper eqs. (1)-(5).

Everything here is closed-form scalar math (float64 numpy); it feeds the
per-pass time budget of the energy optimizer (problem 13) and the pass
scheduler in :mod:`repro.core.constellation`.

Erratum implemented (see DESIGN.md §6): eq. (4) of the paper reads
``T_pass = T_o * alpha_pass / pi`` but the geometry (and the paper's own
quoted ``T_pass ≈ 3.8 min`` for the Table I parameters) requires the
full-circle normalization ``T_o * alpha_pass / (2*pi)``.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

# Physical constants (SI).
R_EARTH_M = 6_371_000.0          # mean Earth radius [m]
MU_EARTH = 3.986_004_418e14      # G*M of Earth [m^3/s^2]
C_LIGHT = 299_792_458.0          # speed of light [m/s]


@dataclasses.dataclass(frozen=True)
class OrbitalPlane:
    """A single orbital ring of ``n_sats`` evenly spaced satellites.

    Matches the network architecture of paper §III-A: the ground terminal
    sees exactly one satellite at a time; after ``T_pass`` the next
    satellite in the ring takes over.
    """

    n_sats: int = 25
    altitude_m: float = 550_000.0
    min_elevation_rad: float = math.radians(30.0)

    # --- eq. (1): orbital period -------------------------------------
    @property
    def period_s(self) -> float:
        a = R_EARTH_M + self.altitude_m
        return 2.0 * math.pi * math.sqrt(a**3 / MU_EARTH)

    # --- eq. (2): slant range at elevation eps -----------------------
    def slant_range_m(self, elevation_rad: float) -> float:
        re, h = R_EARTH_M, self.altitude_m
        s = math.sin(elevation_rad)
        return math.sqrt(re**2 * s**2 + 2.0 * re * h + h**2) - re * s

    @property
    def max_slant_range_m(self) -> float:
        """Largest GS<->LEO distance, at the minimum elevation angle."""
        return self.slant_range_m(self.min_elevation_rad)

    # --- eq. (3): Earth-central angle swept during a pass -------------
    @property
    def pass_central_angle_rad(self) -> float:
        re, h = R_EARTH_M, self.altitude_m
        d = self.max_slant_range_m
        cosarg = ((re + h) ** 2 + re**2 - d**2) / (2.0 * (re**2 + re * h))
        cosarg = min(1.0, max(-1.0, cosarg))
        return 2.0 * math.acos(cosarg)

    # --- eq. (4) with the /(2*pi) erratum fix --------------------------
    @property
    def pass_duration_s(self) -> float:
        return self.period_s * self.pass_central_angle_rad / (2.0 * math.pi)

    # --- eq. (5): intra-plane inter-satellite distance -----------------
    @property
    def isl_distance_m(self) -> float:
        return 2.0 * (R_EARTH_M + self.altitude_m) * math.sin(math.pi / self.n_sats)

    # --- propagation helpers used by eq. (12) --------------------------
    @functools.lru_cache(maxsize=64)
    def mean_slant_range_m(self, n_samples: int = 256) -> float:
        """Average GS<->LEO distance over the visible arc.

        The elevation sweeps ``eps_min -> 90° -> eps_min``; by symmetry we
        average d(eps) over the half-arc parameterized by the central
        angle (uniform in time for a circular orbit).  Memoized per plane
        (the dataclass is frozen/hashable): this sits on the hot path of
        every problem-(13) solve, and re-running the quadrature per solve
        used to dominate constellation-scale sweeps.
        """
        re, h = R_EARTH_M, self.altitude_m
        alpha_half = self.pass_central_angle_rad / 2.0
        # central angle offset from nadir-closest point, uniform in time
        phi = alpha_half * (np.arange(n_samples) + 0.5) / n_samples
        # law of cosines between GS (radius re) and sat (radius re+h)
        d = np.sqrt(re**2 + (re + h) ** 2 - 2.0 * re * (re + h) * np.cos(phi))
        return float(d.mean())

    @property
    def mean_prop_delay_s(self) -> float:
        return self.mean_slant_range_m() / C_LIGHT

    @property
    def isl_prop_delay_s(self) -> float:
        return self.isl_distance_m / C_LIGHT

    def summary(self) -> dict:
        return {
            "n_sats": self.n_sats,
            "altitude_km": self.altitude_m / 1e3,
            "period_min": self.period_s / 60.0,
            "pass_duration_s": self.pass_duration_s,
            "pass_duration_min": self.pass_duration_s / 60.0,
            "max_slant_range_km": self.max_slant_range_m / 1e3,
            "mean_slant_range_km": self.mean_slant_range_m() / 1e3,
            "isl_distance_km": self.isl_distance_m / 1e3,
            "pass_central_angle_deg": math.degrees(self.pass_central_angle_rad),
        }


# Paper Table I constellation.
PAPER_PLANE = OrbitalPlane(n_sats=25, altitude_m=550_000.0, min_elevation_rad=math.radians(30.0))
