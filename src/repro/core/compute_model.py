"""Processing model — paper eqs. (6)-(7).

``T_proc(W, f_p) = n_items * W / (N_c * N_FLOPS * f_p)`` and the cubic
CPU power law ``P(f) = P_p * (f/f_max)^3`` give

``E_proc(W, f_p) = n_items * W * P_p * f_p^2 / (N_c * N_FLOPS * f_max^3)``.

Units erratum (DESIGN.md §6): the paper calls the multiplier ``D`` "the
input size (e.g. pixels)" but every §V numeric result requires it to be the
*number of data items processed per pass* (400 images); ``W`` is FLOPs per
item (fvcore convention). We name it ``n_items``.

``DeviceComputeSpec`` also supports an accelerator-style parameterization
(peak FLOP/s at f_max) so the same energy model covers TPU-class payloads
for the scaled-out track — ``peak_flops = n_cores * flops_per_cycle * f_max``
either way.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class DeviceComputeSpec:
    """A DVFS-capable processor (Table I "Computing" block)."""

    name: str = "paper-device"
    power_max_w: float = 15.0          # P_p: power at f_max
    f_max_hz: float = 625e6            # maximum clock
    n_cores: int = 1024                # N_c
    flops_per_cycle: float = 2.0       # N_FLOPS

    @property
    def peak_flops(self) -> float:
        return self.n_cores * self.flops_per_cycle * self.f_max_hz

    # --- eq. (6) ---------------------------------------------------------
    def proc_time_s(self, work_flops: float, f_hz: float, n_items: float = 1.0) -> float:
        if work_flops <= 0:
            return 0.0
        if f_hz <= 0:
            return math.inf
        return n_items * work_flops / (self.n_cores * self.flops_per_cycle * f_hz)

    # --- eq. (7) ---------------------------------------------------------
    def proc_energy_j(self, work_flops: float, f_hz: float, n_items: float = 1.0) -> float:
        return (
            n_items
            * work_flops
            * self.power_max_w
            * f_hz**2
            / (self.n_cores * self.flops_per_cycle * self.f_max_hz**3)
        )

    # --- time-domain form used by the convex solver ------------------------
    def min_proc_time_s(self, work_flops: float, n_items: float = 1.0) -> float:
        return self.proc_time_s(work_flops, self.f_max_hz, n_items)

    def freq_for_time(self, work_flops: float, t_s: float, n_items: float = 1.0) -> float:
        if work_flops <= 0:
            return 0.0
        if t_s <= 0:
            return math.inf
        return n_items * work_flops / (self.n_cores * self.flops_per_cycle * t_s)

    def energy_for_time(self, work_flops: float, t_s: float, n_items: float = 1.0) -> float:
        """E(t) = k / t^2 with k = P_p/f_max^3 * (n*W/(N_c*N_F))^3: convex, decreasing."""
        if work_flops <= 0:
            return 0.0
        nw = n_items * work_flops / (self.n_cores * self.flops_per_cycle)
        k = self.power_max_w / self.f_max_hz**3 * nw**3
        if k == 0.0:                    # sub-normal work: no meaningful phase
            return 0.0
        if t_s <= 0:
            return math.inf
        return k / (t_s * t_s)


# Table I device (used for both GS and LEO in the paper's evaluation).
PAPER_DEVICE = DeviceComputeSpec()

# A TPU-v5e-class payload for the scaled-out track (197 TFLOP/s bf16).
TPU_V5E_SPEC = DeviceComputeSpec(
    name="tpu-v5e",
    power_max_w=170.0,
    f_max_hz=940e6,
    n_cores=1,
    flops_per_cycle=197e12 / 940e6,
)
