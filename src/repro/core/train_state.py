"""`SLTrainState`: the one-object train state of the split-learning loop.

The pass engine used to thread FOUR loose pytrees — ``params_a``,
``params_b`` and both optimizer states — through every call, which made
the donated-buffer contract easy to violate (pass the same ``params_a``
into two fused passes and jax dies on a deleted buffer, or silently
trains from stale weights with ``donate=False``).  ``SLTrainState``
bundles the two segment parameter trees, both optimizer states and a
step counter into a single registered pytree with explicit semantics:

* ``create(params_a, params_b, optimizer)`` — build a fresh state with
  optimizer state initialized for both segments;
* ``apply_updates(grads_a, grads_b, optimizer)`` — one optimizer step
  on both segments (+1 on the step counter), pure and traceable, so it
  works inside ``lax.scan`` bodies and eager loops alike;
* ``replace(**kw)`` — functional field update (a live copy);
* ``donate()`` / consumption tracking — a state handed to a fused pass
  with buffer donation is *consumed*: its arrays may be freed by XLA.
  The engine marks the input state consumed and every subsequent
  ``apply_updates``/``replace``/``donate``/re-pass on it raises
  ``ValueError`` instead of tripping a deleted-buffer crash (or worse,
  silently reusing stale memory).

The state flattens to ``(params_a, params_b, opt_a, opt_b, step)``, so
it rides a scan carry, crosses ``jax.jit`` boundaries, and donates as
one argument.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SLTrainState:
    """Split-learning train state: both segments + optimizer + step."""

    params_a: Any                      # satellite segment weights
    params_b: Any                      # ground segment weights
    opt_a: Any                         # optimizer state for segment A
    opt_b: Any                         # optimizer state for segment B
    step: Any = 0                      # scalar int32 step counter

    _consumed: bool = dataclasses.field(default=False, init=False,
                                        repr=False, compare=False)

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        return ((self.params_a, self.params_b, self.opt_a, self.opt_b,
                 self.step), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # ------------------------------------------------------ construction
    @classmethod
    def create(cls, params_a, params_b, optimizer) -> "SLTrainState":
        """Fresh state with ``optimizer.init`` run on both segments."""
        return cls(params_a=params_a, params_b=params_b,
                   opt_a=optimizer.init(params_a),
                   opt_b=optimizer.init(params_b),
                   step=jnp.zeros((), jnp.int32))

    # --------------------------------------------------------- semantics
    @property
    def consumed(self) -> bool:
        return self._consumed

    def _require_live(self, op: str) -> None:
        if self._consumed:
            raise ValueError(
                f"SLTrainState.{op}: this state was consumed (its buffers "
                "were donated to a fused pass and may be freed); use the "
                "state returned by that pass instead")

    def donate(self) -> "SLTrainState":
        """Hand the buffers to a donating call: marks *this* reference
        consumed and returns a live alias (sharing the same arrays) for
        the one donating call site.  Guards against the classic footgun
        of reusing donated params after the pass."""
        self._require_live("donate")
        alias = dataclasses.replace(self)
        self._consumed = True
        return alias

    def mark_consumed(self) -> None:
        """Engine hook: flag the state after its buffers were donated."""
        self._consumed = True

    def replace(self, **kw) -> "SLTrainState":
        """Functional update; the returned state is live."""
        self._require_live("replace")
        return dataclasses.replace(self, **kw)

    def apply_updates(self, grads_a, grads_b, optimizer,
                      where=None) -> "SLTrainState":
        """One optimizer step on both segments; returns the new state.

        ``where`` (a boolean scalar, traceable) masks the update: where
        False the returned state equals this one leaf-for-leaf (params,
        optimizer state AND step counter untouched).  This is the carry
        passthrough every masked scan in the repo uses — the fused pass
        engine's padded steps and the device constellation engine's
        skip-below-reserve / beyond-allocation steps all gate the same
        way, so masking semantics live in exactly one place.
        """
        self._require_live("apply_updates")
        pa, oa, _ = optimizer.update(grads_a, self.opt_a, self.params_a)
        pb, ob, _ = optimizer.update(grads_b, self.opt_b, self.params_b)
        new = SLTrainState(params_a=pa, params_b=pb, opt_a=oa, opt_b=ob,
                           step=self.step + 1)
        if where is None:
            return new
        return jax.tree.map(lambda n_, o_: jnp.where(where, n_, o_),
                            new, self)

    def as_tuple(self) -> Tuple[Any, Any, Any, Any]:
        """Legacy 4-tuple view (old ``make_sl_pass`` argument order)."""
        self._require_live("as_tuple")
        return self.params_a, self.params_b, self.opt_a, self.opt_b
