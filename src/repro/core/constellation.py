"""The orbital-ring scheduler: cyclical SL training across N satellites.

Implements the paper's time-window protocol end to end, planned at
*revolution* granularity:

  revolution r: the ring's N upcoming passes are pre-solved as ONE
    batched problem-(13) instance set (core/mission.RevolutionPlanner
    -> resource_opt.solve_with_shedding_batch): per-satellite budgets
    and measured boundary payloads enter as batch rows, infeasible rows
    shed their batch fraction through the same vectorized bisection.
    The plan is cached; it is invalidated only by a membership change
    (join/leave/failure re-shapes the ring) or a boundary-shape change
    (batch shape / handoff payload alters the (13) coefficients), so a
    steady-state revolution costs zero solves.

  pass k: satellite s = ring[k mod N] is visible for T_pass seconds.
    1. resource allocation: consume this pass's pre-solved planner
       entry (exact dual bisection, vectorized across the revolution);
       shedding is already folded in.  The boundary payload is measured
       shape-only (sl_step.boundary_bits), no probe step.
    2. run all allocated SL train steps (core/sl_step.make_sl_pass) on
       the satellite's local non-IID shard in ONE jitted lax.scan —
       the SLTrainState (params of both segments + optimizer states +
       step counter, core/train_state) rides the scan carry with
       donated buffers, so a pass costs one dispatch regardless of step
       count.  The optimizer is pluggable per ConstellationConfig
       (.optimizer = "sgd" | "adamw" | Optimizer instance), which is
       what lets the LM split-training track run through this same loop.
    3. account energy per eq. (11) with the *measured* boundary payloads.
    4. hand segment A to the next satellite over the ISL — implemented
       as an integrity-checked checkpoint (ckpt.save_handoff), so the
       handoff doubles as the fault-tolerance point.

Fault / policy model (the paper's "energy-constrained satellites may
skip" plus the 1000-node hardening):
  * per-satellite battery with solar recharge; below reserve => skip
    pass (ground trains nothing; segment forwarded unchanged).
  * random satellite failure => ring skips it; the successor restores
    the last handoff checkpoint (no training lost beyond one pass).
  * elastic membership: join/leave events re-size the ring between
    passes (T_pass is per-satellite and unchanged; d_ISL shifts with N)
    and invalidate the cached revolution plan.

The canonical train state is ``sim.state`` (an
:class:`~repro.core.train_state.SLTrainState`); the pre-PR-2 4-tuple
views (``sim.params_a`` etc.) are gone.

Per-satellite boundary measurement: the planner batch carries one
(budget, costs) instance per ring member, and each member's boundary
payload is measured from ITS shard's batch shape (``data_for_sat`` must
therefore be pure/peekable — calling it twice for the same indices must
return equivalently-shaped batches).  A heterogeneous ring (per-sat
batch shapes) plans in the same single batched solve as a homogeneous
one.

Host oracle vs device engines
-----------------------------
This Python scheduler is the feature-complete *oracle*: elastic
membership, random failures, checkpoint handoffs and arbitrary data
providers, at one Python dispatch per pass.  ``run(engine="device")``
delegates to a device-resident engine and folds its telemetry back
into :class:`PassRecord` form: steady-state closed loops go to
:mod:`repro.sim.device_sim` (the whole (revolution × ring-slot) loop
as one jitted scan), while elastic runs — join/leave events, seeded
``fail_prob`` failures, dead satellites — go to the fleet engine
(:mod:`repro.fleet`), whose scan carry holds the per-slot aliveness
mask driven by the precomputed event schedule and the oracle's own
seeded failure stream.  Small-ring parity is pinned by
``tests/test_device_sim.py`` and ``tests/test_fleet.py``.  The
battery policy (clamp to ``[0, battery_j]``) is shared with the engine
through :func:`repro.core.energy.clamp_battery`, and recharge is
membership-aware: a satellite collects solar recharge exactly for the
passes it was a ring member of (joiners from their join pass, leavers
until their leave pass).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import resource_opt
from repro.core.energy import (PassBudget, SplitCosts, clamp_battery,
                               solar_recharge_j)
from repro.fleet.events import leave_ids
from repro.core.mission import RevolutionPlanner
from repro.core.orbits import OrbitalPlane
from repro.core.sl_step import (SplitAdapter, make_boundary_meter,
                                make_sl_pass, ring_boundary_bits)
from repro.core.train_state import SLTrainState
from repro.train.optimizer import Optimizer, resolve_optimizer
from repro.utils.treeutil import tree_bytes


@dataclasses.dataclass
class SatelliteState:
    sat_id: int
    battery_j: float
    alive: bool = True
    passes_served: int = 0
    energy_spent_j: float = 0.0
    joined_pass: int = 0              # first pass this sat was a ring member


@dataclasses.dataclass
class PassRecord:
    pass_idx: int
    sat_id: int
    action: str                       # trained | skipped_energy | failed | shed
    loss: Optional[float] = None
    kept_fraction: float = 1.0
    e_total_j: float = 0.0
    e_proc_j: float = 0.0
    e_comm_j: float = 0.0
    e_isl_j: float = 0.0
    t_total_s: float = 0.0
    d_isl_bits: float = 0.0
    n_items: float = 0.0
    battery_j: float = 0.0            # serving sat's battery at pass end
                                      # (post-drain, post-recharge)


@dataclasses.dataclass
class ConstellationConfig:
    n_passes: int = 25
    items_per_pass: float = 400.0        # Table I: images per satellite pass
    batch_size: int = 8
    lr: float = 1e-2
    # "sgd" | "adamw" | an Optimizer instance (train/optimizer.py); a
    # name is resolved with lr=cfg.lr, so the AdamW lr schedule warms up
    # to cfg.lr.  This is the LM-track hook: the same constellation loop
    # trains an lm_adapter split with AdamW.
    optimizer: Union[str, Optimizer] = "sgd"
    quantize_boundary: bool = False
    battery_j: float = 5_000.0
    recharge_w: float = 20.0             # solar recharge between passes
    reserve_j: float = 100.0             # skip threshold
    fail_prob: float = 0.0
    # battery charge (as a fraction of battery_j) a joining satellite
    # arrives with: freshly launched sats need not be topped up, and a
    # partial charge makes the membership-aware recharge accounting
    # observable (a joiner recharges only from its join pass onward)
    join_battery_frac: float = 1.0
    seed: int = 0
    handoff_dir: Optional[str] = None    # persist handoffs (fault tolerance)
    join_events: Dict[int, int] = dataclasses.field(default_factory=dict)
    # pass -> satellite id(s) leaving at that pass: a single int or a
    # sequence of ids (multi-leave churn), resolved ``sid % len(sats)``
    leave_events: Dict[int, Any] = dataclasses.field(default_factory=dict)
    # orbital shadow windows gating solar recharge: any object with a
    # ``sunlit(pass_idx, plane)`` method — canonically a
    # :class:`repro.fleet.scenarios.EclipseConfig` (duck-typed here so
    # the core scheduler does not depend on the fleet layer); None =
    # permanent sunlight.  Device delegation threads it into the fleet
    # engine's scenario, so host and device gate identically.
    eclipse: Optional[Any] = None
    # Simulation-cost ceiling on fused steps per pass.  The allocation
    # itself is uncapped (problem 13 decides the item budget); this only
    # bounds how many of those steps the simulator executes when a
    # shedding scenario keeps millions of items.  None = run them all,
    # streamed through the scan in pass_chunk_steps-sized pieces (memory
    # stays bounded, but simulated compute is proportional to the count).
    max_steps_per_pass: Optional[int] = 128
    pass_chunk_steps: int = 256          # batches materialized per scan
    # problem-(13) solver backend for the revolution planner:
    # "numpy" | "jax" | "auto" (resource_opt.solve_batch backends)
    solver_backend: Optional[str] = None


class ConstellationSim:
    """Round-robin online SL over the orbital ring, training a real model.

    ``data_for_sat(sat_id, batch_idx) -> batch`` MUST be pure (indexable,
    side-effect free): the scheduler *peeks* each ring member's upcoming
    batch once to meter its boundary payload for the revolution plan
    (:meth:`_costs_for`), so a stateful provider (an iterator, a
    consuming stream, an advancing RNG) would silently skip items.  Both
    synthetic shard providers (``ImageryShards.batch_at`` /
    ``TokenShards.batch_at``) satisfy this.
    """

    def __init__(self, adapter: SplitAdapter, budget: PassBudget,
                 data_for_sat: Callable[[int, int], Dict],
                 cfg: Optional[ConstellationConfig] = None):
        # default built per-instance: a shared ConstellationConfig() default
        # would alias its mutable join_events/leave_events dicts across sims
        cfg = ConstellationConfig() if cfg is None else cfg
        self.adapter = adapter
        self.budget = budget
        self.cfg = cfg
        self.data_for_sat = data_for_sat
        self.rng = np.random.default_rng(cfg.seed)

        self.optimizer = resolve_optimizer(cfg.optimizer, lr=cfg.lr)
        pa, pb = adapter.init(jax.random.key(cfg.seed))
        self.state = SLTrainState.create(pa, pb, self.optimizer)
        self.sl_pass = make_sl_pass(adapter,
                                    quantize_boundary=cfg.quantize_boundary,
                                    optimizer=self.optimizer)
        self.planner = RevolutionPlanner(backend=cfg.solver_backend)

        n = budget.plane.n_sats
        self.sats: List[SatelliteState] = [
            SatelliteState(i, cfg.battery_j) for i in range(n)]
        self.records: List[PassRecord] = []
        self._batch_idx = 0
        self._boundary_bits = make_boundary_meter(
            adapter, quantize_boundary=cfg.quantize_boundary)
        # last measured costs per satellite: the planner batch carries one
        # instance per ring member, so a sat with a different boundary
        # payload changes only ITS row (one replan when first observed),
        # not a cache miss on every pass of a heterogeneous ring
        self._sat_costs: Dict[int, SplitCosts] = {}

    # ------------------------------------------------------------- internals
    def _ring(self) -> List[SatelliteState]:
        return [s for s in self.sats if s.alive]

    def _measured_costs(self, dtx_bits_per_item: float) -> SplitCosts:
        base = self.adapter.costs()
        d_isl = 8.0 * tree_bytes(self.state.params_a)  # measured handoff bytes
        return dataclasses.replace(base, dtx_bits=dtx_bits_per_item,
                                   d_isl_bits=d_isl)

    def _costs_for(self, sat_id: int) -> SplitCosts:
        """This satellite's measured costs; first use peeks its shard.

        Genuinely per-satellite boundary measurement: an unmeasured ring
        member's upcoming batch is fetched (``data_for_sat`` is pure, so
        peeking consumes nothing) and metered shape-only, instead of
        broadcasting the current satellite's payload over the ring.
        """
        costs = self._sat_costs.get(sat_id)
        if costs is None:
            batch = self.data_for_sat(sat_id, self._batch_idx)
            n = next(iter(batch.values())).shape[0]
            costs = self._measured_costs(self._boundary_bits(batch) / n)
            self._sat_costs[sat_id] = costs
        return costs

    def _solve_pass(self, sat_id: int, costs: SplitCosts):
        """This pass's allocation, consumed from the revolution plan
        (one batched solve per plan epoch, see core/mission).  Every
        ring member contributes its own measured (budget, costs) batch
        row via :meth:`_costs_for`, so a stable ring — homogeneous or
        not — plans exactly once."""
        self._sat_costs[sat_id] = costs
        ring_ids = tuple(s.sat_id for s in self._ring())
        ring_costs = [self._costs_for(s) for s in ring_ids]
        return self.planner.entry_for(sat_id, ring_ids, self.budget,
                                      ring_costs).shed

    # ------------------------------------------------------------------ run
    def run(self, engine: str = "host") -> List[PassRecord]:
        """Run the configured passes; ``engine`` picks the executor.

        ``"host"`` is this Python scheduler — the feature-complete
        oracle (elastic membership, random failures, checkpoint
        handoffs).  ``"device"`` delegates the run to a device-resident
        engine: steady-state rings go to :mod:`repro.sim.device_sim`
        (the whole closed loop as one jitted scan), while elastic runs
        (join/leave events, ``fail_prob``, dead satellites) go to the
        fleet engine (:mod:`repro.fleet`, a 1-plane fleet whose scan
        carry holds the aliveness mask and seeded failure stream); in
        both cases the telemetry is folded back into
        :class:`PassRecord` form — see :meth:`run_device` for the
        remaining preconditions (traceable provider, no
        ``handoff_dir``).
        """
        if engine == "device":
            return self.run_device()
        if engine != "host":
            raise ValueError(f"unknown engine {engine!r}; expected "
                             "'host' or 'device'")
        cfg = self.cfg
        for k in range(cfg.n_passes):
            # elastic membership
            if k in cfg.join_events:
                for _ in range(cfg.join_events[k]):
                    self.sats.append(SatelliteState(
                        len(self.sats),
                        clamp_battery(cfg.battery_j
                                      * cfg.join_battery_frac,
                                      cfg.battery_j),
                        joined_pass=k))
            if k in cfg.leave_events:
                for sid in leave_ids(cfg.leave_events[k]):
                    self.sats[sid % len(self.sats)].alive = False

            # the ring that serves pass k — recharge accounting below is
            # against THIS snapshot, so a satellite joining at a later
            # pass (or one that left before this pass) cannot collect
            # solar recharge for a pass it was never a member of
            ring = self._ring()
            sat = ring[k % len(ring)]
            rec = self._run_pass(k, sat)
            self.records.append(rec)
            # solar recharge between passes, for this pass's members only
            # (a sat that failed mid-pass is dead: no recharge either;
            # an eclipsed pass harvests exactly 0 J)
            sunlit = cfg.eclipse is None or bool(cfg.eclipse.sunlit(k, 0))
            gain = solar_recharge_j(cfg.recharge_w,
                                    self.budget.plane.pass_duration_s,
                                    sunlit)
            for s in ring:
                if s.alive:
                    s.battery_j = clamp_battery(s.battery_j + gain,
                                                cfg.battery_j)
            rec.battery_j = sat.battery_j     # telemetry (device parity)
        return self.records

    def _run_pass(self, k: int, sat: SatelliteState) -> PassRecord:
        cfg = self.cfg

        # random failure: the ring continues; handoff checkpoint survives
        if self.rng.random() < cfg.fail_prob:
            sat.alive = False
            if cfg.handoff_dir is not None:
                from repro import ckpt
                try:
                    restored, _, _ = ckpt.restore_handoff(
                        cfg.handoff_dir, self.state.params_a)
                    self.state = self.state.replace(params_a=restored)
                except FileNotFoundError:
                    pass        # failed before the first handoff: keep init
            return PassRecord(k, sat.sat_id, "failed")

        # energy policy: skip the pass, forward the segment unchanged
        if sat.battery_j < cfg.reserve_j:
            self._handoff(k)
            return PassRecord(k, sat.sat_id, "skipped_energy",
                              d_isl_bits=8.0 * tree_bytes(
                                  self.state.params_a))

        # measure the true boundary payload shape-only (no probe step);
        # memoized per batch shape so steady-state passes trace nothing
        batch = self.data_for_sat(sat.sat_id, self._batch_idx)
        n_in_batch = next(iter(batch.values())).shape[0]
        dtx_per_item = self._boundary_bits(batch) / n_in_batch

        costs = self._measured_costs(dtx_per_item)
        shed = self._solve_pass(sat.sat_id, costs)
        alloc = shed.report.allocation
        n_items = shed.n_items_kept
        n_steps = max(1, int(round(n_items / n_in_batch)))
        if cfg.max_steps_per_pass is not None:
            n_steps = min(n_steps, cfg.max_steps_per_pass)

        # the whole pass through fused scans, streamed in chunks so host
        # memory stays bounded even for uncapped shedding-scale passes
        loss_parts = []
        start = 0
        while start < n_steps:
            m = min(max(cfg.pass_chunk_steps, 1), n_steps - start)
            batches = [batch if start + j == 0 else
                       self.data_for_sat(sat.sat_id,
                                         self._batch_idx + start + j)
                       for j in range(m)]
            res = self.sl_pass(self.state, batches)
            self.state = res.state
            loss_parts.append(np.asarray(res.losses, dtype=np.float64))
            start += m
        losses = np.concatenate(loss_parts)
        self._batch_idx += n_steps

        e = alloc.e_total
        # the one battery policy (shared with the device engine): charge
        # floors at 0 — an overdrawn pass leaves the battery empty, the
        # energy *accounting* still records the full eq.-(11) cost
        sat.battery_j = clamp_battery(
            sat.battery_j - (alloc.e_proc_sat + alloc.e_comm_down
                             + alloc.e_isl), cfg.battery_j)
        sat.energy_spent_j += e
        sat.passes_served += 1
        self._handoff(k)

        return PassRecord(
            k, sat.sat_id,
            "shed" if shed.kept_fraction < 1.0 else "trained",
            loss=float(np.mean(losses)), kept_fraction=shed.kept_fraction,
            e_total_j=e,
            e_proc_j=alloc.e_proc_sat + alloc.e_proc_gs,
            e_comm_j=alloc.e_comm_down + alloc.e_comm_up,
            e_isl_j=alloc.e_isl, t_total_s=alloc.t_total,
            d_isl_bits=costs.d_isl_bits, n_items=n_items)

    def _handoff(self, k: int):
        """Ship segment A to the successor (checkpoint == ISL payload)."""
        if self.cfg.handoff_dir is not None:
            from repro import ckpt
            ckpt.save_handoff(self.cfg.handoff_dir, k, self.state.params_a,
                              meta={"pass": k})

    # ------------------------------------------------- device-engine bridge
    def as_device_sim(self, n_revolutions: Optional[int] = None):
        """This sim's steady-state closed loop as a device engine.

        Preconditions (the device program is a *static* ring): no
        join/leave events, ``fail_prob == 0``, no ``handoff_dir`` (those
        are host-oracle features), and a *traceable* data provider —
        ``data_for_sat`` must advertise ``traceable = True`` (e.g.
        :class:`repro.sim.data.DeviceImageryShards`) because batches are
        generated inside the jitted scan.  The engine takes over (and
        consumes, via donation) the current train state on ``run``.
        """
        from repro.sim.device_sim import (DeviceConstellationSim,
                                          DeviceSimConfig)

        cfg = self.cfg
        blockers = []
        if cfg.join_events or cfg.leave_events:
            blockers.append("elastic membership (join/leave events)")
        if cfg.fail_prob:
            blockers.append("random failures (fail_prob > 0)")
        if cfg.handoff_dir is not None:
            blockers.append("checkpoint handoffs (handoff_dir)")
        if any(not s.alive for s in self.sats):
            blockers.append("dead satellites in the ring")
        if cfg.eclipse is not None:
            blockers.append("eclipse windows (fleet scenario feature)")
        if blockers:
            raise ValueError(
                "the device engine runs static steady-state rings only; "
                "host-oracle features in use: " + ", ".join(blockers))
        self._require_traceable_provider()
        n = len(self.sats)
        if n_revolutions is None:
            if cfg.n_passes % n:
                raise ValueError(
                    f"n_passes={cfg.n_passes} is not a whole number of "
                    f"revolutions of the {n}-satellite ring")
            n_revolutions = cfg.n_passes // n
        dcfg = DeviceSimConfig(
            n_revolutions=n_revolutions, lr=cfg.lr, optimizer=cfg.optimizer,
            quantize_boundary=cfg.quantize_boundary,
            battery_j=cfg.battery_j, recharge_w=cfg.recharge_w,
            reserve_j=cfg.reserve_j,
            max_steps_per_pass=cfg.max_steps_per_pass, seed=cfg.seed)
        engine = DeviceConstellationSim(self.adapter, self.budget,
                                        self.data_for_sat, dcfg,
                                        state=self.state,
                                        dtx_bits=self._ring_dtx_bits(n))
        # carry the host fleet's charge AND the data cursor over (a
        # fresh sim starts full at batch 0; a chained delegation resumes
        # from the drained batteries and un-consumed samples)
        engine.energy = engine.energy._replace(
            battery_j=jnp.asarray([s.battery_j for s in self.sats],
                                  jnp.float32))
        engine._batch_idx = jnp.asarray(self._batch_idx, jnp.int32)
        return engine

    def _require_traceable_provider(self) -> None:
        """Both device engines generate batches inside a jitted scan."""
        if not getattr(self.data_for_sat, "traceable", False):
            raise ValueError(
                "the device engine generates batches inside the jitted "
                "scan: data_for_sat must be a traceable provider "
                "(traceable = True, e.g. repro.sim.data."
                "DeviceImageryShards), got "
                f"{type(self.data_for_sat).__name__}")

    def _ring_dtx_bits(self, n_slots: int) -> np.ndarray:
        """Per-satellite measured boundary payloads, ``(n_slots,)`` bits.

        The array feed of :func:`~repro.core.sl_step.ring_boundary_bits`
        threaded into device-resident planning: every ring slot's
        upcoming batch is peeked *shape-only* (``jax.eval_shape`` over
        the traceable provider — zero FLOPs, no samples consumed) and
        metered, so heterogeneous rings plan per-satellite instead of
        silently broadcasting slot 0's payload ring-wide.
        """
        batches = jax.eval_shape(lambda: [self.data_for_sat(
            m, self._batch_idx) for m in range(n_slots)])
        bits = ring_boundary_bits(self.adapter, batches,
                                  self.cfg.quantize_boundary)
        per_batch = np.asarray([next(iter(b.values())).shape[0]
                                for b in batches], np.float64)
        return bits / per_batch

    def run_device(self) -> List[PassRecord]:
        """Delegate the whole run to a device engine, then fold its
        telemetry back into host form (``records``, ``sats``, ``state``)
        so ``summary()`` and downstream consumers see one consistent
        view regardless of the engine.  Steady-state static rings run
        on the single-ring engine; elastic runs (join/leave events,
        ``fail_prob``, dead satellites) run on the fleet engine."""
        cfg = self.cfg
        if (cfg.join_events or cfg.leave_events or cfg.fail_prob
                or cfg.eclipse is not None
                or any(not s.alive for s in self.sats)):
            return self._run_fleet_device()
        engine = self.as_device_sim()
        self.device_engine = engine          # kept for inspection/tests
        res = engine.run(stream_telemetry=True)
        self.state = engine.state
        self._batch_idx = int(np.asarray(engine._batch_idx))

        plan = res.plan
        k0 = len(self.records)
        R, n = res.action.shape
        for r in range(R):
            for s in range(n):
                self.records.append(self._plan_record(
                    k0 + r * n + s, s, int(res.action[r, s]),
                    float(res.loss[r, s]), float(res.battery_j[r, s]),
                    plan, s))
        for s, host_sat in enumerate(self.sats):
            host_sat.battery_j = float(res.energy.battery_j[s])
            host_sat.passes_served += int(res.energy.passes_served[s])
            host_sat.energy_spent_j += float(res.energy.energy_spent_j[s])
        return self.records

    @staticmethod
    def _plan_record(pass_idx: int, sat_id: int, code: int, loss: float,
                     battery_j: float, plan, sel) -> PassRecord:
        """One engine telemetry entry as a :class:`PassRecord` — the one
        plan-row → record mapping shared by the static and fleet
        delegation folds.  ``sel`` indexes the plan's row for this slot
        (``s`` for (N,) plans, ``(0, s)`` for fleet (P, M) plans)."""
        from repro.sim.device_sim import (ACTION_FAILED, ACTION_FAULT,
                                          ACTION_NAMES, ACTION_SKIPPED)

        if code == ACTION_FAILED:
            return PassRecord(pass_idx, sat_id, "failed",
                              battery_j=battery_j)
        if code == ACTION_FAULT:
            # transient epidemic fault: a masked no-op pass — no energy,
            # no loss; the slot recovers after its ttl expires
            return PassRecord(pass_idx, sat_id, "faulted",
                              battery_j=battery_j)
        if code == ACTION_SKIPPED:
            return PassRecord(pass_idx, sat_id, "skipped_energy",
                              d_isl_bits=float(plan.d_isl_bits[sel]),
                              battery_j=battery_j)
        return PassRecord(
            pass_idx, sat_id, ACTION_NAMES[code], loss=loss,
            kept_fraction=float(plan.kept_fraction[sel]),
            e_total_j=float(plan.e_total_j[sel]),
            e_proc_j=float(plan.e_proc_j[sel]),
            e_comm_j=float(plan.e_comm_j[sel]),
            e_isl_j=float(plan.e_isl_j[sel]),
            t_total_s=float(plan.t_total_s[sel]),
            d_isl_bits=float(plan.d_isl_bits[sel]),
            n_items=float(plan.n_items_kept[sel]),
            battery_j=battery_j)

    def _run_fleet_device(self) -> List[PassRecord]:
        """Elastic delegation: run join/leave/failure scenarios on the
        fleet engine (:mod:`repro.fleet`) as a 1-plane fleet.

        The event schedule (precomputed joins/leaves + the seeded
        per-pass failure stream of ``np.random.default_rng(seed)`` —
        the very stream this host scheduler consumes) drives a per-slot
        aliveness mask inside the device scan, so the run that used to
        be forced back to the host executes entirely on device.  The
        remaining host-only features are checkpoint *persistence*
        (``handoff_dir``) and non-traceable data providers.
        """
        from repro.fleet import FleetConfig, FleetEngine, \
            build_event_schedule
        from repro.fleet.scenarios import ScenarioConfig

        cfg = self.cfg
        if cfg.handoff_dir is not None:
            raise ValueError(
                "the device engines run the handoff as the scan carry; "
                "persisting handoff checkpoints (handoff_dir) is a "
                "host-oracle feature")
        self._require_traceable_provider()

        n0, K = len(self.sats), cfg.n_passes
        rev_len = n0 if K % n0 == 0 else K
        # membership from the config events; the failure stream is drawn
        # from THIS sim's live generator instead (one host draw per
        # pass, exactly what the host loop would consume), so a fresh
        # sim matches the seeded schedule bit for bit AND chained
        # host/device segments keep consuming one stream
        schedule = build_event_schedule(
            n0, K, join_events=cfg.join_events,
            leave_events=cfg.leave_events, fail_prob=0.0,
            n_planes=1, seed=cfg.seed)
        schedule = dataclasses.replace(schedule, fail_mask=np.array(
            [[self.rng.random() < cfg.fail_prob for _ in range(K)]]),
            fail_prob=float(cfg.fail_prob))
        fcfg = FleetConfig(
            n_planes=1, n_revolutions=K // rev_len,
            passes_per_revolution=rev_len, lr=cfg.lr,
            optimizer=cfg.optimizer,
            quantize_boundary=cfg.quantize_boundary,
            battery_j=cfg.battery_j, recharge_w=cfg.recharge_w,
            reserve_j=cfg.reserve_j,
            max_steps_per_pass=cfg.max_steps_per_pass, seed=cfg.seed,
            fail_prob=cfg.fail_prob, join_events=dict(cfg.join_events),
            leave_events=dict(cfg.leave_events),
            join_battery_frac=cfg.join_battery_frac, avg_every=0,
            scenario=(ScenarioConfig(eclipse=cfg.eclipse)
                      if cfg.eclipse is not None else None))
        engine = FleetEngine(
            self.adapter, self.budget, self.data_for_sat, fcfg,
            state=self.state, schedule=schedule,
            dtx_bits=self._ring_dtx_bits(schedule.n_slots),
            battery0=[s.battery_j for s in self.sats],
            failed0=[not s.alive for s in self.sats])
        self.device_engine = engine          # kept for inspection/tests
        engine._batch_idx = jax.device_put(
            jnp.full((1,), self._batch_idx, jnp.int32), engine._shard)
        res = engine.run(stream_telemetry=True)
        self.state = jax.tree.map(lambda x: x[0], engine.state)
        self._batch_idx = int(np.asarray(engine._batch_idx)[0])

        plan = res.plan                       # (1, M) host rows
        k0 = len(self.records)
        for k in range(K):
            slot = int(res.sat[0, k])
            self.records.append(self._plan_record(
                k0 + k, slot, int(res.action[0, k]),
                float(res.loss[0, k]), float(res.battery_j[0, k]),
                plan, (0, slot)))

        # fold the fleet's slot state back onto the host SatelliteStates
        # (joiners appended with their slot id, exactly like the host run)
        for m in range(len(self.sats), schedule.n_slots):
            self.sats.append(SatelliteState(
                m, 0.0, joined_pass=int(schedule.join_pass[m])))
        for m, sat in enumerate(self.sats):
            sat.battery_j = float(res.energy.battery_j[0, m])
            sat.passes_served += int(res.energy.passes_served[0, m])
            sat.energy_spent_j += float(res.energy.energy_spent_j[0, m])
            sat.alive = (not bool(res.failed[0, m])
                         and int(schedule.leave_pass[m]) > K - 1)
        return self.records

    # ------------------------------------------------------------- reporting
    def summary(self) -> Dict[str, Any]:
        recs = self.records
        trained = [r for r in recs if r.action in ("trained", "shed")]
        return {
            "passes": len(recs),
            "trained": len(trained),
            "skipped": sum(r.action == "skipped_energy" for r in recs),
            "failed": sum(r.action == "failed" for r in recs),
            "faulted": sum(r.action == "faulted" for r in recs),
            "loss_first": trained[0].loss if trained else None,
            "loss_last": trained[-1].loss if trained else None,
            "E_total_J": sum(r.e_total_j for r in recs),
            "E_comm_J": sum(r.e_comm_j for r in recs),
            "E_proc_J": sum(r.e_proc_j for r in recs),
            "E_isl_J": sum(r.e_isl_j for r in recs),
        }
