"""Device-resident problem-(13) engine: the jit+vmap JAX solver backend.

This is the accelerator twin of the NumPy :func:`~repro.core.
resource_opt.solve_batch`: the same dual-waterfilling algorithm — the
Lambert-W closed form for the comm phases, the cube-root closed form for
the processing phases, and one geometric bisection on the dual λ — but
written per-instance in pure JAX, ``vmap``-ped over the batch axis and
``jit``-compiled, so constellation-scale sweeps (1000-sat rings × cut
points × budgets) run entirely on device with zero host round-trips
between planning and pass execution.

Three mutually-checking implementations now exist:

* :func:`resource_opt.solve_reference` — the scalar pure-Python oracle;
* :func:`resource_opt.solve_batch` — NumPy lockstep arrays (the CPU
  fallback and the parity oracle for this module);
* :func:`solve_batch_jax` — this backend, selected through
  ``resource_opt.solve_batch(..., backend="jax"|"auto")``.

Numerical notes
---------------
The dual λ spans hundreds of decades (λ_hi/λ_lo brackets are analytic,
from the marginals at t_min and at the whole budget), so the solver runs
in **float64** regardless of the process-wide JAX default: every entry
point traces and executes under ``jax.experimental.enable_x64``, which
scopes double precision to this module without flipping the global flag
(the SL training stack stays float32).  The Lambert-W branch point gets
the same series guard as the NumPy path: for λ·g̃ below ~1e-6 the
argument (λ·g̃ − 1)/e rounds into the branch point where W₀ loses all
precision, and the series x ≈ √(2·λ·g̃) of ``e^x (x−1) + 1 = λ·g̃`` is
exact; two Newton polish steps on the cancellation-free residual restore
full double precision everywhere else.

The phase structure is static (the canonical [sat_proc, downlink,
gs_proc, uplink] layout with liveness masks), so one compiled executable
serves every instance mix; batch sizes are bucketed to the next power of
two with inert padding rows to keep recompiles O(log B).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np

# batch padding shares the repo-wide bucketing schedule with the pass
# engine's step bucketing: O(log B) compilations, <=25% inert pad rows
from repro.utils.bucketing import bucket_size as _bucket_batch

try:                                            # gate: CPU-only envs without
    import jax                                  # jax still import resource_opt
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64 as _enable_x64
    _JAX_OK = True
except Exception:                               # pragma: no cover
    jax = None
    _JAX_OK = False

_EPS = 1e-12
_LN2 = math.log(2.0)


def available() -> bool:
    """True when the JAX backend can run in this process."""
    return _JAX_OK


def on_accelerator() -> bool:
    """True when the default JAX backend is not the host CPU."""
    return _JAX_OK and jax.default_backend() != "cpu"


# --------------------------------------------------------------------------
# Elementwise building blocks (float64 under enable_x64).
# --------------------------------------------------------------------------

def _lambert_w0(z):
    """Principal-branch Lambert W for z >= -1/e, elementwise.

    Branch-point series init below zero, log init above, then Halley
    iterations (the same scheme as the NumPy fallback in resource_opt).
    """
    w = jnp.where(z < 0.0,
                  -1.0 + jnp.sqrt(jnp.maximum(2.0 * (1.0 + math.e * z), 0.0)),
                  jnp.log1p(jnp.maximum(z, 0.0)))
    big = z > math.e
    lz = jnp.log(jnp.where(big, z, math.e))
    w = jnp.where(big, lz - jnp.log(lz), w)

    def halley(_, w):
        w = jnp.maximum(w, -1.0 + 1e-12)        # keep 2w+2 away from zero
        ew = jnp.exp(jnp.minimum(w, 700.0))
        f = w * ew - z
        denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0)
        return w - f / jnp.where(denom != 0.0, denom, 1.0)

    # 5 cubic steps from these inits reach ~1e-10 everywhere on z >= -1/e;
    # the Newton polish on the solver's own residual finishes the job, so
    # more iterations here only burn device time on the hot path.
    w = lax.fori_loop(0, 5, halley, w)
    return jnp.maximum(w, -1.0)


def _comm_neg_deriv_vec(c, gain, t):
    """−E'(t) of a comm phase, cancellation-free (see resource_opt)."""
    x = jnp.where(t > 0.0, c * _LN2 / jnp.maximum(t, 1e-300), jnp.inf)
    xs = jnp.minimum(x, 500.0)
    e = jnp.expm1(xs)
    nd = (e * xs - (e - xs)) / gain
    return jnp.where(x > 500.0, jnp.inf, nd)


def _comm_t_of_lambda_vec(c, gain, lam, t_min, t_hi):
    """Closed-form t(λ) for the comm phases via Lambert W.

    −E'(t) = λ  ⟺  e^x (x−1) + 1 = λ·g̃  with x = c·ln2/t, so
    x = 1 + W₀((λ·g̃ − 1)/e); series guard x ≈ √(2·λ·g̃) at the branch
    point, two Newton polish steps on the stable residual.
    """
    lg = lam * gain
    z = jnp.maximum((lg - 1.0) / math.e, -1.0 / math.e)
    x = 1.0 + _lambert_w0(z)
    small = lg < 1e-6
    x = jnp.where(small, jnp.sqrt(2.0 * jnp.maximum(lg, 0.0)), x)
    x = jnp.maximum(x, 1e-300)
    for _ in range(2):
        xs = jnp.minimum(x, 500.0)
        em = jnp.expm1(xs)
        f = em * xs - (em - xs) - lg
        fp = (em + 1.0) * xs
        x = jnp.maximum(x - f / jnp.maximum(fp, 1e-300), 1e-300)
    t = c * _LN2 / x
    return jnp.clip(t, t_min, t_hi)


def _proc_t_of_lambda_vec(k, lam, t_min, t_hi):
    """Closed-form t(λ) = (2k/λ)^{1/3} for the processing phases."""
    t = jnp.cbrt(2.0 * k / jnp.maximum(lam, 1e-300))
    return jnp.clip(t, t_min, t_hi)


# --------------------------------------------------------------------------
# The per-instance solver (vmapped over the batch axis).
# --------------------------------------------------------------------------

class CoeffArrays(NamedTuple):
    """Problem-(13) coefficients as arrays — the device-level interface.

    Shapes: ``k``/``tmin_p`` are (..., 2) for [sat_proc, gs_proc];
    ``cc``/``tmin_c`` are (..., 2) for [downlink, uplink] (bits/Hz);
    the rest are (...,).  Any leading batch shape works — it is
    flattened for the vmapped solve and restored on the outputs.  A
    phase with ``k``/``cc`` equal to 0 is absent.
    """

    k: "jnp.ndarray"
    tmin_p: "jnp.ndarray"
    cc: "jnp.ndarray"
    tmin_c: "jnp.ndarray"
    gain: "jnp.ndarray"
    t_budget: "jnp.ndarray"
    e_isl: "jnp.ndarray"
    t_fixed: "jnp.ndarray"

    def scaled_items(self, frac):
        """Coefficients at a per-instance kept item fraction ``frac``.

        Every t_min and the comm payload scale linearly with n_items,
        the processing constant k cubically; the time budget and the
        fixed ISL terms do not depend on it.
        """
        f = jnp.asarray(frac)
        f1 = f[..., None]
        return self._replace(k=self.k * f1**3, tmin_p=self.tmin_p * f1,
                             cc=self.cc * f1, tmin_c=self.tmin_c * f1)


class ArraySolveReport(NamedTuple):
    """Device-array solution of problem (13); see BatchSolveReport."""

    phase_times: "jnp.ndarray"     # (..., 4) seconds
    phase_energy: "jnp.ndarray"    # (..., 4) joules
    lam: "jnp.ndarray"             # (...,)  dual (inf if infeasible)
    kkt_residual: "jnp.ndarray"    # (...,)
    feasible: "jnp.ndarray"        # (...,)  bool
    e_isl: "jnp.ndarray"           # (...,)  joules
    t_fixed: "jnp.ndarray"         # (...,)  seconds

    @property
    def e_total(self):
        """eq. (11) per instance, including the constant E_ISL."""
        return self.phase_energy.sum(axis=-1) + self.e_isl

    @property
    def t_total(self):
        """eq. (12) per instance, including the fixed overhead."""
        return self.phase_times.sum(axis=-1) + self.t_fixed


def _solve_one(k, tmin_p, cc, tmin_c, gain, t_budget, *, tol, max_iters):
    """Solve one problem-(13) instance; shapes (2,)/(); pure JAX."""
    live_p = k > 0.0
    live_c = cc > 0.0
    tmin_p = jnp.where(live_p, tmin_p, 0.0)
    tmin_c = jnp.where(live_c, tmin_c, 0.0)

    t_min_sum = tmin_p.sum() + tmin_c.sum()
    any_live = live_p.any() | live_c.any()
    infeasible = any_live & ((t_budget <= 0.0) | (t_min_sum > t_budget))
    active = any_live & ~infeasible
    t_hi = jnp.maximum(t_budget, 0.0)

    # ---- analytic λ bracket: total_time(λ) is decreasing in λ ----------
    nd_p_lo = 2.0 * k / jnp.maximum(tmin_p, 1e-300) ** 3
    nd_p_hi = 2.0 * k / jnp.maximum(t_hi, 1e-300) ** 3
    nd_c_lo = _comm_neg_deriv_vec(cc, gain, jnp.maximum(tmin_c, 1e-300))
    nd_c_hi = _comm_neg_deriv_vec(cc, gain, jnp.maximum(t_hi, 1e-300))
    nd_lo = jnp.concatenate([jnp.where(live_p, nd_p_lo, -jnp.inf),
                             jnp.where(live_c, nd_c_lo, -jnp.inf)])
    nd_hi = jnp.concatenate([jnp.where(live_p, nd_p_hi, jnp.inf),
                             jnp.where(live_c, nd_c_hi, jnp.inf)])
    lam_hi0 = jnp.maximum(jnp.nan_to_num(nd_lo.max(), neginf=1.0,
                                         posinf=1e300), 1e-300)
    lam_lo0 = jnp.clip(jnp.nan_to_num(nd_hi.min(), posinf=1.0),
                       1e-300, lam_hi0)

    def times_at(lam):
        tp = jnp.where(live_p,
                       _proc_t_of_lambda_vec(k, lam, tmin_p, t_hi), 0.0)
        tc = jnp.where(live_c,
                       _comm_t_of_lambda_vec(cc, gain, lam, tmin_c, t_hi),
                       0.0)
        return tp, tc

    # ---- geometric bisection on λ (lax.while_loop; lockstep via vmap) --
    def cond(carry):
        it, lam_lo, lam_hi = carry
        return (it < max_iters) & active & (lam_hi > lam_lo * (1.0 + tol))

    def body(carry):
        it, lam_lo, lam_hi = carry
        lam = jnp.sqrt(lam_lo * lam_hi)        # geometric mid: λ spans decades
        tp, tc = times_at(lam)
        over = (tp.sum() + tc.sum()) > t_budget
        return (it + 1, jnp.where(over, lam, lam_lo),
                jnp.where(over, lam_hi, lam))

    _, lam_lo, lam_hi = lax.while_loop(
        cond, body, (jnp.zeros((), jnp.int32), lam_lo0, lam_hi0))
    lam = jnp.sqrt(lam_lo * lam_hi)
    tp, tc = times_at(lam)

    # ---- slack redistribution (t_min-clamped phases leave headroom) ----
    slack = t_budget - (tp.sum() + tc.sum())
    int_p = live_p & (tp > tmin_p * (1.0 + 1e-9))
    int_c = live_c & (tc > tmin_c * (1.0 + 1e-9))
    n_int = int_p.sum() + int_c.sum()
    bump = jnp.where(active & (slack > 1e-9 * t_budget) & (n_int > 0),
                     slack / jnp.maximum(n_int, 1), 0.0)
    tp = jnp.where(int_p, tp + bump, tp)
    tc = jnp.where(int_c, tc + bump, tc)

    # ---- infeasible / no-phase instances -------------------------------
    tp = jnp.where(infeasible, tmin_p, tp)
    tc = jnp.where(infeasible, tmin_c, tc)
    tp = jnp.where(any_live, tp, 0.0)
    tc = jnp.where(any_live, tc, 0.0)

    # ---- energies at the final times -----------------------------------
    e_p = jnp.where(live_p & (tp > 0.0),
                    k / jnp.maximum(tp, 1e-300) ** 2, 0.0)
    xc = cc * _LN2 / jnp.maximum(tc, 1e-300)
    e_c = jnp.where(live_c & (tc > 0.0),
                    tc * jnp.expm1(jnp.minimum(xc, 700.0)) / gain, 0.0)
    e_c = jnp.where(live_c & (xc > 700.0), jnp.inf, e_c)

    # ---- KKT residual: spread of marginals among interior phases -------
    nd_p = 2.0 * k / jnp.maximum(tp, 1e-300) ** 3
    nd_c = _comm_neg_deriv_vec(cc, gain, jnp.maximum(tc, 1e-300))
    io_p = live_p & (tp > tmin_p * (1.0 + 1e-6)) & (tp < t_hi * (1.0 - 1e-6))
    io_c = live_c & (tc > tmin_c * (1.0 + 1e-6)) & (tc < t_hi * (1.0 - 1e-6))
    marg = jnp.concatenate([jnp.where(io_p, nd_p, jnp.nan),
                            jnp.where(io_c, nd_c, jnp.nan)])
    n_io = io_p.sum() + io_c.sum()
    filled = jnp.where(n_io >= 2, marg, 1.0)
    mmax = jnp.nanmax(filled)
    mmin = jnp.nanmin(filled)
    kkt = jnp.where(n_io >= 2, (mmax - mmin) / jnp.maximum(mmax, _EPS), 0.0)
    kkt = jnp.where(infeasible, jnp.inf, kkt)

    lam_out = jnp.where(infeasible, jnp.inf, jnp.where(any_live, lam, 0.0))
    phase_times = jnp.stack([tp[0], tc[0], tp[1], tc[1]])
    phase_energy = jnp.stack([e_p[0], e_c[0], e_p[1], e_c[1]])
    return phase_times, phase_energy, lam_out, kkt, ~infeasible


@functools.lru_cache(maxsize=8)
def _solver_fn(tol: float, max_iters: int):
    """jit(vmap(solve_one)) specialized to a (tol, max_iters) pair."""
    one = functools.partial(_solve_one, tol=tol, max_iters=max_iters)
    return jax.jit(jax.vmap(one))


def solve_coeffs(coeffs: CoeffArrays, tol: float = 1e-10,
                 max_iters: int = 80) -> ArraySolveReport:
    """Solve problem (13) for an array of instances, fully on device.

    ``coeffs`` may carry any leading batch shape; the call is traceable,
    so it composes inside larger jitted programs (the revolution sweep
    jits grid construction + shedding + this solve as one executable).
    NOTE: run under ``enable_x64`` (see :func:`x64_scope`) — the dual
    bisection needs float64 range.
    """
    lead = coeffs.gain.shape
    flat = CoeffArrays(*[jnp.reshape(a, (-1,) + a.shape[len(lead):])
                         for a in coeffs])
    pt, pe, lam, kkt, feas = _solver_fn(tol, max_iters)(
        flat.k, flat.tmin_p, flat.cc, flat.tmin_c, flat.gain, flat.t_budget)
    return ArraySolveReport(
        phase_times=jnp.reshape(pt, lead + (4,)),
        phase_energy=jnp.reshape(pe, lead + (4,)),
        lam=jnp.reshape(lam, lead), kkt_residual=jnp.reshape(kkt, lead),
        feasible=jnp.reshape(feas, lead),
        e_isl=coeffs.e_isl, t_fixed=coeffs.t_fixed)


def shed_fractions(coeffs: CoeffArrays,
                   min_fraction: float = 0.05) -> "jnp.ndarray":
    """Per-instance kept fraction restoring feasibility, closed form.

    Every phase's t_min scales linearly with n_items while the time
    budget does not, so the largest feasible fraction is simply
    T_budget / Σ t_min (the NumPy path bisects to the same value within
    its tolerance).  Clamped to [min_fraction, 1]; instances with no
    budget at all sit at the floor, instances with no live phase keep 1.
    """
    tmin_sum = (jnp.where(coeffs.k > 0.0, coeffs.tmin_p, 0.0).sum(axis=-1)
                + jnp.where(coeffs.cc > 0.0, coeffs.tmin_c, 0.0).sum(axis=-1))
    no_phase = tmin_sum == 0.0
    feas_full = no_phase | ((coeffs.t_budget > 0.0)
                            & (tmin_sum <= coeffs.t_budget))
    # one-ulp shave keeps the scaled Σ t_min on the feasible side
    fit = (coeffs.t_budget / jnp.maximum(tmin_sum, 1e-300)) * (1.0 - 1e-12)
    frac = jnp.where(feas_full, 1.0,
                     jnp.clip(fit, min_fraction, 1.0))
    return jnp.where(no_phase | (coeffs.t_budget > 0.0), frac, min_fraction)


def shed_and_solve_coeffs(coeffs: CoeffArrays, min_fraction: float = 0.05,
                          tol: float = 1e-10, max_iters: int = 80
                          ) -> Tuple[ArraySolveReport, "jnp.ndarray"]:
    """Vectorized shedding + solve at the kept item counts, on device."""
    frac = shed_fractions(coeffs, min_fraction)
    return solve_coeffs(coeffs.scaled_items(frac), tol, max_iters), frac


def x64_scope():
    """The float64 scope every entry point of this module runs under."""
    return _enable_x64()


# --------------------------------------------------------------------------
# Drop-in batch API over (PassBudget, SplitCosts) instances.
# --------------------------------------------------------------------------



def _coeffs_from_instances(blist, clist) -> CoeffArrays:
    """Host gather of per-instance coefficients into padded device arrays.

    Pads the batch to a bucketed size with inert no-phase rows
    (k = cc = 0) so distinct batch sizes share O(log B) compilations.
    """
    from repro.core import resource_opt

    arrs = resource_opt._gather_coeff_arrays(blist, clist)
    B = len(blist)
    Bp = _bucket_batch(B)
    if Bp > B:
        pad = Bp - B

        def _pad(a, fill=0.0):
            width = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
            return np.pad(a, width, constant_values=fill)

        arrs = {k: _pad(a, 1.0 if k in ("gain", "t_budget") else 0.0)
                for k, a in arrs.items()}
    return CoeffArrays(
        k=jnp.asarray(arrs["k"]), tmin_p=jnp.asarray(arrs["tmin_p"]),
        cc=jnp.asarray(arrs["cc"]), tmin_c=jnp.asarray(arrs["tmin_c"]),
        gain=jnp.asarray(arrs["gain"]),
        t_budget=jnp.asarray(arrs["t_budget"]),
        e_isl=jnp.asarray(arrs["e_isl"]),
        t_fixed=jnp.asarray(arrs["t_fixed"]))


def solve_batch_jax(budgets, costs, tol: float = 1e-10,
                    max_iters: int = 80):
    """JAX twin of :func:`resource_opt.solve_batch` — same report type.

    Accepts the same (budget | sequence, costs | sequence) broadcasting
    and returns a host :class:`~repro.core.resource_opt.BatchSolveReport`
    (NumPy arrays), so every existing consumer — shedding, best-split,
    the revolution planner — runs on device by flipping ``backend``.
    For a zero-copy device pipeline use :func:`solve_coeffs` directly.
    """
    if not _JAX_OK:                              # pragma: no cover
        raise RuntimeError("jax backend requested but jax is unavailable")
    from repro.core import resource_opt

    blist, clist = resource_opt._broadcast_instances(budgets, costs)
    B = len(blist)
    with x64_scope():
        coeffs = _coeffs_from_instances(blist, clist)
        rep = solve_coeffs(coeffs, tol=tol, max_iters=max_iters)
        out = jax.tree.map(np.asarray, rep)
    return resource_opt.BatchSolveReport(
        phase_times=out.phase_times[:B], phase_energy=out.phase_energy[:B],
        lam=out.lam[:B], kkt_residual=out.kkt_residual[:B],
        feasible=out.feasible[:B], e_isl=out.e_isl[:B],
        t_fixed=out.t_fixed[:B], budgets=tuple(blist), costs=tuple(clist))


# --------------------------------------------------------------------------
# On-device coefficient grids (ring size × cut point × item budget).
# --------------------------------------------------------------------------

class GridScalars(NamedTuple):
    """Scenario constants of a revolution sweep, as dynamic scalars.

    Passing these as traced scalars (not Python closure constants) keeps
    ONE compiled sweep executable across scenario variations — only the
    grid *shape* triggers a recompile.
    """

    pass_duration_s: "jnp.ndarray"
    t_prop_s: "jnp.ndarray"
    gain: "jnp.ndarray"                 # g̃ at the mean slant range
    r_max_bps: "jnp.ndarray"            # link rate at P_max
    bandwidth_hz: "jnp.ndarray"
    isl_rate_bps: "jnp.ndarray"
    isl_tx_power_w: "jnp.ndarray"
    orbit_radius_m: "jnp.ndarray"       # R_earth + altitude
    sat_k_const: "jnp.ndarray"          # P_p / (f_max³ · (N_c·N_F)³)
    sat_t_const: "jnp.ndarray"          # 1 / (N_c·N_F·f_max)
    gs_k_const: "jnp.ndarray"
    gs_t_const: "jnp.ndarray"


def grid_scalars(plane, link, isl, sat_device, gs_device) -> GridScalars:
    """Fold the scenario dataclasses into :class:`GridScalars`."""
    from repro.core.orbits import R_EARTH_M

    d = plane.mean_slant_range_m()
    with x64_scope():                     # float64 from the very first cast
        f64 = functools.partial(jnp.asarray, dtype=jnp.float64)

        def dev_consts(dev):
            nc = dev.n_cores * dev.flops_per_cycle
            return (f64(dev.power_max_w / (dev.f_max_hz ** 3 * nc ** 3)),
                    f64(1.0 / (nc * dev.f_max_hz)))

        sat_k, sat_t = dev_consts(sat_device)
        gs_k, gs_t = dev_consts(gs_device)
        return GridScalars(
            pass_duration_s=f64(plane.pass_duration_s),
            t_prop_s=f64(plane.mean_prop_delay_s),
            gain=f64(link.channel_gain(d)),
            r_max_bps=f64(link.rate_bps(link.max_tx_power_w, d)),
            bandwidth_hz=f64(link.bandwidth_hz),
            isl_rate_bps=f64(isl.rate_bps),
            isl_tx_power_w=f64(isl.tx_power_w),
            orbit_radius_m=f64(R_EARTH_M + plane.altitude_m),
            sat_k_const=sat_k, sat_t_const=sat_t,
            gs_k_const=gs_k, gs_t_const=gs_t)


def ring_grid_coeffs(sc: GridScalars, ring_sizes, w1, w2, dtx, disl,
                     n_items) -> CoeffArrays:
    """Build the (R, C, B) coefficient grid with pure array math.

    ``ring_sizes`` (R,) enters through the ISL hop distance (eq. 5);
    the cut arrays ``w1``/``w2``/``dtx``/``disl`` (C,) carry the split
    plan; ``n_items`` (B,) is the per-pass item budget axis.  Mirrors
    :func:`resource_opt._phase_coeffs` element for element — asserted by
    the sweep parity tests — but never leaves the device.
    """
    from repro.core.orbits import C_LIGHT

    N = jnp.asarray(ring_sizes, jnp.float64)[:, None, None]       # (R,1,1)
    w1 = jnp.asarray(w1, jnp.float64)[None, :, None]              # (1,C,1)
    w2 = jnp.asarray(w2, jnp.float64)[None, :, None]
    dtx = jnp.asarray(dtx, jnp.float64)[None, :, None]
    disl = jnp.asarray(disl, jnp.float64)[None, :, None]
    n = jnp.asarray(n_items, jnp.float64)[None, None, :]          # (1,1,B)

    isl_dist = 2.0 * sc.orbit_radius_m * jnp.sin(jnp.pi / N)
    t_fixed = (2.0 * sc.t_prop_s + disl / sc.isl_rate_bps
               + isl_dist / C_LIGHT)
    t_budget = sc.pass_duration_s - t_fixed
    e_isl = sc.isl_tx_power_w * disl / sc.isl_rate_bps

    k_sat = sc.sat_k_const * (n * w1) ** 3
    k_gs = sc.gs_k_const * (n * w2) ** 3
    tmin_sat = sc.sat_t_const * n * w1
    tmin_gs = sc.gs_t_const * n * w2
    bits = n * dtx
    c_comm = bits / sc.bandwidth_hz
    tmin_comm = jnp.where(bits > 0.0, bits / sc.r_max_bps, 0.0)

    shape = jnp.broadcast_shapes(N.shape, w1.shape, n.shape)
    bcast = functools.partial(jnp.broadcast_to, shape=shape)
    return CoeffArrays(
        k=jnp.stack([bcast(k_sat), bcast(k_gs)], axis=-1),
        tmin_p=jnp.stack([bcast(tmin_sat), bcast(tmin_gs)], axis=-1),
        cc=jnp.stack([bcast(c_comm), bcast(c_comm)], axis=-1),
        tmin_c=jnp.stack([bcast(tmin_comm), bcast(tmin_comm)], axis=-1),
        gain=jnp.broadcast_to(sc.gain, shape),
        t_budget=bcast(t_budget), e_isl=bcast(e_isl),
        t_fixed=bcast(t_fixed))


def ring_pass_coeffs(sc: GridScalars, n_sats, w1, w2, dtx, disl,
                     n_items, *, ring_n: Optional[int] = None
                     ) -> CoeffArrays:
    """One ring revolution's N problem-(13) instances as ``(N,)`` rows.

    The per-*satellite* sibling of :func:`ring_grid_coeffs`: the ring
    population (it enters through the ISL hop distance, eq. 5) is fixed
    and every coefficient input may be a scalar (broadcast ring-wide)
    or a ``(N,)`` array (per-satellite measured boundary payloads,
    heterogeneous item budgets).  Pure array math, so it traces inside
    the device constellation engine's jitted planning call.  Run under
    :func:`x64_scope`.

    ``n_sats`` may also be a shape tuple — e.g. ``(P, M)`` for a fleet
    of P orbital planes whose rings carry M slots each (joiner slots
    included) — in which case ``ring_n`` gives the orbital population
    entering the ISL hop distance (default: the last dimension).  The
    host planner always prices eq. (5) off the configured
    ``budget.plane.n_sats`` regardless of live membership, so elastic
    rings pass that as ``ring_n`` to stay oracle-exact.
    """
    from repro.core.orbits import C_LIGHT

    shape = ((int(n_sats),) if isinstance(n_sats, (int, np.integer))
             else tuple(int(s) for s in n_sats))
    ring_n = shape[-1] if ring_n is None else int(ring_n)
    f64 = functools.partial(jnp.asarray, dtype=jnp.float64)
    bcast = lambda a: jnp.broadcast_to(f64(a), shape)       # noqa: E731
    w1, w2, dtx, disl = bcast(w1), bcast(w2), bcast(dtx), bcast(disl)
    n = bcast(n_items)

    isl_dist = 2.0 * sc.orbit_radius_m * jnp.sin(jnp.pi / float(ring_n))
    t_fixed = (2.0 * sc.t_prop_s + disl / sc.isl_rate_bps
               + isl_dist / C_LIGHT)
    t_budget = sc.pass_duration_s - t_fixed
    e_isl = sc.isl_tx_power_w * disl / sc.isl_rate_bps

    k_sat = sc.sat_k_const * (n * w1) ** 3
    k_gs = sc.gs_k_const * (n * w2) ** 3
    tmin_sat = sc.sat_t_const * n * w1
    tmin_gs = sc.gs_t_const * n * w2
    bits = n * dtx
    c_comm = bits / sc.bandwidth_hz
    tmin_comm = jnp.where(bits > 0.0, bits / sc.r_max_bps, 0.0)

    return CoeffArrays(
        k=jnp.stack([k_sat, k_gs], axis=-1),
        tmin_p=jnp.stack([tmin_sat, tmin_gs], axis=-1),
        cc=jnp.stack([c_comm, c_comm], axis=-1),
        tmin_c=jnp.stack([tmin_comm, tmin_comm], axis=-1),
        gain=jnp.broadcast_to(sc.gain, shape),
        t_budget=t_budget, e_isl=e_isl, t_fixed=t_fixed)


@functools.lru_cache(maxsize=4)
def _sweep_fn(min_fraction: float, tol: float, max_iters: int):
    """One jitted executable: grid build + shedding + solve, zero host."""

    def sweep(sc, ring_sizes, w1, w2, dtx, disl, n_items):
        coeffs = ring_grid_coeffs(sc, ring_sizes, w1, w2, dtx, disl,
                                  n_items)
        rep, frac = shed_and_solve_coeffs(coeffs, min_fraction, tol,
                                          max_iters)
        return rep, frac

    return jax.jit(sweep)


def sweep_grid(sc: GridScalars, ring_sizes, w1, w2, dtx, disl, n_items,
               min_fraction: float = 0.05, tol: float = 1e-10,
               max_iters: int = 80):
    """Plan a whole (ring × cut × budget) grid in one jitted call."""
    with x64_scope():
        return _sweep_fn(min_fraction, tol, max_iters)(
            sc, jnp.asarray(ring_sizes, jnp.float64),
            jnp.asarray(w1, jnp.float64), jnp.asarray(w2, jnp.float64),
            jnp.asarray(dtx, jnp.float64), jnp.asarray(disl, jnp.float64),
            jnp.asarray(n_items, jnp.float64))
