"""Problem (13): per-pass energy minimization — exact solver.

The paper observes problem (13) is quasiconvex and solves it "with the
bisection method".  We make that exact (DESIGN.md §3): substituting the
per-phase *times* as decision variables turns (13) into a separable
convex resource-allocation problem

    min   Σᵢ Eᵢ(tᵢ)
    s.t.  Σᵢ tᵢ ≤ T_budget        (= T_pass − 2·T_prop − T_ISL)
          tᵢ ≥ tᵢ_min             (from f ≤ f_max and p ≤ P_max)

with every Eᵢ convex and strictly decreasing, so the deadline binds at
the optimum and the KKT conditions reduce to the classic waterfilling
form  −Eᵢ'(tᵢ) = λ  (or tᵢ = tᵢ_min where the bound binds).  We bisect
on the dual λ — *this is the paper's bisection, applied to the dual* —
with closed-form tᵢ(λ) for the processing phases and a scalar inner
bisection for the Shannon-rate comm phases.

Phases (i):
    0: sat processing   E(t) = k/t²,  k = (P_p/f_max³)(nW₁/(N_c N_F))³
    1: downlink comm    E(t) = t·(2^{c/t} − 1)/g̃,  c = n·D_tx/B
    2: gs processing    (as 0 with W₂)
    3: uplink comm      (as 1 — same payload per the paper)

Infeasibility (Σ tᵢ_min > T_budget) is reported, and
:func:`solve_with_shedding` implements the straggler-mitigation policy:
shed the smallest batch fraction that restores feasibility.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.energy import (Allocation, PassBudget, SplitCosts,
                               allocation_from_times)

_EPS = 1e-12


# --------------------------------------------------------------------------
# Per-phase convex models in the time domain.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Phase:
    """One separable term: energy(t), its negated derivative, and t_min."""

    name: str
    t_min: float
    energy: Callable[[float], float]
    neg_deriv: Callable[[float], float]   # −E'(t): positive, decreasing in t

    def t_of_lambda(self, lam: float, t_hi: float) -> float:
        """Solve −E'(t) = lam for t ∈ [t_min, t_hi] (monotone bisection)."""
        lo, hi = self.t_min, t_hi
        if self.neg_deriv(lo) <= lam:     # marginal already below λ at the bound
            return lo
        if self.neg_deriv(hi) >= lam:     # even at t_hi the marginal exceeds λ
            return hi
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if self.neg_deriv(mid) > lam:
                lo = mid
            else:
                hi = mid
            if hi - lo < 1e-12 * max(1.0, hi):
                break
        return 0.5 * (lo + hi)


def _proc_phase(name: str, k: float, t_min: float) -> Optional[_Phase]:
    """E(t) = k / t², −E'(t) = 2k/t³; closed-form t(λ) = (2k/λ)^{1/3}."""
    if k <= 0.0:
        return None

    phase = _Phase(
        name=name,
        t_min=t_min,
        energy=lambda t: k / (t * t),
        neg_deriv=lambda t: 2.0 * k / (t * t * t),
    )

    # closed form overrides the generic bisection
    def t_of_lambda(lam: float, t_hi: float, _k=k, _tmin=t_min) -> float:
        t = (2.0 * _k / max(lam, 1e-300)) ** (1.0 / 3.0)
        return min(max(t, _tmin), t_hi)

    object.__setattr__(phase, "t_of_lambda", t_of_lambda)
    return phase


def _comm_phase(name: str, c_bits_per_hz: float, gain: float,
                t_min: float) -> Optional[_Phase]:
    """E(t) = t (2^{c/t} − 1)/g̃ with c = bits/B.

    −E'(t) = [2^{c/t}((c ln2)/t − 1) + 1]/g̃, positive and decreasing.
    Evaluated via expm1 to avoid catastrophic cancellation for small
    c/t (the naive form loses ~1e-3 relative accuracy at u ~ 1e-6,
    which corrupts the dual bisection — caught by the KKT-residual
    hypothesis test).
    """
    if c_bits_per_hz <= 0.0:
        return None
    ln2 = math.log(2.0)

    def energy(t: float, c=c_bits_per_hz, g=gain) -> float:
        return t * math.expm1((c / t) * ln2) / g

    def neg_deriv(t: float, c=c_bits_per_hz, g=gain) -> float:
        u = c / t
        ul = u * ln2
        if ul > 500.0:                     # avoid overflow: exp regime
            return math.exp(500.0) / g     # effectively +inf marginal
        e = math.expm1(ul)
        # 1 + (1+e)(ul - 1) = e*ul - (e - ul); both terms O(u^2), stable
        return (e * ul - (e - ul)) / g

    return _Phase(name=name, t_min=t_min, energy=energy, neg_deriv=neg_deriv)


def _build_phases(budget: PassBudget, costs: SplitCosts) -> List[Optional[_Phase]]:
    """Phases in canonical order [sat_proc, down, gs_proc, up]; None = absent."""
    n = budget.n_items
    d = budget.mean_distance_m
    link = budget.link
    gain = link.channel_gain(d)

    def proc_k(dev, w):
        nw = n * w / (dev.n_cores * dev.flops_per_cycle)
        return dev.power_max_w / dev.f_max_hz**3 * nw**3

    def proc_tmin(dev, w):
        return dev.min_proc_time_s(w, n)

    down_bits = n * costs.dtx_bits
    up_bits = n * costs.dtx_bits
    c_down = down_bits / link.bandwidth_hz
    c_up = up_bits / link.bandwidth_hz
    r_max = link.rate_bps(link.max_tx_power_w, d)
    t_min_down = down_bits / r_max if down_bits > 0 else 0.0
    t_min_up = up_bits / r_max if up_bits > 0 else 0.0

    return [
        _proc_phase("sat_proc", proc_k(budget.sat_device, costs.w1_flops),
                    proc_tmin(budget.sat_device, costs.w1_flops)),
        _comm_phase("downlink", c_down, gain, t_min_down),
        _proc_phase("gs_proc", proc_k(budget.gs_device, costs.w2_flops),
                    proc_tmin(budget.gs_device, costs.w2_flops)),
        _comm_phase("uplink", c_up, gain, t_min_up),
    ]


# --------------------------------------------------------------------------
# The dual-bisection (waterfilling) solver.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolveReport:
    allocation: Allocation
    lam: float
    kkt_residual: float
    iterations: int
    phase_times: dict


def solve(budget: PassBudget, costs: SplitCosts,
          tol: float = 1e-10) -> SolveReport:
    """Exact solution of problem (13) via bisection on the dual variable."""
    phases = _build_phases(budget, costs)
    live = [p for p in phases if p is not None]
    t_budget = budget.time_budget_s(costs)

    t_min_sum = sum(p.t_min for p in live)
    if not live:
        alloc = allocation_from_times(budget, costs, 0.0, 0.0, 0.0, 0.0)
        return SolveReport(alloc, 0.0, 0.0, 0, {})
    if t_budget <= 0.0 or t_min_sum > t_budget:
        # Infeasible: even at f_max / P_max the pass deadline cannot be met.
        times = {p.name: p.t_min for p in live}
        alloc = _alloc_from_phase_times(budget, costs, phases, times, feasible=False)
        return SolveReport(alloc, math.inf, math.inf, 0, times)

    t_hi = t_budget  # no phase can use more than the whole budget

    def total_time(lam: float) -> float:
        return sum(p.t_of_lambda(lam, t_hi) for p in live)

    # Bracket λ: total_time is decreasing in λ.
    lam_lo, lam_hi = 1e-20, 1.0
    for _ in range(400):
        if total_time(lam_hi) <= t_budget:
            break
        lam_hi *= 4.0
    for _ in range(400):
        if total_time(lam_lo) >= t_budget:
            break
        lam_lo /= 4.0

    iters = 0
    for iters in range(1, 300):
        lam = math.sqrt(lam_lo * lam_hi)   # geometric mid: λ spans decades
        if total_time(lam) > t_budget:
            lam_lo = lam
        else:
            lam_hi = lam
        if lam_hi / lam_lo < 1.0 + tol:
            break
    lam = math.sqrt(lam_lo * lam_hi)

    times = {p.name: p.t_of_lambda(lam, t_hi) for p in live}
    # Use any slack (from t_min-clamped phases) on the cheapest marginal —
    # distribute residual to interior phases by a final λ refinement pass:
    slack = t_budget - sum(times.values())
    if slack > 1e-9 * t_budget:
        interior = [p for p in live if times[p.name] > p.t_min * (1 + 1e-9)]
        for p in interior:
            times[p.name] += slack / max(len(interior), 1)

    # KKT residual: max relative spread of marginals among interior phases.
    interior_marginals = [p.neg_deriv(times[p.name]) for p in live
                          if times[p.name] > p.t_min * (1 + 1e-6)
                          and times[p.name] < t_hi * (1 - 1e-6)]
    if len(interior_marginals) >= 2:
        mmin, mmax = min(interior_marginals), max(interior_marginals)
        kkt = (mmax - mmin) / max(mmax, _EPS)
    else:
        kkt = 0.0

    alloc = _alloc_from_phase_times(budget, costs, phases, times, feasible=True)
    return SolveReport(alloc, lam, kkt, iters, times)


def _alloc_from_phase_times(budget, costs, phases, times, feasible):
    def t_of(idx, name):
        p = phases[idx]
        return times.get(name, 0.0) if p is not None else 0.0
    return allocation_from_times(
        budget, costs,
        t_proc_sat=t_of(0, "sat_proc"),
        t_comm_down=t_of(1, "downlink"),
        t_proc_gs=t_of(2, "gs_proc"),
        t_comm_up=t_of(3, "uplink"),
        feasible=feasible,
    )


# --------------------------------------------------------------------------
# Straggler mitigation: shed batch fraction until the deadline is met.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SheddingReport:
    report: SolveReport
    kept_fraction: float
    n_items_kept: float


def solve_with_shedding(budget: PassBudget, costs: SplitCosts,
                        min_fraction: float = 0.05,
                        tol: float = 1e-4) -> SheddingReport:
    """If (13) is infeasible, find the max batch fraction that fits.

    t_min of every phase scales linearly with n_items, so feasibility is
    monotone in the kept fraction — bisect on it.  This is the per-pass
    deadline acting as straggler mitigation (DESIGN.md §2): a slow or
    energy-poor satellite processes a prefix of its batch rather than
    stalling the ring.
    """
    rep = solve(budget, costs)
    if rep.allocation.feasible:
        return SheddingReport(rep, 1.0, budget.n_items)

    lo, hi = min_fraction, 1.0
    if not _feasible_at(budget, costs, lo):
        rep = solve(dataclasses.replace(budget, n_items=budget.n_items * lo), costs)
        return SheddingReport(rep, lo, budget.n_items * lo)

    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if _feasible_at(budget, costs, mid):
            lo = mid
        else:
            hi = mid
    frac = lo
    rep = solve(dataclasses.replace(budget, n_items=budget.n_items * frac), costs)
    return SheddingReport(rep, frac, budget.n_items * frac)


def _feasible_at(budget: PassBudget, costs: SplitCosts, frac: float) -> bool:
    b = dataclasses.replace(budget, n_items=budget.n_items * frac)
    phases = [p for p in _build_phases(b, costs) if p is not None]
    return sum(p.t_min for p in phases) <= b.time_budget_s(costs)


# --------------------------------------------------------------------------
# Microbatch-pipelined SL (beyond-paper): overlap sat-compute / links /
# gs-compute across M microbatches (parallel split learning).
# --------------------------------------------------------------------------

def solve_pipelined(budget: PassBudget, costs: SplitCosts,
                    n_microbatches: int = 8) -> SolveReport:
    """With M microbatches in flight the four resources (sat CPU, downlink,
    GS CPU, uplink) run concurrently; wall time ≈ (M+3)/M · max_i t_i
    (pipeline fill/drain) instead of Σ_i t_i.  Each phase may therefore
    stretch to T_eff = T_budget·M/(M+3) *independently*, and since every
    E_i(t) is decreasing the optimum is simply t_i = max(t_i_min, T_eff)
    — no waterfilling needed.  Energy drops ∝ (Σt→T each): the cubic CPU
    law turns the extra time straight into f² savings, compounding with
    the paper's optimizer (EXPERIMENTS.md §Perf beyond-paper row).
    """
    phases = [p for p in _build_phases(budget, costs) if p is not None]
    t_budget = budget.time_budget_s(costs)
    m = max(1, n_microbatches)
    t_eff = t_budget * m / (m + 3.0)
    if not phases:
        alloc = allocation_from_times(budget, costs, 0, 0, 0, 0)
        return SolveReport(alloc, 0.0, 0.0, 0, {})
    if any(p.t_min > t_eff for p in phases) or t_eff <= 0:
        times = {p.name: p.t_min for p in phases}
        feas = max(p.t_min for p in phases) <= t_eff > 0
        alloc = _alloc_from_phase_times(
            budget, costs, _build_phases(budget, costs), times, feasible=feas)
        return SolveReport(alloc, math.inf, math.inf, 0, times)
    times = {p.name: t_eff for p in phases}
    alloc = _alloc_from_phase_times(
        budget, costs, _build_phases(budget, costs), times, feasible=True)
    # NOTE: alloc.t_total sums phases (sequential accounting); the
    # pipelined wall-clock is (m+3)/m * max(times) + fixed overhead.
    return SolveReport(alloc, 0.0, 0.0, 1, times)


# --------------------------------------------------------------------------
# Split-point search (beyond-paper: the paper hand-picks ℓ).
# --------------------------------------------------------------------------

def best_split(budget: PassBudget,
               candidates: Sequence[SplitCosts]) -> Tuple[SplitCosts, SolveReport]:
    """Jointly pick the cut point ℓ and the resource allocation."""
    best: Optional[Tuple[SplitCosts, SolveReport]] = None
    for costs in candidates:
        rep = solve(budget, costs)
        if not rep.allocation.feasible:
            continue
        if best is None or rep.allocation.e_total < best[1].allocation.e_total:
            best = (costs, rep)
    if best is None:
        # nothing feasible: fall back to max shedding on the least-bad plan
        sheds = [(c, solve_with_shedding(budget, c)) for c in candidates]
        c, s = max(sheds, key=lambda cs: cs[1].kept_fraction)
        return c, s.report
    return best
