"""Problem (13): per-pass energy minimization — exact solver.

The paper observes problem (13) is quasiconvex and solves it "with the
bisection method".  We make that exact (DESIGN.md §3): substituting the
per-phase *times* as decision variables turns (13) into a separable
convex resource-allocation problem

    min   Σᵢ Eᵢ(tᵢ)
    s.t.  Σᵢ tᵢ ≤ T_budget        (= T_pass − 2·T_prop − T_ISL)
          tᵢ ≥ tᵢ_min             (from f ≤ f_max and p ≤ P_max)

with every Eᵢ convex and strictly decreasing, so the deadline binds at
the optimum and the KKT conditions reduce to the classic waterfilling
form  −Eᵢ'(tᵢ) = λ  (or tᵢ = tᵢ_min where the bound binds).  We bisect
on the dual λ — *this is the paper's bisection, applied to the dual* —
with closed-form tᵢ(λ) for the processing phases and a scalar inner
bisection for the Shannon-rate comm phases.

Phases (i):
    0: sat processing   E(t) = k/t²,  k = (P_p/f_max³)(nW₁/(N_c N_F))³
    1: downlink comm    E(t) = t·(2^{c/t} − 1)/g̃,  c = n·D_tx/B
    2: gs processing    (as 0 with W₂)
    3: uplink comm      (as 1 — same payload per the paper)

Infeasibility (Σ tᵢ_min > T_budget) is reported, and
:func:`solve_with_shedding` implements the straggler-mitigation policy:
shed the smallest batch fraction that restores feasibility.

Batched solver (the constellation-scale hot path)
-------------------------------------------------
:func:`solve_batch` solves problem (13) for an *array* of
(budget, costs) instances at once.  The dual-λ bisection is vectorized
across instances with NumPy, and the scalar inner bisection for the
comm phases disappears entirely: the comm-phase stationarity condition
``−E'(t) = λ`` is, in ``x = c·ln2/t``,

    e^x (x − 1) + 1 = λ·g̃      ⟹      x = 1 + W₀((λ·g̃ − 1)/e)

a closed form in the principal Lambert-W branch (two stable Newton
polish steps recover full precision near the branch point).  One
geometric λ-bisection with analytic brackets — λ_hi = maxᵢ −Eᵢ'(tᵢ_min),
λ_lo = minᵢ −Eᵢ'(T_budget) — then solves every instance simultaneously
in ~50 vectorized iterations, ~100× faster than looping the scalar
solver (see benchmarks/run.py ``solve_batch_256`` row).

The scalar :func:`solve` is a thin wrapper over a 1-instance batch; the
original pure-Python implementation is kept as :func:`solve_reference`
and the test suite asserts element-wise parity between the two.
:func:`best_split_batch` runs the cut-point sweep through one batched
call.

Solver backends
---------------
:func:`solve_batch` (and everything layered on it — shedding, best
split, the revolution planner) takes ``backend="numpy" | "jax" |
"auto"``.  ``"numpy"`` is this module's lockstep-array path: the CPU
fallback and the parity oracle.  ``"jax"`` routes through
:mod:`repro.core.resource_opt_jax` — the same algorithm as one jitted
``vmap`` + ``lax.while_loop`` program, so device-resident sweeps skip
the host round-trip entirely.  ``"auto"`` (the default, overridable via
``REPRO_SOLVER_BACKEND``) picks jax on an accelerator or for large
batches, numpy otherwise.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.energy import (Allocation, PassBudget, SplitCosts,
                               allocation_from_times)

_EPS = 1e-12
_LN2 = math.log(2.0)

try:
    from scipy.special import lambertw as _scipy_lambertw
except ModuleNotFoundError:                     # pragma: no cover
    _scipy_lambertw = None


# --------------------------------------------------------------------------
# Per-phase convex models in the time domain.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Phase:
    """One separable term: energy(t), its negated derivative, and t_min."""

    name: str
    t_min: float
    energy: Callable[[float], float]
    neg_deriv: Callable[[float], float]   # −E'(t): positive, decreasing in t

    def t_of_lambda(self, lam: float, t_hi: float) -> float:
        """Solve −E'(t) = lam for t ∈ [t_min, t_hi] (monotone bisection)."""
        lo, hi = self.t_min, t_hi
        if self.neg_deriv(lo) <= lam:     # marginal already below λ at the bound
            return lo
        if self.neg_deriv(hi) >= lam:     # even at t_hi the marginal exceeds λ
            return hi
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if self.neg_deriv(mid) > lam:
                lo = mid
            else:
                hi = mid
            if hi - lo < 1e-12 * max(1.0, hi):
                break
        return 0.5 * (lo + hi)


def _proc_phase(name: str, k: float, t_min: float) -> Optional[_Phase]:
    """E(t) = k / t², −E'(t) = 2k/t³; closed-form t(λ) = (2k/λ)^{1/3}."""
    if k <= 0.0:
        return None

    phase = _Phase(
        name=name,
        t_min=t_min,
        energy=lambda t: k / (t * t),
        neg_deriv=lambda t: 2.0 * k / (t * t * t),
    )

    # closed form overrides the generic bisection
    def t_of_lambda(lam: float, t_hi: float, _k=k, _tmin=t_min) -> float:
        t = (2.0 * _k / max(lam, 1e-300)) ** (1.0 / 3.0)
        return min(max(t, _tmin), t_hi)

    object.__setattr__(phase, "t_of_lambda", t_of_lambda)
    return phase


def _comm_phase(name: str, c_bits_per_hz: float, gain: float,
                t_min: float) -> Optional[_Phase]:
    """E(t) = t (2^{c/t} − 1)/g̃ with c = bits/B.

    −E'(t) = [2^{c/t}((c ln2)/t − 1) + 1]/g̃, positive and decreasing.
    Evaluated via expm1 to avoid catastrophic cancellation for small
    c/t (the naive form loses ~1e-3 relative accuracy at u ~ 1e-6,
    which corrupts the dual bisection — caught by the KKT-residual
    hypothesis test).
    """
    if c_bits_per_hz <= 0.0:
        return None
    ln2 = math.log(2.0)

    def energy(t: float, c=c_bits_per_hz, g=gain) -> float:
        return t * math.expm1((c / t) * ln2) / g

    def neg_deriv(t: float, c=c_bits_per_hz, g=gain) -> float:
        u = c / t
        ul = u * ln2
        if ul > 500.0:                     # avoid overflow: exp regime
            return math.exp(500.0) / g     # effectively +inf marginal
        e = math.expm1(ul)
        # 1 + (1+e)(ul - 1) = e*ul - (e - ul); both terms O(u^2), stable
        return (e * ul - (e - ul)) / g

    return _Phase(name=name, t_min=t_min, energy=energy, neg_deriv=neg_deriv)


@dataclasses.dataclass(frozen=True)
class _PhaseCoeffs:
    """Raw per-instance coefficients of the four canonical phases.

    The single source of truth shared by the scalar phase objects
    (:func:`_build_phases`) and the vectorized batch arrays
    (:func:`solve_batch`): ``k`` for the two processing phases
    (E = k/t²), ``c`` (bits/Hz) and ``gain`` for the two comm phases,
    plus every phase's t_min.
    """

    k_sat: float
    t_min_sat: float
    c_down: float
    t_min_down: float
    k_gs: float
    t_min_gs: float
    c_up: float
    t_min_up: float
    gain: float


def _phase_coeffs(budget: PassBudget, costs: SplitCosts) -> _PhaseCoeffs:
    n = budget.n_items
    d = budget.mean_distance_m
    link = budget.link
    gain = link.channel_gain(d)

    def proc_k(dev, w):
        nw = n * w / (dev.n_cores * dev.flops_per_cycle)
        return dev.power_max_w / dev.f_max_hz**3 * nw**3

    down_bits = n * costs.dtx_bits
    up_bits = n * costs.dtx_bits
    r_max = link.rate_bps(link.max_tx_power_w, d)

    return _PhaseCoeffs(
        k_sat=proc_k(budget.sat_device, costs.w1_flops),
        t_min_sat=budget.sat_device.min_proc_time_s(costs.w1_flops, n),
        c_down=down_bits / link.bandwidth_hz,
        t_min_down=down_bits / r_max if down_bits > 0 else 0.0,
        k_gs=proc_k(budget.gs_device, costs.w2_flops),
        t_min_gs=budget.gs_device.min_proc_time_s(costs.w2_flops, n),
        c_up=up_bits / link.bandwidth_hz,
        t_min_up=up_bits / r_max if up_bits > 0 else 0.0,
        gain=gain,
    )


def _build_phases(budget: PassBudget, costs: SplitCosts) -> List[Optional[_Phase]]:
    """Phases in canonical order [sat_proc, down, gs_proc, up]; None = absent."""
    cf = _phase_coeffs(budget, costs)
    return [
        _proc_phase("sat_proc", cf.k_sat, cf.t_min_sat),
        _comm_phase("downlink", cf.c_down, cf.gain, cf.t_min_down),
        _proc_phase("gs_proc", cf.k_gs, cf.t_min_gs),
        _comm_phase("uplink", cf.c_up, cf.gain, cf.t_min_up),
    ]


# --------------------------------------------------------------------------
# The dual-bisection (waterfilling) solver.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolveReport:
    allocation: Allocation
    lam: float
    kkt_residual: float
    iterations: int
    phase_times: dict


def solve_reference(budget: PassBudget, costs: SplitCosts,
                    tol: float = 1e-10) -> SolveReport:
    """Scalar reference solver (pure-Python nested bisection).

    Kept as the oracle the vectorized :func:`solve_batch` is tested
    against; the public :func:`solve` now routes through the batch path.
    """
    phases = _build_phases(budget, costs)
    live = [p for p in phases if p is not None]
    t_budget = budget.time_budget_s(costs)

    t_min_sum = sum(p.t_min for p in live)
    if not live:
        alloc = allocation_from_times(budget, costs, 0.0, 0.0, 0.0, 0.0)
        return SolveReport(alloc, 0.0, 0.0, 0, {})
    if t_budget <= 0.0 or t_min_sum > t_budget:
        # Infeasible: even at f_max / P_max the pass deadline cannot be met.
        times = {p.name: p.t_min for p in live}
        alloc = _alloc_from_phase_times(budget, costs, phases, times, feasible=False)
        return SolveReport(alloc, math.inf, math.inf, 0, times)

    t_hi = t_budget  # no phase can use more than the whole budget

    def total_time(lam: float) -> float:
        return sum(p.t_of_lambda(lam, t_hi) for p in live)

    # Bracket λ: total_time is decreasing in λ.
    lam_lo, lam_hi = 1e-20, 1.0
    for _ in range(400):
        if total_time(lam_hi) <= t_budget:
            break
        lam_hi *= 4.0
    for _ in range(400):
        if total_time(lam_lo) >= t_budget:
            break
        lam_lo /= 4.0

    iters = 0
    for iters in range(1, 300):
        lam = math.sqrt(lam_lo * lam_hi)   # geometric mid: λ spans decades
        if total_time(lam) > t_budget:
            lam_lo = lam
        else:
            lam_hi = lam
        if lam_hi / lam_lo < 1.0 + tol:
            break
    lam = math.sqrt(lam_lo * lam_hi)

    times = {p.name: p.t_of_lambda(lam, t_hi) for p in live}
    # Use any slack (from t_min-clamped phases) on the cheapest marginal —
    # distribute residual to interior phases by a final λ refinement pass:
    slack = t_budget - sum(times.values())
    if slack > 1e-9 * t_budget:
        interior = [p for p in live if times[p.name] > p.t_min * (1 + 1e-9)]
        for p in interior:
            times[p.name] += slack / max(len(interior), 1)

    # KKT residual: max relative spread of marginals among interior phases.
    interior_marginals = [p.neg_deriv(times[p.name]) for p in live
                          if times[p.name] > p.t_min * (1 + 1e-6)
                          and times[p.name] < t_hi * (1 - 1e-6)]
    if len(interior_marginals) >= 2:
        mmin, mmax = min(interior_marginals), max(interior_marginals)
        kkt = (mmax - mmin) / max(mmax, _EPS)
    else:
        kkt = 0.0

    alloc = _alloc_from_phase_times(budget, costs, phases, times, feasible=True)
    return SolveReport(alloc, lam, kkt, iters, times)


def _alloc_from_phase_times(budget, costs, phases, times, feasible):
    def t_of(idx, name):
        p = phases[idx]
        return times.get(name, 0.0) if p is not None else 0.0
    return allocation_from_times(
        budget, costs,
        t_proc_sat=t_of(0, "sat_proc"),
        t_comm_down=t_of(1, "downlink"),
        t_proc_gs=t_of(2, "gs_proc"),
        t_comm_up=t_of(3, "uplink"),
        feasible=feasible,
    )


# --------------------------------------------------------------------------
# Vectorized (batched) solver: problem (13) over an array of instances.
# --------------------------------------------------------------------------

def _lambert_w0(z: np.ndarray) -> np.ndarray:
    """Principal-branch Lambert W, vectorized; z >= -1/e elementwise."""
    if _scipy_lambertw is not None:
        return np.real(_scipy_lambertw(z))
    # Halley fallback (no scipy): branch-point init for z < 0, log init above.
    z = np.asarray(z, dtype=np.float64)
    w = np.where(z < 0.0,
                 -1.0 + np.sqrt(np.maximum(2.0 * (1.0 + math.e * z), 0.0)),
                 np.log1p(np.maximum(z, 0.0)))
    with np.errstate(divide="ignore", invalid="ignore"):
        big = z > math.e
        lz = np.log(np.where(big, z, math.e))
        w = np.where(big, lz - np.log(lz), w)
    for _ in range(20):
        w = np.maximum(w, -1.0 + 1e-12)     # keep 2w+2 away from zero
        ew = np.exp(np.minimum(w, 700.0))
        f = w * ew - z
        denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0)
        w = w - f / np.where(denom != 0.0, denom, 1.0)
    return np.maximum(w, -1.0)


def _comm_neg_deriv_vec(c, gain, t):
    """−E'(t) of a comm phase, elementwise-stable (see _comm_phase)."""
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        x = np.where(t > 0.0, c * _LN2 / np.maximum(t, 1e-300), np.inf)
        xs = np.minimum(x, 500.0)
        e = np.expm1(xs)
        nd = (e * xs - (e - xs)) / gain
        return np.where(x > 500.0, np.inf, nd)


def _comm_t_of_lambda_vec(c, gain, lam, t_min, t_hi):
    """Closed-form t(λ) for the comm phases via Lambert W.

    −E'(t) = λ  ⟺  e^x (x−1) + 1 = λ·g̃  with x = c·ln2/t, so
    x = 1 + W₀((λ·g̃ − 1)/e).  Two Newton steps on the cancellation-free
    residual  expm1(x)·x − (expm1(x) − x) − λ·g̃  restore full precision
    near the branch point (small λ·g̃  ⟹  x ≈ √(2λg̃)).
    """
    lg = lam * gain
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        z = np.maximum((lg - 1.0) / math.e, -1.0 / math.e)
        x = 1.0 + _lambert_w0(z)
        # Branch-point underflow: for λ·g̃ ≲ 2.2e-16 the argument rounds
        # to exactly −1/e and W₀ returns NaN; the series x ≈ √(2·λg̃) of
        # e^x(x−1)+1 = λg̃ is exact there (and the Newton polish below
        # removes its O(x²) error for the rest of the small-λ range).
        small = lg < 1e-6
        x = np.where(small, np.sqrt(2.0 * np.maximum(lg, 0.0)), x)
        x = np.maximum(x, 1e-300)
        for _ in range(2):
            xs = np.minimum(x, 500.0)
            em = np.expm1(xs)
            f = em * xs - (em - xs) - lg
            fp = (em + 1.0) * xs
            x = np.maximum(x - f / np.maximum(fp, 1e-300), 1e-300)
        t = c * _LN2 / x
    return np.clip(t, t_min, t_hi)


def _proc_t_of_lambda_vec(k, lam, t_min, t_hi):
    """Closed-form t(λ) = (2k/λ)^{1/3} for the processing phases."""
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        t = np.cbrt(2.0 * k / np.maximum(lam, 1e-300))
    return np.clip(t, t_min, t_hi)


@dataclasses.dataclass(frozen=True)
class BatchSolveReport:
    """Vectorized solution of problem (13) for B instances.

    Arrays are NumPy, shape (B,) or (B, 4); the phase axis is the
    canonical order [sat_proc, downlink, gs_proc, uplink] with zeros
    where a phase is absent.  :meth:`report_at` materializes the full
    scalar :class:`SolveReport` (with the implied (f, p) allocation)
    for one instance.
    """

    phase_times: np.ndarray       # (B, 4) seconds
    phase_energy: np.ndarray      # (B, 4) joules
    lam: np.ndarray               # (B,) dual variable (inf if infeasible)
    kkt_residual: np.ndarray      # (B,)
    feasible: np.ndarray          # (B,) bool
    e_isl: np.ndarray             # (B,) joules (constant term of eq. 11)
    t_fixed: np.ndarray           # (B,) seconds (constant term of eq. 12)
    budgets: Tuple[PassBudget, ...] = dataclasses.field(repr=False,
                                                        default=())
    costs: Tuple[SplitCosts, ...] = dataclasses.field(repr=False,
                                                      default=())

    @property
    def n(self) -> int:
        return self.phase_times.shape[0]

    @property
    def e_total(self) -> np.ndarray:
        """eq. (11) per instance, including the constant E_ISL."""
        return self.phase_energy.sum(axis=1) + self.e_isl

    @property
    def t_total(self) -> np.ndarray:
        """eq. (12) per instance, including the fixed overhead."""
        return self.phase_times.sum(axis=1) + self.t_fixed

    def report_at(self, i: int) -> SolveReport:
        names = ("sat_proc", "downlink", "gs_proc", "uplink")
        budget, costs = self.budgets[i], self.costs[i]
        phases = _build_phases(budget, costs)
        times = {nm: float(self.phase_times[i, j])
                 for j, nm in enumerate(names) if phases[j] is not None}
        alloc = _alloc_from_phase_times(budget, costs, phases, times,
                                        feasible=bool(self.feasible[i]))
        return SolveReport(alloc, float(self.lam[i]),
                           float(self.kkt_residual[i]), 0, times)


# "auto" flips to the jax backend at this batch size on CPU (measured
# crossover in benchmarks/run.py `solver_backend` rows); any accelerator
# flips immediately.  Override the default with REPRO_SOLVER_BACKEND.
_AUTO_MIN_JAX_BATCH = 512


def _resolve_backend(backend: Optional[str], n_instances: int) -> str:
    """Map the user's backend choice (or "auto") to "numpy" | "jax"."""
    backend = backend or os.environ.get("REPRO_SOLVER_BACKEND", "auto")
    if backend == "auto":
        try:
            from repro.core import resource_opt_jax
        except Exception:                        # pragma: no cover
            return "numpy"
        if not resource_opt_jax.available():
            return "numpy"
        if resource_opt_jax.on_accelerator() \
                or n_instances >= _AUTO_MIN_JAX_BATCH:
            return "jax"
        return "numpy"
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown solver backend {backend!r}; expected "
                         "'numpy', 'jax' or 'auto'")
    return backend


def _gather_coeff_arrays_reference(
        blist: Sequence[PassBudget],
        clist: Sequence[SplitCosts]) -> Dict[str, np.ndarray]:
    """Per-instance coefficient gather, one ``_phase_coeffs`` at a time.

    The original O(B)-Python-objects loop, kept as the oracle the
    vectorized :func:`_gather_coeff_arrays` is tested against.
    """
    B = len(blist)
    k = np.zeros((B, 2))          # [sat_proc, gs_proc]
    tmin_p = np.zeros((B, 2))
    cc = np.zeros((B, 2))         # [downlink, uplink] bits/Hz
    tmin_c = np.zeros((B, 2))
    gain = np.zeros(B)
    t_budget = np.zeros(B)
    e_isl = np.zeros(B)
    t_fixed = np.zeros(B)
    for i, (b, c) in enumerate(zip(blist, clist)):
        cf = _phase_coeffs(b, c)
        k[i] = (cf.k_sat, cf.k_gs)
        tmin_p[i] = (cf.t_min_sat, cf.t_min_gs)
        cc[i] = (cf.c_down, cf.c_up)
        tmin_c[i] = (cf.t_min_down, cf.t_min_up)
        gain[i] = cf.gain
        t_budget[i] = b.time_budget_s(c)
        e_isl[i] = b.isl_energy_j(c)
        t_fixed[i] = b.fixed_overhead_s(c)
    return dict(k=k, tmin_p=tmin_p, cc=cc, tmin_c=tmin_c, gain=gain,
                t_budget=t_budget, e_isl=e_isl, t_fixed=t_fixed)


def _gather_coeff_arrays(blist: Sequence[PassBudget],
                         clist: Sequence[SplitCosts]) -> Dict[str, np.ndarray]:
    """Per-instance coefficient arrays, vectorized over the batch.

    The single host-side gather shared by the NumPy solver below and the
    JAX backend (:mod:`repro.core.resource_opt_jax`), so both batch
    paths consume identical float64 inputs.  This used to be a Python
    loop over ``_phase_coeffs`` that dominated full-call ``solve_batch``
    at large B; now only the per-instance *scalars* (n_items and the
    four cost terms) are pulled out of the dataclasses, the scenario
    constants (orbit geometry, link budget, device DVFS constants) are
    computed once per distinct (plane, link, isl, devices) tuple —
    typically once per batch — and every coefficient is plain NumPy
    array math, mirroring :func:`resource_opt_jax.ring_grid_coeffs`
    element for element.
    """
    B = len(blist)
    n = np.fromiter((b.n_items for b in blist), np.float64, B)
    w1 = np.fromiter((c.w1_flops for c in clist), np.float64, B)
    w2 = np.fromiter((c.w2_flops for c in clist), np.float64, B)
    dtx = np.fromiter((c.dtx_bits for c in clist), np.float64, B)
    disl = np.fromiter((c.d_isl_bits for c in clist), np.float64, B)

    # scenario constants, one row per unique (plane, link, isl, devices)
    scen_idx = np.empty(B, np.int64)
    rows: Dict[Tuple, int] = {}
    consts: List[Tuple[float, ...]] = []
    for i, b in enumerate(blist):
        key = (b.plane, b.link, b.isl, b.sat_device, b.gs_device)
        j = rows.get(key)
        if j is None:
            j = rows[key] = len(consts)
            d = b.plane.mean_slant_range_m()
            sd, gd = b.sat_device, b.gs_device
            nc_s = sd.n_cores * sd.flops_per_cycle
            nc_g = gd.n_cores * gd.flops_per_cycle
            consts.append((
                b.link.channel_gain(d),
                b.link.rate_bps(b.link.max_tx_power_w, d),
                b.link.bandwidth_hz,
                sd.power_max_w / sd.f_max_hz ** 3 / nc_s ** 3,
                1.0 / (nc_s * sd.f_max_hz),
                gd.power_max_w / gd.f_max_hz ** 3 / nc_g ** 3,
                1.0 / (nc_g * gd.f_max_hz),
                b.plane.pass_duration_s,
                2.0 * b.plane.mean_prop_delay_s + b.plane.isl_prop_delay_s,
                b.isl.rate_bps,
                b.isl.tx_power_w,
            ))
        scen_idx[i] = j
    (gain, r_max, bw, ksat_c, tsat_c, kgs_c, tgs_c, pass_s, prop_s,
     isl_rate, isl_pw) = np.asarray(consts, np.float64)[scen_idx].T

    k = np.stack([ksat_c * (n * w1) ** 3, kgs_c * (n * w2) ** 3], axis=1)
    tmin_p = np.stack([tsat_c * n * w1, tgs_c * n * w2], axis=1)
    bits = n * dtx                      # one-way boundary payload
    c_comm = bits / bw
    tmin_comm = np.where(bits > 0.0, bits / r_max, 0.0)
    t_fixed = prop_s + disl / isl_rate
    return dict(
        k=k, tmin_p=tmin_p,
        cc=np.stack([c_comm, c_comm], axis=1),
        tmin_c=np.stack([tmin_comm, tmin_comm], axis=1),
        gain=gain, t_budget=pass_s - t_fixed,
        e_isl=isl_pw * disl / isl_rate, t_fixed=t_fixed)


def solve_batch(budgets: Union[PassBudget, Sequence[PassBudget]],
                costs: Union[SplitCosts, Sequence[SplitCosts]],
                tol: float = 1e-10, max_iters: int = 80,
                backend: Optional[str] = None) -> BatchSolveReport:
    """Solve problem (13) for B (budget, costs) instances at once.

    ``budgets`` and ``costs`` may each be a single object or a sequence;
    a single object is broadcast against the other argument.  All B
    dual bisections run simultaneously as NumPy array ops — the comm
    phases use the Lambert-W closed form instead of an inner bisection —
    so the cost is O(iterations) vector ops total, not O(B · iterations)
    Python arithmetic.

    ``backend`` selects the implementation: ``"numpy"`` (this module),
    ``"jax"`` (jit+vmap on the default JAX device, see
    :mod:`repro.core.resource_opt_jax`) or ``"auto"``/None.
    """
    blist, clist = _broadcast_instances(budgets, costs)
    B = len(blist)
    if _resolve_backend(backend, B) == "jax":
        from repro.core import resource_opt_jax
        return resource_opt_jax.solve_batch_jax(blist, clist, tol=tol,
                                                max_iters=max_iters)

    # ---- gather per-instance coefficients (cheap Python setup loop) ----
    arrs = _gather_coeff_arrays(blist, clist)
    k, tmin_p = arrs["k"], arrs["tmin_p"]
    cc, tmin_c = arrs["cc"], arrs["tmin_c"]
    gain, t_budget = arrs["gain"], arrs["t_budget"]
    e_isl, t_fixed = arrs["e_isl"], arrs["t_fixed"]

    live_p = k > 0.0
    live_c = cc > 0.0
    tmin_p = np.where(live_p, tmin_p, 0.0)
    tmin_c = np.where(live_c, tmin_c, 0.0)
    g2 = gain[:, None]

    t_min_sum = tmin_p.sum(axis=1) + tmin_c.sum(axis=1)
    any_live = live_p.any(axis=1) | live_c.any(axis=1)
    infeasible = any_live & ((t_budget <= 0.0) | (t_min_sum > t_budget))
    active = any_live & ~infeasible

    t_hi = np.maximum(t_budget, 0.0)[:, None]

    # ---- analytic λ bracket: total_time(λ) is decreasing in λ ----------
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        nd_p_lo = 2.0 * k / np.maximum(tmin_p, 1e-300) ** 3
        nd_p_hi = 2.0 * k / np.maximum(t_hi, 1e-300) ** 3
    nd_c_lo = _comm_neg_deriv_vec(cc, g2, np.maximum(tmin_c, 1e-300))
    nd_c_hi = _comm_neg_deriv_vec(cc, g2, np.maximum(t_hi, 1e-300))
    nd_lo = np.concatenate([np.where(live_p, nd_p_lo, -np.inf),
                            np.where(live_c, nd_c_lo, -np.inf)], axis=1)
    nd_hi = np.concatenate([np.where(live_p, nd_p_hi, np.inf),
                            np.where(live_c, nd_c_hi, np.inf)], axis=1)
    lam_hi = np.maximum(np.nan_to_num(nd_lo.max(axis=1), neginf=1.0,
                                      posinf=1e300), 1e-300)
    lam_lo = np.clip(np.nan_to_num(nd_hi.min(axis=1), posinf=1.0),
                     1e-300, lam_hi)

    def times_at(lam):
        l2 = lam[:, None]
        tp = np.where(live_p, _proc_t_of_lambda_vec(k, l2, tmin_p, t_hi), 0.0)
        tc = np.where(live_c,
                      _comm_t_of_lambda_vec(cc, g2, l2, tmin_c, t_hi), 0.0)
        return tp, tc

    # ---- geometric bisection on λ, all instances in lockstep -----------
    for _ in range(max_iters):
        if np.all(~active | (lam_hi <= lam_lo * (1.0 + tol))):
            break
        lam = np.sqrt(lam_lo * lam_hi)
        tp, tc = times_at(lam)
        over = (tp.sum(axis=1) + tc.sum(axis=1)) > t_budget
        lam_lo = np.where(active & over, lam, lam_lo)
        lam_hi = np.where(active & ~over, lam, lam_hi)
    lam = np.sqrt(lam_lo * lam_hi)
    tp, tc = times_at(lam)

    # ---- slack redistribution (t_min-clamped phases leave headroom) ----
    slack = t_budget - (tp.sum(axis=1) + tc.sum(axis=1))
    int_p = live_p & (tp > tmin_p * (1.0 + 1e-9))
    int_c = live_c & (tc > tmin_c * (1.0 + 1e-9))
    n_int = int_p.sum(axis=1) + int_c.sum(axis=1)
    bump = np.where(active & (slack > 1e-9 * t_budget) & (n_int > 0),
                    slack / np.maximum(n_int, 1), 0.0)[:, None]
    tp = np.where(int_p, tp + bump, tp)
    tc = np.where(int_c, tc + bump, tc)

    # ---- infeasible / no-phase instances -------------------------------
    tp = np.where(infeasible[:, None], tmin_p, tp)
    tc = np.where(infeasible[:, None], tmin_c, tc)
    tp = np.where(any_live[:, None], tp, 0.0)
    tc = np.where(any_live[:, None], tc, 0.0)

    # ---- energies at the final times -----------------------------------
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        e_p = np.where(live_p & (tp > 0.0),
                       k / np.maximum(tp, 1e-300) ** 2, 0.0)
        xc = cc * _LN2 / np.maximum(tc, 1e-300)
        e_c = np.where(live_c & (tc > 0.0),
                       tc * np.expm1(np.minimum(xc, 700.0)) / g2, 0.0)
        e_c = np.where(live_c & (xc > 700.0), np.inf, e_c)

    # ---- KKT residual: spread of marginals among interior phases -------
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        nd_p = 2.0 * k / np.maximum(tp, 1e-300) ** 3
    nd_c = _comm_neg_deriv_vec(cc, g2, np.maximum(tc, 1e-300))
    io_p = live_p & (tp > tmin_p * (1.0 + 1e-6)) & (tp < t_hi * (1.0 - 1e-6))
    io_c = live_c & (tc > tmin_c * (1.0 + 1e-6)) & (tc < t_hi * (1.0 - 1e-6))
    marg = np.concatenate([np.where(io_p, nd_p, np.nan),
                           np.where(io_c, nd_c, np.nan)], axis=1)
    n_io = io_p.sum(axis=1) + io_c.sum(axis=1)
    with np.errstate(invalid="ignore"):
        mmax = np.nanmax(np.where(n_io[:, None] >= 2, marg, 1.0), axis=1)
        mmin = np.nanmin(np.where(n_io[:, None] >= 2, marg, 1.0), axis=1)
    kkt = np.where(n_io >= 2, (mmax - mmin) / np.maximum(mmax, _EPS), 0.0)
    kkt = np.where(infeasible, np.inf, kkt)

    phase_times = np.stack([tp[:, 0], tc[:, 0], tp[:, 1], tc[:, 1]], axis=1)
    phase_energy = np.stack([e_p[:, 0], e_c[:, 0], e_p[:, 1], e_c[:, 1]],
                            axis=1)
    lam_out = np.where(infeasible, np.inf, np.where(any_live, lam, 0.0))

    return BatchSolveReport(
        phase_times=phase_times, phase_energy=phase_energy, lam=lam_out,
        kkt_residual=kkt, feasible=~infeasible, e_isl=e_isl,
        t_fixed=t_fixed, budgets=tuple(blist), costs=tuple(clist))


def solve(budget: PassBudget, costs: SplitCosts,
          tol: float = 1e-10) -> SolveReport:
    """Exact solution of problem (13) — thin wrapper over solve_batch."""
    return solve_batch(budget, costs, tol=tol).report_at(0)


# --------------------------------------------------------------------------
# Straggler mitigation: shed batch fraction until the deadline is met.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SheddingReport:
    report: SolveReport
    kept_fraction: float
    n_items_kept: float


def _broadcast_instances(budgets, costs):
    """(budget|seq, costs|seq) -> equal-length lists (shared helper)."""
    blist = [budgets] if isinstance(budgets, PassBudget) else list(budgets)
    clist = [costs] if isinstance(costs, SplitCosts) else list(costs)
    B = max(len(blist), len(clist))
    if len(blist) == 1:
        blist = blist * B
    if len(clist) == 1:
        clist = clist * B
    if len(blist) != B or len(clist) != B:
        raise ValueError(f"length mismatch: {len(blist)} budgets vs "
                         f"{len(clist)} costs")
    return blist, clist


@dataclasses.dataclass(frozen=True)
class BatchSheddingReport:
    """Vectorized shedding solution for B instances.

    ``report`` is the :class:`BatchSolveReport` solved at the *kept*
    item counts; ``at(i)`` materializes the scalar
    :class:`SheddingReport` for one instance.
    """

    report: BatchSolveReport
    kept_fraction: np.ndarray      # (B,)
    n_items_kept: np.ndarray       # (B,)

    @property
    def n(self) -> int:
        return len(self.kept_fraction)

    def at(self, i: int) -> SheddingReport:
        return SheddingReport(self.report.report_at(i),
                              float(self.kept_fraction[i]),
                              float(self.n_items_kept[i]))


def solve_with_shedding_batch(
        budgets: Union[PassBudget, Sequence[PassBudget]],
        costs: Union[SplitCosts, Sequence[SplitCosts]],
        min_fraction: float = 0.05,
        tol: float = 1e-4,
        backend: Optional[str] = None) -> BatchSheddingReport:
    """Vectorized :func:`solve_with_shedding` over B instances.

    Every phase's t_min scales linearly with n_items while the time
    budget does not depend on it, so feasibility at fraction f reduces
    to ``f · Σ t_min ≤ T_budget`` — the kept-fraction bisection runs in
    lockstep across all instances as array arithmetic (no inner solves),
    then ONE :func:`solve_batch` call allocates every instance at its
    kept item count.  This is the planner-scale path: a whole ring
    revolution's shedding decisions cost one batched solve.  ``backend``
    selects that solve's implementation (see :func:`solve_batch`); a
    fully device-side shedding path lives in
    :func:`repro.core.resource_opt_jax.shed_and_solve_coeffs`.
    """
    blist, clist = _broadcast_instances(budgets, costs)
    B = len(blist)

    arrs = _gather_coeff_arrays(blist, clist)
    t_min_sum = arrs["tmin_p"].sum(axis=1) + arrs["tmin_c"].sum(axis=1)
    t_budget = arrs["t_budget"]

    # No live phase => solve() reports feasible regardless of budget.
    no_phase = t_min_sum == 0.0
    feas_full = no_phase | ((t_budget > 0.0) & (t_min_sum <= t_budget))
    feas_floor = (t_budget > 0.0) & (min_fraction * t_min_sum <= t_budget)

    frac = np.ones(B)
    frac = np.where(feas_full, 1.0, np.where(feas_floor, frac,
                                             min_fraction))
    active = ~feas_full & feas_floor
    lo = np.full(B, min_fraction)
    hi = np.ones(B)
    while np.any(active & (hi - lo > tol)):
        mid = 0.5 * (lo + hi)
        ok = mid * t_min_sum <= t_budget
        lo = np.where(active & ok, mid, lo)
        hi = np.where(active & ~ok, mid, hi)
    frac = np.where(active, lo, frac)

    scaled = [b if f == 1.0 else dataclasses.replace(b,
                                                     n_items=b.n_items * f)
              for b, f in zip(blist, frac)]
    rep = solve_batch(scaled, clist, backend=backend)
    n_kept = np.array([b.n_items for b in blist]) * frac
    return BatchSheddingReport(rep, frac, n_kept)


def solve_with_shedding(budget: PassBudget, costs: SplitCosts,
                        min_fraction: float = 0.05,
                        tol: float = 1e-4) -> SheddingReport:
    """If (13) is infeasible, find the max batch fraction that fits.

    t_min of every phase scales linearly with n_items, so feasibility is
    monotone in the kept fraction — bisect on it.  This is the per-pass
    deadline acting as straggler mitigation (DESIGN.md §2): a slow or
    energy-poor satellite processes a prefix of its batch rather than
    stalling the ring.  Thin wrapper over a 1-instance
    :func:`solve_with_shedding_batch`.
    """
    return solve_with_shedding_batch(budget, costs, min_fraction=min_fraction,
                                     tol=tol).at(0)


def _feasible_at(budget: PassBudget, costs: SplitCosts, frac: float) -> bool:
    b = dataclasses.replace(budget, n_items=budget.n_items * frac)
    phases = [p for p in _build_phases(b, costs) if p is not None]
    return sum(p.t_min for p in phases) <= b.time_budget_s(costs)


# --------------------------------------------------------------------------
# Microbatch-pipelined SL (beyond-paper): overlap sat-compute / links /
# gs-compute across M microbatches (parallel split learning).
# --------------------------------------------------------------------------

def solve_pipelined(budget: PassBudget, costs: SplitCosts,
                    n_microbatches: int = 8) -> SolveReport:
    """With M microbatches in flight the four resources (sat CPU, downlink,
    GS CPU, uplink) run concurrently; wall time ≈ (M+3)/M · max_i t_i
    (pipeline fill/drain) instead of Σ_i t_i.  Each phase may therefore
    stretch to T_eff = T_budget·M/(M+3) *independently*, and since every
    E_i(t) is decreasing the optimum is simply t_i = max(t_i_min, T_eff)
    — no waterfilling needed.  Energy drops ∝ (Σt→T each): the cubic CPU
    law turns the extra time straight into f² savings, compounding with
    the paper's optimizer (EXPERIMENTS.md §Perf beyond-paper row).
    """
    phases = [p for p in _build_phases(budget, costs) if p is not None]
    t_budget = budget.time_budget_s(costs)
    m = max(1, n_microbatches)
    t_eff = t_budget * m / (m + 3.0)
    if not phases:
        alloc = allocation_from_times(budget, costs, 0, 0, 0, 0)
        return SolveReport(alloc, 0.0, 0.0, 0, {})
    if any(p.t_min > t_eff for p in phases) or t_eff <= 0:
        times = {p.name: p.t_min for p in phases}
        feas = max(p.t_min for p in phases) <= t_eff > 0
        alloc = _alloc_from_phase_times(
            budget, costs, _build_phases(budget, costs), times, feasible=feas)
        return SolveReport(alloc, math.inf, math.inf, 0, times)
    times = {p.name: t_eff for p in phases}
    alloc = _alloc_from_phase_times(
        budget, costs, _build_phases(budget, costs), times, feasible=True)
    # NOTE: alloc.t_total sums phases (sequential accounting); the
    # pipelined wall-clock is (m+3)/m * max(times) + fixed overhead.
    return SolveReport(alloc, 0.0, 0.0, 1, times)


# --------------------------------------------------------------------------
# Split-point search (beyond-paper: the paper hand-picks ℓ).
# --------------------------------------------------------------------------

def best_split_batch(budget: PassBudget,
                     candidates: Sequence[SplitCosts],
                     backend: Optional[str] = None
                     ) -> Tuple[SplitCosts, SolveReport]:
    """Jointly pick the cut point ℓ and the allocation — one batched solve.

    All candidate cuts go through a single :func:`solve_batch` call; the
    feasible minimum-energy instance wins (ties break to the shallower
    cut, matching the scalar sweep's first-strict-minimum rule).
    """
    cands = list(candidates)
    if not cands:
        raise ValueError("no split candidates")
    rep = solve_batch(budget, cands, backend=backend)
    e = np.where(rep.feasible, rep.e_total, np.inf)
    i = int(np.argmin(e))
    if np.isfinite(e[i]):
        return cands[i], rep.report_at(i)
    # nothing feasible: fall back to max shedding on the least-bad plan —
    # one vectorized kept-fraction bisection + solve across all cuts
    shed = solve_with_shedding_batch(budget, cands, backend=backend)
    j = int(np.argmax(shed.kept_fraction))
    return cands[j], shed.at(j).report


def best_split(budget: PassBudget,
               candidates: Sequence[SplitCosts]) -> Tuple[SplitCosts, SolveReport]:
    """Jointly pick the cut point ℓ and the resource allocation."""
    return best_split_batch(budget, candidates)
