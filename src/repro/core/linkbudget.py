"""Communication model — paper eqs. (8)-(10) plus the Table I link budget."""
from __future__ import annotations

import dataclasses
import math

from repro.core.orbits import C_LIGHT


def fspl_linear(distance_m: float, carrier_hz: float) -> float:
    """Free-space path loss as a linear power ratio (>= 1)."""
    return (4.0 * math.pi * distance_m * carrier_hz / C_LIGHT) ** 2


def db(x: float) -> float:
    return 10.0 * math.log10(x)


def from_db(x_db: float) -> float:
    return 10.0 ** (x_db / 10.0)


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """A Shannon-capacity link (GS<->LEO per Table I, or an ISL)."""

    bandwidth_hz: float = 500e6
    carrier_hz: float = 20e9
    antenna_gain_db: float = 66.33      # total (tx+rx) gain
    noise_power_dbw: float = -119.0
    max_tx_power_w: float = 10.0

    def channel_gain(self, distance_m: float) -> float:
        """g̃ = G / (FSPL * sigma^2): linear SNR per watt of tx power."""
        g = from_db(self.antenna_gain_db)
        fspl = fspl_linear(distance_m, self.carrier_hz)
        sigma2 = from_db(self.noise_power_dbw)
        return g / (fspl * sigma2)

    # --- eq. (8): rate and time ----------------------------------------
    def rate_bps(self, p_tx_w: float, distance_m: float) -> float:
        snr = p_tx_w * self.channel_gain(distance_m)
        return self.bandwidth_hz * math.log2(1.0 + snr)

    def comm_time_s(self, data_bits: float, p_tx_w: float, distance_m: float) -> float:
        r = self.rate_bps(p_tx_w, distance_m)
        return data_bits / r if r > 0 else math.inf

    # --- eq. (9): energy -------------------------------------------------
    def comm_energy_j(self, data_bits: float, p_tx_w: float, distance_m: float) -> float:
        return p_tx_w * self.comm_time_s(data_bits, p_tx_w, distance_m)

    # --- inverse: tx power needed to move data_bits in t seconds ----------
    def power_for_time(self, data_bits: float, t_s: float, distance_m: float) -> float:
        if t_s <= 0:
            return math.inf
        x = data_bits / (self.bandwidth_hz * t_s) * math.log(2.0)
        snr_needed = math.expm1(x) if x < 700 else math.inf
        return snr_needed / self.channel_gain(distance_m)

    def min_comm_time_s(self, data_bits: float, distance_m: float) -> float:
        """Fastest possible transfer: at max tx power."""
        return self.comm_time_s(data_bits, self.max_tx_power_w, distance_m)

    def energy_for_time(self, data_bits: float, t_s: float, distance_m: float) -> float:
        """E(t) = t * p(t): convex & decreasing in t (used by the solver)."""
        return t_s * self.power_for_time(data_bits, t_s, distance_m)


@dataclasses.dataclass(frozen=True)
class ISLConfig:
    """Fixed-rate intra-plane inter-satellite link — eq. (10)."""

    rate_bps: float = 5e9
    tx_power_w: float = 0.5

    def time_s(self, data_bits: float) -> float:
        return data_bits / self.rate_bps

    def energy_j(self, data_bits: float) -> float:
        return self.tx_power_w * self.time_s(data_bits)


# Table I links.
PAPER_GS_LINK = LinkConfig()
PAPER_ISL = ISLConfig()
