"""Split plans: the orbit-aware cost terms W1(ℓ), W2(ℓ), D_tx(ℓ), D_ISL(ℓ).

A sequential model is a list of :class:`LayerCost` units; cutting after
layer ℓ-1 (``cut_index = ℓ``) puts layers [0, ℓ) on the satellite and
[ℓ, L) on the ground terminal (paper §III-B: "the first split is held at
the satellite").  The four cost terms of a cut:

  W1(ℓ)    = TRAIN_MULT · Σ_{i<ℓ} fwd_flops_i       (fwd+bwd, per item)
  W2(ℓ)    = TRAIN_MULT · Σ_{i≥ℓ} fwd_flops_i
  D_tx(ℓ)  = out_bits of layer ℓ-1                   (boundary payload, one way)
  D_ISL(ℓ) = 8 · Σ_{i<ℓ} param_bytes_i               (segment-A handoff)

The paper treats gradient and activation payloads as equal-sized, which
eq. (11) encodes by charging D_tx twice — see energy.py.

``enumerate_cuts`` yields every admissible cut; ``plan_for_arch`` builds
the LayerCost list for the assigned LM architectures from their configs
(analytic FLOPs, utils/flops.py), keeping the embedding with segment A
and the head with segment B (neither is cuttable — the satellite owns
the data/tokenizer side, the ground owns the loss side, as in Fig. 2).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.energy import SplitCosts
from repro.utils.flops import (LayerCost, TRAIN_MULT, autoencoder_layer_costs,
                               lm_block_fwd_flops, lm_embed_head_fwd_flops,
                               resnet18_layer_costs)


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """A sequential model as cuttable units + fixed head/tail work."""

    name: str
    layers: Sequence[LayerCost]
    # Work that always stays with a side regardless of ℓ:
    sat_fixed_fwd_flops: float = 0.0      # e.g. embedding lookup / frontend stub
    gs_fixed_fwd_flops: float = 0.0       # e.g. LM head + loss
    sat_fixed_param_bytes: float = 0.0    # embedding table (ships with seg A)
    train_mult: float = TRAIN_MULT
    boundary_bits_scale: float = 1.0      # <1.0 = boundary compression (beyond-paper)

    @property
    def n_cuts(self) -> int:
        return len(self.layers) + 1

    def costs_at(self, cut_index: int) -> SplitCosts:
        """SplitCosts for cutting before layer ``cut_index`` ∈ [0, L]."""
        if not 0 <= cut_index <= len(self.layers):
            raise ValueError(f"cut_index {cut_index} out of [0, {len(self.layers)}]")
        seg_a = self.layers[:cut_index]
        seg_b = self.layers[cut_index:]
        w1 = self.train_mult * (self.sat_fixed_fwd_flops
                                + sum(l.fwd_flops for l in seg_a))
        w2 = self.train_mult * (self.gs_fixed_fwd_flops
                                + sum(l.fwd_flops for l in seg_b))
        if cut_index == 0:
            dtx = self.layers[0].out_bits if self.layers else 0.0
            # cut before everything: boundary is the raw input of layer 0;
            # callers wanting the direct-download baseline should use
            # energy.direct_download_costs instead.
            dtx = 0.0
        else:
            dtx = self.layers[cut_index - 1].out_bits
        d_isl = 8.0 * (self.sat_fixed_param_bytes
                       + sum(l.param_bytes for l in seg_a))
        return SplitCosts(
            w1_flops=w1, w2_flops=w2,
            dtx_bits=dtx * self.boundary_bits_scale,
            d_isl_bits=d_isl,
            name=f"{self.name}@{cut_index}",
        )

    def enumerate_cuts(self, stride: int = 1) -> List[SplitCosts]:
        return [self.costs_at(i) for i in range(1, len(self.layers), stride)]

    def with_boundary_compression(self, bits_scale: float) -> "SplitPlan":
        """Beyond-paper: int8 (0.25) / fp8 boundary quantization."""
        return dataclasses.replace(self, boundary_bits_scale=bits_scale,
                                   name=f"{self.name}+bq{bits_scale:g}")


# --------------------------------------------------------------------------
# Paper models.
# --------------------------------------------------------------------------

def autoencoder_plan(**kw) -> SplitPlan:
    return SplitPlan("autoencoder", autoencoder_layer_costs(**kw))


def resnet18_plan(**kw) -> SplitPlan:
    return SplitPlan("resnet18", resnet18_layer_costs(**kw))


# Cut indices matching the paper's Table II l1/l2/l3 (after stage1/2/3):
RESNET18_PAPER_CUTS = {"l1": 3, "l2": 5, "l3": 7}


# --------------------------------------------------------------------------
# Assigned LM architectures (works off repro.configs ArchConfig objects).
# --------------------------------------------------------------------------

def lm_plan(cfg, seq_len: int, act_bits: int = 32,
            param_bits: int = 32) -> SplitPlan:
    """Build a SplitPlan for an LM ArchConfig at a given sequence length.

    One LayerCost per block; the boundary between any two blocks is the
    residual stream (seq · d_model · act_bits).  The token embedding
    stays on the satellite side (it ships over the ISL with segment A);
    the LM head + loss stay on the ground.
    """
    layers: List[LayerCost] = []
    boundary_bits = float(seq_len) * cfg.d_model * act_bits
    for i, kind in enumerate(cfg.block_kinds()):
        f = lm_block_fwd_flops(
            d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff, seq=seq_len,
            block_kind=kind, n_experts=cfg.n_experts, top_k=cfg.top_k,
            d_head=cfg.d_head, ssm_state=cfg.ssm_state,
            causal=cfg.causal, window=cfg.window, mlp_kind=cfg.mlp_kind)
        pcount = cfg.block_param_count(kind)
        active = cfg.block_active_param_count(kind)
        layers.append(LayerCost(
            name=f"{kind}{i}", fwd_flops=f,
            param_bytes=pcount * param_bits / 8.0,
            out_bits=boundary_bits,
            param_count=pcount, active_param_count=active))
    embed_params = cfg.vocab * cfg.d_model
    head_flops = lm_embed_head_fwd_flops(cfg.d_model, cfg.vocab, seq_len)
    return SplitPlan(
        name=cfg.name, layers=layers,
        sat_fixed_fwd_flops=0.0,
        gs_fixed_fwd_flops=head_flops,
        sat_fixed_param_bytes=embed_params * param_bits / 8.0,
    )
