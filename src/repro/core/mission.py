"""Revolution-level mission planning: batch problem (13) over the ring.

The paper's protocol is *cyclical* — every satellite in the ring trains
exactly once per revolution — yet the scheduler used to re-solve
problem (13) from scratch at every pass, a scalar solve per pass.  The
:class:`RevolutionPlanner` exploits the cycle structure: the N upcoming
passes of one revolution are N instances of (13) differing only in
their per-satellite budgets and boundary payloads, so ONE
``solve_with_shedding_batch`` call (vectorized dual bisection +
vectorized kept-fraction shedding, core/resource_opt) pre-plans the
whole revolution.

The plan is cached and reused across revolutions; it is invalidated
only when the inputs actually change:

* **membership change** — a satellite joins, leaves, or fails, so the
  ring (and with it d_ISL, the pass order, and possibly per-sat
  budgets) shifts;
* **boundary-shape change** — the measured boundary payload or the
  segment-A handoff size changes (different batch shape, different cut,
  quantization toggled), which alters the (13) coefficients.

Steady-state constellations therefore pay ZERO per-pass solves: the
planner's ``solve_calls`` counter (asserted in tests) shows one batched
solve per plan epoch, however many passes consume it.

The planner's batched solve dispatches through the solver backend
selector (``backend="numpy" | "jax" | "auto"``, see
:mod:`repro.core.resource_opt_jax`), and :func:`sweep_revolutions`
goes one step further: a whole (ring size × cut point × item budget)
scenario grid — e.g. 1000-sat rings × every ``SplitCosts`` cut — is
built, shed and solved as ONE jitted device program, and its outputs
(kept item counts, allocations) feed the fused pass executor as device
arrays, with no host transfer between planning and training.

A swept grid also feeds *whole-revolution* execution:
:meth:`RevolutionSweep.revolution_plan` broadcasts one planned cell
over its ring into a :class:`~repro.sim.device_sim.DevicePassPlan`
(per-slot step counts, battery drains, eq. (11)/(12) records) that the
device constellation engine consumes directly — N masked fused passes
per revolution with zero per-pass Python dispatch and the plan resident
on device end to end.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, Hashable, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro.core import resource_opt
from repro.core.energy import PassBudget, SplitCosts


def _costs_key(c: SplitCosts) -> Tuple[float, float, float, float]:
    """Numeric identity of a cost instance (name changes don't replan)."""
    return (c.w1_flops, c.w2_flops, c.dtx_bits, c.d_isl_bits)


def _budget_key(b: PassBudget) -> Hashable:
    # PassBudget and all its components are frozen dataclasses, hence
    # hashable by value — the object itself is the cache key.
    return b


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One satellite's pre-solved allocation for its pass this revolution."""

    sat_id: int
    slot: int                                # position in the revolution
    shed: resource_opt.SheddingReport        # allocation (+ kept fraction)

    @property
    def allocation(self):
        return self.shed.report.allocation


class RevolutionPlanner:
    """Pre-solves problem (13) for a whole ring revolution at once.

    Usage (the constellation scheduler's flow)::

        planner = RevolutionPlanner()
        entry = planner.entry_for(sat_id, ring_ids, budget, costs)
        alloc = entry.allocation          # this pass's (f, p) allocation

    ``entry_for`` is cheap when the plan is warm; on a cold or
    invalidated cache it issues exactly one
    :func:`~repro.core.resource_opt.solve_with_shedding_batch` call for
    every satellite in ``ring_ids`` (per-satellite budgets/costs as
    batch instances) and stores the entries.  ``solve_calls`` counts
    batched solves, ``invalidations`` counts cache drops — both are
    observable for tests and benchmarks.

    ``backend`` selects the problem-(13) solver implementation for the
    batched solve ("numpy" | "jax" | "auto", default auto — see
    :func:`~repro.core.resource_opt.solve_batch`).
    """

    def __init__(self, backend: Optional[str] = None) -> None:
        self.backend = backend
        self.solve_calls = 0
        self.invalidations = 0
        self._key: Optional[Hashable] = None
        self._entries: Dict[int, PlanEntry] = {}

    # ----------------------------------------------------------- planning
    @staticmethod
    def _instances(ring: Sequence[int], budgets, costs):
        """Broadcast (budgets, costs) over the ring; returns the
        per-satellite instance lists and their canonical cache key."""
        blist, clist = resource_opt._broadcast_instances(budgets, costs)
        if len(blist) == 1:
            blist = blist * len(ring)
            clist = clist * len(ring)
        if len(blist) != len(ring):
            raise ValueError(f"{len(blist)} instances for {len(ring)} "
                             "satellites")
        key = (tuple(ring),
               tuple(_budget_key(b) for b in blist),
               tuple(_costs_key(c) for c in clist))
        return blist, clist, key

    def plan_revolution(self, ring_ids: Sequence[int],
                        budgets: Union[PassBudget, Sequence[PassBudget]],
                        costs: Union[SplitCosts, Sequence[SplitCosts]],
                        ) -> Dict[int, PlanEntry]:
        """Solve (13) for every satellite of the revolution in one batch.

        ``budgets``/``costs`` are broadcast against ``ring_ids`` the way
        :func:`solve_batch` broadcasts (a single object serves all
        satellites; a sequence gives each its own instance).  The cache
        key is updated to these instances, so a subsequent
        :meth:`entry_for` with matching inputs reuses this plan.
        """
        ring = list(ring_ids)
        if not ring:
            raise ValueError("cannot plan an empty ring")
        blist, clist, key = self._instances(ring, budgets, costs)
        shed = resource_opt.solve_with_shedding_batch(blist, clist,
                                                      backend=self.backend)
        self.solve_calls += 1
        self._entries = {sid: PlanEntry(sid, slot, shed.at(slot))
                         for slot, sid in enumerate(ring)}
        self._key = key
        return self._entries

    def entry_for(self, sat_id: int, ring_ids: Sequence[int],
                  budgets: Union[PassBudget, Sequence[PassBudget]],
                  costs: Union[SplitCosts, Sequence[SplitCosts]],
                  ) -> PlanEntry:
        """This pass's pre-solved entry; replans only on invalidation.

        ``budgets``/``costs`` may be a single object (broadcast ring-
        wide) or one instance per satellite of ``ring_ids``.  The cache
        key is (ring membership, per-satellite budget and cost
        signatures): joins/leaves/failures change the membership tuple,
        a batch-shape or handoff-size change alters a cost signature —
        anything else reuses the cached revolution plan.
        """
        _, _, key = self._instances(list(ring_ids), budgets, costs)
        if key != self._key:
            if self._key is not None:
                self.invalidations += 1
            self.plan_revolution(ring_ids, budgets, costs)
        entry = self._entries.get(sat_id)
        if entry is None:
            raise KeyError(f"satellite {sat_id} is not in the planned ring "
                           f"{sorted(self._entries)}")
        return entry

    # ---------------------------------------------------------- inspection
    @property
    def planned(self) -> bool:
        return self._key is not None

    def invalidate(self) -> None:
        """Drop the cached plan (next entry_for replans)."""
        if self._key is not None:
            self.invalidations += 1
        self._key = None
        self._entries = {}


# --------------------------------------------------------------------------
# On-device revolution sweeps: (ring size × cut point × item budget) grids.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RevolutionSweep:
    """A planned (ring size × cut × budget) grid, resident on device.

    Every array is a JAX device array of shape (R, C, B) — ring sizes ×
    cut points × item budgets — in float64 (the sweep solves under the
    backend's x64 scope).  Nothing here has touched the host: chaining
    into pass execution (:meth:`steps_for` → ``make_sl_pass(...,
    n_valid=...)``) keeps the whole plan→train pipeline device-side.
    Call :meth:`to_host` once at the end to materialize results.
    """

    ring_sizes: np.ndarray              # (R,) host metadata
    cut_names: Tuple[str, ...]          # (C,) host metadata
    n_items: np.ndarray                 # (B,) host metadata
    d_isl_bits: np.ndarray              # (C,) host metadata (handoff bits)
    e_pass: Any                         # (R,C,B) eq. (11) per pass [J]
    t_pass: Any                         # (R,C,B) eq. (12) per pass [s]
    kept_fraction: Any                  # (R,C,B) shedding outcome
    n_items_kept: Any                   # (R,C,B)
    feasible: Any                       # (R,C,B) bool (post-shedding)
    kkt_residual: Any                   # (R,C,B)
    phase_times: Any                    # (R,C,B,4) canonical phase order
    phase_energy: Any                   # (R,C,B,4) [J] same order
    e_isl: Any                          # (R,C,B) constant E_ISL term [J]
    e_revolution: Any                   # (R,C,B) ring size × e_pass
    best_cut: Any                       # (R,B) argmin-energy cut; -1 if
                                        # no cut is feasible in that cell

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (len(self.ring_sizes), len(self.cut_names),
                len(self.n_items))

    def steps_for(self, batch_size: int):
        """Fused-pass step counts per grid cell, as a device int32 array.

        The bridge into :func:`~repro.core.sl_step.make_sl_pass`: pick a
        cell of this array (still on device) and hand it to the executor
        as ``n_valid`` — the pass scans exactly the allocated number of
        steps without ever reading the plan back to the host.
        """
        from repro.core import resource_opt_jax as roj
        import jax.numpy as jnp

        with roj.x64_scope():
            steps = jnp.ceil(self.n_items_kept / float(batch_size))
            return jnp.maximum(steps, 1.0).astype(jnp.int32)

    def revolution_plan(self, batch_size: int, *, ring: int = 0,
                        cut: Optional[int] = None, budget: int = 0,
                        max_steps_per_pass: Optional[int] = None):
        """One planned grid cell as a whole-revolution execution plan.

        Broadcasts cell ``(ring, cut, budget)`` over its ring's N slots
        into a :class:`~repro.sim.device_sim.DevicePassPlan` — per-slot
        fused step counts, battery drains and eq. (11)/(12) records as
        float32/int32 device arrays — which
        :class:`~repro.sim.device_sim.DeviceConstellationSim` executes
        as N masked fused passes with zero per-pass Python dispatch.
        This closes the plan→train bridge at *revolution* granularity:
        a swept scenario grid feeds closed-loop execution directly,
        without re-solving and without the plan ever visiting the host.

        ``cut=None`` picks the cell's minimum-energy feasible cut
        (``best_cut``; one host scalar read).  The cell's allocation is
        identical for every slot — per-satellite heterogeneous plans
        come from :func:`repro.sim.device_sim.plan_ring_passes` instead.
        """
        from repro.core import resource_opt_jax as roj
        from repro.sim.device_sim import plan_from_report
        import jax.numpy as jnp

        n = int(self.ring_sizes[ring])
        if cut is None:
            cut = int(np.asarray(self.best_cut[ring, budget]))
            if cut < 0:
                raise ValueError(
                    f"no feasible cut in sweep cell (ring={ring}, "
                    f"budget={budget}); pass cut= explicitly to plan an "
                    "infeasible allocation anyway")
        sel = (ring, cut, budget)
        with roj.x64_scope():
            bcast = lambda a: jnp.broadcast_to(a[sel], (n,))   # noqa: E731
            rep = roj.ArraySolveReport(
                phase_times=jnp.broadcast_to(self.phase_times[sel], (n, 4)),
                phase_energy=jnp.broadcast_to(self.phase_energy[sel],
                                              (n, 4)),
                lam=jnp.zeros((n,)), kkt_residual=bcast(self.kkt_residual),
                feasible=bcast(self.feasible), e_isl=bcast(self.e_isl),
                t_fixed=bcast(self.t_pass)
                - jnp.broadcast_to(self.phase_times[sel], (n, 4)).sum(-1))
            return plan_from_report(
                rep, bcast(self.kept_fraction),
                jnp.full((n,), float(self.n_items[budget])),
                float(self.d_isl_bits[cut]), batch_size,
                max_steps_per_pass)

    def fleet_plan(self, batch_size: int, n_planes: int, *, ring: int = 0,
                   cut: Optional[int] = None, budget: int = 0,
                   max_steps_per_pass: Optional[int] = None):
        """One planned grid cell as a P-plane fleet execution plan.

        Broadcasts :meth:`revolution_plan`'s ``(N,)`` cell plan over
        ``n_planes`` into the ``(P, N)`` layout the fleet engine
        (:class:`repro.fleet.FleetEngine`) consumes — a swept scenario
        grid drives a whole sharded constellation with zero re-solves.
        Heterogeneous per-satellite fleet plans come from
        :func:`repro.sim.device_sim.plan_ring_passes` with a ``(P, M)``
        row shape instead.
        """
        import jax.numpy as jnp

        plan = self.revolution_plan(batch_size, ring=ring, cut=cut,
                                    budget=budget,
                                    max_steps_per_pass=max_steps_per_pass)
        return type(plan)(*[jnp.broadcast_to(a, (int(n_planes),)
                                             + a.shape) for a in plan])

    def to_host(self) -> Dict[str, np.ndarray]:
        """One explicit device→host sync of every result array."""
        out = {"ring_sizes": self.ring_sizes, "n_items": self.n_items,
               "d_isl_bits": self.d_isl_bits}
        for f in ("e_pass", "t_pass", "kept_fraction", "n_items_kept",
                  "feasible", "kkt_residual", "phase_times",
                  "phase_energy", "e_isl", "e_revolution", "best_cut"):
            out[f] = np.asarray(getattr(self, f))
        return out


def sweep_revolutions(ring_sizes: Sequence[int],
                      costs: Sequence[SplitCosts],
                      n_items: Sequence[float],
                      *,
                      budget: Optional[PassBudget] = None,
                      dtx_bits=None,
                      min_fraction: float = 0.05,
                      tol: float = 1e-10,
                      max_iters: int = 80) -> RevolutionSweep:
    """Plan a whole scenario grid as ONE jitted device program.

    The grid is (ring size × cut point × item budget): ``ring_sizes``
    vary the ring population (entering problem (13) through the ISL hop
    distance, eq. 5), ``costs`` carry the candidate cut points, and
    ``n_items`` the per-pass item budgets.  Coefficient construction,
    the vectorized kept-fraction shedding, and the jit+vmap dual
    bisection all run inside one compiled call on the default JAX
    device — the classic 1000-sat × every-cut sweep never round-trips
    through host NumPy, and the resulting plan feeds
    :func:`~repro.core.sl_step.make_sl_pass` as arrays
    (:meth:`RevolutionSweep.steps_for`).

    ``budget`` is the scenario template (plane/link/ISL/devices; its
    ``n_items`` and the plane's ``n_sats`` are overridden by the grid
    axes).  ``dtx_bits`` optionally overrides the cuts' boundary
    payloads with *measured* per-cut values — e.g. the array produced
    by :func:`~repro.core.sl_step.ring_boundary_bits` — so the sweep
    plans from what the model actually transmits.
    """
    from repro.core import resource_opt_jax as roj

    if not roj.available():                       # pragma: no cover
        raise RuntimeError(
            "sweep_revolutions needs the JAX solver backend "
            "(repro.core.resource_opt_jax); install jax or use "
            "RevolutionPlanner with backend='numpy' instead")
    import jax.numpy as jnp

    budget = PassBudget() if budget is None else budget
    costs = list(costs)
    ring = np.asarray(list(ring_sizes), dtype=np.int64)
    items = np.asarray(list(n_items), dtype=np.float64)
    if ring.size == 0 or not costs or items.size == 0:
        raise ValueError("sweep_revolutions needs non-empty ring_sizes, "
                         "costs and n_items axes")
    if np.any(ring < 1):
        raise ValueError("ring sizes must be >= 1 satellite")

    w1 = [c.w1_flops for c in costs]
    w2 = [c.w2_flops for c in costs]
    disl = [c.d_isl_bits for c in costs]
    dtx = [c.dtx_bits for c in costs] if dtx_bits is None else dtx_bits

    sc = roj.grid_scalars(budget.plane, budget.link, budget.isl,
                          budget.sat_device, budget.gs_device)
    rep, frac = roj.sweep_grid(sc, ring, w1, w2, dtx, disl, items,
                               min_fraction=min_fraction, tol=tol,
                               max_iters=max_iters)
    with roj.x64_scope():                 # derived arrays, still on device
        e_pass = rep.e_total
        t_pass = rep.t_total
        n_kept = frac * jnp.asarray(items)[None, None, :]
        e_rev = jnp.asarray(ring, jnp.float64)[:, None, None] * e_pass
        # -1 sentinel where even max shedding leaves every cut infeasible
        # (argmin over all-inf would silently report cut 0)
        best_cut = jnp.where(
            rep.feasible.any(axis=1),
            jnp.argmin(jnp.where(rep.feasible, e_pass, jnp.inf), axis=1),
            -1).astype(jnp.int32)
    return RevolutionSweep(
        ring_sizes=ring, cut_names=tuple(c.name for c in costs),
        n_items=items,
        d_isl_bits=np.asarray(disl, dtype=np.float64),
        e_pass=e_pass, t_pass=t_pass, kept_fraction=frac,
        n_items_kept=n_kept, feasible=rep.feasible,
        kkt_residual=rep.kkt_residual, phase_times=rep.phase_times,
        phase_energy=rep.phase_energy, e_isl=rep.e_isl,
        e_revolution=e_rev, best_cut=best_cut)
