"""Revolution-level mission planning: batch problem (13) over the ring.

The paper's protocol is *cyclical* — every satellite in the ring trains
exactly once per revolution — yet the scheduler used to re-solve
problem (13) from scratch at every pass, a scalar solve per pass.  The
:class:`RevolutionPlanner` exploits the cycle structure: the N upcoming
passes of one revolution are N instances of (13) differing only in
their per-satellite budgets and boundary payloads, so ONE
``solve_with_shedding_batch`` call (vectorized dual bisection +
vectorized kept-fraction shedding, core/resource_opt) pre-plans the
whole revolution.

The plan is cached and reused across revolutions; it is invalidated
only when the inputs actually change:

* **membership change** — a satellite joins, leaves, or fails, so the
  ring (and with it d_ISL, the pass order, and possibly per-sat
  budgets) shifts;
* **boundary-shape change** — the measured boundary payload or the
  segment-A handoff size changes (different batch shape, different cut,
  quantization toggled), which alters the (13) coefficients.

Steady-state constellations therefore pay ZERO per-pass solves: the
planner's ``solve_calls`` counter (asserted in tests) shows one batched
solve per plan epoch, however many passes consume it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Hashable, Optional, Sequence, Tuple, Union

from repro.core import resource_opt
from repro.core.energy import PassBudget, SplitCosts


def _costs_key(c: SplitCosts) -> Tuple[float, float, float, float]:
    """Numeric identity of a cost instance (name changes don't replan)."""
    return (c.w1_flops, c.w2_flops, c.dtx_bits, c.d_isl_bits)


def _budget_key(b: PassBudget) -> Hashable:
    # PassBudget and all its components are frozen dataclasses, hence
    # hashable by value — the object itself is the cache key.
    return b


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One satellite's pre-solved allocation for its pass this revolution."""

    sat_id: int
    slot: int                                # position in the revolution
    shed: resource_opt.SheddingReport        # allocation (+ kept fraction)

    @property
    def allocation(self):
        return self.shed.report.allocation


class RevolutionPlanner:
    """Pre-solves problem (13) for a whole ring revolution at once.

    Usage (the constellation scheduler's flow)::

        planner = RevolutionPlanner()
        entry = planner.entry_for(sat_id, ring_ids, budget, costs)
        alloc = entry.allocation          # this pass's (f, p) allocation

    ``entry_for`` is cheap when the plan is warm; on a cold or
    invalidated cache it issues exactly one
    :func:`~repro.core.resource_opt.solve_with_shedding_batch` call for
    every satellite in ``ring_ids`` (per-satellite budgets/costs as
    batch instances) and stores the entries.  ``solve_calls`` counts
    batched solves, ``invalidations`` counts cache drops — both are
    observable for tests and benchmarks.
    """

    def __init__(self) -> None:
        self.solve_calls = 0
        self.invalidations = 0
        self._key: Optional[Hashable] = None
        self._entries: Dict[int, PlanEntry] = {}

    # ----------------------------------------------------------- planning
    @staticmethod
    def _instances(ring: Sequence[int], budgets, costs):
        """Broadcast (budgets, costs) over the ring; returns the
        per-satellite instance lists and their canonical cache key."""
        blist, clist = resource_opt._broadcast_instances(budgets, costs)
        if len(blist) == 1:
            blist = blist * len(ring)
            clist = clist * len(ring)
        if len(blist) != len(ring):
            raise ValueError(f"{len(blist)} instances for {len(ring)} "
                             "satellites")
        key = (tuple(ring),
               tuple(_budget_key(b) for b in blist),
               tuple(_costs_key(c) for c in clist))
        return blist, clist, key

    def plan_revolution(self, ring_ids: Sequence[int],
                        budgets: Union[PassBudget, Sequence[PassBudget]],
                        costs: Union[SplitCosts, Sequence[SplitCosts]],
                        ) -> Dict[int, PlanEntry]:
        """Solve (13) for every satellite of the revolution in one batch.

        ``budgets``/``costs`` are broadcast against ``ring_ids`` the way
        :func:`solve_batch` broadcasts (a single object serves all
        satellites; a sequence gives each its own instance).  The cache
        key is updated to these instances, so a subsequent
        :meth:`entry_for` with matching inputs reuses this plan.
        """
        ring = list(ring_ids)
        if not ring:
            raise ValueError("cannot plan an empty ring")
        blist, clist, key = self._instances(ring, budgets, costs)
        shed = resource_opt.solve_with_shedding_batch(blist, clist)
        self.solve_calls += 1
        self._entries = {sid: PlanEntry(sid, slot, shed.at(slot))
                         for slot, sid in enumerate(ring)}
        self._key = key
        return self._entries

    def entry_for(self, sat_id: int, ring_ids: Sequence[int],
                  budgets: Union[PassBudget, Sequence[PassBudget]],
                  costs: Union[SplitCosts, Sequence[SplitCosts]],
                  ) -> PlanEntry:
        """This pass's pre-solved entry; replans only on invalidation.

        ``budgets``/``costs`` may be a single object (broadcast ring-
        wide) or one instance per satellite of ``ring_ids``.  The cache
        key is (ring membership, per-satellite budget and cost
        signatures): joins/leaves/failures change the membership tuple,
        a batch-shape or handoff-size change alters a cost signature —
        anything else reuses the cached revolution plan.
        """
        _, _, key = self._instances(list(ring_ids), budgets, costs)
        if key != self._key:
            if self._key is not None:
                self.invalidations += 1
            self.plan_revolution(ring_ids, budgets, costs)
        entry = self._entries.get(sat_id)
        if entry is None:
            raise KeyError(f"satellite {sat_id} is not in the planned ring "
                           f"{sorted(self._entries)}")
        return entry

    # ---------------------------------------------------------- inspection
    @property
    def planned(self) -> bool:
        return self._key is not None

    def invalidate(self) -> None:
        """Drop the cached plan (next entry_for replans)."""
        if self._key is not None:
            self.invalidations += 1
        self._key = None
        self._entries = {}
