"""The paper's own models in JAX: conv autoencoder (Fig. 3 top) and
ResNet-18 (Fig. 3 bottom / Table II).

Both are expressed as *sequential cuttable stages* matching
core/splitting.py's LayerCost lists, so the SL constellation driver can
execute segment [0, l) on the "satellite" and [l, L) on the "ground".

Deviation noted (DESIGN.md): BatchNorm is replaced by GroupNorm(8) —
batch statistics don't interact well with the per-pass microbatching of
the SL driver and GN keeps the layer a pure function; FLOPs/param costs
are within 0.1% of the BN variant.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.param import ParamSpec


def _conv_spec(cin, cout, k):
    return {"w": ParamSpec((k, k, cin, cout), (None, None, None, "mlp")),
            "b": ParamSpec((cout,), ("mlp",), "zeros")}


def _gn_spec(c):
    return {"scale": ParamSpec((c,), ("mlp",), "ones"),
            "bias": ParamSpec((c,), ("mlp",), "zeros")}


def _conv(p, x, stride=1, transpose=False):
    if transpose:
        y = jax.lax.conv_transpose(
            x, p["w"].astype(x.dtype), (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    else:
        y = jax.lax.conv_general_dilated(
            x, p["w"].astype(x.dtype), (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"].astype(x.dtype)


def _gn(p, x, groups=8, eps=1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xf = x.astype(jnp.float32).reshape(B, H, W, g, C // g)
    mu = xf.mean(axis=(1, 2, 4), keepdims=True)
    var = xf.var(axis=(1, 2, 4), keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(B, H, W, C)
    return (xf * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ==========================================================================
# Autoencoder: 224x224x3 -> 7x7xlatent -> 224x224x3 (5 stride-2 stages).
# ==========================================================================

AE_CHANS = [3, 16, 32, 64, 128, 3]


def ae_abstract_params(base: int = 16, latent_ch: int = 3) -> Dict:
    chans = [3, base, base * 2, base * 4, base * 8, latent_ch]
    dchans = [latent_ch, base * 8, base * 4, base * 2, base, 3]
    tree: Dict[str, Any] = {}
    for i in range(5):
        tree[f"enc{i}"] = {"conv": _conv_spec(chans[i], chans[i + 1], 3)}
        if i != 4:      # the latent (the transmitted code) is not normalized
            tree[f"enc{i}"]["gn"] = _gn_spec(chans[i + 1])
    for i in range(5):
        tree[f"dec{i}"] = {"conv": _conv_spec(dchans[i], dchans[i + 1], 3)}
        if i != 4:      # neither is the reconstructed output
            tree[f"dec{i}"]["gn"] = _gn_spec(dchans[i + 1])
    return tree


def ae_stage_names() -> List[str]:
    return [f"enc{i}" for i in range(5)] + [f"dec{i}" for i in range(5)]


def ae_apply_range(params, x, lo: int, hi: int):
    """Apply stages [lo, hi) of the 10-stage autoencoder."""
    names = ae_stage_names()
    for idx in range(lo, hi):
        name = names[idx]
        p = params[name]
        is_dec = name.startswith("dec")
        x = _conv(p["conv"], x, stride=2, transpose=is_dec)
        if "gn" in p:
            x = _gn(p["gn"], x)
            x = jax.nn.silu(x.astype(jnp.float32)).astype(x.dtype)
    return x


def ae_loss(params, images, *, cut=None):
    """MSE reconstruction; ``cut`` optionally runs the two segments with
    an explicit boundary (matching the SL execution graph)."""
    if cut is None:
        recon = ae_apply_range(params, images, 0, 10)
    else:
        z = ae_apply_range(params, images, 0, cut)
        recon = ae_apply_range(params, z, cut, 10)
    return jnp.mean(jnp.square(recon.astype(jnp.float32)
                               - images.astype(jnp.float32)))


# ==========================================================================
# ResNet-18.
# ==========================================================================

def _basic_block_spec(cin, cout):
    s = {"conv1": _conv_spec(cin, cout, 3), "gn1": _gn_spec(cout),
         "conv2": _conv_spec(cout, cout, 3), "gn2": _gn_spec(cout)}
    if cin != cout:
        s["down"] = _conv_spec(cin, cout, 1)
    return s


def resnet18_abstract_params(n_classes: int = 1000) -> Dict:
    tree: Dict[str, Any] = {
        "stem": {"conv": _conv_spec(3, 64, 7), "gn": _gn_spec(64)},
        "s1b1": _basic_block_spec(64, 64), "s1b2": _basic_block_spec(64, 64),
        "s2b1": _basic_block_spec(64, 128), "s2b2": _basic_block_spec(128, 128),
        "s3b1": _basic_block_spec(128, 256), "s3b2": _basic_block_spec(256, 256),
        "s4b1": _basic_block_spec(256, 512), "s4b2": _basic_block_spec(512, 512),
        "head": {"w": ParamSpec((512, n_classes), ("embed", "vocab")),
                 "b": ParamSpec((n_classes,), ("vocab",), "zeros")},
    }
    return tree


RESNET_STAGES = ["stem", "s1b1", "s1b2", "s2b1", "s2b2", "s3b1", "s3b2",
                 "s4b1", "s4b2", "head"]
_STRIDES = {"s2b1": 2, "s3b1": 2, "s4b1": 2}


def _basic_block(p, x, stride):
    h = _conv(p["conv1"], x, stride=stride)
    h = jax.nn.relu(_gn(p["gn1"], h).astype(jnp.float32)).astype(x.dtype)
    h = _conv(p["conv2"], h, stride=1)
    h = _gn(p["gn2"], h)
    if "down" in p:
        x = _conv(p["down"], x, stride=stride)
    return jax.nn.relu((x + h).astype(jnp.float32)).astype(x.dtype)


def resnet18_apply_range(params, x, lo: int, hi: int):
    """Apply stages [lo, hi) of RESNET_STAGES."""
    for idx in range(lo, hi):
        name = RESNET_STAGES[idx]
        p = params[name]
        if name == "stem":
            x = _conv(p["conv"], x, stride=2)
            x = jax.nn.relu(_gn(p["gn"], x).astype(jnp.float32)).astype(x.dtype)
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
        elif name == "head":
            x = jnp.mean(x, axis=(1, 2))
            x = (x @ p["w"].astype(x.dtype)
                 + p["b"].astype(x.dtype)).astype(jnp.float32)
        else:
            x = _basic_block(p, x, _STRIDES.get(name, 1))
    return x


def resnet18_loss(params, images, labels, *, cut=None):
    if cut is None:
        logits = resnet18_apply_range(params, images, 0, 10)
    else:
        z = resnet18_apply_range(params, images, 0, cut)
        logits = resnet18_apply_range(params, z, cut, 10)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)
