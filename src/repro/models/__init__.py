"""Model zoo: pure-JAX init/apply models with logical-axis sharding."""
