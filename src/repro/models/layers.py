"""Model layers: RMSNorm, RoPE/M-RoPE, GQA attention, SwiGLU/GELU MLP,
top-k MoE with capacity dispatch, Mamba-2, mLSTM, sLSTM.

Every layer is a (spec_*, apply_*) pair: ``spec_*`` returns the
ParamSpec tree (shapes + logical sharding axes), ``apply_*`` is the pure
function. Compute runs in the activation dtype (bf16 by default) with
fp32 params cast at use; attention/scan inner math is fp32 (see
kernels/ops.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.param import ParamSpec, ShardingRules, constrain


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Per-call context threaded through blocks."""

    cfg: Any
    mesh: Any = None
    rules: ShardingRules = ShardingRules()
    mode: str = "train"                  # train | prefill | decode
    positions: Optional[jnp.ndarray] = None    # (B,) decode positions
    rope: Optional[Tuple] = None         # precomputed (cos, sin)
    enc_out: Optional[jnp.ndarray] = None      # whisper cross-attn memory
    act_dtype: Any = jnp.bfloat16
    use_pallas: Optional[bool] = False
    block_q: int = 512
    block_k: int = 512
    mamba_chunk: int = 128
    mlstm_chunk: int = 256
    attn_compute_dtype: Any = jnp.float32
    moe_dispatch: str = "global"         # global | batch_local

    def c(self, x, *axes):
        return constrain(x, self.rules, self.mesh, *axes)


# --------------------------------------------------------------------------
# Norms.
# --------------------------------------------------------------------------

def spec_rmsnorm(d: int) -> Dict:
    return {"scale": ParamSpec((d,), ("embed",), "ones")}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE / M-RoPE.
# --------------------------------------------------------------------------

def rope_tables(positions, dim: int, theta: float):
    """positions: (...,) int -> cos/sin (..., dim/2) fp32."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_tables(pos_thw, dim: int, theta: float, sections=(0.25, 0.375, 0.375)):
    """Qwen2-VL M-RoPE: rotary dims split into (t, h, w) sections.

    pos_thw: (3, ...) int position ids. Returns cos/sin (..., dim/2).
    """
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    n_t = int(half * sections[0])
    n_h = int(half * sections[1])
    sec = jnp.concatenate([
        jnp.zeros((n_t,), jnp.int32),
        jnp.ones((n_h,), jnp.int32),
        jnp.full((half - n_t - n_h,), 2, jnp.int32)])
    # per rotary index j, position = pos_thw[sec[j]]
    p = jnp.moveaxis(pos_thw, 0, -1)                       # (..., 3)
    pos_per_freq = jnp.take(p, sec, axis=-1)               # (..., half)
    ang = pos_per_freq.astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2) or (B, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2 and cos.shape[0] == x.shape[1]:        # (S, half)
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    elif cos.ndim == 2:                                     # (B, half) decode
        cos = cos[:, None, None, :]
        sin = sin[:, None, None, :]
    else:                                                   # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def text_mrope_positions(batch: int, seq: int, frontend_len: int,
                         offset=0):
    """(3, B, S) ids: vision prefix gets (t=0, h=i//g, w=i%g) grid ids."""
    idx = jnp.arange(seq) + offset
    t = jnp.where(idx < frontend_len, 0, idx)
    g = max(int(math.sqrt(max(frontend_len, 1))), 1)
    h = jnp.where(idx < frontend_len, idx // g, idx)
    w = jnp.where(idx < frontend_len, idx % g, idx)
    ids = jnp.stack([t, h, w])                              # (3, S)
    return jnp.broadcast_to(ids[:, None, :], (3, batch, seq))


# --------------------------------------------------------------------------
# GQA attention.
# --------------------------------------------------------------------------

def spec_attention(cfg, cross: bool = False) -> Dict:
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    spec = {
        "wq": ParamSpec((d, H * dh), ("embed", "heads")),
        "wk": ParamSpec((d, KV * dh), ("embed", "kv_heads")),
        "wv": ParamSpec((d, KV * dh), ("embed", "kv_heads")),
        "wo": ParamSpec((H * dh, d), ("heads", "embed")),
    }
    return spec


def _split_heads(x, n, dh):
    B, S, _ = x.shape
    return x.reshape(B, S, n, dh)


def apply_attention(p, x, ctx: Ctx, *, causal=True, window=None,
                    cache=None, kv_input=None, use_rope=True,
                    is_cross=False):
    """x: (B, S, d). cache: {'k','v'} (B, KV, S_max, dh) for decode.

    Returns (y, new_cache). kv_input overrides the KV source (cross-attn
    at train/prefill); at decode a cross block reads its cached encoder
    memory and never writes the cache.
    """
    cfg = ctx.cfg
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    B, S, _ = x.shape
    dt = x.dtype

    q = _split_heads(x @ p["wq"].astype(dt), H, dh)
    src = x if kv_input is None else kv_input.astype(dt)
    if not (is_cross and ctx.mode == "decode"):
        k = _split_heads(src @ p["wk"].astype(dt), KV, dh)
        v = _split_heads(src @ p["wv"].astype(dt), KV, dh)
    else:
        k = v = None                    # cross-attn decode: cache holds k/v

    if use_rope and ctx.rope is not None and not is_cross:
        cos, sin = ctx.rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if ctx.mode == "decode" and not is_cross:
        # self-attn decode: write the token into the cache ring
        pos = ctx.positions                                 # (B,)
        s_max = cache["k"].shape[2]
        widx = pos % s_max if window is not None else jnp.minimum(pos, s_max - 1)
        k_t = jnp.swapaxes(k, 1, 2)                         # (B, KV, 1, dh)
        v_t = jnp.swapaxes(v, 1, 2)
        bidx = jnp.arange(B)
        new_k = cache["k"].at[bidx, :, widx].set(
            k_t[:, :, 0].astype(cache["k"].dtype))
        new_v = cache["v"].at[bidx, :, widx].set(
            v_t[:, :, 0].astype(cache["v"].dtype))
        lengths = jnp.minimum(pos + 1, s_max)
        q_t = jnp.swapaxes(q, 1, 2)                         # (B, H, 1, dh)
        o = ops.decode_attention(q_t, new_k, new_v, lengths,
                                 use_pallas=ctx.use_pallas)
        y = jnp.swapaxes(o, 1, 2).reshape(B, S, H * dh)
        new_cache = {"k": new_k, "v": new_v}
    elif ctx.mode == "decode":
        # cross-attn decode: attend to the fixed encoder memory in cache
        q_t = jnp.swapaxes(q, 1, 2)
        s_enc = cache["k"].shape[2]
        lengths = jnp.full((B,), s_enc, jnp.int32)
        o = ops.decode_attention(q_t, cache["k"], cache["v"], lengths,
                                 use_pallas=ctx.use_pallas)
        y = jnp.swapaxes(o, 1, 2).reshape(B, S, H * dh)
        new_cache = cache
    else:
        qh = jnp.swapaxes(q, 1, 2)                          # (B, H, S, dh)
        kh = jnp.swapaxes(k, 1, 2)
        vh = jnp.swapaxes(v, 1, 2)
        o = ops.flash_attention(qh, kh, vh, causal=causal, window=window,
                                block_q=ctx.block_q, block_k=ctx.block_k,
                                use_pallas=ctx.use_pallas,
                                compute_dtype=ctx.attn_compute_dtype)
        y = jnp.swapaxes(o, 1, 2).reshape(B, S, H * dh)
        new_cache = None
        if ctx.mode == "prefill":
            # self-attn: the running KV; cross-attn: the (fixed) encoder
            # memory projections, reused by every decode step
            new_cache = {"k": kh.astype(dt), "v": vh.astype(dt)}
    y = ctx.c(y, "batch", "seq", "heads")
    return y @ p["wo"].astype(dt), new_cache


# --------------------------------------------------------------------------
# Dense MLPs.
# --------------------------------------------------------------------------

def spec_mlp(cfg) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        return {
            "wi": ParamSpec((d, 2 * f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def apply_mlp(p, x, ctx: Ctx):
    cfg = ctx.cfg
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if cfg.mlp_kind == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    h = ctx.c(h, "batch", "seq", "mlp")
    return h @ p["wo"].astype(dt)


# --------------------------------------------------------------------------
# Top-k MoE with capacity-based dispatch (GShard-style, static shapes).
# --------------------------------------------------------------------------

def spec_moe(cfg) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, E), ("embed", None), scale=0.02),
        "wi": ParamSpec((E, d, 2 * f), ("experts", "embed", "mlp")),
        "wo": ParamSpec((E, f, d), ("experts", "mlp", "embed")),
    }


def apply_moe(p, x, ctx: Ctx):
    """Token-dropping top-k dispatch, two layouts:

    * ``global``  - one global (E, C, d) buffer; GSPMD turns the scatter/
      gather into all-gathers of the whole buffer (the measured baseline
      collective bottleneck; EXPERIMENTS.md §Perf).
    * ``batch_local`` - dispatch within each batch row: buffer
      (B, E, C_row, d) with B sharded over data, scatter indices local to
      the row => zero dispatch collectives; only the TP reduction of the
      grouped GEMMs remains. Finer-grained capacity => slightly higher
      drop variance (standard per-batch dispatch trade).

    Active FLOPs = top_k x dense-FFN either way.
    """
    if ctx.moe_dispatch == "batch_local":
        return _apply_moe_batch_local(p, x, ctx)
    cfg = ctx.cfg
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = max(1, int(math.ceil(T * k / E * cfg.capacity_factor)))
    dt = x.dtype

    xt = x.reshape(T, d)
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                     # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert buffer
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)        # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                    # (T*k, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(T, k)         # (T, k)
    keep = pos < C
    slot = jnp.where(keep, eidx * C + pos, E * C)            # overflow -> trash

    buf = jnp.zeros((E * C + 1, d), dt).at[slot.reshape(-1)].add(
        jnp.repeat(xt, k, axis=0).reshape(T, k, d).reshape(T * k, d))
    buf = buf[:-1].reshape(E, C, d)
    buf = ctx.c(buf, "experts", "batch", "embed")

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dt))  # (E, C, 2f)
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    h = ctx.c(h, "experts", "batch", "mlp")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))
    out_buf = jnp.concatenate(
        [out_buf.reshape(E * C, d), jnp.zeros((1, d), dt)], axis=0)

    y = out_buf[slot.reshape(-1)].reshape(T, k, d)
    y = jnp.sum(y * (gate * keep).astype(dt)[..., None], axis=1)
    # aux: load-balancing loss term (Switch) exposed via ctx-free return
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux


def _apply_moe_batch_local(p, x, ctx: Ctx):
    cfg = ctx.cfg
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(math.ceil(S * k / E * cfg.capacity_factor)))
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)    # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                     # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)        # (B, S, k, E)
    flat = onehot.reshape(B, S * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # (B, S*k, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(B, S, k)
    keep = pos < C
    slot = jnp.where(keep, eidx * C + pos, E * C)            # (B, S, k)

    xrep = jnp.broadcast_to(x[:, :, None, :], (B, S, k, d)).reshape(
        B, S * k, d)
    bidx = jnp.arange(B)[:, None]
    buf = jnp.zeros((B, E * C + 1, d), dt).at[
        bidx, slot.reshape(B, S * k)].add(xrep)
    buf = buf[:, :-1].reshape(B, E, C, d)
    buf = ctx.c(buf, "batch", "experts", None, "embed")

    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(dt))
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    h = ctx.c(h, "batch", "experts", None, "mlp")
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dt))
    out_buf = jnp.concatenate(
        [out_buf.reshape(B, E * C, d), jnp.zeros((B, 1, d), dt)], axis=1)

    y = out_buf[bidx, slot.reshape(B, S * k)].reshape(B, S, k, d)
    y = jnp.sum(y * (gate * keep).astype(dt)[..., None], axis=2)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return y, aux


# --------------------------------------------------------------------------
# Mamba-2 block.
# --------------------------------------------------------------------------

def spec_mamba2(cfg) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    N = cfg.ssm_state or 64
    H = di // min(64, di)            # head channel size P = 64
    P = di // H
    return {
        "w_in": ParamSpec((d, 2 * di), ("embed", "inner")),
        "conv_w": ParamSpec((4, di), ("conv_k", "inner"), scale=0.5),
        "w_bc": ParamSpec((di, 2 * N), ("inner", "state")),
        "w_dt": ParamSpec((di, H), ("inner", None), scale=0.02),
        "dt_bias": ParamSpec((H,), (None,), "zeros"),
        "a_log": ParamSpec((H,), (None,), "zeros"),
        "d_skip": ParamSpec((H,), (None,), "ones"),
        "w_out": ParamSpec((di, d), ("inner", "embed")),
    }


def mamba_dims(cfg):
    di = cfg.d_inner
    H = di // min(64, di)
    return di, H, di // H, cfg.ssm_state or 64


def apply_mamba2(p, x, ctx: Ctx, cache=None):
    """cache: {'conv': (B, 3, di), 'h': (B, H, P, N)} for decode."""
    cfg = ctx.cfg
    di, H, P, N = mamba_dims(cfg)
    B, S, d = x.shape
    dt_ = x.dtype

    xz = x @ p["w_in"].astype(dt_)                          # (B, S, 2di)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = ctx.c(xs, "batch", "seq", "inner")

    conv_w = p["conv_w"].astype(jnp.float32)                # (4, di)
    if ctx.mode == "decode":
        hist = jnp.concatenate(
            [cache["conv"].astype(dt_), xs], axis=1)        # (B, 4, di)
        new_conv = hist[:, 1:]
        xc = jnp.einsum("bkd,kd->bd", hist.astype(jnp.float32),
                        conv_w)[:, None, :]
    else:
        pad = jnp.pad(xs.astype(jnp.float32), ((0, 0), (3, 0), (0, 0)))
        xc = sum(pad[:, i:i + S] * conv_w[i] for i in range(4))
        new_conv = pad[:, S: S + 3].astype(dt_) if S >= 3 else None
    xc = jax.nn.silu(xc).astype(dt_)                        # (B, S, di)

    bc = xc @ p["w_bc"].astype(dt_)                         # (B, S, 2N)
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt_pre = xc @ p["w_dt"].astype(dt_)                     # (B, S, H)
    dtv = jax.nn.softplus(dt_pre.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    xh = xc.reshape(B, S, H, P)

    if ctx.mode == "decode":
        y, h_new = ops.mamba_decode_step(
            cache["h"], xh[:, 0], dtv[:, 0], p["a_log"], bmat[:, 0], cmat[:, 0])
        y = y[:, None]                                      # (B, 1, H, P)
        new_cache = {"conv": new_conv, "h": h_new}
    else:
        y, h_final = ops.mamba_scan(xh, dtv, p["a_log"], bmat, cmat,
                                    chunk=ctx.mamba_chunk,
                                    use_pallas=ctx.use_pallas)
        new_cache = None
        if ctx.mode == "prefill":
            conv_tail = jnp.pad(xs.astype(dt_), ((0, 0), (3, 0), (0, 0)))[:, S:S + 3]
            new_cache = {"conv": conv_tail, "h": h_final}
    y = y + xh * p["d_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, di) * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    y = ctx.c(y, "batch", "seq", "inner")
    return y @ p["w_out"].astype(dt_), new_cache


# --------------------------------------------------------------------------
# xLSTM blocks.
# --------------------------------------------------------------------------

def spec_mlstm(cfg) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    H = cfg.n_heads
    return {
        "w_qkv": ParamSpec((d, 3 * di), ("embed", "inner")),
        "w_if": ParamSpec((d, 2 * H), ("embed", None), scale=0.02),
        "b_if": ParamSpec((2 * H,), (None,), "zeros"),
        "w_out": ParamSpec((di, d), ("inner", "embed")),
    }


def apply_mlstm(p, x, ctx: Ctx, cache=None):
    """cache: (C (B,H,P,P), n (B,H,P), m (B,H)) for decode."""
    cfg = ctx.cfg
    B, S, d = x.shape
    di, H = cfg.d_inner, cfg.n_heads
    P = di // H
    dt_ = x.dtype

    qkv = x @ p["w_qkv"].astype(dt_)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = ctx.c(q.reshape(B, S, H, P), "batch", "seq", "heads")
    k = ctx.c(k.reshape(B, S, H, P), "batch", "seq", "heads")
    v = ctx.c(v.reshape(B, S, H, P), "batch", "seq", "heads")
    gates = (x @ p["w_if"].astype(dt_)).astype(jnp.float32) + \
        p["b_if"].astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)             # (B, S, H)

    if ctx.mode == "decode":
        h, state = ops.mlstm_decode_step(
            cache, q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0])
        h = h[:, None]
        new_cache = state
    else:
        h, state = ops.mlstm_scan(q, k, v, i_pre, f_pre,
                                  chunk=ctx.mlstm_chunk,
                                  use_pallas=ctx.use_pallas)
        new_cache = state if ctx.mode == "prefill" else None
    h = h.reshape(B, S, di)
    h = ctx.c(h, "batch", "seq", "inner")
    return h @ p["w_out"].astype(dt_), new_cache


def spec_slstm(cfg) -> Dict:
    d = cfg.d_model
    return {
        "w_x": ParamSpec((d, 4 * d), ("embed", "mlp")),
        "w_h": ParamSpec((d, 4 * d), ("embed", "mlp")),
        "bias": ParamSpec((4 * d,), ("mlp",), "zeros"),
    }


def apply_slstm(p, x, ctx: Ctx, cache=None):
    """Sequential scalar-LSTM with exponential gating (true recurrence).

    cache: (c, n, h, m) each (B, d) for decode.
    """
    B, S, d = x.shape
    dt_ = x.dtype
    wx = p["w_x"].astype(jnp.float32)
    wh = p["w_h"].astype(jnp.float32)
    bias = p["bias"].astype(jnp.float32)
    xproj = x.astype(jnp.float32) @ wx + bias               # (B, S, 4d)

    if cache is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.zeros((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
        m0 = jnp.full((B, d), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = [t.astype(jnp.float32) for t in cache]

    hs, (cT, nT, hT, mT) = ops.slstm_scan(xproj, wh, c0, n0, h0, m0)
    y = hs.astype(dt_)
    new_cache = (cT, nT, hT, mT) if ctx.mode in ("prefill", "decode") else None
    return y, new_cache
