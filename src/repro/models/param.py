"""Abstract parameter machinery: one source of truth for shapes, init,
logical sharding axes and dtype.

A model defines a pytree of :class:`ParamSpec` (``abstract_params``); the
same tree materializes as
  * real arrays          (:func:`init_params`),
  * ShapeDtypeStructs    (:func:`shape_structs`, for .lower without alloc),
  * PartitionSpecs       (:func:`partition_specs`, logical->mesh rules).

Logical axis names used across the zoo:
  batch seq embed mlp heads kv_heads head_dim vocab experts layers
  conv_k inner state unit
Rules map each to a mesh axis (or None = replicated, or a tuple).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim
    init: str = "normal"                     # normal|zeros|ones|embed
    scale: Optional[float] = None            # None => 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map_specs(f: Callable[[ParamSpec], Any], tree):
    return jax.tree.map(f, tree, is_leaf=is_spec)


def shape_structs(tree):
    return _tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def init_params(tree, rng: jax.Array):
    """Materialize a ParamSpec tree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, max(len(leaves), 1))
    out = []
    for spec, key in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, spec.dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, spec.dtype))
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(fan_in)
            if spec.init == "embed":
                scale = spec.scale if spec.scale is not None else 0.02
            out.append((jax.random.normal(key, spec.shape, jnp.float32)
                        * scale).astype(spec.dtype))
    return jax.tree.unflatten(treedef, out)


# --------------------------------------------------------------------------
# Logical -> physical sharding rules.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis names to mesh axis names.

    Values may be a mesh-axis name, a tuple of names, or None (replicate).
    ``resolve`` drops axes that are absent from the mesh, so one rule set
    serves both the (data, model) and (pod, data, model) meshes.
    """

    batch: Any = ("pod", "data")
    seq: Any = None                  # sequence sharding (activations only)
    embed: Any = None
    mlp: Any = "model"
    heads: Any = "model"
    kv_heads: Any = "model"
    head_dim: Any = None
    vocab: Any = "model"
    experts: Any = None              # expert-parallel axis (hillclimb knob)
    inner: Any = "model"             # mamba/mlstm inner channels
    state: Any = None
    layers: Any = None
    unit: Any = None
    conv_k: Any = None
    frontend: Any = None
    zero: Any = "data"               # optimizer-state (ZeRO) sharding axis

    def lookup(self, logical: Optional[str]) -> Any:
        if logical is None:
            return None
        return getattr(self, logical)

    def resolve(self, axes: Sequence[Optional[str]], mesh,
                shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tuple of logical axes against a mesh.

        With ``shape``, axes whose mesh extent does not divide the dim are
        dropped (e.g. 15 attention heads on a 16-way model axis, or
        granite's 49155-row vocab) — the dim stays replicated, which is
        exactly what a production partitioner would fall back to.
        """
        names = set(mesh.axis_names)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        used = set()
        out = []
        for i, ax in enumerate(axes):
            phys = self.lookup(ax)
            if phys is None:
                out.append(None)
                continue
            if isinstance(phys, str):
                phys = (phys,)
            keep = tuple(p for p in phys if p in names and p not in used)
            if shape is not None and keep:
                extent = 1
                for p in keep:
                    extent *= sizes[p]
                if shape[i] % extent != 0:
                    keep = ()
            used.update(keep)
            if len(keep) == 0:
                out.append(None)
            elif len(keep) == 1:
                out.append(keep[0])
            else:
                out.append(keep)
        return P(*out)


def partition_specs(tree, rules: ShardingRules, mesh):
    return _tree_map_specs(lambda s: rules.resolve(s.axes, mesh, s.shape), tree)


def named_shardings(tree, rules: ShardingRules, mesh):
    from jax.sharding import NamedSharding
    return _tree_map_specs(
        lambda s: NamedSharding(mesh, rules.resolve(s.axes, mesh, s.shape)),
        tree)


def constrain(x, rules: ShardingRules, mesh, *logical_axes):
    """with_sharding_constraint by logical axis names (no-op off-mesh)."""
    if mesh is None:
        return x
    spec = rules.resolve(logical_axes, mesh, x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
