"""The generic LM: embedding -> scanned pattern units -> norm -> head.

One model covers all 10 assigned architectures, driven by ArchConfig:
the repeating block pattern (cfg.pattern_unit()) is stacked along a
leading "unit" axis and iterated with lax.scan, keeping the HLO O(1) in
depth (compile-time critical: the dry-run compiles 80 (arch x shape x
mesh) cells). Zamba2's shared attention block lives OUTSIDE the scan
(loop-invariant closure => weights broadcast once), whisper adds an
encoder scan + per-decoder-unit cross-attention.

Entry points:
  init / abstract_params            parameter trees (ParamSpec)
  forward                           logits for train/prefill
  loss                              next-token CE + MoE aux
  init_cache / decode_step          serving (one token vs KV cache)
  forward_segment                   SL split execution [lo, hi) blocks
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.param import ParamSpec, is_spec


# --------------------------------------------------------------------------
# Param specs.
# --------------------------------------------------------------------------

def _block_spec(cfg, kind: str) -> Dict:
    d = cfg.d_model
    if kind in ("attn", "shared_attn", "moe"):
        spec = {"norm1": L.spec_rmsnorm(d), "attn": L.spec_attention(cfg)}
        if cfg.enc_dec:
            spec["norm_x"] = L.spec_rmsnorm(d)
            spec["cross"] = L.spec_attention(cfg, cross=True)
        if cfg.d_ff:
            spec["norm2"] = L.spec_rmsnorm(d)
            spec["mlp"] = L.spec_moe(cfg) if kind == "moe" else L.spec_mlp(cfg)
        return spec
    if kind == "mamba2":
        return {"norm1": L.spec_rmsnorm(d), "mamba": L.spec_mamba2(cfg)}
    if kind == "mlstm":
        return {"norm1": L.spec_rmsnorm(d), "mlstm": L.spec_mlstm(cfg)}
    if kind == "slstm":
        return {"norm1": L.spec_rmsnorm(d), "slstm": L.spec_slstm(cfg)}
    raise ValueError(kind)


def _unit_spec(cfg) -> Dict:
    return {f"{j}:{kind}": _block_spec(cfg, kind)
            for j, kind in enumerate(cfg.pattern_unit())
            if kind != "shared_attn"}


def _stack(tree, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("unit",) + s.axes, s.init, s.scale,
                            s.dtype),
        tree, is_leaf=is_spec)


def abstract_params(cfg) -> Dict:
    d, V = cfg.d_model, cfg.vocab
    tree: Dict[str, Any] = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), "embed"),
        "units": _stack(_unit_spec(cfg), cfg.n_units),
        "final_norm": L.spec_rmsnorm(d),
    }
    if "shared_attn" in cfg.pattern_unit():
        tree["shared"] = _block_spec(cfg, "shared_attn")
    if not cfg.tie_embeddings:
        tree["head"] = ParamSpec((d, V), ("embed", "vocab"))
    if cfg.enc_dec:
        enc_cfg = dataclasses.replace(cfg, enc_dec=False, causal=False)
        tree["enc_units"] = _stack(
            {"0:attn": _block_spec(enc_cfg, "attn")}, cfg.n_enc_layers)
        tree["enc_norm"] = L.spec_rmsnorm(d)
    return tree


def init(cfg, rng) -> Dict:
    from repro.models.param import init_params
    return init_params(abstract_params(cfg), rng)


# --------------------------------------------------------------------------
# Positional tables.
# --------------------------------------------------------------------------

def _sinusoid(S: int, d: int):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _rope_for(cfg, batch: int, seq: int, positions=None,
              frontend_len: int = 0):
    """cos/sin tables. positions: (B,) decode positions or None (0..S)."""
    dh = cfg.head_dim
    if cfg.enc_dec:
        return None                     # whisper: absolute sinusoid instead
    if cfg.mrope:
        if positions is None:
            ids = L.text_mrope_positions(batch, seq, frontend_len)
        else:
            ids = jnp.broadcast_to(positions[None, :, None], (3, batch, 1))
        return L.mrope_tables(ids, dh, cfg.rope_theta)
    if positions is None:
        return L.rope_tables(jnp.arange(seq), dh, cfg.rope_theta)
    return L.rope_tables(positions, dh, cfg.rope_theta)


# --------------------------------------------------------------------------
# Block application.
# --------------------------------------------------------------------------

def _apply_block(kind: str, p, x, ctx: L.Ctx, cache):
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    cache = cache or {}

    if kind in ("attn", "shared_attn", "moe"):
        h, nc = L.apply_attention(
            p["attn"], L.rmsnorm(p["norm1"], x, cfg.norm_eps), ctx,
            causal=cfg.causal, window=cfg.window,
            cache=cache.get("attn"), use_rope=not cfg.enc_dec)
        x = x + h
        if nc is not None:
            new_cache["attn"] = nc
        if cfg.enc_dec and "cross" in p:
            h, nc = L.apply_attention(
                p["cross"], L.rmsnorm(p["norm_x"], x, cfg.norm_eps), ctx,
                causal=False, cache=cache.get("cross"),
                kv_input=ctx.enc_out, use_rope=False, is_cross=True)
            x = x + h
            if nc is not None:
                new_cache["cross"] = nc
        if cfg.d_ff:
            xn = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
            if kind == "moe":
                h, aux = L.apply_moe(p["mlp"], xn, ctx)
            else:
                h = L.apply_mlp(p["mlp"], xn, ctx)
            x = x + h
    elif kind == "mamba2":
        h, nc = L.apply_mamba2(p["mamba"],
                               L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                               ctx, cache=cache.get("mamba"))
        x = x + h
        if nc is not None:
            new_cache["mamba"] = nc
    elif kind == "mlstm":
        h, nc = L.apply_mlstm(p["mlstm"],
                              L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                              ctx, cache=cache.get("mlstm"))
        x = x + h
        if nc is not None:
            new_cache["mlstm"] = nc
    elif kind == "slstm":
        h, nc = L.apply_slstm(p["slstm"],
                              L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                              ctx, cache=cache.get("slstm"))
        x = x + h
        if nc is not None:
            new_cache["slstm"] = nc
    else:
        raise ValueError(kind)
    x = ctx.c(x, "batch", "seq", "embed")
    return x, new_cache, aux


def _apply_unit(cfg, unit_params, shared_params, x, ctx: L.Ctx, unit_cache):
    new_caches = {}
    aux = jnp.zeros((), jnp.float32)
    for j, kind in enumerate(cfg.pattern_unit()):
        key = f"{j}:{kind}"
        p = shared_params if kind == "shared_attn" else unit_params[key]
        c = unit_cache.get(key) if unit_cache else None
        x, nc, a = _apply_block(kind, p, x, ctx, c)
        aux = aux + a
        if nc:
            new_caches[key] = nc
    return x, new_caches, aux


# --------------------------------------------------------------------------
# Forward (train / prefill).
# --------------------------------------------------------------------------

def _embed_tokens(cfg, params, tokens, act_dtype):
    return jnp.take(params["embed"], tokens, axis=0).astype(act_dtype)


def _run_encoder(cfg, params, enc_frames, ctx: L.Ctx, unroll: int = 1):
    """Whisper encoder over (stub) frame embeddings."""
    S = enc_frames.shape[1]
    x = enc_frames.astype(ctx.act_dtype) + \
        _sinusoid(S, cfg.d_model).astype(ctx.act_dtype)[None]
    enc_cfg = dataclasses.replace(cfg, enc_dec=False, causal=False)
    ectx = dataclasses.replace(ctx, cfg=enc_cfg, mode="train", rope=None)

    def unit_fn(h, up):
        h, _, _ = _apply_unit(enc_cfg, up, None, h, ectx, None)
        return h, None

    x, _ = jax.lax.scan(unit_fn, x, params["enc_units"], unroll=unroll)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(cfg, params, tokens, *, ctx: L.Ctx, frontend_embed=None,
            enc_frames=None, remat: str = "full", unroll: int = 1):
    """Full-sequence logits. mode = train (no cache) or prefill (cache out).

    Returns (logits fp32, aux_loss, caches_or_None).
    """
    B, S = tokens.shape
    x = _embed_tokens(cfg, params, tokens, ctx.act_dtype)
    if cfg.frontend == "vision" and frontend_embed is not None:
        F = cfg.frontend_len
        x = jnp.concatenate(
            [frontend_embed.astype(ctx.act_dtype), x[:, F:]], axis=1)
    if cfg.enc_dec:
        x = x + _sinusoid(S, cfg.d_model).astype(ctx.act_dtype)[None]
        enc_out = _run_encoder(cfg, params, enc_frames, ctx, unroll=unroll)
        ctx = dataclasses.replace(ctx, enc_out=enc_out)
    F = cfg.frontend_len if (cfg.frontend == "vision"
                             and frontend_embed is not None) else 0
    ctx = dataclasses.replace(ctx, rope=_rope_for(cfg, B, S, frontend_len=F))
    x = ctx.c(x, "batch", "seq", "embed")

    shared = params.get("shared")
    collect_cache = ctx.mode == "prefill"

    def unit_fn(h, up):
        h, caches, aux = _apply_unit(cfg, up, shared, h, ctx, None)
        return h, (caches if collect_cache else None, aux)

    if remat == "full":
        unit_fn = jax.checkpoint(unit_fn)
    elif remat == "dots":
        unit_fn = jax.checkpoint(
            unit_fn, policy=jax.checkpoint_policies.checkpoint_dots)

    x, (caches, auxs) = jax.lax.scan(unit_fn, x, params["units"],
                                     unroll=unroll)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _head(cfg, params, x)
    return logits, jnp.sum(auxs), caches


def _head(cfg, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def loss(cfg, params, tokens, labels, *, ctx: L.Ctx,
         frontend_embed=None, enc_frames=None, remat: str = "full",
         aux_weight: float = 0.01, unroll: int = 1):
    """Next-token CE (labels = targets aligned to positions; -1 = pad)."""
    logits, aux, _ = forward(cfg, params, tokens, ctx=ctx,
                             frontend_embed=frontend_embed,
                             enc_frames=enc_frames, remat=remat,
                             unroll=unroll)
    mask = (labels >= 0)
    if cfg.frontend == "vision":
        pos = jnp.arange(labels.shape[1])[None, :]
        mask = mask & (pos >= cfg.frontend_len)
    labels_c = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    ce = (lse - ll) * mask
    n = jnp.maximum(jnp.sum(mask), 1)
    ce_mean = jnp.sum(ce) / n
    return ce_mean + aux_weight * aux, {"ce": ce_mean, "aux": aux,
                                        "ntok": n}


# --------------------------------------------------------------------------
# Serving: cache init + single-token decode.
# --------------------------------------------------------------------------

def _block_cache_shapes(cfg, kind: str, batch: int, s_max: int,
                        act_dtype) -> Dict:
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    out: Dict[str, Any] = {}
    if kind in ("attn", "shared_attn", "moe"):
        s_eff = min(cfg.window, s_max) if cfg.window else s_max
        out["attn"] = {
            "k": jnp.zeros((batch, KV, s_eff, dh), act_dtype),
            "v": jnp.zeros((batch, KV, s_eff, dh), act_dtype)}
        if cfg.enc_dec:
            out["cross"] = {
                "k": jnp.zeros((batch, KV, cfg.frontend_len, dh), act_dtype),
                "v": jnp.zeros((batch, KV, cfg.frontend_len, dh), act_dtype)}
    elif kind == "mamba2":
        di, H, P, N = L.mamba_dims(cfg)
        out["mamba"] = {"conv": jnp.zeros((batch, 3, di), act_dtype),
                        "h": jnp.zeros((batch, H, P, N), jnp.float32)}
    elif kind == "mlstm":
        H = cfg.n_heads
        P = cfg.d_inner // H
        out["mlstm"] = (jnp.zeros((batch, H, P, P), jnp.float32),
                        jnp.zeros((batch, H, P), jnp.float32),
                        jnp.full((batch, H), -1e30, jnp.float32))
    elif kind == "slstm":
        d = cfg.d_model
        out["slstm"] = tuple(
            jnp.full((batch, d), -1e30 if i == 3 else 0.0, jnp.float32)
            for i in range(4))
    return out


def init_cache(cfg, batch: int, s_max: int, act_dtype=jnp.bfloat16) -> Dict:
    """Per-unit stacked cache pytree (leading axis n_units)."""
    unit = {f"{j}:{kind}": _block_cache_shapes(cfg, kind, batch, s_max,
                                               act_dtype)
            for j, kind in enumerate(cfg.pattern_unit())}
    unit = {k: v for k, v in unit.items() if v}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_units,) + a.shape).copy()
        if not isinstance(a, (int, float)) else a, unit)


def cache_from_prefill(cfg, caches, s_max: int, act_dtype=jnp.bfloat16):
    """Convert ``forward(mode=prefill)`` caches into a decode cache of
    capacity ``s_max`` (ring-indexed for sliding-window attention).

    Recurrent states (mamba/mlstm/slstm) pass through; full-attention
    K/V pads to s_max; SWA K/V scatters the last ``window`` positions
    into their ring slots (slot = pos % window), matching the decode
    write index.
    """
    def ring(kv):
        U, B, KV, S, dh = kv.shape
        s_eff = min(cfg.window, s_max) if cfg.window else s_max
        out = jnp.zeros((U, B, KV, s_eff, dh), act_dtype)
        take = min(S, s_eff)
        slots = jnp.arange(S - take, S) % s_eff
        return out.at[:, :, :, slots, :].set(
            kv[:, :, :, S - take:, :].astype(act_dtype))

    out = {}
    for key, blk in caches.items():
        out[key] = {}
        for sub, val in blk.items():
            if sub == "attn":                    # self-attn KV -> ring/pad
                out[key][sub] = {kk: ring(vv) for kk, vv in val.items()}
            elif sub == "cross":                 # fixed encoder memory
                out[key][sub] = jax.tree.map(
                    lambda a: a.astype(act_dtype), val)
            else:                                # recurrent states pass through
                out[key][sub] = val
    return out


def decode_step(cfg, params, cache, tokens, positions, *, ctx: L.Ctx,
                unroll: int = 1):
    """One decode step. tokens: (B, 1); positions: (B,).

    Returns (logits (B, 1, V) fp32, new_cache).
    """
    B = tokens.shape[0]
    x = _embed_tokens(cfg, params, tokens, ctx.act_dtype)
    if cfg.enc_dec:
        pos_emb = _sinusoid(1 << 17, cfg.d_model)  # static table, gathered
        x = x + pos_emb[positions][:, None].astype(ctx.act_dtype)
    ctx = dataclasses.replace(
        ctx, mode="decode", positions=positions,
        rope=_rope_for(cfg, B, 1, positions=positions))
    shared = params.get("shared")

    def unit_fn(h, inp):
        up, uc = inp
        h, new_c, _ = _apply_unit(cfg, up, shared, h, ctx, uc)
        return h, new_c

    x, new_cache = jax.lax.scan(unit_fn, x, (params["units"], cache),
                                unroll=unroll)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _head(cfg, params, x), new_cache


# --------------------------------------------------------------------------
# Split serving: decode with the model cut at a unit boundary.
# --------------------------------------------------------------------------

def split_serve_params(cfg, params, cut_units: int):
    """Split the decode-path params at unit boundary ``cut_units``.

    Returns ``(params_sat, params_gnd)``: the satellite half holds the
    embedding and units ``[0, cut)``; the ground half holds units
    ``[cut, U)``, the final norm and the head (for tied embeddings the
    ground station keeps its own copy of the embedding matrix — the
    paper's segment-B weights).  Zamba2's shared attention block is
    replicated to both halves (it is applied inside units on each side).
    """
    if not 1 <= cut_units <= cfg.n_units - 1:
        raise ValueError(f"cut_units must be in [1, {cfg.n_units - 1}], "
                         f"got {cut_units}")
    if cfg.enc_dec:
        raise NotImplementedError("split serving does not cover enc-dec "
                                  "(whisper) architectures")
    pa = {"embed": params["embed"],
          "units": jax.tree.map(lambda a: a[:cut_units], params["units"])}
    pb = {"units": jax.tree.map(lambda a: a[cut_units:], params["units"]),
          "final_norm": params["final_norm"]}
    if cfg.tie_embeddings:
        pb["embed"] = params["embed"]
    else:
        pb["head"] = params["head"]
    if "shared" in params:
        pa["shared"] = params["shared"]
        pb["shared"] = params["shared"]
    return pa, pb


def decode_step_split(cfg, params_sat, params_gnd, cache, tokens, positions,
                      *, ctx: L.Ctx, unroll: int = 1):
    """One decode step of the SPLIT model (satellite half then ground
    half), numerically identical to :func:`decode_step` on the unsplit
    params: ``lax.scan`` over units is sequential, so running two scans
    over the two halves applies the same blocks in the same order.

    ``cache`` is the full stacked decode cache; its leading unit axis is
    sliced per half and the updated halves are re-concatenated.

    Returns ``(logits (B, 1, V) fp32, new_cache, boundary)`` where
    ``boundary`` is the smashed activation ``(B, 1, d_model)`` that
    crosses the satellite->ground downlink — its size is the per-token
    D_tx payload the serving energy model charges.
    """
    cut = jax.tree.leaves(params_sat["units"])[0].shape[0]
    B = tokens.shape[0]
    x = _embed_tokens(cfg, params_sat, tokens, ctx.act_dtype)
    ctx = dataclasses.replace(
        ctx, mode="decode", positions=positions,
        rope=_rope_for(cfg, B, 1, positions=positions))

    def unit_fn(shared):
        def f(h, inp):
            up, uc = inp
            h, new_c, _ = _apply_unit(cfg, up, shared, h, ctx, uc)
            return h, new_c
        return f

    cache_a = jax.tree.map(lambda a: a[:cut], cache)
    cache_b = jax.tree.map(lambda a: a[cut:], cache)
    boundary, new_a = jax.lax.scan(
        unit_fn(params_sat.get("shared")), x,
        (params_sat["units"], cache_a), unroll=unroll)
    x, new_b = jax.lax.scan(
        unit_fn(params_gnd.get("shared")), boundary,
        (params_gnd["units"], cache_b), unroll=unroll)
    x = L.rmsnorm(params_gnd["final_norm"], x, cfg.norm_eps)
    new_cache = jax.tree.map(
        lambda a, b: jnp.concatenate([a, b], axis=0), new_a, new_b)
    return _head(cfg, params_gnd, x), new_cache, boundary


# --------------------------------------------------------------------------
# Split-learning segment execution (the paper's cut, on a real model).
# --------------------------------------------------------------------------

def n_blocks(cfg) -> int:
    return cfg.n_units * len(cfg.pattern_unit())


def _unit_slice(params_units, u: int):
    return jax.tree.map(lambda a: a[u], params_units)


def forward_segment(cfg, params, x, lo: int, hi: int, *, ctx: L.Ctx,
                    tokens=None, unit_offset: int = 0):
    """Apply blocks [lo, hi). lo==0 consumes ``tokens`` via the embedding;
    hi==n_blocks applies final norm + head. Python-loop (non-scanned) path
    used by the SL constellation driver on ground/satellite segments.
    ``unit_offset``: params["units"] holds units starting at this index
    (segment trees are slices of the full stacked tree).
    """
    pat = cfg.pattern_unit()
    if lo == 0:
        assert tokens is not None
        x = _embed_tokens(cfg, params, tokens, ctx.act_dtype)
        B, S = tokens.shape
    else:
        B, S = x.shape[0], x.shape[1]
    ctx = dataclasses.replace(ctx, rope=_rope_for(cfg, B, S))
    for idx in range(lo, hi):
        u, j = divmod(idx, len(pat))
        kind = pat[j]
        p = (params.get("shared") if kind == "shared_attn"
             else _unit_slice(params["units"], u - unit_offset)[f"{j}:{kind}"])
        x, _, _ = _apply_block(kind, p, x, ctx, None)
    if hi == n_blocks(cfg):
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return _head(cfg, params, x)
    return x
