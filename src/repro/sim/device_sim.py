"""Device-resident constellation simulator: the closed loop as ONE scan.

The host :class:`~repro.core.constellation.ConstellationSim` advances
battery/recharge state and dispatches every pass from Python — fine for
protocol studies, but each pass costs a host round-trip, which caps
closed-loop energy studies at small rings and few revolutions.  This
module promotes the whole loop to a first-class device program:

    one jitted nested ``lax.scan`` over (revolution × ring-slot), where
    each slot's pass = [reserve-skip policy → masked fused SL steps →
    battery drain → fleet recharge], with the model state and the
    per-satellite :class:`~repro.sim.energy_state.EnergyState` riding
    the donated carry.

Layering (who owns what):

* **planning** — :func:`plan_ring_passes` builds the ring's N
  problem-(13) instances with
  :func:`~repro.core.resource_opt_jax.ring_pass_coeffs` and sheds+solves
  them on device (``shed_and_solve_coeffs``) under the solver's float64
  scope, then casts the pass plan (:class:`DevicePassPlan`) to
  float32/int32 arrays at the planning/training boundary — the SL stack
  stays float32.  The plan is revolution-invariant for a static ring
  (membership and batch shapes fixed), so planning once inside setup
  equals replanning every revolution.  A plan may also come from a
  whole scenario grid: ``RevolutionSweep.revolution_plan`` broadcasts
  one planned grid cell over its ring (see :mod:`repro.core.mission`).
* **training** — every step runs the SAME masked kernel as the host
  pass engine (:func:`~repro.core.sl_step.make_pass_step`); ``n_valid``
  step masks gate allocation-driven step counts, a reserve skip masks
  the whole pass.  The handoff is the carry itself: the train state
  simply arrives at the next slot ("segment A rides the scan"), with
  the ISL cost charged by the plan.
* **energy** — :mod:`repro.sim.energy_state` arrays; the battery clamp
  policy is shared verbatim with the host sim.

Host contact: ZERO dispatch between passes; telemetry syncs at most
once per revolution (``stream_telemetry=True``) or once per run.  The
``traces`` / ``device_calls`` / ``host_syncs`` counters make that
contract testable.

The host sim remains the parity oracle: with a traceable batch provider
(:class:`~repro.sim.data.DeviceImageryShards`) both engines consume
identical samples, and ``ConstellationSim.run(engine="device")``
delegates steady-state runs here, folding telemetry back into
``PassRecord`` form.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import PassBudget, SplitCosts
from repro.obs.metrics import (MetricsRegistry, counter_property,
                               global_registry)
from repro.obs.ring import (EV_PASS, FlightRecorder, TelemetryRing,
                            record as ring_record, ring_init)
from repro.core.sl_step import (SplitAdapter, boundary_bits,
                                dedupe_state_buffers, make_pass_step)
from repro.core.train_state import SLTrainState
from repro.sim import energy_state as es_mod
from repro.sim.energy_state import EnergyState, init_energy_state
from repro.train.optimizer import resolve_optimizer
from repro.utils.bucketing import bucket_size as _bucket_size
from repro.utils.treeutil import tree_bytes

ACTION_TRAINED = 0
ACTION_SHED = 1
ACTION_SKIPPED = 2
ACTION_FAILED = 3          # fleet engine only: a static ring cannot fail
ACTION_FAULT = 4           # fleet scenarios only: transient epidemic fault
ACTION_NAMES = {ACTION_TRAINED: "trained", ACTION_SHED: "shed",
                ACTION_SKIPPED: "skipped_energy", ACTION_FAILED: "failed",
                ACTION_FAULT: "faulted"}


class DevicePassPlan(NamedTuple):
    """One ring revolution of pre-solved pass allocations, ``(N,)`` arrays.

    Everything the closed loop needs per slot, already at the float32
    training boundary: fused-step counts (``n_steps``, the ``n_valid``
    feed of the shared pass kernel), the satellite-side battery drain
    (E_proc^sat + E_comm^down + E_ISL — what the host sim subtracts) and
    the eq. (11)/(12) records.  Built by :func:`plan_ring_passes` or
    broadcast from a swept grid cell
    (``RevolutionSweep.revolution_plan``).
    """

    n_steps: Any              # (N,) int32   fused SL steps per pass (>=1)
    n_items_kept: Any         # (N,) float32 post-shedding item count
    kept_fraction: Any        # (N,) float32
    drain_j: Any              # (N,) float32 satellite battery draw / pass
    e_total_j: Any            # (N,) float32 eq. (11) incl. E_ISL
    e_proc_j: Any             # (N,) float32 sat + gs processing
    e_comm_j: Any             # (N,) float32 downlink + uplink
    e_isl_j: Any              # (N,) float32
    t_total_s: Any            # (N,) float32 eq. (12)
    d_isl_bits: Any           # (N,) float32 segment-A handoff payload
    feasible: Any             # (N,) bool   post-shedding feasibility

    @property
    def n_sats(self) -> int:
        return self.n_steps.shape[0]

    def to_host(self) -> "DevicePassPlan":
        """One explicit device→host sync of the whole plan."""
        return DevicePassPlan(*[np.asarray(a) for a in self])


def plan_from_report(rep, frac, n_items, d_isl_bits, batch_size,
                     max_steps_per_pass=None) -> DevicePassPlan:
    """Fold a solved ``ArraySolveReport`` (+ shed fractions) into a
    :class:`DevicePassPlan`, casting to the float32 training boundary.

    Shared by :func:`plan_ring_passes` and the sweep-cell bridge in
    :mod:`repro.core.mission`; call under the solver's x64 scope.  The
    step count mirrors the host scheduler exactly:
    ``max(1, round(n_items_kept / batch_size))`` capped at
    ``max_steps_per_pass``.
    """
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    n_kept = jnp.asarray(frac) * jnp.asarray(n_items, jnp.float64)
    steps = jnp.maximum(jnp.round(n_kept / float(batch_size)), 1.0)
    if max_steps_per_pass is not None:
        steps = jnp.minimum(steps, float(max_steps_per_pass))
    pe = rep.phase_energy                # (..., 4) canonical phase order
    return DevicePassPlan(
        n_steps=steps.astype(jnp.int32),
        n_items_kept=f32(n_kept),
        kept_fraction=f32(frac),
        drain_j=f32(pe[..., 0] + pe[..., 1] + rep.e_isl),
        e_total_j=f32(rep.e_total),
        e_proc_j=f32(pe[..., 0] + pe[..., 2]),
        e_comm_j=f32(pe[..., 1] + pe[..., 3]),
        e_isl_j=f32(rep.e_isl),
        t_total_s=f32(rep.t_total),
        d_isl_bits=f32(jnp.broadcast_to(jnp.asarray(d_isl_bits),
                                        steps.shape)),
        feasible=rep.feasible)


def plan_ring_passes(budget: PassBudget, costs: SplitCosts, *,
                     batch_size: int, n_sats=None,
                     dtx_bits=None, n_items=None,
                     max_steps_per_pass: Optional[int] = None,
                     min_fraction: float = 0.05, tol: float = 1e-10,
                     max_iters: int = 80,
                     ring_n: Optional[int] = None) -> DevicePassPlan:
    """Shed + solve one ring revolution's N passes, entirely on device.

    The device twin of ``RevolutionPlanner.plan_revolution``: N
    problem-(13) instances (one per ring slot) built by
    :func:`~repro.core.resource_opt_jax.ring_pass_coeffs` — scalars
    broadcast ring-wide, or per-satellite ``(N,)`` arrays for measured
    heterogeneous payloads (``dtx_bits``) / item budgets (``n_items``).

    ``n_sats`` may be a shape tuple (the fleet engine plans ``(P, M)``
    rows in one solve); ``ring_n`` then pins the orbital population of
    the eq.-(5) ISL hop distance (the host oracle always prices it off
    the configured plane, not live membership).
    """
    from repro.core import resource_opt_jax as roj

    if not roj.available():                        # pragma: no cover
        raise RuntimeError("the device constellation engine needs the JAX "
                           "solver backend (repro.core.resource_opt_jax)")
    n_sats = budget.plane.n_sats if n_sats is None else n_sats
    dtx = costs.dtx_bits if dtx_bits is None else dtx_bits
    items = budget.n_items if n_items is None else n_items
    sc = roj.grid_scalars(budget.plane, budget.link, budget.isl,
                          budget.sat_device, budget.gs_device)
    with roj.x64_scope():
        coeffs = roj.ring_pass_coeffs(sc, n_sats, costs.w1_flops,
                                      costs.w2_flops, dtx,
                                      costs.d_isl_bits, items,
                                      ring_n=ring_n)
        rep, frac = roj.shed_and_solve_coeffs(coeffs, min_fraction, tol,
                                              max_iters)
        return plan_from_report(rep, frac, items, costs.d_isl_bits,
                                batch_size, max_steps_per_pass)


def measure_and_plan(adapter: SplitAdapter, budget: PassBudget, batch_fn,
                     *, quantize_boundary: bool, params_a, n_sats,
                     ring_n: Optional[int] = None, dtx_bits=None,
                     max_steps_per_pass: Optional[int] = None,
                     min_fraction: float = 0.05, plan=None,
                     isl_extra_bits=0.0):
    """The shared construction block of every device engine.

    Measures the boundary payload shape-only (one ``eval_shape`` probe
    batch), folds the measured costs (``dtx_bits`` per item, segment-A
    handoff bytes from the live ``params_a``), plans the pass rows on
    device (or accepts an external ``plan``), and sizes the static
    per-pass scan from the plan's actual largest step count (ONE host
    read, construction only), bucketed on the repo-wide schedule so
    replans recompile O(log k) at most.  Returns
    ``(batch_size, costs, plan, scan_steps)``.  Keeping this in one
    place is what keeps the single-ring engine and the fleet engine
    measuring and planning identically — the host-oracle parity
    invariant.
    """
    abstract = jax.eval_shape(lambda: batch_fn(0, 0))
    batch_size = int(jax.tree.leaves(abstract)[0].shape[0])
    dtx = boundary_bits(adapter, abstract, quantize_boundary) / batch_size
    # ``isl_extra_bits`` (scalar or per-instance array) adds the fleet
    # exchange's amortized per-pass wire volume (repro.isl) on top of
    # the segment-A handoff, so a codec choice reshapes the planned
    # problem-(13) allocation, not just a telemetry counter
    costs = dataclasses.replace(adapter.costs(), dtx_bits=dtx,
                                d_isl_bits=8.0 * tree_bytes(params_a)
                                + isl_extra_bits)
    if plan is None:
        plan = plan_ring_passes(budget, costs, batch_size=batch_size,
                                n_sats=n_sats, ring_n=ring_n,
                                dtx_bits=dtx_bits,
                                max_steps_per_pass=max_steps_per_pass,
                                min_fraction=min_fraction)
    k_max = int(np.asarray(jnp.max(plan.n_steps)))
    return batch_size, costs, plan, _bucket_size(max(k_max, 1))


class PassTelemetry(NamedTuple):
    """Per-pass scan outputs, stacked to ``(R, N)`` by the nested scan."""

    action: Any               # int32 ACTION_* code
    loss: Any                 # float32 mean loss over executed steps (NaN
                              # when skipped)
    battery_j: Any            # float32 serving sat's battery at pass end
                              # (post-drain, post-recharge)
    n_steps: Any              # int32 steps actually executed


@dataclasses.dataclass(frozen=True)
class DeviceSimConfig:
    """Closed-loop knobs, mirroring the steady-state subset of
    :class:`~repro.core.constellation.ConstellationConfig`.  Elastic
    membership and random failures belong to the fleet engine
    (:mod:`repro.fleet`, whose scan carry holds the aliveness mask);
    checkpoint *persistence* (``handoff_dir``) remains host-oracle —
    it touches the filesystem, which no device program can."""

    n_revolutions: int = 1
    lr: float = 1e-2
    optimizer: Union[str, Any] = "sgd"
    quantize_boundary: bool = False
    battery_j: float = 5_000.0
    recharge_w: float = 20.0
    reserve_j: float = 100.0
    # static scan length per pass; None = sized from the plan's largest
    # step count (one host read at construction time)
    max_steps_per_pass: Optional[int] = 128
    min_fraction: float = 0.05
    seed: int = 0


@dataclasses.dataclass
class DeviceSimResult:
    """Host-side view of one closed-loop run (synced telemetry)."""

    action: np.ndarray        # (R, N)
    loss: np.ndarray          # (R, N) NaN where skipped
    battery_j: np.ndarray     # (R, N) serving sat battery at pass end
    n_steps: np.ndarray       # (R, N)
    plan: DevicePassPlan      # host copies
    energy: EnergyState       # final fleet state, host copies
    state: Any                # final SLTrainState (device arrays)

    def summary(self) -> Dict[str, Any]:
        """Same shape as ``ConstellationSim.summary()``."""
        R, N = self.action.shape
        sat = np.tile(np.arange(N), (R, 1))
        trained = self.action != ACTION_SKIPPED
        losses = self.loss[trained]
        return {
            "passes": int(R * N),
            "trained": int(trained.sum()),
            "skipped": int((~trained).sum()),
            "failed": 0,
            "loss_first": float(losses[0]) if losses.size else None,
            "loss_last": float(losses[-1]) if losses.size else None,
            "E_total_J": float(self.plan.e_total_j[sat[trained]].sum()),
            "E_comm_J": float(self.plan.e_comm_j[sat[trained]].sum()),
            "E_proc_J": float(self.plan.e_proc_j[sat[trained]].sum()),
            "E_isl_J": float(self.plan.e_isl_j[sat[trained]].sum()),
        }


class DeviceConstellationSim:
    """The paper's cyclical SL protocol as one jitted device program.

    ``batch_fn(sat, idx) -> batch`` must be traceable (e.g.
    :class:`~repro.sim.data.DeviceImageryShards`): it runs INSIDE the
    scan, so the engine never stages a dataset.  ``state`` chains an
    existing :class:`~repro.core.train_state.SLTrainState` (donated —
    the input is consumed); ``plan`` overrides on-device planning with
    an external :class:`DevicePassPlan` (e.g. a swept grid cell).

    Observability: every pass also records an ``EV_PASS`` event into a
    :class:`~repro.obs.ring.TelemetryRing` riding the scan carry,
    flushed into ``self.recorder`` at the existing telemetry sync — the
    flight-recorder feed of :mod:`repro.obs.timeline`.  The legacy
    counters ``traces`` (jit traces of the closed loop — stays at 1
    across repeated runs of the same shape), ``device_calls``
    (dispatches; one per run, or one per revolution when streaming) and
    ``host_syncs`` (telemetry device→host reads; ≤ 1 per revolution by
    construction) live on ``self.metrics`` (a
    :class:`~repro.obs.metrics.MetricsRegistry` under the ``sim``
    namespace) behind read-through properties.
    """

    traces = counter_property("traces")
    device_calls = counter_property("device_calls")
    host_syncs = counter_property("host_syncs")

    def __init__(self, adapter: SplitAdapter, budget: PassBudget,
                 batch_fn: Callable[[Any, Any], Dict],
                 cfg: Optional[DeviceSimConfig] = None, *,
                 state: Optional[SLTrainState] = None,
                 plan: Optional[DevicePassPlan] = None,
                 dtx_bits=None):
        cfg = DeviceSimConfig() if cfg is None else cfg
        self.adapter = adapter
        self.budget = budget
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.n_sats = budget.plane.n_sats
        self.optimizer = resolve_optimizer(cfg.optimizer, lr=cfg.lr)
        if state is None:
            pa, pb = adapter.init(jax.random.key(cfg.seed))
            state = SLTrainState.create(pa, pb, self.optimizer)
        self.state = state
        self.energy = init_energy_state(self.n_sats, cfg.battery_j)

        # measured costs + on-device plan + static scan sizing, via the
        # construction block shared with the fleet engine.  dtx_bits:
        # per-satellite measured boundary payloads ((N,) rows, e.g.
        # from sl_step.ring_boundary_bits) plan a heterogeneous ring in
        # the same single device solve; None broadcasts the measured
        # scalar.
        self.dtx_bits = dtx_bits
        self.batch_size, self.costs, self.plan, self._scan_steps = \
            measure_and_plan(adapter, budget, batch_fn,
                             quantize_boundary=cfg.quantize_boundary,
                             params_a=state.params_a, n_sats=self.n_sats,
                             dtx_bits=dtx_bits,
                             max_steps_per_pass=cfg.max_steps_per_pass,
                             min_fraction=cfg.min_fraction, plan=plan)
        if self.plan.n_sats != self.n_sats:
            raise ValueError(f"plan covers {self.plan.n_sats} slots but the "
                             f"ring has {self.n_sats} satellites")

        self._pass_step = make_pass_step(
            adapter, self.optimizer,
            quantize_boundary=cfg.quantize_boundary)
        self._batch_idx = jnp.zeros((), jnp.int32)
        self._fns: Dict[int, Any] = {}
        self.metrics = MetricsRegistry("sim", parent=global_registry())
        self.metrics.gauge("n_sats").set(self.n_sats)
        self.recorder = FlightRecorder(self.metrics)
        self._passes_done = 0      # absolute pass count across chained runs

    # ------------------------------------------------------- the program
    def _compiled(self, n_revolutions: int):
        """The jitted (revolution × ring-slot) closed loop for R
        revolutions; cached per R (same trace serves every run)."""
        fn = self._fns.get(n_revolutions)
        if fn is not None:
            return fn

        cfg = self.cfg
        N, K = self.n_sats, self._scan_steps
        pass_step = self._pass_step
        batch_fn = self.batch_fn
        recharge_j = jnp.float32(cfg.recharge_w
                                 * self.budget.plane.pass_duration_s)
        reserve = jnp.float32(cfg.reserve_j)
        cap = jnp.float32(cfg.battery_j)
        step_ids = jnp.arange(K, dtype=jnp.int32)

        def pass_body(carry, sat):
            state, energy, bidx, ring, plan = carry
            # energy policy first, exactly like the host scheduler: below
            # reserve => the whole pass is a masked no-op (the segment
            # still "moves on" — it's the carry)
            skip = energy.battery_j[sat] < reserve
            n_valid = jnp.where(skip, 0,
                                jnp.minimum(plan.n_steps[sat], K))

            def step_body(st, j):
                return pass_step(st, batch_fn(sat, bidx + j), j < n_valid)

            state, losses = jax.lax.scan(step_body, state, step_ids)
            valid = step_ids < n_valid
            loss = jnp.where(
                skip, jnp.nan,
                jnp.where(valid, losses, 0.0).sum()
                / jnp.maximum(n_valid, 1).astype(jnp.float32))

            energy = es_mod.apply_pass(energy, sat, plan.drain_j[sat],
                                       plan.e_total_j[sat], cap, ~skip)
            energy = es_mod.recharge(energy, recharge_j, cap)
            bidx = bidx + n_valid
            action = jnp.where(
                skip, ACTION_SKIPPED,
                jnp.where(plan.kept_fraction[sat] < 1.0, ACTION_SHED,
                          ACTION_TRAINED)).astype(jnp.int32)
            telem = PassTelemetry(action=action, loss=loss,
                                  battery_j=energy.battery_j[sat],
                                  n_steps=n_valid)
            # flight recorder: one EV_PASS per pass; the ring's own
            # cursor IS the dispatch-local pass index (every pass
            # records exactly once), rebased to the run timeline by
            # the host at ingest
            ring = ring_record(
                ring, EV_PASS, ring.cursor, sat,
                (action.astype(jnp.float32), energy.battery_j[sat], loss,
                 n_valid.astype(jnp.float32), plan.kept_fraction[sat],
                 0.0, 1.0, 0.0))
            return (state, energy, bidx, ring, plan), telem

        def rev_body(carry, _):
            return jax.lax.scan(pass_body, carry,
                                jnp.arange(N, dtype=jnp.int32))

        def closed_loop(state, energy, bidx, ring, plan):
            # side effect fires at trace time
            self.metrics.inc("traces")
            carry, telem = jax.lax.scan(rev_body,
                                        (state, energy, bidx, ring, plan),
                                        None, length=n_revolutions)
            state, energy, bidx, ring, _ = carry
            return state, energy, bidx, ring, telem

        fn = jax.jit(closed_loop, donate_argnums=(0, 1, 3))
        self._fns[n_revolutions] = fn
        return fn

    # --------------------------------------------------------------- run
    def run(self, n_revolutions: Optional[int] = None, *,
            stream_telemetry: bool = False) -> DeviceSimResult:
        """Run R closed-loop revolutions; chainable (state persists).

        ``stream_telemetry=True`` dispatches one revolution at a time
        and syncs its telemetry (exactly one host sync per revolution —
        long 1000-sat studies stay observable); the default runs all R
        revolutions in one dispatch with a single sync at the end.
        """
        R = self.cfg.n_revolutions if n_revolutions is None else n_revolutions
        if R < 1:
            raise ValueError("need at least one revolution")
        self.state._require_live("device closed loop")
        state = dedupe_state_buffers(self.state)
        self.state.mark_consumed()
        energy, bidx = self.energy, self._batch_idx

        chunks = []
        r_chunk = 1 if stream_telemetry else R
        fn = self._compiled(r_chunk)
        for _ in range(R if stream_telemetry else 1):
            # the ring is donated with the carry: a fresh (empty) one
            # per dispatch, flushed whole at the telemetry sync below
            ring = ring_init(r_chunk * self.n_sats)
            t0 = time.perf_counter()
            state, energy, bidx, ring, telem = fn(state, energy, bidx,
                                                  ring, self.plan)
            # commit the carry per dispatch: an interrupted streaming
            # study keeps every completed revolution and stays chainable
            self.state, self.energy, self._batch_idx = state, energy, bidx
            self.metrics.inc("device_calls")
            chunks.append(jax.tree.map(np.asarray, telem))   # the ONE sync
            self.metrics.inc("host_syncs")
            self.metrics.histogram("dispatch_s").record(
                time.perf_counter() - t0)
            # ring flush rides the same sync boundary — no extra sync
            self.recorder.ingest(ring, t_offset=self._passes_done)
            self._passes_done += r_chunk * self.n_sats

        telem = jax.tree.map(lambda *xs: np.concatenate(xs), *chunks)
        return DeviceSimResult(
            action=telem.action, loss=telem.loss,
            battery_j=telem.battery_j, n_steps=telem.n_steps,
            plan=self.plan.to_host(),
            energy=EnergyState(*[np.asarray(a) for a in energy]),
            state=state)


def _smoke(argv=None) -> None:                     # pragma: no cover
    """``python -m repro.sim.device_sim --smoke``: a fast host-vs-device
    closed-loop parity check (8 sats × 2 revolutions) for CI."""
    import time

    from repro.core.constellation import (ConstellationConfig,
                                          ConstellationSim)
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.sim.data import DeviceImageryShards

    shards = DeviceImageryShards(img=32, batch=4)
    adapter = autoencoder_adapter(cut=5, img=32)
    # n_items scales the per-pass satellite drain to ~48 J so the 200 J
    # batteries hit the reserve-skip policy mid-run (max_steps_per_pass
    # caps the simulated compute; the allocation itself is per-item)
    budget = PassBudget(plane=OrbitalPlane(n_sats=4), n_items=4e6)

    def sim():
        return ConstellationSim(adapter, budget, shards, ConstellationConfig(
            n_passes=16, batch_size=4, battery_j=200.0, recharge_w=0.01,
            reserve_j=150.0, max_steps_per_pass=4))

    t0 = time.time()
    host = sim()
    host.run()
    hs = host.summary()
    t1 = time.time()
    dev = sim()
    dev.run(engine="device")
    ds = dev.summary()
    t2 = time.time()

    eng = dev.device_engine
    print(f"host   {t1 - t0:6.1f}s  {hs}")
    print(f"device {t2 - t1:6.1f}s  {ds}  "
          f"(traces={eng.traces}, syncs={eng.host_syncs})")
    actions = [(h.action, d.action) for h, d in zip(host.records,
                                                    dev.records)]
    assert all(h == d for h, d in actions), actions
    assert hs["skipped"] == ds["skipped"] and hs["skipped"] > 0, actions
    np.testing.assert_allclose(ds["loss_last"], hs["loss_last"],
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(ds["E_total_J"], hs["E_total_J"], rtol=1e-5)
    assert eng.traces == 1 and eng.host_syncs <= eng.cfg.n_revolutions
    print("device-sim smoke: OK (host == device closed loop)")


if __name__ == "__main__":                          # pragma: no cover
    import sys

    _smoke(sys.argv[1:])
