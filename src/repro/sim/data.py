"""Traceable data providers for the device-resident constellation engine.

The host scheduler consumes batches through an arbitrary Python callback
(``data_for_sat(sat_id, batch_idx) -> batch``); the device engine needs
the same interface as a *traced* function so batch generation happens
inside the jitted (revolution × ring-slot) scan — no host data transfers
between passes, and a 1000-sat × many-revolution run never materializes
its dataset.

:class:`DeviceImageryShards` is the ``jax.random`` twin of
:class:`repro.data.synthetic.ImageryShards`: per-satellite non-IID class
priors (Dirichlet tilt), gaussian-blob "imagery", everything derived
from ``fold_in(seed, sat, idx)`` so a batch is a pure function of its
indices.  Crucially the SAME object also serves as a host
``data_for_sat`` (``batch_at`` just calls the traced function eagerly),
which is what makes bit-identical host-vs-device closed-loop parity
tests possible: both engines train on exactly the same samples.

Providers advertise ``traceable = True``;
``ConstellationSim.run(engine="device")`` checks this flag before
delegating.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DeviceImageryShards:
    """Non-IID synthetic imagery as a traceable ``(sat, idx) -> batch``.

    Returns ``{"images": (batch, img, img, channels) f32,
    "labels": (batch,) i32}`` — the same contract as
    ``ImageryShards.batch_at``, usable by the autoencoder and ResNet
    split adapters.  ``__call__`` composes under ``jit``/``scan`` with
    traced ``sat``/``idx``; :meth:`batch_at` is the eager host view of
    the identical function.
    """

    img: int = 32
    channels: int = 3
    n_classes: int = 10
    batch: int = 4
    seed: int = 0

    traceable = True

    def __call__(self, sat, idx) -> Dict[str, jnp.ndarray]:
        sat = jnp.asarray(sat, jnp.uint32)
        idx = jnp.asarray(idx, jnp.uint32)
        kshard = jax.random.fold_in(jax.random.key(self.seed), sat)
        # per-satellite class-prior tilt => genuinely non-IID shards
        prior = jax.random.dirichlet(kshard,
                                     jnp.full((self.n_classes,), 0.5))
        klab, kimg = jax.random.split(jax.random.fold_in(kshard, idx))
        labels = jax.random.categorical(
            klab, jnp.log(prior + 1e-9), shape=(self.batch,)
        ).astype(jnp.int32)

        xs = jnp.linspace(-1.0, 1.0, self.img, dtype=jnp.float32)
        xx, yy = jnp.meshgrid(xs, xs)

        def one(key, lab):
            kc, kn = jax.random.split(key)
            cxy = jax.random.uniform(kc, (2,), minval=-0.5, maxval=0.5)
            sx = 0.15 + 0.04 * (lab % 5).astype(jnp.float32)
            blob = jnp.exp(-(((xx - cxy[0]) ** 2 + (yy - cxy[1]) ** 2)
                             / (2.0 * sx * sx)))
            phase = 2.0 * jnp.pi * lab.astype(jnp.float32) / self.n_classes
            chans = jnp.stack(
                [blob * jnp.cos(phase + c) for c in range(self.channels)],
                axis=-1)
            noise = jax.random.normal(
                kn, (self.img, self.img, self.channels))
            return (chans + 0.05 * noise).astype(jnp.float32)

        imgs = jax.vmap(one)(jax.random.split(kimg, self.batch), labels)
        return {"images": imgs, "labels": labels}

    def batch_at(self, sat: int, idx: int) -> Dict[str, jnp.ndarray]:
        """Host-eager view of the same pure function (for the host sim)."""
        return self(sat, idx)
