"""Per-satellite energy state as device arrays — the sim carry's battery.

The host :class:`~repro.core.constellation.ConstellationSim` keeps one
Python ``SatelliteState`` object per satellite; the device engine
(:mod:`repro.sim.device_sim`) keeps the same bookkeeping as a single
:class:`EnergyState` of ``(N,)`` arrays riding a ``lax.scan`` carry, so
battery drain, solar recharge and the reserve-skip policy execute on the
accelerator with zero host round-trips.

Array layout (all shape ``(N,)``, indexed by ring slot = satellite id):

* ``battery_j``       float32 — charge, clamped to ``[0, capacity]``;
* ``energy_spent_j``  float32 — cumulative eq. (11) energy of served
  passes (satellite + ground + ISL, matching the host sim's
  ``SatelliteState.energy_spent_j``);
* ``passes_served``   int32   — trained (incl. shed) pass count;
* ``passes_skipped``  int32   — reserve-policy skips.

Battery clamping policy lives in exactly ONE place —
:func:`repro.core.energy.clamp_battery` (re-exported here) — shared by
the host scheduler (scalar floats) and the device engine (arrays):
charge never exceeds the battery capacity and never goes below zero (a
pass whose allocation would overdraw the battery leaves it empty, not
negative; the energy *accounting* still records the full eq.-(11)
cost).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax.numpy as jnp

from repro.core.energy import clamp_battery


class EnergyState(NamedTuple):
    """Constellation-wide battery/serving counters as ``(N,)`` arrays."""

    battery_j: Any
    energy_spent_j: Any
    passes_served: Any
    passes_skipped: Any

    @property
    def n_sats(self) -> int:
        return self.battery_j.shape[0]


def init_energy_state(n_sats: int, battery_j: float) -> EnergyState:
    """Fresh fleet: full batteries, zero counters."""
    return EnergyState(
        battery_j=jnp.full((n_sats,), battery_j, jnp.float32),
        energy_spent_j=jnp.zeros((n_sats,), jnp.float32),
        passes_served=jnp.zeros((n_sats,), jnp.int32),
        passes_skipped=jnp.zeros((n_sats,), jnp.int32))


def recharge(state: EnergyState, energy_j, capacity_j,
             member_mask: Optional[Any] = None,
             sunlit: Optional[Any] = None) -> EnergyState:
    """Solar recharge between passes, clamped at capacity.

    ``member_mask`` (bool ``(N,)``) limits recharge to the satellites
    that were ring members during the pass; None recharges the whole
    (static) ring — the device engine's case.

    ``sunlit`` (bool scalar, traceable) gates the whole plane's solar
    input: during an eclipse window (False) no energy is harvested and
    batteries only drain — which is how the scenario engine couples
    orbital shadow into the reserve-skip policy.  None (the default)
    means permanent sunlight, the pre-scenario behavior.
    """
    gain = energy_j if sunlit is None else \
        jnp.where(sunlit, energy_j, 0.0)
    if member_mask is not None:
        gain = jnp.where(member_mask, gain, 0.0)
    return state._replace(
        battery_j=clamp_battery(state.battery_j + gain, capacity_j))


def apply_serve(state: EnergyState, sat, drain_j, capacity_j) -> EnergyState:
    """Account inference drain for satellite ``sat`` (all args traceable).

    Serving draws from the SAME battery training drains — that sharing
    is the whole point of the serve-fleet subsystem: a decode-heavy
    pass window leaves less charge for the next training pass, and the
    reserve-skip policy sees it.  ``drain_j`` (per-window decode energy:
    tokens x (E_proc + E_comm^down per token)) is subtracted from the
    battery AND recorded in ``energy_spent_j`` so the eq.-(11)
    accounting covers both workloads; the pass counters are untouched
    (serving is not a training pass — the serve engine keeps its own
    token/request telemetry).
    """
    d = jnp.asarray(drain_j, jnp.float32)
    battery = state.battery_j.at[sat].add(-d)
    return state._replace(
        battery_j=clamp_battery(battery, capacity_j),
        energy_spent_j=state.energy_spent_j.at[sat].add(d))


def apply_pass(state: EnergyState, sat, drain_j, e_total_j, capacity_j,
               trained, skipped: Optional[Any] = None) -> EnergyState:
    """Account one pass for satellite ``sat`` (all args traceable).

    ``trained`` (bool scalar) gates everything: a reserve-policy skip
    drains nothing and bumps ``passes_skipped`` instead.  ``drain_j`` is
    the satellite-side battery draw (E_proc^sat + E_comm^down + E_ISL —
    what the host sim subtracts), ``e_total_j`` the full eq.-(11) cost
    recorded in ``energy_spent_j``.

    ``skipped`` (bool scalar) defaults to ``~trained`` — the static
    ring's dichotomy.  The fleet engine passes it explicitly so a
    failure (or an empty-ring pass) bumps *neither* counter, matching
    the host oracle's "failed" records.
    """
    t = jnp.asarray(trained)
    s = ~t if skipped is None else jnp.asarray(skipped)
    f = t.astype(jnp.float32)
    battery = state.battery_j.at[sat].add(-drain_j * f)
    return EnergyState(
        battery_j=clamp_battery(battery, capacity_j),
        energy_spent_j=state.energy_spent_j.at[sat].add(e_total_j * f),
        passes_served=state.passes_served.at[sat].add(t.astype(jnp.int32)),
        passes_skipped=state.passes_skipped.at[sat].add(
            s.astype(jnp.int32)))
