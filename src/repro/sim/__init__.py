"""Device-resident constellation simulation.

Layering: the host :class:`~repro.core.constellation.ConstellationSim`
is the feature-complete *oracle* (elastic membership, random failures,
checkpoint handoffs, arbitrary Python data providers); this package is
the *engine* — the steady-state closed loop (orbit plan → energy policy
→ fused SL passes → recharge) compiled into one jitted scan for
constellation-scale studies.  ``ConstellationSim.run(engine="device")``
bridges the two.
"""
from repro.sim.data import DeviceImageryShards
from repro.sim.device_sim import (ACTION_NAMES, ACTION_SHED,
                                  ACTION_SKIPPED, ACTION_TRAINED,
                                  DeviceConstellationSim, DevicePassPlan,
                                  DeviceSimConfig, DeviceSimResult,
                                  plan_ring_passes)
from repro.sim.energy_state import (EnergyState, clamp_battery,
                                    init_energy_state)

__all__ = [
    "ACTION_NAMES", "ACTION_SHED", "ACTION_SKIPPED", "ACTION_TRAINED",
    "DeviceConstellationSim", "DeviceImageryShards", "DevicePassPlan",
    "DeviceSimConfig", "DeviceSimResult", "EnergyState", "clamp_battery",
    "init_energy_state", "plan_ring_passes",
]
