"""ISL comms subsystem: bandwidth-limited, compressed,
staleness-tolerant inter-plane exchange, planned as a problem-(13)
resource.

* :mod:`repro.isl.link` — contact windows, rates, capacities, transmit
  energy (modular arithmetic over the pass index; horizon-free).
* :mod:`repro.isl.codec` — delta-checkpoint compression with error
  feedback and exact wire-bit metering.
* :mod:`repro.isl.exchange` — the in-scan async gossip / sync codec
  steps, battery charging, and the NumPy host-prefix oracle.

``python -m repro.isl`` runs the subsystem smoke (contact schedule vs
oracle, sync parity, async exchange under compression).
"""
from repro.isl.codec import (CodecConfig, codec_label, delta_payload_bits,
                             encode_delta, residual_init)
from repro.isl.exchange import (EXCHANGE_MODES, ExchangeConfig,
                                ExchangeState, async_gossip_step,
                                exchange_events, exchange_init,
                                null_exchange_state, oracle_exchange,
                                staleness_weight, sync_exchange_step)
from repro.isl.link import ContactConfig

__all__ = [
    "CodecConfig", "ContactConfig", "EXCHANGE_MODES", "ExchangeConfig",
    "ExchangeState", "async_gossip_step", "codec_label",
    "delta_payload_bits", "encode_delta", "exchange_events",
    "exchange_init", "null_exchange_state", "oracle_exchange",
    "residual_init", "staleness_weight", "sync_exchange_step",
]
