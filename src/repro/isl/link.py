"""Contact-window model: time-varying inter-plane ISL topology.

Which plane pairs can exchange at pass ``k``, at what rate, for how
long — the geometry layer of the ISL comms subsystem.  Everything is
pure modular arithmetic over the pass index (the same discipline as
:class:`repro.fleet.scenarios.EclipseConfig.sunlit`), so one expression
serves three callers:

* the device scan (traced JAX scalars — no precomputed horizon, so
  chained runs keep exchanging on schedule forever);
* the NumPy host-prefix oracle (bit-exact replay of every contact
  decision);
* host-side planning (Python ints).

A *contact* opens every ``period`` passes (offset by ``phase``); the
``c``-th contact connects plane ``p`` to plane ``(p + offsets[c % len])
% P`` — cycling the offset tuple is what makes the topology
time-varying (contact 0 talks to the adjacent plane, contact 1 two
planes over, ...).  Each contact lasts ``window_s`` seconds at the
eq.-(10) fixed ISL rate from :class:`repro.core.linkbudget.ISLConfig`
(or the eq.-(8) Shannon rate at the configured cross-plane distance),
giving a hard per-contact bit capacity ``rate_bps * window_s`` — a
payload that doesn't fit simply does not transfer, which is what makes
the link bandwidth-*limited* rather than merely bandwidth-*priced*.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.linkbudget import ISLConfig, LinkConfig


@dataclasses.dataclass(frozen=True)
class ContactConfig:
    """The inter-plane contact schedule, as arithmetic on the pass index.

    ``open_at(k)`` — a contact window opens at pass ``k`` iff
    ``(k + phase) % period == 0``.  ``offset_at(k)`` — the plane-pair
    offset of that contact, cycling through ``offsets``; plane ``p``
    pushes to ``(p + offset) % P`` and receives from
    ``(p - offset) % P``, so every contact is a fixed-point-free
    permutation of the planes (for ``offset % P != 0``).

    ``window_s`` bounds the contact duration; with ``distance_m`` unset
    the link runs at the eq.-(10) fixed ISL rate, otherwise at the
    eq.-(8) Shannon rate for that cross-plane distance.
    """

    period: int = 1              # passes between contact-window opens
    phase: int = 0               # global schedule offset, in passes
    window_s: float = 1.0        # contact window duration, seconds
    offsets: Tuple[int, ...] = (1,)   # plane-pair offset cycle
    distance_m: Optional[float] = None  # cross-plane slant range (Shannon)

    def __post_init__(self):
        if self.period < 1:
            raise ValueError(f"contact period must be >= 1, "
                             f"got {self.period}")
        if self.window_s <= 0.0:
            raise ValueError(f"contact window must be > 0 s, "
                             f"got {self.window_s}")
        if not self.offsets:
            raise ValueError("need at least one plane-pair offset")

    # ---- schedule arithmetic (int / np / traced jnp alike) -----------
    def open_at(self, k):
        """Does a contact window open at pass ``k``?"""
        return (k + self.phase) % self.period == 0

    def contact_index(self, k):
        """Which contact (0-based) pass ``k``'s window is — meaningful
        only where :meth:`open_at` holds."""
        return (k + self.phase) // self.period

    def offset_at(self, k, xp=np):
        """The plane-pair offset of pass ``k``'s contact.  Pass
        ``xp=jnp`` inside a traced scan (the offset table is a static
        constant either way — only the index is dynamic)."""
        offs = xp.asarray(self.offsets, xp.int32)
        return offs[self.contact_index(k) % len(self.offsets)]

    def partner(self, plane, k, n_planes: int, xp=np):
        """The plane that ``plane`` pushes to at pass ``k``'s contact."""
        return (plane + self.offset_at(k, xp)) % n_planes

    def contacts_in(self, n_passes: int, start: int = 0) -> int:
        """How many contact windows open in ``[start, start+n_passes)``
        (host-side, for ring capacity sizing and amortization)."""
        return sum(1 for k in range(start, start + n_passes)
                   if (k + self.phase) % self.period == 0)

    # ---- physics ------------------------------------------------------
    def rate_bps(self, isl: ISLConfig,
                 link: Optional[LinkConfig] = None) -> float:
        """Contact data rate: eq. (10) fixed, or the eq.-(8) Shannon
        rate at ``distance_m`` when a :class:`LinkConfig` is given."""
        if self.distance_m is not None and link is not None:
            return float(link.rate_bps(isl.tx_power_w, self.distance_m))
        return float(isl.rate_bps)

    def capacity_bits(self, isl: ISLConfig,
                      link: Optional[LinkConfig] = None) -> float:
        """Hard per-contact bit budget: ``rate * window_s``."""
        return self.rate_bps(isl, link) * self.window_s

    def tx_energy_j(self, bits: float, isl: ISLConfig,
                    link: Optional[LinkConfig] = None) -> float:
        """Transmit energy of one ``bits``-sized push:
        ``isl_pw * bits / rate`` — the same pricing as the planner's
        eq.-(11) E_ISL term, drained from the pushing satellite."""
        return isl.tx_power_w * bits / self.rate_bps(isl, link)
