"""Asynchronous, metered inter-plane exchange inside the fleet scan.

The fleet's legacy inter-plane "ISL" was a free, instantaneous
full-float :func:`~repro.fleet.engine.average_planes` barrier at
revolution boundaries.  This module replaces it with a *modeled* link:

* **async gossip** (``mode="async"``, SFL-LEO style) — at every
  contact window (:class:`~repro.isl.link.ContactConfig`), each plane
  pushes its compressed checkpoint delta
  (:mod:`repro.isl.codec`) to the contacted neighbor and merges what it
  received with a staleness-discounted weight
  ``mix / (1 + lam * staleness)`` — no barrier, no revolution
  alignment, valid beyond any precomputed horizon;
* **sync codec** (``mode="sync"``) — the familiar revolution-boundary
  aggregation, but exchanging compressed delta reconstructions instead
  of free full-float checkpoints (with ``scheme="none"`` it reduces
  bit-for-bit to the legacy barrier — the parity default).

Either way the payload is *charged*: the push's transmit energy
``isl_pw * bits / rate`` drains the serving satellite's battery through
the SAME :class:`~repro.sim.energy_state.EnergyState` training and
serving share, a payload larger than the contact's ``rate * window_s``
capacity simply does not transfer, and the amortized per-pass bit
volume feeds the planner's problem-(13) ``d_isl_bits`` term
(:func:`repro.sim.device_sim.measure_and_plan` ``isl_extra_bits=``), so
choosing a codec changes the *planned* time/energy allocation, not just
a counter.

Everything the scan executes lives in :func:`async_gossip_step` /
:func:`sync_exchange_step` (jnp-pure — guarded by
``scripts/lint_scan_purity.py``); :func:`oracle_exchange` replays every
contact/merge decision (pass, partner offset, paying slot, wire bits,
drained joules, staleness, merge weight) in NumPy, bit-exactly, for the
precomputed horizon — the same host-prefix discipline as
:func:`repro.fleet.scenarios.oracle_actions`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import clamp_battery
from repro.obs.ring import EV_EXCHANGE, record as ring_record
from repro.isl.codec import (CodecConfig, delta_payload_bits, encode_delta,
                             residual_init)
from repro.isl.link import ContactConfig

EXCHANGE_MODES = ("sync", "async")


@dataclasses.dataclass(frozen=True)
class ExchangeConfig:
    """How the fleet's planes exchange checkpoints over the ISL.

    ``mode="sync"`` keeps the revolution-boundary aggregation cadence
    (``FleetConfig.avg_every``) but routes it through the codec and the
    meter; ``mode="async"`` replaces the barrier with contact-window
    gossip.  ``mix`` is the merge weight applied to a received delta at
    zero staleness; ``staleness_lam`` discounts it as
    ``mix / (1 + lam * s)`` where ``s`` is how many passes the sender's
    delta accumulated since its previous push (SFL-LEO style
    staleness tolerance).
    """

    mode: str = "async"
    codec: CodecConfig = CodecConfig()
    contact: ContactConfig = ContactConfig()
    mix: float = 0.5
    staleness_lam: float = 0.1

    def __post_init__(self):
        if self.mode not in EXCHANGE_MODES:
            raise ValueError(f"unknown exchange mode {self.mode!r}; "
                             f"expected one of {EXCHANGE_MODES}")
        if not 0.0 < self.mix <= 1.0:
            raise ValueError(f"mix must be in (0, 1], got {self.mix}")
        if self.staleness_lam < 0.0:
            raise ValueError(f"staleness_lam must be >= 0, "
                             f"got {self.staleness_lam}")

    def mean_contacts_per_pass(self, rev_len: int, avg_every: int) -> float:
        """Amortized exchange frequency — what scales the per-push wire
        bits into the planner's per-pass ``d_isl_bits`` surcharge."""
        if self.mode == "async":
            return 1.0 / float(self.contact.period)
        if avg_every <= 0:
            return 0.0
        return 1.0 / float(avg_every * rev_len)


class ExchangeState(NamedTuple):
    """The exchange's scan-carry state, ``(P, ...)``-leading.

    ``anchor`` is each plane's last *pushed* checkpoint (the reference
    its next delta is taken against); ``residual`` the error-feedback
    carry of the codec; ``last_k`` the pass of the last successful
    push (staleness = current pass − ``last_k``); ``bits`` / ``e_j`` /
    ``n_contacts`` the cumulative wire meter.
    """

    anchor: Any        # ((params_a, params_b))-shaped pytree
    residual: Any      # same tree, fp32 error-feedback carry
    last_k: Any        # (P,) int32
    bits: Any          # (P,) float32 cumulative pushed wire bits
    e_j: Any           # (P,) float32 cumulative ISL transmit joules
    n_contacts: Any    # (P,) int32 successful pushes


def exchange_init(params_tree, n_planes: int) -> ExchangeState:
    """Fresh exchange state for fleet-shaped (``(P, ...)``-leading)
    ``params_tree = (params_a, params_b)``; anchors start at the
    current checkpoint (first delta = training since run start)."""
    return ExchangeState(
        anchor=jax.tree.map(jnp.array, params_tree),
        residual=residual_init(params_tree),
        last_k=jnp.zeros((n_planes,), jnp.int32),
        bits=jnp.zeros((n_planes,), jnp.float32),
        e_j=jnp.zeros((n_planes,), jnp.float32),
        n_contacts=jnp.zeros((n_planes,), jnp.int32))


def null_exchange_state(n_planes: int) -> ExchangeState:
    """The disabled-exchange carry (empty trees, zero meters) — keeps
    the scan signature uniform whether or not an exchange is wired."""
    return ExchangeState(
        anchor=(), residual=(),
        last_k=jnp.zeros((n_planes,), jnp.int32),
        bits=jnp.zeros((n_planes,), jnp.float32),
        e_j=jnp.zeros((n_planes,), jnp.float32),
        n_contacts=jnp.zeros((n_planes,), jnp.int32))


def staleness_weight(stale, mix: float, lam: float, xp=np):
    """THE merge-weight rule ``mix / (1 + lam * s)`` — float32 end to
    end, shared verbatim (via ``xp=jnp``) by the device scan and the
    NumPy oracle so recorded weights replay bit-exactly."""
    s = xp.asarray(stale, xp.float32)
    return xp.float32(mix) / (xp.float32(1.0) + xp.float32(lam) * s)


def _encode_planes(codec: CodecConfig, params, anchor, residual):
    """Delta-encode every plane (vmap over the leading plane axis)."""
    return jax.vmap(
        lambda p, a, r: encode_delta(p, a, r, codec))(
            params, anchor, residual)


def _tree_where(do, new, old):
    return jax.tree.map(lambda a, b: jnp.where(do, a, b), new, old)


def _charge(energy, slot, drain, cap):
    """Drain ``drain[p]`` joules from plane ``p``'s serving slot —
    subtract-then-clamp on the whole (P, M) battery (untouched entries
    subtract exactly 0.0), mirrored scalar-wise by the oracle."""
    M = energy.battery_j.shape[-1]
    hit = (jnp.arange(M, dtype=jnp.int32)[None, :]
           == jnp.clip(slot, 0, M - 1)[:, None])
    d2 = jnp.where(hit, drain[:, None], jnp.float32(0.0))
    return energy._replace(
        battery_j=clamp_battery(energy.battery_j - d2, cap),
        energy_spent_j=energy.energy_spent_j + d2)


def async_gossip_step(exch: ExchangeConfig, state, ex: ExchangeState,
                      energy, ring, k, sat, action, *, wire_bits: float,
                      e_push_j: float, battery_cap: float, n_planes: int,
                      action_failed: int):
    """One contact-window attempt at pass ``k`` — runs INSIDE the
    fleet's jitted scan (jnp-pure; lint-guarded), every pass.

    When the window is shut (``open_at(k)`` False) the step is a traced
    no-op: the same program, nothing written.  When open: every plane
    simultaneously (1) snapshots + delta-encodes its checkpoint against
    its anchor, (2) pushes to plane ``(p + offset) % P`` (a gather
    along the plane axis — a collective permute under the fleet mesh),
    (3) merges the received delta with the staleness-discounted weight,
    (4) pays the transmit energy from its serving slot's battery (a
    plane whose pass FAILED has no transmitter up — it still merges
    received state, but drains nothing), and (5) records one
    ``EV_EXCHANGE`` event per plane.
    """
    P = n_planes
    cc = exch.contact
    do = cc.open_at(k)
    off = cc.offset_at(k, xp=jnp)
    params = (state.params_a, state.params_b)
    kept, resid = _encode_planes(exch.codec, params, ex.anchor,
                                 ex.residual)
    stale = (k - ex.last_k).astype(jnp.float32)              # (P,)
    src = (jnp.arange(P, dtype=jnp.int32) - off) % P         # q <- (q-off)
    recv = jax.tree.map(lambda x: jnp.take(x, src, axis=0), kept)
    stale_r = jnp.take(stale, src)
    w = staleness_weight(stale_r, exch.mix, exch.staleness_lam, xp=jnp)

    def merge(x, d):
        wd = w.reshape((P,) + (1,) * (d.ndim - 1))
        return jnp.where(do, (x.astype(jnp.float32)
                              + wd * d).astype(x.dtype), x)

    state = state.replace(
        params_a=jax.tree.map(merge, state.params_a, recv[0]),
        params_b=jax.tree.map(merge, state.params_b, recv[1]))

    pays = do & (action != action_failed)                    # (P,)
    drain = jnp.where(pays, jnp.float32(e_push_j), jnp.float32(0.0))
    energy = _charge(energy, sat, drain, jnp.float32(battery_cap))
    ex = ExchangeState(
        anchor=_tree_where(do, params, ex.anchor),
        residual=_tree_where(do, resid, ex.residual),
        last_k=jnp.where(do, k, ex.last_k),
        bits=ex.bits + jnp.where(do, jnp.float32(wire_bits),
                                 jnp.float32(0.0)),
        e_j=ex.e_j + drain,
        n_contacts=ex.n_contacts
        + jnp.where(do, 1, 0).astype(jnp.int32))
    slot_rec = jnp.where(pays, sat, -1).astype(jnp.int32)
    ring = jax.vmap(lambda r, sl, dr, st, wq: ring_record(
        r, EV_EXCHANGE, k, sl,
        (jnp.float32(0.0), jnp.float32(wire_bits), dr, st, wq),
        mask=do))(ring, slot_rec, drain, stale_r, w)
    return state, ex, energy, ring


def sync_exchange_step(exch: ExchangeConfig, aggregate_mode: str, state,
                       ex: ExchangeState, energy, ring, k, sat, action,
                       do, *, wire_bits: float, e_push_j: float,
                       battery_cap: float, n_planes: int,
                       action_failed: int):
    """The revolution-boundary exchange, codec'd and metered — runs
    INSIDE the fleet's jitted scan (jnp-pure; lint-guarded).

    Optimizer state and any non-param float leaves aggregate exactly
    like the legacy barrier (:func:`~repro.fleet.scenarios
    .aggregate_planes`); the params travel as compressed delta
    reconstructions ``anchor + delta_hat``.  With ``scheme="none"`` the
    reconstruction IS the live checkpoint, so the merged state is
    bit-for-bit the legacy barrier's — the parity default — while the
    meter still charges the full-float wire bits.
    """
    from repro.fleet.scenarios import aggregate_planes

    P = n_planes
    params = (state.params_a, state.params_b)
    stale = (k - ex.last_k).astype(jnp.float32)
    if exch.codec.scheme == "none":
        # exact delta -> reconstruction == live params: take the legacy
        # aggregation verbatim (bit-exact parity incl. rounding)
        resid = ex.residual
        new_state = aggregate_planes(state, aggregate_mode)
    else:
        kept, resid = _encode_planes(exch.codec, params, ex.anchor,
                                     ex.residual)
        recon = jax.tree.map(lambda a, d: a + d, ex.anchor, kept)
        merged = aggregate_planes(recon, aggregate_mode)
        new_state = aggregate_planes(state, aggregate_mode).replace(
            params_a=merged[0], params_b=merged[1])
    state = _tree_where(do, new_state, state)

    pays = do & (action != action_failed)
    drain = jnp.where(pays, jnp.float32(e_push_j), jnp.float32(0.0))
    energy = _charge(energy, sat, drain, jnp.float32(battery_cap))
    new_anchor = (new_state.params_a, new_state.params_b)
    ex = ExchangeState(
        anchor=_tree_where(do, new_anchor, ex.anchor),
        residual=_tree_where(do, resid, ex.residual),
        last_k=jnp.where(do, k, ex.last_k),
        bits=ex.bits + jnp.where(do, jnp.float32(wire_bits),
                                 jnp.float32(0.0)),
        e_j=ex.e_j + drain,
        n_contacts=ex.n_contacts
        + jnp.where(do, 1, 0).astype(jnp.int32))
    w = jnp.full((P,), jnp.float32(1.0 / P))
    slot_rec = jnp.where(pays, sat, -1).astype(jnp.int32)
    ring = jax.vmap(lambda r, sl, dr, st, wq: ring_record(
        r, EV_EXCHANGE, k, sl,
        (jnp.float32(1.0), jnp.float32(wire_bits), dr, st, wq),
        mask=do))(ring, slot_rec, drain, stale, w)
    return state, ex, energy, ring


# --------------------------------------------------------------------------
# Host-prefix oracle (NumPy replay — the style of scenarios.oracle_actions)
# --------------------------------------------------------------------------

def oracle_exchange(fleet, n_passes: Optional[int] = None
                    ) -> Dict[str, np.ndarray]:
    """Replay every contact/merge decision of ``fleet``'s exchange for
    the precomputed horizon, bit-exactly, before the fleet runs.

    Returns one row per exchange event: ``t`` (pass index as recorded
    in the ring), ``offset`` (plane-pair offset; 0 for sync),
    ``aggregate`` (1.0 sync / 0.0 async) and per-plane ``slot`` (the
    paying transmitter, −1 when that plane's pass FAILED), ``bits``,
    ``e_isl_j`` (actual drained joules), ``staleness`` and ``weight`` —
    exactly the ``EV_EXCHANGE`` payload columns the device ring must
    contain, in order.  Covers both modes; an exchange-free fleet (or a
    payload that exceeds the contact capacity) yields zero rows.
    """
    from repro.fleet.scenarios import oracle_actions
    from repro.sim.device_sim import ACTION_FAILED

    empty = {"t": np.zeros((0,), np.int32),
             "offset": np.zeros((0,), np.int32),
             "aggregate": np.zeros((0,), np.float32),
             "slot": np.zeros((0, fleet.n_planes), np.int32),
             "bits": np.zeros((0, fleet.n_planes), np.float32),
             "e_isl_j": np.zeros((0, fleet.n_planes), np.float32),
             "staleness": np.zeros((0, fleet.n_planes), np.float32),
             "weight": np.zeros((0, fleet.n_planes), np.float32)}
    exch = fleet.exchange
    if exch is None or not fleet._ex_on:
        return empty
    actions, slots = oracle_actions(fleet, return_slots=True)
    P = fleet.n_planes
    K = actions.shape[1] if n_passes is None else min(int(n_passes),
                                                      actions.shape[1])
    bits_c = np.float32(fleet._ex_bits)
    e_c = np.float32(fleet._ex_energy_j)
    cc, L, avg_every = exch.contact, fleet.rev_len, fleet.cfg.avg_every
    last_k = np.zeros((P,), np.int64)
    rows = []

    def row(t, off, agg, stale_r, weight, pay_k):
        pays = actions[:, pay_k] != ACTION_FAILED
        rows.append((t, off, agg,
                     np.where(pays, slots[:, pay_k], -1).astype(np.int32),
                     np.full((P,), bits_c, np.float32),
                     np.where(pays, e_c, np.float32(0.0)),
                     stale_r.astype(np.float32),
                     weight.astype(np.float32)))

    for k in range(K):
        if exch.mode == "async":
            if cc.open_at(k):
                off = int(cc.offset_at(k))
                src = (np.arange(P) - off) % P
                stale_r = (k - last_k)[src]
                w = staleness_weight(stale_r, exch.mix,
                                     exch.staleness_lam, xp=np)
                row(k, off, 0.0, stale_r, w, k)
                last_k[:] = k
        elif avg_every > 0:
            kb = k + 1           # the boundary index rev_body records
            if kb % L == 0 and (kb // L) % avg_every == 0:
                stale = kb - last_k
                w = np.full((P,), np.float32(1.0 / P))
                row(kb, 0, 1.0, stale, w, k)
                last_k[:] = kb
    if not rows:
        return empty
    cols = list(zip(*rows))
    return {"t": np.asarray(cols[0], np.int32),
            "offset": np.asarray(cols[1], np.int32),
            "aggregate": np.asarray(cols[2], np.float32),
            "slot": np.stack(cols[3]),
            "bits": np.stack(cols[4]),
            "e_isl_j": np.stack(cols[5]),
            "staleness": np.stack(cols[6]),
            "weight": np.stack(cols[7])}


def exchange_events(recorder) -> Dict[str, np.ndarray]:
    """The device's ``EV_EXCHANGE`` rows from a
    :class:`~repro.obs.ring.FlightRecorder`, reshaped to the oracle's
    layout (one row per event time, per-plane columns) for direct
    ``np.testing`` comparison."""
    from repro.obs.ring import EXCHANGE_FIELDS

    ev = recorder.events()
    m = ev["kind"] == EV_EXCHANGE
    t, plane = ev["t"][m], ev["plane"][m]
    slot, pay = ev["slot"][m], ev["payload"][m]
    times = np.unique(t)
    P = int(plane.max()) + 1 if plane.size else 0
    out = {"t": times.astype(np.int32),
           "aggregate": np.zeros((times.size,), np.float32),
           "slot": np.full((times.size, P), -1, np.int32),
           "bits": np.zeros((times.size, P), np.float32),
           "e_isl_j": np.zeros((times.size, P), np.float32),
           "staleness": np.zeros((times.size, P), np.float32),
           "weight": np.zeros((times.size, P), np.float32)}
    col = {f: EXCHANGE_FIELDS.index(f) for f in EXCHANGE_FIELDS}
    for i, tt in enumerate(times):
        sel = t == tt
        out["aggregate"][i] = pay[sel][0, col["aggregate"]]
        for p, s, prow in zip(plane[sel], slot[sel], pay[sel]):
            out["slot"][i, p] = s
            out["bits"][i, p] = prow[col["bits"]]
            out["e_isl_j"][i, p] = prow[col["e_isl_j"]]
            out["staleness"][i, p] = prow[col["staleness"]]
            out["weight"][i, p] = prow[col["weight"]]
    return out
