"""``python -m repro.isl``: the ISL comms subsystem smoke, for CI.

Forces a 2-device CPU topology (when none is configured) BEFORE jax
initializes — the exchange's cross-plane gather and all-reduce must run
over a real multi-device mesh.  Asserts, on a small 2-plane fleet:

1. codec bit metering is monotone (none > int8 > top-k 10% > top-k 1%);
2. a ``mode="sync"``, ``scheme="none"`` exchange reproduces the legacy
   free barrier bit-for-bit (actions + final checkpoints) while
   metering its wire bits — the parity default;
3. an async compressed (top-k) gossip exchange matches its NumPy
   host-prefix oracles bit-exactly: every action, and every contact's
   ``{t, slot, bits, e_isl_j, staleness, weight}`` row;
4. losses stay finite under gossip, the battery meter moved, and the
   ≤-1-host-sync-per-revolution contract holds throughout.

Env knobs (small-machine CI): ``REPRO_ISL_SMOKE_SATS`` (default 4),
``REPRO_ISL_SMOKE_PLANES`` (default 2), ``REPRO_ISL_SMOKE_REVS``
(default 2).
"""
import os

if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2").strip()


def _smoke(n_sats: int = 4, n_planes: int = 2,
           n_revolutions: int = 2) -> None:       # pragma: no cover
    import time

    import jax
    import numpy as np

    from repro.core.energy import PassBudget
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.fleet.engine import FleetConfig, FleetEngine
    from repro.fleet.scenarios import oracle_actions
    from repro.isl import (CodecConfig, ContactConfig, ExchangeConfig,
                           codec_label, delta_payload_bits,
                           exchange_events, oracle_exchange)
    from repro.obs.timeline import timeline_summary
    from repro.sim.data import DeviceImageryShards

    shards = DeviceImageryShards(img=32, batch=4)
    adapter = autoencoder_adapter(cut=5, img=32)
    budget = PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=4e6)
    base = dict(n_planes=n_planes, n_revolutions=n_revolutions,
                max_steps_per_pass=2, seed=0)
    t0 = time.time()

    # 1 ---- codec metering is monotone ---------------------------------
    pa, pb = adapter.init(jax.random.key(0))
    codecs = [CodecConfig("none"), CodecConfig("int8"),
              CodecConfig("topk", topk_ratio=0.10),
              CodecConfig("topk", topk_ratio=0.01)]
    bits = [delta_payload_bits((pa, pb), c) for c in codecs]
    labels = [codec_label(c) for c in codecs]
    assert bits == sorted(bits, reverse=True) and bits[-1] > 0, \
        dict(zip(labels, bits))
    print("isl: payload bits " +
          " > ".join(f"{l}={b:.3g}" for l, b in zip(labels, bits)))

    # 2 ---- sync scheme="none" == the legacy free barrier --------------
    legacy = FleetEngine(adapter, budget, shards,
                         FleetConfig(avg_every=1, **base))
    res_l = legacy.run()
    syncf = FleetEngine(adapter, budget, shards, FleetConfig(
        avg_every=1, exchange=ExchangeConfig(mode="sync"), **base))
    expect_sync = oracle_exchange(syncf)
    res_s = syncf.run()
    np.testing.assert_array_equal(res_l.action, res_s.action)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        (res_l.state.params_a, res_l.state.params_b),
        (res_s.state.params_a, res_s.state.params_b))
    got_sync = exchange_events(syncf.recorder)
    assert got_sync["t"].size == expect_sync["t"].size > 0
    for col in ("t", "slot", "bits", "e_isl_j", "staleness", "weight"):
        np.testing.assert_array_equal(got_sync[col], expect_sync[col], col)
    s = res_s.summary()
    assert s["ISL_exchange_bits"] > 0 and s["ISL_exchange_J"] > 0, s
    assert res_l.summary()["ISL_exchange_bits"] == 0.0
    assert syncf.traces == 1 and syncf.host_syncs <= n_revolutions
    print(f"isl: sync/none == legacy barrier (checkpoints bit-exact), "
          f"metered {s['ISL_exchange_bits']:.3g} bits / "
          f"{s['ISL_exchange_J']:.2e} J")

    # 3 ---- async compressed gossip vs the host-prefix oracles ---------
    af = FleetEngine(adapter, budget, shards, FleetConfig(
        avg_every=0, exchange=ExchangeConfig(
            mode="async", codec=CodecConfig("topk", topk_ratio=0.01),
            contact=ContactConfig(period=2, offsets=(1,)),
            mix=0.5, staleness_lam=0.1), **base))
    expect_act = oracle_actions(af)
    expect_ex = oracle_exchange(af)
    res_a = af.run(stream_telemetry=True)
    np.testing.assert_array_equal(res_a.action, expect_act)
    got = exchange_events(af.recorder)
    assert got["t"].size == expect_ex["t"].size > 0
    for col in ("t", "slot", "bits", "e_isl_j", "staleness", "weight"):
        np.testing.assert_array_equal(got[col], expect_ex[col], col)
    finite = res_a.loss[np.isfinite(res_a.loss)]
    assert finite.size > 0 and np.isfinite(finite).all()
    assert res_a.isl_bits.sum() > 0 and res_a.isl_e_j.sum() > 0
    assert int(res_a.isl_contacts.sum()) == expect_ex["t"].size * n_planes
    assert af.traces == 1 and af.host_syncs <= n_revolutions
    print(f"isl: async top-k 1% gossip: {expect_ex['t'].size} contacts, "
          f"action + exchange oracle parity bit-exact, "
          f"{float(res_a.isl_bits.sum()):.3g} bits / "
          f"{float(res_a.isl_e_j.sum()):.2e} J over ISL")
    print("  " + timeline_summary(af.recorder.events())
          .replace("\n", "\n  "))
    print(f"isl: smoke OK ({time.time() - t0:.1f}s, "
          f"{len(jax.devices())} device(s))")


if __name__ == "__main__":                          # pragma: no cover
    _smoke(n_sats=int(os.environ.get("REPRO_ISL_SMOKE_SATS", "4")),
           n_planes=int(os.environ.get("REPRO_ISL_SMOKE_PLANES", "2")),
           n_revolutions=int(os.environ.get("REPRO_ISL_SMOKE_REVS", "2")))
