"""Delta-checkpoint codec: what actually crosses the ISL.

A plane never ships its full checkpoint — it ships the *delta* since
the last checkpoint it pushed (its ``anchor``), compressed by one of
the :mod:`repro.train.compression` schemes with error feedback carried
in the scan state: compression error accumulates in a residual and
rides into the next push instead of being lost, so an async gossip
exchange stays unbiased in the long run (Stich et al.).

The codec also *meters* every payload exactly — via the same
``payload_bits`` accounting the compressors themselves emit (top-k:
``k * (value_bits + index_bits)``; int8: ``numel * 8 +
scale_rows * 32``; none: dense fp32) — so the bits the fleet charges
against batteries and the problem-(13) D_ISL term are the wire truth,
not an estimate.

Device API (traceable; the fleet engine vmaps :func:`encode_delta`
over its plane axis):

* :func:`encode_delta` — ``(params, anchor, residual) -> (delta_hat,
  new_residual)``: accumulate ``params - anchor`` plus the carried
  residual, compress, return the dequantized/sparsified delta the
  receiver will apply and the residual to carry.

Host API: :func:`delta_payload_bits` (shape-only, exact),
:func:`codec_label` for benchmark rows.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.train.compression import (ErrorFeedbackState, SCHEMES, compress,
                                     payload_bits)


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """How a checkpoint delta is compressed for the wire.

    ``scheme`` — ``"none"`` (dense fp32), ``"topk"`` (top-``ratio``
    magnitude sparsification + positions) or ``"int8"`` (symmetric
    per-row int8 + fp32 scales), all with error feedback.
    """

    scheme: str = "none"
    topk_ratio: float = 0.01
    value_bits: int = 32

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown codec scheme {self.scheme!r}; "
                             f"expected one of {SCHEMES}")
        if not 0.0 < self.topk_ratio <= 1.0:
            raise ValueError(f"topk_ratio must be in (0, 1], "
                             f"got {self.topk_ratio}")


def codec_label(codec: CodecConfig) -> str:
    """Short human tag for benchmark rows (``topk1pc`` / ``int8`` /
    ``none``)."""
    if codec.scheme == "topk":
        pct = codec.topk_ratio * 100.0
        tag = f"{pct:g}".replace(".", "p")
        return f"topk{tag}pc"
    return codec.scheme


def delta_payload_bits(params_tree, codec: CodecConfig) -> float:
    """Exact wire bits of one compressed delta push of ``params_tree``
    (shape-only: arrays or ``ShapeDtypeStruct``s).  Static per codec —
    shapes don't change mid-scan — which is what lets the planner price
    the exchange into problem (13) before the run starts while the
    in-scan meter records the same number per contact."""
    return float(payload_bits(params_tree, codec.scheme,
                              topk_ratio=codec.topk_ratio,
                              value_bits=codec.value_bits))


def residual_init(params_tree):
    """Zero error-feedback residual shaped like ``params_tree``."""
    return jax.tree.map(lambda p: jnp.zeros(jnp.shape(p), jnp.float32),
                        params_tree)


def encode_delta(params_tree, anchor_tree, residual_tree,
                 codec: CodecConfig) -> Tuple[Any, Any]:
    """One delta push: ``(delta_hat, new_residual)``.

    ``delta_hat`` is the receiver-side reconstruction (dense; the
    sparsity/quantization already applied), ``new_residual`` the error
    to carry.  Traceable and jnp-pure — it runs inside the fleet's
    jitted scan, vmapped over planes.  For ``scheme="none"`` the delta
    is exact and the residual passes through untouched (all-zero).
    """
    delta = jax.tree.map(lambda p, a: p.astype(jnp.float32) - a,
                         params_tree, anchor_tree)
    kept, ef, _ = compress(delta, ErrorFeedbackState(residual_tree),
                           scheme=codec.scheme,
                           topk_ratio=codec.topk_ratio)
    return kept, ef.residual
