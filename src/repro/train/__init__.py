"""Training substrate: optimizers (from scratch), ZeRO sharding,
gradient compression, and the pjit train-step builder."""
