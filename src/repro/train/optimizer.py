"""Optimizers from scratch (no optax): AdamW and SGD-momentum.

Two layers:

* raw functions (``sgd_init``/``sgd_update``, ``adamw_init``/
  ``adamw_update``) — the arithmetic, kept exactly as before;
* the :class:`Optimizer` protocol — a uniform ``(init, update)`` pair
  the SL pass engine and the constellation scheduler program against,
  so SGD and AdamW (with its warmup+cosine lr schedule) are
  interchangeable through ``ConstellationConfig.optimizer``.  Both
  states are NamedTuples of pytrees, so either rides a ``lax.scan``
  carry (the fused pass engine) unchanged.

Optimizer state mirrors the parameter pytree; ``zero_specs`` produces
PartitionSpecs that additionally shard every state tensor (and the fp32
master copy) along the ZeRO axis (rules.zero, default "data") on its
largest replicated dimension — ZeRO-1/2 style optimizer-state sharding
on top of whatever tensor-parallel sharding the parameter already has.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.param import ParamSpec, ShardingRules, is_spec


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay (standard LM schedule)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                      state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu), {"grad_norm": gn, "lr": lr}


# --------------------------------------------------------------------------
# SGD with momentum (used by the SL constellation driver; the paper's
# "online learning" loop uses plain first-order updates).
# --------------------------------------------------------------------------

class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


def sgd_init(params) -> SGDState:
    return SGDState(jnp.zeros((), jnp.int32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))


def sgd_update(grads, state: SGDState, params, *, lr=1e-2, beta=0.9,
               grad_clip=1.0):
    grads, gn = clip_by_global_norm(grads, grad_clip)
    mom = jax.tree.map(lambda m, g: beta * m + g, state.momentum, grads)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, mom)
    return new_params, SGDState(state.step + 1, mom), {"grad_norm": gn}


# --------------------------------------------------------------------------
# The Optimizer protocol: a uniform (init, update) pair.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    """Pluggable optimizer: ``init(params) -> state`` plus
    ``update(grads, state, params) -> (new_params, new_state, metrics)``.

    All hyperparameters (lr, schedules, clipping) are closed over at
    construction, so the pair is scan-carry compatible: the state is a
    pytree and ``update`` is a pure traced function of (grads, state,
    params) only.
    """

    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any, Dict[str, Any]]]


def sgd(lr: float = 1e-2, beta: float = 0.9,
        grad_clip: float = 1.0) -> Optimizer:
    """SGD-momentum as an :class:`Optimizer` (the paper's online loop)."""

    def update(grads, state, params):
        return sgd_update(grads, state, params, lr=lr, beta=beta,
                          grad_clip=grad_clip)

    return Optimizer("sgd", sgd_init, update)


def adamw(cfg: Optional[AdamWConfig] = None, **overrides) -> Optimizer:
    """AdamW (incl. the warmup+cosine lr schedule) as an Optimizer.

    ``overrides`` patch individual :class:`AdamWConfig` fields, e.g.
    ``adamw(lr=3e-4, warmup_steps=50)``.
    """
    cfg = dataclasses.replace(cfg or AdamWConfig(), **overrides)

    def update(grads, state, params):
        return adamw_update(cfg, grads, state, params)

    return Optimizer("adamw", adamw_init, update)


_OPTIMIZER_FACTORIES: Dict[str, Callable[..., Optimizer]] = {
    "sgd": sgd,
    "adamw": adamw,
}


def resolve_optimizer(spec: Union[str, Optimizer, None],
                      **defaults) -> Optimizer:
    """Turn ``"sgd"`` / ``"adamw"`` / an Optimizer instance into one.

    ``defaults`` (e.g. ``lr=...``, ``grad_clip=...``) feed the factory
    when ``spec`` is a name; an explicit Optimizer instance wins as-is.
    """
    if spec is None:
        spec = "sgd"
    if isinstance(spec, Optimizer):
        return spec
    try:
        factory = _OPTIMIZER_FACTORIES[spec]
    except KeyError:
        raise ValueError(
            f"unknown optimizer {spec!r}; expected one of "
            f"{sorted(_OPTIMIZER_FACTORIES)} or an Optimizer instance")
    return factory(**defaults)


# --------------------------------------------------------------------------
# ZeRO sharding of optimizer state.
# --------------------------------------------------------------------------

def zero_axis_for(spec: ParamSpec, rules: ShardingRules, mesh) -> P:
    """Shard the optimizer-state copy of ``spec`` along rules.zero too.

    The ZeRO axis is attached to the largest dim that the parameter
    sharding leaves unpartitioned and that the axis divides; if none
    qualifies the state stays like the param (replicated state for tiny
    norms/biases is the right call — partitioning them costs more in
    collective latency than it saves).
    """
    base = rules.resolve(spec.axes, mesh, spec.shape)
    zaxis = rules.zero
    if isinstance(zaxis, str):
        zaxis = (zaxis,)
    zaxis = tuple(a for a in (zaxis or ()) if a in mesh.axis_names)
    if not zaxis:
        return base
    taken = set()
    for e in base:
        if e is None:
            continue
        taken.update(e if isinstance(e, tuple) else (e,))
    if set(zaxis) & taken:
        return base
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    extent = 1
    for a in zaxis:
        extent *= sizes[a]
    order = sorted(range(len(spec.shape)), key=lambda i: -spec.shape[i])
    for i in order:
        if base[i] is None and spec.shape[i] % extent == 0 and spec.shape[i] >= extent:
            parts = list(base)
            parts[i] = zaxis[0] if len(zaxis) == 1 else zaxis
            return P(*parts)
    return base


def zero_partition_specs(abstract_tree, rules: ShardingRules, mesh):
    """PartitionSpec tree for optimizer state (mu/nu/master fp32)."""
    return jax.tree.map(lambda s: zero_axis_for(s, rules, mesh),
                        abstract_tree, is_leaf=is_spec)


def adamw_state_specs(abstract_tree, rules: ShardingRules, mesh):
    zspec = zero_partition_specs(abstract_tree, rules, mesh)
    return AdamWState(step=P(), mu=zspec,
                      nu=jax.tree.map(lambda x: x, zspec))
