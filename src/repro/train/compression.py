"""Gradient/delta compression with exact payload-bit metering.

Two schemes, both with error feedback so compression error accumulates
locally instead of biasing the update (Stich et al., memory-compensated
SGD):

  * top-k sparsification — keep the k largest-|g| entries per tensor
    (k = ratio * numel); the residual feeds back into the next step.
  * int8 rows — the same symmetric per-row quantizer the SL boundary
    uses (kernels/split_quant), applied to gradients.

In the paper's constellation these compress the *ISL checkpoint-delta
payload* (:mod:`repro.isl.codec` wires them into the fleet's
inter-plane exchange, metered against the eq. (11)/(13) ISL terms); in
the scaled-out LM track they model all-reduce volume reduction.

Every scheme meters its wire payload exactly — not an estimate:

  * top-k:  ``k * (value_bits + index_bits)`` per tensor, where
    ``index_bits = ceil(log2(numel))`` (the position of each survivor);
  * int8:   ``numel * 8 + scale_rows * 32`` per tensor (one fp32 scale
    per quantized row);
  * none:   ``numel * value_bits`` (the dense fp32 tensor).

:func:`payload_bits` computes these from shapes alone (works on arrays
and ``ShapeDtypeStruct``s), and both compressors surface the same
number as ``compress_payload_bits`` in their metrics dict, so every
layer that meters bits — codec, planner, telemetry — agrees to the bit.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

#: wire width of one kept value (fp32 mantissa payload of both schemes)
VALUE_BITS = 32
#: wire width of one int8 row scale (fp32)
SCALE_BITS = 32

SCHEMES = ("none", "topk", "int8")


class ErrorFeedbackState(NamedTuple):
    residual: Any            # same pytree as grads, fp32


def ef_init(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


# ------------------------------------------------------- bit accounting

def _numel(leaf) -> int:
    size = 1
    for d in jnp.shape(leaf):
        size *= int(d)
    return size


def index_bits(numel: int) -> int:
    """Bits to address one entry of a ``numel``-element tensor."""
    return max(1, math.ceil(math.log2(numel))) if numel > 1 else 1


def topk_payload_bits(tree, ratio: float, value_bits: int = VALUE_BITS
                      ) -> int:
    """Exact top-k wire bits: ``k * (value_bits + index_bits)`` per
    tensor, summed over the pytree (shape-only — no data needed)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = _numel(leaf)
        k = max(1, int(n * ratio))
        total += k * (value_bits + index_bits(n))
    return total


def int8_payload_bits(tree, scale_bits: int = SCALE_BITS) -> int:
    """Exact int8-rows wire bits: ``numel * 8 + scale_rows * 32`` per
    tensor (one fp32 scale per quantized row; tensors of rank < 2
    quantize as a single row, matching :func:`_int8_one`)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = jnp.shape(leaf)
        n = _numel(leaf)
        rows = (n // int(shape[-1])) if (len(shape) >= 2 and n) else 1
        total += n * 8 + rows * scale_bits
    return total


def payload_bits(tree, scheme: str = "none", *, topk_ratio: float = 0.01,
                 value_bits: int = VALUE_BITS) -> int:
    """Exact wire bits of one compressed pytree under ``scheme``."""
    if scheme == "none":
        return sum(_numel(leaf) * value_bits
                   for leaf in jax.tree.leaves(tree))
    if scheme == "topk":
        return topk_payload_bits(tree, topk_ratio, value_bits)
    if scheme == "int8":
        return int8_payload_bits(tree)
    raise ValueError(scheme)


def _norms(kept, resid):
    kept_norm = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                             for x in jax.tree.leaves(kept)))
    res_norm = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                            for x in jax.tree.leaves(resid)))
    return kept_norm, res_norm


# ------------------------------------------------------------- schemes

def _topk_one(g, ratio: float):
    flat = g.reshape(-1)
    k = max(1, int(flat.size * ratio))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return kept.reshape(g.shape)


def topk_compress(grads, ef: ErrorFeedbackState, *, ratio: float = 0.01
                  ) -> Tuple[Any, ErrorFeedbackState, dict]:
    """Returns (compressed_grads, new_ef, metrics)."""
    acc = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                       grads, ef.residual)
    kept = jax.tree.map(lambda a: _topk_one(a, ratio), acc)
    resid = jax.tree.map(lambda a, kk: a - kk, acc, kept)
    kept_norm, res_norm = _norms(kept, resid)
    return kept, ErrorFeedbackState(resid), {
        "compress_kept_norm": kept_norm,
        "compress_residual_norm": res_norm,
        "compress_payload_bits": jnp.float32(
            topk_payload_bits(grads, ratio))}


def _int8_one(g):
    x = g.astype(jnp.float32)
    if x.ndim < 2:
        x2 = x.reshape(1, -1)
    else:
        x2 = x.reshape(-1, x.shape[-1])
    q, s = ops.quantize_boundary(x2, use_pallas=False)
    return ops.dequantize_boundary(q, s).reshape(g.shape)


def int8_compress(grads, ef: ErrorFeedbackState
                  ) -> Tuple[Any, ErrorFeedbackState, dict]:
    """Returns (compressed_grads, new_ef, metrics) — the same metrics
    contract as :func:`topk_compress` (kept/residual norms + exact
    payload bits), so the codec layer meters every scheme uniformly."""
    acc = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                       grads, ef.residual)
    deq = jax.tree.map(_int8_one, acc)
    resid = jax.tree.map(lambda a, d: a - d, acc, deq)
    kept_norm, res_norm = _norms(deq, resid)
    return deq, ErrorFeedbackState(resid), {
        "compress_kept_norm": kept_norm,
        "compress_residual_norm": res_norm,
        "compress_payload_bits": jnp.float32(int8_payload_bits(grads))}


def compress(grads, ef, *, scheme: str = "none", topk_ratio: float = 0.01):
    if scheme == "none":
        return grads, ef, {}
    if scheme == "topk":
        return topk_compress(grads, ef, ratio=topk_ratio)
    if scheme == "int8":
        return int8_compress(grads, ef)
    raise ValueError(scheme)
