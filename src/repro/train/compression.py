"""Gradient compression for the DP all-reduce (beyond-paper, §Perf).

Two schemes, both with error feedback so compression error accumulates
locally instead of biasing the update (Stich et al., memory-compensated
SGD):

  * top-k sparsification — keep the k largest-|g| entries per tensor
    (k = ratio * numel); the residual feeds back into the next step.
  * int8 rows — the same symmetric per-row quantizer the SL boundary
    uses (kernels/split_quant), applied to gradients.

In the paper's constellation these compress the *ISL gradient payload*
(for the FL-hybrid extension the paper's conclusion sketches); in the
scaled-out LM track they model all-reduce volume reduction.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


class ErrorFeedbackState(NamedTuple):
    residual: Any            # same pytree as grads, fp32


def ef_init(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _topk_one(g, ratio: float):
    flat = g.reshape(-1)
    k = max(1, int(flat.size * ratio))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return kept.reshape(g.shape)


def topk_compress(grads, ef: ErrorFeedbackState, *, ratio: float = 0.01
                  ) -> Tuple[Any, ErrorFeedbackState, dict]:
    """Returns (compressed_grads, new_ef, metrics)."""
    acc = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                       grads, ef.residual)
    kept = jax.tree.map(lambda a: _topk_one(a, ratio), acc)
    resid = jax.tree.map(lambda a, kk: a - kk, acc, kept)
    kept_norm = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                             for x in jax.tree.leaves(kept)))
    res_norm = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                            for x in jax.tree.leaves(resid)))
    return kept, ErrorFeedbackState(resid), {
        "compress_kept_norm": kept_norm, "compress_residual_norm": res_norm}


def _int8_one(g):
    x = g.astype(jnp.float32)
    if x.ndim < 2:
        x2 = x.reshape(1, -1)
    else:
        x2 = x.reshape(-1, x.shape[-1])
    q, s = ops.quantize_boundary(x2, use_pallas=False)
    return ops.dequantize_boundary(q, s).reshape(g.shape)


def int8_compress(grads, ef: ErrorFeedbackState
                  ) -> Tuple[Any, ErrorFeedbackState, dict]:
    acc = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                       grads, ef.residual)
    deq = jax.tree.map(_int8_one, acc)
    resid = jax.tree.map(lambda a, d: a - d, acc, deq)
    return deq, ErrorFeedbackState(resid), {}


def compress(grads, ef, *, scheme: str = "none", topk_ratio: float = 0.01):
    if scheme == "none":
        return grads, ef, {}
    if scheme == "topk":
        return topk_compress(grads, ef, ratio=topk_ratio)
    if scheme == "int8":
        return int8_compress(grads, ef)
    raise ValueError(scheme)
