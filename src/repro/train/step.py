"""pjit train/serve step builders for the LM track.

``make_train_step`` returns (step_fn, state_shardings): a donated,
fully-sharded AdamW step — loss+grad (remat policy), optional gradient
compression with error feedback, global-norm clip, AdamW with
ZeRO-sharded state. Under pjit's global-view semantics the DP gradient
all-reduce is implicit in the partitioned matmul transposes; the mesh
rules decide what becomes all-reduce vs reduce-scatter.

``make_prefill_step`` / ``make_decode_step`` build the serving entry
points the decode_* / long_* dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.layers import Ctx
from repro.models.param import ShardingRules, partition_specs, shape_structs
from repro.train import compression
from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                   adamw_state_specs, adamw_update)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    remat: str = "full"                  # none | dots | full
    compression: str = "none"            # none | topk | int8
    topk_ratio: float = 0.01
    act_dtype: Any = jnp.bfloat16
    aux_weight: float = 0.01
    use_pallas: Optional[bool] = False
    block_q: int = 512
    block_k: int = 512
    scan_unroll: int = 1
    attn_compute_dtype: Any = jnp.float32
    mamba_chunk: int = 128
    mlstm_chunk: int = 256
    moe_dispatch: str = "global"


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any                              # error-feedback residuals or None


def _batch_sharding(mesh, rules: ShardingRules, struct):
    spec = rules.resolve(("batch",) + (None,) * (len(struct.shape) - 1),
                         mesh, struct.shape)
    return NamedSharding(mesh, spec)


def make_train_step(cfg, mesh, rules: ShardingRules,
                    tcfg: TrainConfig = TrainConfig()):
    """Returns (train_step, state_shardings, batch_shardings_fn).

    train_step(state, batch) -> (state, metrics); batch is a dict with
    tokens/labels (+ frontend stubs). Donates state.
    """
    abstract = lm.abstract_params(cfg)
    pspecs = partition_specs(abstract, rules, mesh)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    opt_specs = adamw_state_specs(abstract, rules, mesh)
    opt_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_specs,
                          is_leaf=lambda x: isinstance(x, P))
    ef_sh = opt_sh.mu if tcfg.compression != "none" else None

    state_sh = TrainState(params=param_sh, opt=opt_sh, ef=ef_sh)
    ctx = Ctx(cfg=cfg, mesh=mesh, rules=rules, mode="train",
              act_dtype=tcfg.act_dtype, use_pallas=tcfg.use_pallas,
              block_q=tcfg.block_q, block_k=tcfg.block_k,
              attn_compute_dtype=tcfg.attn_compute_dtype,
              mamba_chunk=tcfg.mamba_chunk, mlstm_chunk=tcfg.mlstm_chunk,
              moe_dispatch=tcfg.moe_dispatch)

    def loss_fn(params, batch):
        return lm.loss(cfg, params, batch["tokens"], batch["labels"],
                       ctx=ctx,
                       frontend_embed=batch.get("frontend_embed"),
                       enc_frames=batch.get("enc_frames"),
                       remat=tcfg.remat, aux_weight=tcfg.aux_weight,
                       unroll=tcfg.scan_unroll)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        (lv, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        ef = state.ef
        if tcfg.compression != "none":
            grads, ef_state, cm = compression.compress(
                grads, compression.ErrorFeedbackState(ef),
                scheme=tcfg.compression, topk_ratio=tcfg.topk_ratio)
            ef = ef_state.residual
            metrics.update(cm)
        params, opt, om = adamw_update(tcfg.adamw, grads, state.opt,
                                       state.params)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = lv
        return TrainState(params, opt, ef), metrics

    def init_state(rng) -> TrainState:
        params = lm.init(cfg, rng)
        ef = (compression.ef_init(params).residual
              if tcfg.compression != "none" else None)
        return TrainState(params, adamw_init(params), ef)

    def batch_shardings(input_structs: Dict) -> Dict:
        return {k: _batch_sharding(mesh, rules, v)
                for k, v in input_structs.items()}

    jitted = jax.jit(train_step,
                     in_shardings=(state_sh, None),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
    return jitted, state_sh, batch_shardings, init_state


# --------------------------------------------------------------------------
# Serving steps (the decode/prefill dry-run cells).
# --------------------------------------------------------------------------

def make_prefill_step(cfg, mesh, rules: ShardingRules,
                      act_dtype=jnp.bfloat16, use_pallas=False,
                      block_q: int = 512, block_k: int = 512,
                      unroll: int = 1):
    """prefill_step(params, batch) -> (logits_last, cache)."""
    ctx = Ctx(cfg=cfg, mesh=mesh, rules=rules, mode="prefill",
              act_dtype=act_dtype, use_pallas=use_pallas,
              block_q=block_q, block_k=block_k)
    abstract = lm.abstract_params(cfg)
    pspecs = partition_specs(abstract, rules, mesh)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    def prefill_step(params, batch):
        logits, _, cache = lm.forward(
            cfg, params, batch["tokens"], ctx=ctx,
            frontend_embed=batch.get("frontend_embed"),
            enc_frames=batch.get("enc_frames"), remat="none",
            unroll=unroll)
        return logits[:, -1:], cache

    return jax.jit(prefill_step, in_shardings=(param_sh, None)), param_sh


def cache_shardings(cfg, cache, mesh, rules: ShardingRules):
    """Shard caches: batch over data axes, kv-heads/channels over model."""
    def spec_for(path_leaf):
        shp = path_leaf.shape
        if len(shp) == 5:        # (U, B, KV, S, dh) attention cache
            return rules.resolve(("layers", "batch", "kv_heads", None, None),
                                 mesh, shp)
        if len(shp) == 4:        # (U, B, H, P) / (U, B, 3, di) style
            return rules.resolve(("layers", "batch", None, "inner"),
                                 mesh, shp)
        if len(shp) == 5 + 0:
            pass
        return rules.resolve(("layers", "batch") + (None,) * (len(shp) - 2),
                             mesh, shp)

    return jax.tree.map(
        lambda a: NamedSharding(mesh, spec_for(a)), cache)


def make_decode_step(cfg, mesh, rules: ShardingRules,
                     batch: int, s_max: int, act_dtype=jnp.bfloat16,
                     use_pallas=False, unroll: int = 1):
    """serve_step(params, cache, tokens, positions) -> (logits, cache).

    Cache is donated (in-place KV update — the production decode loop).
    """
    ctx = Ctx(cfg=cfg, mesh=mesh, rules=rules, mode="decode",
              act_dtype=act_dtype, use_pallas=use_pallas)
    abstract = lm.abstract_params(cfg)
    pspecs = partition_specs(abstract, rules, mesh)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    cache_struct = jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, s_max, act_dtype))
    cache_sh = cache_shardings(cfg, cache_struct, mesh, rules)

    def serve_step(params, cache, tokens, positions):
        return lm.decode_step(cfg, params, cache, tokens, positions, ctx=ctx,
                              unroll=unroll)

    tok_sh = NamedSharding(mesh, rules.resolve(("batch", None), mesh,
                                               (batch, 1)))
    pos_sh = NamedSharding(mesh, rules.resolve(("batch",), mesh, (batch,)))
    jitted = jax.jit(serve_step,
                     in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(1,))
    return jitted, param_sh, cache_sh, cache_struct
