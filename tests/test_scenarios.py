"""Degraded-ops scenario engine: eclipse windows (host-vs-device
parity, battery-clamp edge cases), Byzantine satellites vs robust
aggregation (the acceptance criterion: trimmed-mean recovers, plain
mean diverges), epidemic fault propagation (host-prefix bit parity,
in-scan refresh beyond the precomputed horizon), multi-leave events and
collision-free failure streams."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.constellation import ConstellationConfig, ConstellationSim
from repro.core.energy import PassBudget, solar_recharge_j
from repro.core.orbits import OrbitalPlane
from repro.core.sl_step import autoencoder_adapter
from repro.fleet import (FleetConfig, FleetEngine, build_event_schedule,
                         oracle_actions)
from repro.fleet.scenarios import (ByzantineConfig, EclipseConfig,
                                   EpidemicConfig, ScenarioConfig,
                                   aggregate_planes,
                                   build_scenario_schedule,
                                   epidemic_oracle)
from repro.sim.data import DeviceImageryShards
from repro.sim.device_sim import (ACTION_FAILED, ACTION_FAULT,
                                  ACTION_SKIPPED, ACTION_TRAINED)

SHARDS = DeviceImageryShards(img=32, batch=4)
ADAPTER = autoencoder_adapter(cut=5, img=32)


def _budget(n_sats=4, n_items=4e6):
    return PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=n_items)


def _fleet(budget, **cfg_kw):
    return FleetEngine(ADAPTER, budget, SHARDS,
                       FleetConfig(**cfg_kw))


# ----------------------------------------------------- scenario configs

def test_eclipse_config_windows():
    """The shadow sits at the start of each cycle, staggered per plane,
    and the same modular arithmetic serves ints and traced scalars."""
    ec = EclipseConfig(period=4, duty=0.5, stagger=1)
    assert [bool(ec.sunlit(k)) for k in range(6)] == \
        [False, False, True, True, False, False]
    # stagger shifts plane 1's shadow one pass earlier
    assert [bool(ec.sunlit(k, 1)) for k in range(4)] == \
        [False, True, True, False]
    assert all(EclipseConfig(period=3, duty=0.0).sunlit(k)
               for k in range(9))
    assert not any(EclipseConfig(period=3, duty=1.0).sunlit(k)
                   for k in range(9))
    assert bool(jax.jit(lambda k: ec.sunlit(k))(2))
    with pytest.raises(ValueError, match="duty"):
        EclipseConfig(period=4, duty=1.5)
    with pytest.raises(ValueError, match="period"):
        EclipseConfig(period=0, duty=0.5)
    # the host-side gate: eclipse harvests a literal 0 J
    assert solar_recharge_j(20.0, 100.0, sunlit=False) == 0.0
    assert solar_recharge_j(20.0, 100.0, sunlit=True) == 2000.0


def test_aggregate_planes_modes():
    """Coordinate-wise centers over the plane axis, broadcast back;
    integer leaves stay per-plane; bad modes/plane counts raise."""
    tree = {"w": jnp.asarray([[1., 2.], [3., 4.], [100., -100.], [5., 6.]]),
            "step": jnp.asarray([1, 2, 3, 4])}
    mean = aggregate_planes(tree, "mean")
    np.testing.assert_allclose(np.asarray(mean["w"]),
                               np.full((4, 2), [27.25, -22.0]))
    # median/trimmed_mean shrug off the (100, -100) outlier plane
    med = aggregate_planes(tree, "median")
    trim = aggregate_planes(tree, "trimmed_mean")
    np.testing.assert_allclose(np.asarray(med["w"][0]), [4.0, 3.0])
    np.testing.assert_allclose(np.asarray(trim["w"][0]), [4.0, 3.0])
    for out in (mean, med, trim):
        assert out["w"].shape == (4, 2)
        np.testing.assert_array_equal(np.asarray(out["step"]),
                                      [1, 2, 3, 4])
    with pytest.raises(ValueError, match="mode"):
        aggregate_planes(tree, "geometric")
    with pytest.raises(ValueError, match="planes"):
        aggregate_planes({"w": jnp.zeros((2, 3))}, "trimmed_mean")


def test_byzantine_mask_and_modes():
    bz = ByzantineConfig(planes=(3,), slots={0: [1, 2], 1: 0})
    mask = bz.mask(4, 4)
    assert mask[3].all() and mask[0, 1] and mask[0, 2] and mask[1, 0]
    assert mask.sum() == 7
    with pytest.raises(ValueError, match="mode"):
        ByzantineConfig(mode="bitrot")


def test_epidemic_oracle_spread_and_recovery():
    """beta=1: the fault front advances one ring slot per pass in both
    directions (recovered slots are immediately susceptible again, so
    the ring saturates); beta=0: seeds fault for exactly ttl passes."""
    scn = ScenarioConfig(epidemic=EpidemicConfig(
        beta=1.0, ttl=2, init_slots=(0,), start=0))
    sched = build_scenario_schedule(scn, 1, 6, 8, seed=0)
    inf = epidemic_oracle(scn, sched)
    expect = np.zeros((8, 6), bool)
    expect[0, [0]] = True                       # seeded
    expect[1, [0, 1, 5]] = True                 # spread to ring neighbors
    expect[2, [0, 1, 2, 4, 5]] = True           # 0 recovers, is reinfected
    expect[3:] = True                           # fronts meet: saturated
    np.testing.assert_array_equal(inf[0], expect)
    # beta=0: only the seeds fault, for exactly ttl passes
    scn0 = ScenarioConfig(epidemic=EpidemicConfig(
        beta=0.0, ttl=3, init_slots=(2,), start=1))
    inf0 = epidemic_oracle(scn0, build_scenario_schedule(scn0, 1, 4, 8))
    assert inf0.sum() == 3 and inf0[0, 1:4, 2].all()


# ------------------------------------------- the acceptance criterion

def test_trimmed_mean_recovers_byzantine_plane():
    """ISSUE 6 acceptance: with 1 of 4 planes Byzantine (sign-flipped,
    scaled), trimmed-mean aggregation recovers the honest planes' final
    loss to within 10% of the fault-free run while plain mean diverges;
    scenario runs keep the ≤-1-sync-per-revolution contract."""
    budget = _budget(n_sats=4)
    byz = ScenarioConfig(byzantine=ByzantineConfig(
        planes=(3,), mode="sign_flip", scale=8.0))

    def run(scenario, aggregate):
        fleet = _fleet(budget, n_planes=4, n_revolutions=6,
                       max_steps_per_pass=4, seed=0, avg_every=1,
                       scenario=scenario, aggregate=aggregate)
        res = fleet.run(stream_telemetry=True)
        assert fleet.traces == 1
        assert fleet.host_syncs == 6          # one per revolution
        # final loss over the HONEST planes (0..2)
        last = [row[np.isfinite(row)][-1] for row in res.loss[:3]]
        return float(np.mean(last))

    clean = run(None, "mean")
    poisoned = run(byz, "mean")
    recovered = run(byz, "trimmed_mean")
    assert np.isfinite(clean) and clean > 0
    # plain mean lets the corrupted plane poison the exchange
    assert poisoned > 10.0 * clean, (poisoned, clean)
    # trimmed-mean drops the outlier coordinate-wise and recovers
    assert abs(recovered - clean) <= 0.10 * clean, (recovered, clean)


def test_median_aggregation_also_recovers():
    """The median mode survives the same corrupted plane (smaller run:
    scaled-noise corruption instead of sign flips)."""
    budget = _budget(n_sats=4)
    byz = ScenarioConfig(byzantine=ByzantineConfig(
        planes=(3,), mode="scaled_noise", scale=5.0))
    losses = {}
    for scn, agg in ((None, "mean"), (byz, "median")):
        fleet = _fleet(budget, n_planes=4, n_revolutions=4,
                       max_steps_per_pass=4, seed=0, avg_every=1,
                       scenario=scn, aggregate=agg)
        res = fleet.run()
        last = [row[np.isfinite(row)][-1] for row in res.loss[:3]]
        losses[agg] = float(np.mean(last))
    assert abs(losses["median"] - losses["mean"]) <= \
        0.10 * losses["mean"], losses


# ------------------------------------------------ eclipse: host parity

ECLIPSE = EclipseConfig(period=4, duty=0.5)
TIGHT = dict(battery_j=200.0, recharge_w=0.02, reserve_j=180.0,
             max_steps_per_pass=2)


def test_eclipse_host_device_parity():
    """A host run with eclipse-gated recharge delegates to the fleet
    scenario engine and reproduces the action sequence and battery
    trajectory exactly; the eclipse observably deepens the skip count
    vs the permanently-sunlit run."""
    budget = _budget()

    def mk(eclipse):
        return ConstellationSim(
            ADAPTER, budget, SHARDS,
            ConstellationConfig(batch_size=4, n_passes=12,
                                eclipse=eclipse, **TIGHT))

    host, dev = mk(ECLIPSE), mk(ECLIPSE)
    host.run()
    dev.run(engine="device")
    assert [(r.action, r.sat_id) for r in host.records] == \
        [(r.action, r.sat_id) for r in dev.records]
    for h, d in zip(host.records, dev.records):
        np.testing.assert_allclose(d.battery_j, h.battery_j, rtol=1e-5,
                                   atol=0.05)
    skips = host.summary()["skipped"]
    assert skips > 0
    sunny = mk(None)
    sunny.run()
    assert sunny.summary()["skipped"] < skips
    # eclipse is a fleet-scenario feature: the static engine refuses it
    with pytest.raises(ValueError, match="eclipse"):
        mk(ECLIPSE).as_device_sim()


def test_battery_clamp_zero_capacity():
    """Zero-capacity satellites: every pass reserve-skips, batteries
    pin at exactly 0 J (never negative), no div-by-zero anywhere."""
    budget = _budget()
    fleet = _fleet(budget, n_planes=2, n_revolutions=2, battery_j=0.0,
                   recharge_w=5.0, reserve_j=10.0, max_steps_per_pass=2,
                   seed=0,
                   scenario=ScenarioConfig(eclipse=ECLIPSE))
    np.testing.assert_array_equal(oracle_actions(fleet),
                                  np.full((2, 8), ACTION_SKIPPED))
    res = fleet.run()
    assert (res.action == ACTION_SKIPPED).all()
    assert (res.battery_j == 0.0).all()
    assert (np.asarray(res.energy.battery_j) == 0.0).all()
    assert np.isfinite(np.asarray(res.energy.energy_spent_j)).all()
    assert (res.n_steps == 0).all()


def test_battery_clamp_full_revolution_eclipse():
    """duty=1.0 gates recharge to exactly 0 J across the whole run:
    batteries only ever drain, monotonically, the reserve-skip policy
    fires on every pass once depleted, and nothing goes negative —
    bit-identically on host and device."""
    budget = _budget()
    dark = EclipseConfig(period=4, duty=1.0)

    host = ConstellationSim(
        ADAPTER, budget, SHARDS,
        ConstellationConfig(batch_size=4, n_passes=16, eclipse=dark,
                            battery_j=60.0, recharge_w=5.0,
                            reserve_j=50.0, max_steps_per_pass=2))
    dev = ConstellationSim(
        ADAPTER, budget, SHARDS,
        ConstellationConfig(batch_size=4, n_passes=16, eclipse=dark,
                            battery_j=60.0, recharge_w=5.0,
                            reserve_j=50.0, max_steps_per_pass=2))
    host.run()
    dev.run(engine="device")
    assert [r.action for r in host.records] == \
        [r.action for r in dev.records]
    for h, d in zip(host.records, dev.records):
        np.testing.assert_allclose(d.battery_j, h.battery_j, rtol=1e-5,
                                   atol=0.05)
    # each sat trains once (draining below reserve), then every later
    # pass skips: recharge contributed exactly 0 J
    acts = [r.action for r in host.records]
    assert acts[:4] == ["trained"] * 4 and \
        acts[4:] == ["skipped_energy"] * 12
    batteries = np.asarray([s.battery_j for s in host.sats])
    assert (batteries >= 0.0).all() and (batteries < 50.0).all()
    # per-sat battery telemetry never increases under a 100% eclipse
    for s in range(4):
        traj = [r.battery_j for r in host.records if r.sat_id == s]
        assert all(b1 <= b0 + 1e-6 for b0, b1 in zip(traj, traj[1:]))


def test_reserve_skip_every_pass():
    """Batteries that start below the reserve skip every pass yet stay
    clamped at their initial charge (recharge off, nothing drains)."""
    budget = _budget()
    fleet = _fleet(budget, n_planes=1, n_revolutions=3, battery_j=100.0,
                   recharge_w=0.0, reserve_j=150.0,
                   max_steps_per_pass=2, seed=0)
    res = fleet.run()
    assert (res.action == ACTION_SKIPPED).all()
    np.testing.assert_allclose(res.battery_j, 100.0)
    assert (np.asarray(res.energy.passes_skipped).sum()
            == res.action.size)


# --------------------------------- epidemic: prefix parity + beyond

def test_epidemic_prefix_parity_and_beyond_horizon():
    """Device actions equal the NumPy host-prefix oracle bit for bit
    over the precomputed horizon; chained revolutions beyond it keep
    drawing epidemic spreads AND failures from jax.random inside the
    scan (ROADMAP item 4's in-scan refresh)."""
    budget = _budget(n_sats=6)
    scn = ScenarioConfig(epidemic=EpidemicConfig(
        beta=0.5, ttl=4, init_slots=(0, 3), start=0))
    fleet = _fleet(budget, n_planes=2, n_revolutions=2,
                   max_steps_per_pass=2, seed=3, fail_prob=0.1,
                   avg_every=0, scenario=scn)
    expect = oracle_actions(fleet)
    res = fleet.run(stream_telemetry=True)
    np.testing.assert_array_equal(res.action, expect)
    assert (res.action == ACTION_FAULT).sum() > 0
    assert res.summary()["faulted"] == (res.action == ACTION_FAULT).sum()
    # telemetry counts every faulted slot, serving or not
    assert (res.n_infected >= (res.action == ACTION_FAULT)).all()
    assert res.n_infected.max() > 1

    # beyond the precomputed horizon: same compiled program, and the
    # degraded-ops streams stay active (neither faults nor failures
    # freeze at the horizon)
    res2 = fleet.run(4, stream_telemetry=True)
    assert fleet.traces == 1          # R=1 streaming program reused
    beyond = res2.action
    assert (beyond == ACTION_FAULT).sum() > 0, "epidemic froze"
    assert (beyond == ACTION_FAILED).sum() > 0, "failure stream froze"
    assert int(np.asarray(fleet._pass_idx)) == 36


def test_epidemic_faulted_slot_pays_no_energy():
    """A faulted pass is a masked no-op: no drain, no steps, no loss,
    and the slot returns to training once its ttl expires."""
    budget = _budget()
    scn = ScenarioConfig(epidemic=EpidemicConfig(
        beta=0.0, ttl=2, init_slots=(1,), start=1))
    fleet = _fleet(budget, n_planes=1, n_revolutions=3,
                   max_steps_per_pass=2, seed=0, scenario=scn)
    res = fleet.run()
    # slot 1 serves passes 1, 5, 9; infected at passes 1-2 only
    assert res.action[0, 1] == ACTION_FAULT
    assert res.n_steps[0, 1] == 0 and not np.isfinite(res.loss[0, 1])
    assert res.action[0, 5] == ACTION_TRAINED
    assert res.action[0, 9] == ACTION_TRAINED
    assert np.asarray(res.energy.passes_served)[0, 1] == 2
    assert (res.fault_ttl == 0).all()


# ------------------------------------- events: multi-leave + streams

def test_multi_leave_events():
    """``leave_events`` accepts a sequence of ids per pass; host and
    schedule resolve the same slots; host-vs-device parity holds."""
    sched = build_event_schedule(4, 8, leave_events={3: [0, 2], 5: 1})
    assert list(sched.leave_pass) == [3, 5, 3,
                                      np.iinfo(np.int32).max]
    assert list(sched.member_at(6)) == [False, False, False, True]

    budget = _budget()

    def mk():
        return ConstellationSim(
            ADAPTER, budget, SHARDS,
            ConstellationConfig(batch_size=4, n_passes=8,
                                leave_events={3: (0, 2)},
                                max_steps_per_pass=2))

    host, dev = mk(), mk()
    host.run()
    dev.run(engine="device")
    assert [(r.action, r.sat_id) for r in host.records] == \
        [(r.action, r.sat_id) for r in dev.records]
    # after pass 3 only sats 1 and 3 remain in the rotation
    assert {r.sat_id for r in host.records[3:]} == {1, 3}


def test_spawned_streams_fix_seed_collisions():
    """``default_rng(seed + p)`` collides: (seed=0, plane=1) equals
    (seed=1, plane=0).  SeedSequence-spawned streams do not, and stay
    deterministic; the legacy path still matches the host oracle."""
    legacy0 = build_event_schedule(4, 64, fail_prob=0.5, n_planes=2,
                                   seed=0)
    legacy1 = build_event_schedule(4, 64, fail_prob=0.5, n_planes=2,
                                   seed=1)
    assert (legacy0.fail_mask[1] == legacy1.fail_mask[0]).all()

    spawn0 = build_event_schedule(4, 64, fail_prob=0.5, n_planes=2,
                                  seed=0, legacy_streams=False)
    spawn1 = build_event_schedule(4, 64, fail_prob=0.5, n_planes=2,
                                  seed=1, legacy_streams=False)
    assert not (spawn0.fail_mask[1] == spawn1.fail_mask[0]).all()
    assert not (spawn0.fail_mask[0] == spawn0.fail_mask[1]).all()
    again = build_event_schedule(4, 64, fail_prob=0.5, n_planes=2,
                                 seed=0, legacy_streams=False)
    np.testing.assert_array_equal(spawn0.fail_mask, again.fail_mask)
    # legacy stays the default (host-parity tests depend on it) and the
    # fleet threads the flag through to its schedule
    assert legacy0.legacy_streams and not spawn0.legacy_streams
    fleet = _fleet(_budget(), n_planes=2, n_revolutions=1,
                   max_steps_per_pass=2, seed=0, fail_prob=0.5,
                   legacy_streams=False)
    assert not fleet.schedule.legacy_streams
    np.testing.assert_array_equal(fleet.schedule.fail_mask[:, :4],
                                  spawn0.fail_mask[:, :4])
    # the oracle replays spawned streams just as exactly (it reads the
    # initial state, so compute it before running)
    expect = oracle_actions(fleet)
    np.testing.assert_array_equal(fleet.run().action, expect)
