"""Checkpointing: atomicity, integrity, restart discovery."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, meta={"step": 7})
    out, meta = ckpt.restore(str(tmp_path), 7, t)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_discovery(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    for s in (1, 5, 3):
        ckpt.save(str(tmp_path), s, _tree(s))
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_integrity_failure_detected(tmp_path):
    t = _tree()
    path = ckpt.save(str(tmp_path), 1, t)
    # corrupt the array file
    arr = os.path.join(path, "arrays.npz")
    data = bytearray(open(arr, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(arr, "wb").write(bytes(data))
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), 1, t)


def test_shape_mismatch_detected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    wrong = {"a": jnp.zeros((3, 8)), "b": {"c": jnp.zeros(6, jnp.int32),
                                           "d": jnp.float32(0)}}
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 1, wrong)


def test_no_silent_overwrite(tmp_path):
    t = _tree(1)
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 1, _tree(2))     # must keep the original
    out, _ = ckpt.restore(str(tmp_path), 1, t)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))


def test_no_tmp_litter(tmp_path):
    ckpt.save(str(tmp_path), 3, _tree())
    entries = [e for e in os.listdir(tmp_path) if e.startswith(".tmp")]
    assert entries == []


def test_none_leaves_skipped(tmp_path):
    """TrainState.ef is None when compression is off; checkpoints must
    treat None as an empty subtree (jax semantics), not an object array."""
    t = {"a": jnp.arange(3.0), "ef": None, "nested": {"x": None,
                                                      "y": jnp.ones(2)}}
    ckpt.save(str(tmp_path), 1, t)
    out, _ = ckpt.restore(str(tmp_path), 1, t)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(3.0))
    assert out["ef"] is None
    assert out["nested"]["x"] is None
