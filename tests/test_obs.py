"""Flight recorder: ring semantics, the metrics registry + sync-budget
guard, ring-vs-telemetry parity on all three engines, the chained
≤-1-sync-per-revolution regression contracts, timeline export, the
scan-purity lint and the benchmark run header."""
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.energy import PassBudget
from repro.core.orbits import OrbitalPlane
from repro.core.sl_step import autoencoder_adapter
from repro.fleet import (EclipseConfig, EpidemicConfig, FleetConfig,
                         FleetEngine, ScenarioConfig)
from repro.obs import (EV_EXCHANGE, EV_PASS, EV_SERVE, PASS_FIELDS,
                       SERVE_FIELDS, FlightRecorder, MetricsRegistry,
                       SyncBudgetExceeded, flush, merge_events,
                       payload_column, record, ring_init, sync_budget,
                       timeline_summary, to_chrome_trace,
                       validate_chrome_trace)
from repro.sim.data import DeviceImageryShards
from repro.sim.device_sim import (ACTION_NAMES, DeviceConstellationSim,
                                  DeviceSimConfig)
from repro.serve_fleet.engine import (FleetServeEngine, ServeCost,
                                      ServeFleetConfig, TrainLoad)
from repro.serve_fleet.traffic import TrafficConfig

SHARDS = DeviceImageryShards(img=32, batch=4)
ADAPTER = autoencoder_adapter(cut=5, img=32)
ENERGY = dict(battery_j=200.0, recharge_w=0.01, reserve_j=150.0,
              max_steps_per_pass=2)


def _budget(n_sats=4, n_items=16.0):
    return PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=n_items)


def _serve_fleet(*, train=None, eclipse=None, P=2, M=8, K=24, seed=2):
    cost = ServeCost(tokens_per_s=50.0, e_token_j=0.02,
                     dtx_bits_token=2048.0)
    scfg = ServeFleetConfig(n_planes=P, n_sats=M, n_windows=K,
                            battery_j=60.0, recharge_w=0.02,
                            reserve_serve_j=5.0, reserve_train_j=30.0,
                            window_s=90.0, eclipse=eclipse)
    return FleetServeEngine(scfg, TrafficConfig(users_per_day=60_000.0,
                                                decode_len=4, seed=seed),
                            cost, train=train)


# ------------------------------------------------------------------ ring

def test_ring_record_order_and_flush():
    ring = ring_init(8)
    for i in range(5):
        ring = record(ring, EV_PASS, 10 + i, i, (float(i), 100.0 + i))
    ev = flush(ring)
    assert ev.dropped == 0
    np.testing.assert_array_equal(ev.kind, [EV_PASS] * 5)
    np.testing.assert_array_equal(ev.t, np.arange(10, 15))
    np.testing.assert_array_equal(ev.slot, np.arange(5))
    np.testing.assert_allclose(ev.payload[:, 0], np.arange(5.0))
    np.testing.assert_allclose(ev.payload[:, 1], 100.0 + np.arange(5.0))
    # short payloads zero-pad to the full row width
    assert ev.payload.shape[1] == 8
    np.testing.assert_array_equal(ev.payload[:, 2:], 0.0)


def test_ring_wraparound_keeps_newest_and_reports_dropped():
    ring = ring_init(4)
    for i in range(10):
        ring = record(ring, EV_PASS, i, 0, (float(i),))
    ev = flush(ring)
    assert ev.dropped == 6
    # oldest-first among the surviving newest 4
    np.testing.assert_array_equal(ev.t, [6, 7, 8, 9])
    np.testing.assert_allclose(ev.payload[:, 0], [6.0, 7.0, 8.0, 9.0])


def test_ring_masked_record_is_noop():
    ring = ring_init(4)
    ring = record(ring, EV_PASS, 0, 0, (1.0,))
    skipped = record(ring, EV_PASS, 1, 1, (2.0,), mask=False)
    for a, b in zip(ring, skipped):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(skipped.cursor) == 1


def test_ring_records_under_vmap_and_jit():
    P = 3

    @jax.jit
    def go(ring):
        def body(r, k):
            r = jax.vmap(
                lambda rp, p: record(rp, EV_PASS, k, p,
                                     (p.astype(jnp.float32),)))(
                r, jnp.arange(P, dtype=jnp.int32))
            return r, None
        ring, _ = jax.lax.scan(body, ring, jnp.arange(5, dtype=jnp.int32))
        return ring

    ring = go(ring_init(8, batch=(P,)))
    rec = FlightRecorder()
    assert rec.ingest(ring) == 15
    ev = rec.events()
    for p in range(P):
        sel = ev["plane"] == p
        assert sel.sum() == 5
        np.testing.assert_array_equal(ev["t"][sel], np.arange(5))
        np.testing.assert_allclose(ev["payload"][sel][:, 0], float(p))


def test_recorder_t_offset_and_merge():
    r1 = record(ring_init(2), EV_PASS, 0, 0, (1.0,))
    r2 = record(ring_init(2), EV_SERVE, 0, 0, (2.0,))
    rec = FlightRecorder()
    rec.ingest(r1)
    rec.ingest(r2, t_offset=7)
    ev = rec.events()
    np.testing.assert_array_equal(ev["t"], [0, 7])
    merged = merge_events(ev, ev)
    assert merged["kind"].shape[0] == 4
    assert list(merged["t"]) == sorted(merged["t"])


def test_recorder_save_load_roundtrip(tmp_path):
    rec = FlightRecorder()
    rec.ingest(record(ring_init(2), EV_PASS, 3, 1, (5.0,)))
    path = str(tmp_path / "events.npz")
    rec.save(path)
    back = FlightRecorder.load(path)
    a, b = rec.events(), back.events()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


# --------------------------------------------------------------- metrics

def test_registry_counters_propagate_to_parent():
    parent = MetricsRegistry()
    child = MetricsRegistry("fleet", parent=parent)
    child.inc("host_syncs")
    child.inc("host_syncs", 2)
    assert child.counter("host_syncs").value == 3
    assert parent.counter("fleet.host_syncs").value == 3
    child.counter("host_syncs").set(1)       # absolute writes re-sync too
    assert parent.counter("fleet.host_syncs").value == 1
    d = parent.to_dict()
    assert d == {"fleet.host_syncs": 1}


def test_histogram_summary():
    reg = MetricsRegistry()
    h = reg.histogram("dispatch_s")
    for x in (0.5, 1.5, 200.0):
        h.record(x)
    v = h.to_value()
    assert v["count"] == 3 and v["min"] == 0.5 and v["max"] == 200.0
    np.testing.assert_allclose(v["mean"], (0.5 + 1.5 + 200.0) / 3)
    assert v["buckets"]["le_0.5"] == 1 and v["buckets"]["le_inf"] == 1


def test_sync_budget_passes_and_raises():
    reg = MetricsRegistry()
    child = MetricsRegistry("sim", parent=reg)
    with sync_budget(2, registry=reg):
        child.inc("host_syncs", 2)
    with pytest.raises(SyncBudgetExceeded):
        with sync_budget(1, registry=reg):
            child.inc("host_syncs", 2)
    # counters created inside the region count from zero
    with pytest.raises(SyncBudgetExceeded):
        with sync_budget(0, registry=reg):
            MetricsRegistry("fresh", parent=reg).inc("host_syncs")


def test_engine_counters_are_registry_backed():
    sim = DeviceConstellationSim(
        ADAPTER, _budget(), SHARDS,
        DeviceSimConfig(n_revolutions=2, **ENERGY))
    res = sim.run()
    assert sim.traces == 1 and sim.device_calls == 1
    assert sim.host_syncs == 1
    # the old attributes are live views of the registry counters
    assert sim.metrics.counter("host_syncs").value == 1
    sim.host_syncs = 5                       # compat setter writes through
    assert sim.metrics.counter("host_syncs").value == 5
    # ring events mirror the dense telemetry one-for-one
    assert len(sim.recorder) == res.action.size
    ev = sim.recorder.events()
    np.testing.assert_array_equal(
        payload_column(ev, EV_PASS, "action").astype(np.int32),
        res.action.reshape(-1))
    np.testing.assert_allclose(
        payload_column(ev, EV_PASS, "battery_j"),
        res.battery_j.reshape(-1), rtol=1e-6)


# -------------------------------------------- chained sync-contract tests

def test_chained_scenario_runs_keep_sync_contract():
    """The ≤-1-sync-per-revolution contract under eclipse + epidemic +
    seeded failures, across CHAINED runs (the regression the plain
    closed-loop assertions never covered)."""
    scn = ScenarioConfig(
        eclipse=EclipseConfig(period=4, duty=0.5, stagger=1),
        epidemic=EpidemicConfig(beta=0.6, ttl=2, init_slots=(0,),
                                start=0))
    cfg = FleetConfig(n_planes=2, n_revolutions=2, fail_prob=0.2,
                      seed=0, avg_every=1, scenario=scn,
                      aggregate="median", **ENERGY)
    fleet = FleetEngine(ADAPTER, _budget(), SHARDS, cfg)
    with sync_budget(2, registry=fleet.metrics):
        res1 = fleet.run(stream_telemetry=True)
    with sync_budget(1, registry=fleet.metrics):
        res2 = fleet.run(n_revolutions=1)
    assert fleet.traces <= 2                 # one per distinct R at most
    assert fleet.host_syncs == 3
    with pytest.raises(SyncBudgetExceeded):
        with sync_budget(0, registry=fleet.metrics):
            fleet.run(n_revolutions=1)
    # the recorder saw every pass of every chained run, on one absolute
    # timeline (no t collisions between runs), plus exchange markers
    ev = fleet.recorder.events()
    n_pass = int((ev["kind"] == EV_PASS).sum())
    assert n_pass == res1.action.size + 2 * res2.action.size
    assert (ev["kind"] == EV_EXCHANGE).sum() > 0
    # 2 streamed revolutions + two chained 1-revolution runs
    pass_t = ev["t"][ev["kind"] == EV_PASS]
    assert pass_t.max() == fleet.n_passes + 2 * fleet.rev_len - 1
    # eclipse bits made it into the payload
    sunlit = payload_column(ev, EV_PASS, "sunlit")
    assert (sunlit == 0.0).any() and (sunlit == 1.0).any()


def test_fleet_ring_matches_telemetry_per_plane():
    cfg = FleetConfig(n_planes=2, n_revolutions=2, fail_prob=0.3,
                      seed=0, avg_every=0, **ENERGY)
    fleet = FleetEngine(ADAPTER, _budget(), SHARDS, cfg)
    res = fleet.run()
    ev = fleet.recorder.events()
    for p in range(2):
        sel = (ev["kind"] == EV_PASS) & (ev["plane"] == p)
        order = np.argsort(ev["t"][sel])
        pay = ev["payload"][sel][order]
        np.testing.assert_array_equal(
            pay[:, PASS_FIELDS.index("action")].astype(np.int32),
            res.action[p])
        np.testing.assert_array_equal(
            ev["slot"][sel][order], res.sat[p])
        # NaN batteries (failed pass) must match elementwise too
        np.testing.assert_array_equal(
            np.isnan(pay[:, PASS_FIELDS.index("battery_j")]),
            np.isnan(res.battery_j[p]))
        np.testing.assert_allclose(
            pay[:, PASS_FIELDS.index("battery_j")],
            res.battery_j[p], rtol=1e-6)


def test_serve_train_contention_chained_sync_contract():
    train = TrainLoad(drain_j=8.0, e_total_j=12.0)
    fleet = _serve_fleet(train=train,
                         eclipse=EclipseConfig(period=6, duty=0.5))
    with sync_budget(1, registry=fleet.metrics):
        res1 = fleet.run()
    with sync_budget(1, registry=fleet.metrics):
        res2 = fleet.run(n_windows=8)
    assert fleet.host_syncs == 2 and fleet.device_calls == 2
    ev = fleet.recorder.events()
    assert (ev["kind"] == EV_SERVE).sum() == \
        res1.arrivals.size + res2.arrivals.size
    # chained runs continue the absolute window timeline
    assert ev["t"].max() == 24 + 8 - 1
    served = payload_column(ev, EV_SERVE, "served")
    total = res1.served.sum() + res2.served.sum()
    np.testing.assert_allclose(served.sum(), total)
    trained = payload_column(ev, EV_SERVE, "trained")
    assert set(np.unique(trained)) <= {0.0, 1.0}


# -------------------------------------------------------------- timeline

def test_chrome_trace_render_and_validate(tmp_path):
    cfg = FleetConfig(n_planes=2, n_revolutions=1, seed=0, avg_every=1,
                      scenario=ScenarioConfig(
                          eclipse=EclipseConfig(period=2, duty=0.5)),
                      aggregate="median", **ENERGY)
    fleet = FleetEngine(ADAPTER, _budget(), SHARDS, cfg)
    fleet.run()
    serve = _serve_fleet(K=6)
    serve.run()
    merged = merge_events(fleet.recorder.events(),
                          serve.recorder.events())
    trace = to_chrome_trace(merged, window_s=90.0)
    validate_chrome_trace(trace)
    path = tmp_path / "trace.json"
    with open(path, "w") as fh:
        json.dump(trace, fh)
    validate_chrome_trace(json.loads(path.read_text()))
    evs = trace["traceEvents"]
    cats = {e.get("cat") for e in evs}
    assert "train" in cats and "serve" in cats and "eclipse" in cats
    names = {e["name"] for e in evs if e["ph"] == "X"}
    assert names & set(ACTION_NAMES.values())
    # metadata names every plane process
    procs = {e["pid"] for e in evs if e["ph"] == "M"
             and e["name"] == "process_name"}
    assert procs == {0, 1}
    # ts/dur scale with window_s
    xs = [e for e in evs if e["ph"] == "X" and e["cat"] == "train"]
    assert all(abs(e["dur"] - 90e6) < 1e-3 for e in xs)
    assert timeline_summary(merged).startswith("flight recorder:")


def test_validate_rejects_malformed_traces():
    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X", "pid": 0,
                                                "tid": 0, "name": "x",
                                                "ts": 0}]})  # no dur


# ------------------------------------------------------------------ lint

def _load_lint():
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "scripts", "lint_scan_purity.py")
    spec = importlib.util.spec_from_file_location("lint_scan_purity", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_lint_scan_purity_repo_is_clean():
    assert _load_lint().main([]) == 0


def test_lint_scan_purity_flags_violations(tmp_path):
    lint = _load_lint()
    bad = tmp_path / "engine.py"
    bad.write_text(
        "import numpy as np\n"
        "import jax\n"
        "class E:\n"
        "    def _compiled(self):\n"
        "        def body(c, x):\n"
        "            jax.debug.print('k={}', x)\n"
        "            y = np.float32(c)\n"
        "            x.block_until_ready()\n"
        "            return c, y\n"
        "        return body\n")
    hits, found = lint.lint_file(str(bad), ("_compiled",))
    assert found == ["_compiled"]
    msgs = " ".join(m for _, _, m in hits)
    assert len(hits) == 3
    assert "jax.debug.print" in msgs
    assert "block_until_ready" in msgs and "numpy" in msgs
    # clean scope -> no hits; missing scope -> reported
    ok = tmp_path / "ok.py"
    ok.write_text("def _compiled():\n    return 1\n")
    assert lint.lint_file(str(ok), ("_compiled",)) == ([], ["_compiled"])
    assert lint.lint_file(str(ok), ("nope",)) == ([], [])


# ------------------------------------------------------------ benchmarks

def test_bench_run_header_fields():
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    try:
        from benchmarks.run import run_header
    finally:
        sys.path.pop(0)
    h = run_header(quick=True)
    assert h["quick"] is True
    assert h["jax_version"] == jax.__version__
    assert h["device_count"] == len(jax.devices())
    assert isinstance(h["rev"], str) and h["rev"]
    assert h["mesh_shape"] is None or isinstance(h["mesh_shape"], dict)
