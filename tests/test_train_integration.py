"""Training substrate integration: pjit train step, optimizer, ZeRO
specs, gradient compression, data pipeline determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.data.synthetic import ImageryShards, TokenShards, prefetch
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.param import ShardingRules, partition_specs
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   lr_at, sgd_init, sgd_update)
from repro.train.step import TrainConfig, make_train_step


def _loss_drops(compression="none", steps=8, topk_ratio=0.1):
    cfg = configs.get_smoke("smollm_360m")
    mesh = make_host_mesh()
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-2, warmup_steps=2,
                                         total_steps=steps),
                       compression=compression, remat="none",
                       topk_ratio=topk_ratio,
                       act_dtype=jnp.float32)
    step, _, _, init_state = make_train_step(cfg, mesh, ShardingRules(), tcfg)
    shards = TokenShards(vocab=cfg.vocab, seq_len=32, batch=4)
    with mesh:
        state = init_state(jax.random.key(0))
        losses = []
        # one fixed batch: loss must drop when memorizing
        batch = jax.tree.map(jnp.asarray, shards.batch_at(0, 0))
        for i in range(steps):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    return losses


def test_train_step_loss_decreases():
    losses = _loss_drops()
    assert losses[-1] < losses[0] - 0.3


@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compressed_training_still_learns(scheme):
    losses = _loss_drops(compression=scheme, steps=12)
    assert losses[-1] < losses[0] - 0.05


def test_adamw_beats_reference_quadratic():
    """AdamW on a quadratic reaches the optimum; bias correction kicks in
    on step 1 (no cold-start shrinkage)."""
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000, min_lr_frac=1.0, grad_clip=1e9)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(300):
        g = {"w": 2.0 * params["w"]}
        params, state, _ = adamw_update(cfg, g, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1)
    assert float(lr_at(cfg, jnp.asarray(55))) < 1.0


def test_grad_clip_applies():
    cfg = AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    _, _, m = adamw_update(cfg, {"w": jnp.full(3, 100.0)}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(np.sqrt(3) * 100, rel=1e-5)


def test_zero_specs_shard_optimizer_state():
    from repro.train.optimizer import adamw_state_specs
    import os
    cfg = configs.get_smoke("llama3_8b")
    # a fake 4-device mesh via reshaped host devices isn't available on
    # 1 CPU; use a (1,1) mesh and check spec STRUCTURE instead
    mesh = make_host_mesh()
    specs = adamw_state_specs(lm.abstract_params(cfg), ShardingRules(), mesh)
    leaves = jax.tree.leaves(specs.mu, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in leaves)
    # at least the big 2D weights get a zero-axis entry ("data")
    named = [s for s in leaves if any(e is not None for e in s)]
    assert len(named) > 0


def test_sgd_momentum_descends():
    params = {"w": jnp.array([4.0])}
    state = sgd_init(params)
    for _ in range(150):    # momentum oscillates through the minimum
        params, state, _ = sgd_update({"w": 2 * params["w"]}, state, params,
                                      lr=0.05)
    assert abs(float(params["w"][0])) < 0.05


def test_token_shards_deterministic_and_noniid():
    sh = TokenShards(vocab=128, seq_len=16, batch=4, seed=1)
    a1 = sh.batch_at(0, 0)
    a2 = sh.batch_at(0, 0)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])
    b = sh.batch_at(1, 0)
    assert not np.array_equal(a1["tokens"], b["tokens"])
    # labels are next-token shifted
    full = sh.batch_at(0, 5)
    assert full["tokens"].shape == (4, 16)


def test_imagery_shards_noniid_priors():
    sh = ImageryShards(img=16, batch=64, n_classes=10, seed=0)
    l0 = sh.batch_at(0, 0)["labels"]
    l1 = sh.batch_at(7, 0)["labels"]
    h0 = np.bincount(l0, minlength=10) / 64
    h1 = np.bincount(l1, minlength=10) / 64
    assert np.abs(h0 - h1).sum() > 0.2        # different class priors


def test_prefetch_preserves_order():
    sh = TokenShards(vocab=64, seq_len=8, batch=2, seed=0)
    it = prefetch(sh.iterate(0), size=2)
    got = [np.asarray(next(it)["tokens"]) for _ in range(3)]
    want = [sh.batch_at(0, i)["tokens"] for i in range(3)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
