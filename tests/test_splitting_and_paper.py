"""Split plans + reproduction of the paper's quantitative claims."""
import pytest

from repro import configs
from repro.core.energy import PassBudget
from repro.core.splitting import (RESNET18_PAPER_CUTS, autoencoder_plan,
                                  lm_plan, resnet18_plan)


def test_work_conserved_across_cuts():
    plan = resnet18_plan()
    total = plan.costs_at(0).w2_flops + plan.costs_at(0).w1_flops
    for i in range(plan.n_cuts):
        c = plan.costs_at(i)
        assert c.w1_flops + c.w2_flops == pytest.approx(total, rel=1e-12)


def test_d_isl_monotone_in_cut():
    plan = resnet18_plan()
    prev = -1.0
    for i in range(plan.n_cuts):
        d = plan.costs_at(i).d_isl_bits
        assert d >= prev
        prev = d


def test_table2_w_values_match_paper():
    """Paper counts W in GMAC units with train mult 3 (W1+W2 = 3 x 1.82
    GMACs of ResNet-18); ours uses 2 FLOPs/MAC so ours == 2 x paper."""
    plan = resnet18_plan(img=224, n_classes=1000)
    paper = {"l1": (1.765e9, 3.714e9), "l2": (3.006e9, 2.474e9),
             "l3": (4.243e9, 1.237e9)}
    for name, cut in RESNET18_PAPER_CUTS.items():
        c = plan.costs_at(cut)
        w1p, w2p = paper[name]
        assert c.w1_flops / 2 == pytest.approx(w1p, rel=0.08), name
        assert c.w2_flops / 2 == pytest.approx(w2p, rel=0.08), name


def test_table2_dtx_exact():
    plan = resnet18_plan(img=224, n_classes=1000)
    paper = {"l1": 6.423e6, "l2": 3.211e6, "l3": 1.605e6}
    for name, cut in RESNET18_PAPER_CUTS.items():
        assert plan.costs_at(cut).dtx_bits == pytest.approx(
            paper[name], rel=0.01), name


def test_table2_disl_matches_paper_as_segment_b():
    """Erratum #2: the paper's D_ISL equals total-params - segA."""
    plan = resnet18_plan(img=224, n_classes=1000)
    total_bits = 8.0 * sum(l.param_bytes for l in plan.layers)
    paper = {"l1": 369.056e6, "l2": 352.224e6, "l3": 285.024e6}
    for name, cut in RESNET18_PAPER_CUTS.items():
        seg_b = total_bits - plan.costs_at(cut).d_isl_bits
        assert seg_b == pytest.approx(paper[name], rel=0.02), name


def test_autoencoder_dtx_is_47kbit():
    plan = autoencoder_plan(img=224)
    assert plan.costs_at(5).dtx_bits == pytest.approx(4.7e3, rel=0.01)


def test_boundary_compression_scales_dtx_only():
    plan = resnet18_plan()
    base = plan.costs_at(5)
    q = plan.with_boundary_compression(0.25).costs_at(5)
    assert q.dtx_bits == pytest.approx(base.dtx_bits * 0.25)
    assert q.d_isl_bits == base.d_isl_bits
    assert q.w1_flops == base.w1_flops


def test_lm_plan_applies_to_every_assigned_arch():
    """DESIGN.md §4: the paper's split applies to all 10 archs."""
    for name in configs.ASSIGNED:
        cfg = configs.get(name)
        plan = lm_plan(cfg, seq_len=4096)
        assert len(plan.layers) == cfg.n_layers
        c = plan.costs_at(cfg.n_layers // 2)
        assert c.w1_flops > 0 and c.w2_flops > 0
        assert c.dtx_bits == 4096 * cfg.d_model * 32
        assert c.d_isl_bits > 0


def test_fig3_claims():
    from benchmarks.paper_tables import fig3_bottom, fig3_top
    top = fig3_top()
    # the paper's ~97% savings reproduces in the comm-dominated regime
    assert top["W_as_total(/400)"]["savings_pct"] > 90.0
    bot = fig3_bottom()
    assert bot["l1"]["e_total"] > bot["l2"]["e_total"] > bot["l3"]["e_total"]


def test_pass_duration_budget_positive_for_all_paper_splits():
    b = PassBudget()
    plan = resnet18_plan()
    for i in range(1, plan.n_cuts - 1):
        assert b.time_budget_s(plan.costs_at(i)) > 0
