"""Serve-fleet subsystem: traffic determinism, ring routing, FIFO
latency, split-decode parity, and the device scan vs the NumPy oracle
(f32 energy parity, battery clamp, backlog conservation, train-vs-serve
contention, eclipse starvation, chained runs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.fleet.scenarios import EclipseConfig
from repro.models import lm
from repro.models.layers import Ctx
from repro.serve.engine import DecodeEngine, Request
from repro.serve_fleet import router
from repro.serve_fleet.engine import (FleetServeEngine, ServeCost,
                                      ServeFleetConfig, SplitDecodeEngine,
                                      TrainLoad, assert_host_parity,
                                      host_oracle)
from repro.serve_fleet.traffic import PassWindowTraffic, TrafficConfig


def _fleet(users=60_000.0, *, train=None, eclipse=None, P=2, M=8, K=24,
           cost=None, seed=2, **cfg_kw):
    cost = cost or ServeCost(tokens_per_s=50.0, e_token_j=0.02,
                             dtx_bits_token=2048.0)
    base = dict(battery_j=60.0, recharge_w=0.02, reserve_serve_j=5.0,
                reserve_train_j=30.0, window_s=90.0)
    base.update(cfg_kw)
    scfg = ServeFleetConfig(n_planes=P, n_sats=M, n_windows=K,
                            eclipse=eclipse, **base)
    traffic = TrafficConfig(users_per_day=users, decode_len=4, seed=seed)
    return FleetServeEngine(scfg, traffic, cost, train=train)


# --------------------------------------------------------------------------
# Traffic.
# --------------------------------------------------------------------------

def test_traffic_host_twin_matches_elementwise():
    tw = PassWindowTraffic(TrafficConfig(users_per_day=50_000.0, seed=3),
                           window_s=120.0, n_planes=2)
    grid = tw.realize(6)
    assert grid.shape == (2, 6) and grid.dtype == np.int32
    for p in range(2):
        for k in range(6):
            assert int(tw(p, k)) == grid[p, k]      # same pure function


def test_traffic_diurnal_profile_and_seeding():
    cfg = TrafficConfig(users_per_day=200_000.0, diurnal_amp=0.5,
                        peak_utc_s=0.0, seed=0)
    tw = PassWindowTraffic(cfg, window_s=600.0, n_planes=1)
    peak = float(tw.rate(0))                        # near t=0 (the peak)
    trough = float(tw.rate(43_200 // 600))          # half a day later
    assert peak > 1.8 * trough
    # seeded: same config reproduces, different seed diverges
    again = PassWindowTraffic(cfg, window_s=600.0, n_planes=1)
    other = PassWindowTraffic(dataclasses.replace(cfg, seed=9),
                              window_s=600.0, n_planes=1)
    assert np.array_equal(tw.realize(8), again.realize(8))
    assert not np.array_equal(tw.realize(8), other.realize(8))


def test_traffic_scales_to_millions():
    tw = PassWindowTraffic(TrafficConfig(users_per_day=2.0e6),
                           window_s=228.0, n_planes=1)
    arr = tw.realize(4)[0]
    assert (arr > 2000).all()               # thousands of requests/window


# --------------------------------------------------------------------------
# Router.
# --------------------------------------------------------------------------

def test_serving_slot_ring_rotation_np_vs_jnp():
    member = np.array([True, False, True, True, False])
    alive = [0, 2, 3]
    for k in range(7):
        want = alive[k % 3]
        assert int(router.serving_slot(member, k, xp=np)) == want
        assert int(router.serving_slot(jnp.asarray(member),
                                       jnp.int32(k), xp=jnp)) == want
    empty = np.zeros((4,), bool)
    assert int(router.serving_slot(empty, 5, xp=np)) == -1


def test_drain_queue_carry_over():
    f32 = np.float32
    served, backlog = router.drain_queue(f32(3.0), f32(5.0), f32(6.0),
                                         True, xp=np)
    assert served == 6.0 and backlog == 2.0          # capacity-capped
    served, backlog = router.drain_queue(f32(2.0), f32(1.0), f32(6.0),
                                         False, xp=np)
    assert served == 0.0 and backlog == 3.0          # gated: all carries


def test_fifo_latency_windows_hand_example():
    # w0: 2 arrive, 1 served; w1: 0 arrive, 1 served; w2: 1 arrive, 1 served
    waits = router.fifo_latency_windows([2, 0, 1], [1, 1, 1])
    assert waits.tolist() == [0, 1, 0]
    assert router.fifo_latency_windows([3, 0], [0, 0]).size == 0


# --------------------------------------------------------------------------
# Split-decode engine.
# --------------------------------------------------------------------------

def test_split_decode_engine_matches_full_engine():
    cfg = configs.get_smoke("granite_3_2b")
    params = lm.init(cfg, jax.random.key(0))
    reqs = lambda: [Request(rid=i,
                            prompt=rng2.integers(0, cfg.vocab, 5)
                            .astype(np.int32), max_new_tokens=5)
                    for i in range(3)]
    rng2 = np.random.default_rng(1)
    full = DecodeEngine(cfg, params, n_slots=2, s_max=32,
                        act_dtype=jnp.float32).submit_and_run(reqs())
    rng2 = np.random.default_rng(1)
    eng = SplitDecodeEngine(cfg, params, cut_units=1, n_slots=2, s_max=32,
                            act_dtype=jnp.float32)
    assert eng.submit_and_run(reqs()) == full
    assert eng.boundary_bits_per_token == cfg.d_model * 32


def test_split_decode_step_boundary_and_parity():
    cfg = configs.get_smoke("granite_3_2b")
    params = lm.init(cfg, jax.random.key(0))
    ctx = Ctx(cfg=cfg, mode="decode", act_dtype=jnp.float32)
    cache = lm.init_cache(cfg, 2, 16, jnp.float32)
    toks = jnp.array([[3], [7]], jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    ref, ref_cache = lm.decode_step(cfg, params, cache, toks, pos, ctx=ctx)
    pa, pb = lm.split_serve_params(cfg, params, 1)
    got, got_cache, z = lm.decode_step_split(cfg, pa, pb, cache, toks, pos,
                                             ctx=ctx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    assert z.shape == (2, 1, cfg.d_model)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ref_cache, got_cache)


# --------------------------------------------------------------------------
# Fleet scan vs NumPy oracle.
# --------------------------------------------------------------------------

def test_fleet_scan_host_parity_and_conservation():
    train = TrainLoad(drain_j=8.0, e_total_j=12.0)
    fleet = _fleet(train=train, eclipse=EclipseConfig(period=6, duty=0.5))
    res = fleet.run()
    assert_host_parity(res, train)          # bit-exact routing + f32 energy
    assert fleet.traces == 1 and fleet.host_syncs == 1
    # every arrival is either served or still queued (per plane)
    arrived = res.arrivals.sum(axis=1)
    accounted = res.served.sum(axis=1) + res.backlog[:, -1]
    np.testing.assert_allclose(accounted, arrived, rtol=1e-6)


def test_battery_clamped_to_capacity_range():
    # huge serving drain: batteries must pin at 0, never below, and the
    # recharge clamp must never push past capacity
    cost = ServeCost(tokens_per_s=1e4, e_token_j=5.0,
                     dtx_bits_token=2048.0)
    fleet = _fleet(users=500_000.0, cost=cost, battery_j=40.0,
                   recharge_w=2.0, reserve_serve_j=0.0)
    res = fleet.run()
    assert_host_parity(res, None)
    b = np.asarray(res.energy.battery_j)
    assert res.battery_j.min() >= 0.0 and b.min() >= 0.0
    assert res.battery_j.max() <= 40.0 and b.max() <= 40.0


def test_reserve_gate_stops_serving_when_depleted():
    # no recharge at all (permanent eclipse): serving drains the ring to
    # the reserve, after which windows serve nothing and backlog grows
    cost = ServeCost(tokens_per_s=1e4, e_token_j=1.0,
                     dtx_bits_token=2048.0)
    fleet = _fleet(users=500_000.0, cost=cost, P=1, M=2, K=30,
                   battery_j=100.0, reserve_serve_j=50.0,
                   eclipse=EclipseConfig(period=4, duty=1.0))
    res = fleet.run()
    assert_host_parity(res, None)
    assert res.served[0, -1] == 0.0                  # starved
    assert res.backlog[0, -1] > 0.0
    assert (np.asarray(res.energy.battery_j) >= 0.0).all()


def test_train_vs_serve_contention():
    """Concurrent serving drain must flip trained passes into
    reserve-skips relative to the idle-constellation baseline."""
    cost = ServeCost(tokens_per_s=2000.0, e_token_j=0.5,
                     dtx_bits_token=2048.0)
    train = TrainLoad(drain_j=25.0, e_total_j=40.0)
    kw = dict(cost=cost, train=train, P=1, M=4, K=40, battery_j=100.0,
              recharge_w=0.08, reserve_serve_j=0.0, reserve_train_j=60.0)
    res_busy = _fleet(users=40_000.0, **kw).run()
    res_idle = _fleet(users=0.0, **kw).run()
    assert_host_parity(res_busy, train)
    trained_busy = int(np.asarray(res_busy.energy.passes_served).sum())
    trained_idle = int(np.asarray(res_idle.energy.passes_served).sum())
    skipped_busy = int(np.asarray(res_busy.energy.passes_skipped).sum())
    assert trained_idle == 40                       # idle: trains always
    assert trained_busy < trained_idle
    assert skipped_busy == 40 - trained_busy


def test_chained_runs_continue_the_stream():
    """Two chained runs must reproduce one long run exactly: arrivals
    fold_in on the absolute window index and state carries over."""
    mk = lambda: _fleet(train=TrainLoad(drain_j=8.0, e_total_j=12.0),
                        P=1, M=4, K=12)
    one = mk()
    r_full = one.run(24)
    two = mk()
    r_a, r_b = two.run(12), two.run(12)
    np.testing.assert_array_equal(
        np.concatenate([r_a.arrivals, r_b.arrivals], axis=1),
        r_full.arrivals)
    np.testing.assert_array_equal(
        np.concatenate([r_a.served, r_b.served], axis=1), r_full.served)
    np.testing.assert_allclose(np.asarray(two.energy.battery_j),
                               np.asarray(one.energy.battery_j),
                               rtol=1e-6, atol=1e-6)
    assert two.k == one.k == 24


def test_result_latency_and_throughput_metrics():
    fleet = _fleet(users=400_000.0, cost=ServeCost(
        tokens_per_s=2.0, e_token_j=1e-4, dtx_bits_token=2048.0))
    res = fleet.run()
    s = res.summary()
    # capacity 2 tok/s * 90 s / 4 tok = 45 req/window vs >=100 offered
    # per plane even at the diurnal trough: overload -> backlog ->
    # positive queueing delay in the p99
    assert s["final_backlog_requests"] > 0
    assert s["p99_latency_s"] > res.window_s
    assert 0.0 < s["sustained_tokens_per_s"] <= 2.0 * fleet.cfg.n_planes
    o = host_oracle(res.cfg, res.traffic, res.cost, None,
                    res.arrivals.shape[1], arrivals=res.arrivals)
    np.testing.assert_array_equal(res.served, o["served"])
