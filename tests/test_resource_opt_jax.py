"""Solver-backend parity: the jit+vmap JAX problem-(13) engine vs the
NumPy batch path vs the scalar reference oracle, element-wise over a
randomized instance grid (feasibility, E_total, phase times, KKT
residuals), plus the device-resident revolution sweep and its
zero-host-transfer bridge into the fused pass executor."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import resource_opt as ro
from repro.core.energy import PassBudget, SplitCosts, direct_download_costs
from repro.core.mission import RevolutionPlanner, sweep_revolutions
from repro.core.orbits import OrbitalPlane

roj = pytest.importorskip("repro.core.resource_opt_jax")
if not roj.available():                       # pragma: no cover
    pytest.skip("jax solver backend unavailable", allow_module_level=True)

BUDGET = PassBudget()
W_MAX = BUDGET.sat_device.peak_flops * BUDGET.plane.pass_duration_s \
    / BUDGET.n_items


def _instance_grid():
    """Feasible, comm/proc-heavy, phase-absent, infeasible, and
    Lambert-W branch-point (series-guard) instances + a random cloud."""
    cases = [
        SplitCosts(1e9, 1e9, 1e4, 1e6),              # easy feasible
        SplitCosts(3e11, 1e11, 1e6, 1e8),            # paper-scale
        SplitCosts(0.0, 1e9, 1e5, 0.0),              # no sat segment
        SplitCosts(1e9, 1e9, 0.0, 1e6),              # no comm phases
        SplitCosts(0.0, 1e6, 0.0, 0.0),              # degenerate: gs only
        SplitCosts(W_MAX * 0.9, 1e6, 1e3, 0.0),      # near the deadline
        SplitCosts(W_MAX * 1000, 1e6, 1e3, 0.0),     # infeasible budget
        SplitCosts(1e9, 1e9, 5e9, 1e6),              # comm-infeasible
        direct_download_costs(1.605e6, 3.4e9),       # fig-3 baseline
        # tiny payloads: λ·g̃ underflows the W₀ branch point, exercising
        # the series guard x ≈ √(2·λ·g̃)
        SplitCosts(0.0, 0.0, 1.0, 0.0),
        SplitCosts(0.0, 0.0, 1e-3, 0.0),
        SplitCosts(1e9, 1e9, 1.0, 1e6),
    ]
    rng = np.random.default_rng(11)
    for _ in range(28):
        cases.append(SplitCosts(
            w1_flops=float(rng.uniform(0, 5e11)),
            w2_flops=float(rng.uniform(1e6, 5e11)),
            dtx_bits=float(10.0 ** rng.uniform(-3, 7)),
            d_isl_bits=float(rng.uniform(0, 1e9))))
    return cases


def test_solve_batch_jax_matches_reference_elementwise():
    costs = _instance_grid()
    rep = roj.solve_batch_jax(BUDGET, costs)
    assert rep.n == len(costs)
    for i, c in enumerate(costs):
        ref = ro.solve_reference(BUDGET, c)
        assert bool(rep.feasible[i]) == ref.allocation.feasible, c
        assert rep.e_total[i] == pytest.approx(ref.allocation.e_total,
                                               rel=1e-6, abs=1e-12), c
        assert rep.t_total[i] == pytest.approx(ref.allocation.t_total,
                                               rel=1e-6, abs=1e-12), c
        if ref.allocation.feasible:
            assert rep.kkt_residual[i] < 1e-6


def test_solve_batch_jax_matches_numpy_phase_times():
    costs = _instance_grid()
    rj = roj.solve_batch_jax(BUDGET, costs)
    rn = ro.solve_batch(BUDGET, costs, backend="numpy")
    np.testing.assert_allclose(rj.phase_times, rn.phase_times,
                               rtol=1e-6, atol=1e-12)
    np.testing.assert_allclose(rj.phase_energy, rn.phase_energy,
                               rtol=1e-6, atol=1e-12)
    np.testing.assert_array_equal(rj.feasible, rn.feasible)
    # finite duals agree (loosely at clamp-dominated optima, where λ is
    # only identified to bisection-path noise); infeasible rows are inf
    # on both sides
    fin = np.isfinite(rn.lam) & (rn.lam > 0)
    np.testing.assert_allclose(rj.lam[fin], rn.lam[fin], rtol=1e-4)
    assert np.array_equal(np.isinf(rj.lam), np.isinf(rn.lam))


def test_backend_selector_dispatch_and_validation():
    costs = _instance_grid()[:4]
    rj = ro.solve_batch(BUDGET, costs, backend="jax")
    rn = ro.solve_batch(BUDGET, costs, backend="numpy")
    np.testing.assert_allclose(rj.e_total, rn.e_total, rtol=1e-8)
    with pytest.raises(ValueError, match="backend"):
        ro.solve_batch(BUDGET, costs, backend="fortran")
    # "auto" resolves without error at any batch size
    assert ro._resolve_backend("auto", 1) in ("numpy", "jax")
    assert ro._resolve_backend(None, 10**6) in ("numpy", "jax")


def test_shedding_batch_backend_parity():
    grid = [
        SplitCosts(1e9, 1e9, 1e4, 1e6),              # no shed
        SplitCosts(W_MAX * 2, 1e6, 1e3, 0.0),        # sheds ~0.5
        SplitCosts(W_MAX * 1000, 1e6, 1e3, 0.0),     # floor
        SplitCosts(1e9, 1e9, 5e9, 1e6),              # comm-driven shed
        SplitCosts(0.0, 1e6, 0.0, 0.0),              # gs-proc only
    ]
    sj = ro.solve_with_shedding_batch(BUDGET, grid, backend="jax")
    sn = ro.solve_with_shedding_batch(BUDGET, grid, backend="numpy")
    np.testing.assert_allclose(sj.kept_fraction, sn.kept_fraction,
                               atol=2e-4)
    np.testing.assert_allclose(sj.report.e_total, sn.report.e_total,
                               rtol=1e-8)
    # fully-device shedding (closed-form fraction) matches the host
    # bisection within its tolerance
    with roj.x64_scope():
        coeffs = roj._coeffs_from_instances(
            *ro._broadcast_instances(BUDGET, grid))
        _, frac = roj.shed_and_solve_coeffs(coeffs)
        frac = np.asarray(frac)[:len(grid)]
    np.testing.assert_allclose(frac, sn.kept_fraction, atol=2e-4)


def test_best_split_batch_backend_parity():
    from repro.core.splitting import resnet18_plan
    cands = resnet18_plan().enumerate_cuts()
    cj, repj = ro.best_split_batch(BUDGET, cands, backend="jax")
    cn, repn = ro.best_split_batch(BUDGET, cands, backend="numpy")
    assert cj.name == cn.name
    assert repj.allocation.e_total == pytest.approx(
        repn.allocation.e_total, rel=1e-8)


def test_planner_jax_backend_matches_numpy():
    ring = list(range(8))
    budgets = [PassBudget(n_items=100.0 + 150.0 * s) for s in ring]
    costs = [SplitCosts(1e9 * (s + 1), 1e9, 1e4 * (s + 1), 1e6)
             for s in ring]
    ej = RevolutionPlanner(backend="jax").plan_revolution(
        ring, budgets, costs)
    en = RevolutionPlanner(backend="numpy").plan_revolution(
        ring, budgets, costs)
    for s in ring:
        assert ej[s].allocation.e_total == pytest.approx(
            en[s].allocation.e_total, rel=1e-8)
        assert ej[s].shed.kept_fraction == pytest.approx(
            en[s].shed.kept_fraction, abs=2e-4)


# --------------------------------------------------------------------------
# On-device revolution sweeps
# --------------------------------------------------------------------------

def test_sweep_revolutions_matches_scalar_shedding_oracle():
    ring_sizes = [4, 25, 1000]
    cuts = [SplitCosts(1e9, 1e9, 1e4, 1e6, name="light"),
            SplitCosts(3e11, 1e11, 1e6, 1e8, name="paper"),
            SplitCosts(W_MAX * 3, 1e6, 1e3, 0.0, name="shed")]
    n_items = [100.0, 400.0]
    sweep = sweep_revolutions(ring_sizes, cuts, n_items)
    assert sweep.shape == (3, 3, 2)
    host = sweep.to_host()
    for i, N in enumerate(ring_sizes):
        plane = OrbitalPlane(n_sats=N)
        for j, c in enumerate(cuts):
            for b, n in enumerate(n_items):
                shed = ro.solve_with_shedding(
                    PassBudget(plane=plane, n_items=n), c)
                ref = shed.report.allocation
                assert bool(host["feasible"][i, j, b]) == ref.feasible
                assert host["kept_fraction"][i, j, b] == pytest.approx(
                    shed.kept_fraction, abs=2e-4)
                # shed cells inherit the fraction tolerance cubed through
                # the processing energy; exact cells are tight
                rel = 1e-2 if shed.kept_fraction < 1.0 else 1e-6
                assert host["e_pass"][i, j, b] == pytest.approx(
                    ref.e_total, rel=rel)
                assert host["t_pass"][i, j, b] == pytest.approx(
                    ref.t_total, rel=1e-6)
    # revolution energy scales with the ring population
    np.testing.assert_allclose(
        host["e_revolution"],
        host["e_pass"] * np.asarray(ring_sizes)[:, None, None], rtol=1e-12)
    # best_cut picks the min-energy feasible cut per (ring, budget) cell
    e = np.where(host["feasible"], host["e_pass"], np.inf)
    np.testing.assert_array_equal(host["best_cut"], np.argmin(e, axis=1))


def test_sweep_best_cut_sentinel_when_nothing_feasible():
    """A cell where even floor-shedding leaves every cut infeasible must
    report best_cut = -1, not a silent argmin-over-inf zero."""
    hopeless = SplitCosts(W_MAX * 1e6, 1e6, 1e3, 0.0, name="hopeless")
    sweep = sweep_revolutions([25], [hopeless], [400.0])
    host = sweep.to_host()
    assert not host["feasible"].any()
    assert (host["best_cut"] == -1).all()


def test_sweep_revolutions_measured_dtx_override():
    cuts = [SplitCosts(1e9, 1e9, 1e4, 1e6, name="a"),
            SplitCosts(1e9, 1e9, 1e4, 1e6, name="b")]
    base = sweep_revolutions([25], cuts, [400.0])
    bigger = sweep_revolutions([25], cuts, [400.0],
                               dtx_bits=[1e4, 5e6])   # measured payloads
    h0, h1 = base.to_host(), bigger.to_host()
    np.testing.assert_allclose(h1["e_pass"][0, 0], h0["e_pass"][0, 0],
                               rtol=1e-9)              # unchanged cut
    assert h1["e_pass"][0, 1, 0] > h0["e_pass"][0, 1, 0]  # heavier boundary


def test_sweep_steps_feed_sl_pass_without_host_sync():
    """RevolutionSweep.steps_for -> make_sl_pass(..., n_valid=...): the
    planned step count drives the fused pass as a device scalar, and
    exactly n_valid steps train (the rest are NaN-masked no-ops)."""
    from repro.core.sl_step import autoencoder_adapter, make_sl_pass
    from repro.core.train_state import SLTrainState
    from repro.data.synthetic import ImageryShards
    from repro.train.optimizer import sgd

    ad = autoencoder_adapter(cut=5, img=32)
    batch_size = 4
    sweep = sweep_revolutions([25], [ad.costs()], [3 * batch_size])
    n_valid = sweep.steps_for(batch_size)[0, 0, 0]     # device int32 scalar
    assert isinstance(n_valid, jax.Array)
    assert n_valid.dtype == jnp.int32

    shards = ImageryShards(img=32, batch=batch_size)
    batches = [jax.tree.map(jnp.asarray, shards.batch_at(0, i))
               for i in range(5)]                      # more than allocated
    state = SLTrainState.create(*ad.init(jax.random.key(0)), sgd(lr=1e-2))
    res = make_sl_pass(ad, optimizer=sgd(lr=1e-2))(state, batches,
                                                   n_valid=n_valid)
    losses = np.asarray(res.losses)
    assert losses.shape == (5,)
    assert np.isfinite(losses[:3]).all()               # planned steps ran
    assert np.isnan(losses[3:]).all()                  # beyond-plan masked
    # masked steps left the weights untouched: replaying only the first
    # 3 batches from the same init lands on identical params
    state2 = SLTrainState.create(*ad.init(jax.random.key(0)), sgd(lr=1e-2))
    res3 = make_sl_pass(ad, optimizer=sgd(lr=1e-2))(state2, batches[:3])
    for got, ref in zip(jax.tree.leaves(res.state.params_a),
                        jax.tree.leaves(res3.state.params_a)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-7)


def test_ring_boundary_bits_array_feed():
    from repro.core.sl_step import (autoencoder_adapter, boundary_bits,
                                    ring_boundary_bits)
    from repro.data.synthetic import ImageryShards

    # cut=4 keeps the boundary spatially dependent on the input size
    # (at cut=5 the AE latent collapses to 1x1 for both image sizes)
    ad = autoencoder_adapter(cut=4, img=32)
    b32 = jax.tree.map(jnp.asarray, ImageryShards(img=32, batch=4)
                       .batch_at(0, 0))
    b16 = jax.tree.map(jnp.asarray, ImageryShards(img=16, batch=4)
                       .batch_at(1, 0))
    bits = ring_boundary_bits(ad, [b32, b16, b32])
    assert bits.shape == (3,)
    assert bits[0] == boundary_bits(ad, b32)
    assert bits[1] == boundary_bits(ad, b16)
    assert bits[0] == bits[2] != bits[1]


def test_constellation_threads_per_sat_boundary_measurements():
    """Ring members with different batch shapes contribute their OWN
    measured boundary payloads to the revolution plan — one batched
    solve covers the heterogeneous ring, no replan per observation."""
    from repro import configs
    from repro.core.constellation import (ConstellationConfig,
                                          ConstellationSim)
    from repro.core.sl_step import lm_adapter
    from repro.data.synthetic import TokenShards

    cfg = configs.get_smoke("smollm_360m")
    ad = lm_adapter(cfg, cut_units=1, seq_len=16)
    # sat 1 serves shorter sequences => its boundary payload per item
    # (S · d_model · 32 bits) is half everyone else's
    long_sh = TokenShards(vocab=cfg.vocab, seq_len=16, batch=2)
    short_sh = TokenShards(vocab=cfg.vocab, seq_len=8, batch=2)

    def data(s, i):
        shards = short_sh if s == 1 else long_sh
        return jax.tree.map(jnp.asarray, shards.batch_at(s, i))

    plane = OrbitalPlane(n_sats=3)
    sim = ConstellationSim(
        ad, PassBudget(plane=plane, n_items=4.0), data,
        ConstellationConfig(n_passes=6, batch_size=2))
    recs = sim.run()
    assert all(r.action in ("trained", "shed") for r in recs)
    # per-sat measurement, not a ring-wide broadcast of sat 0's payload
    assert sim._sat_costs[1].dtx_bits == pytest.approx(
        sim._sat_costs[0].dtx_bits / 2.0)
    assert sim._sat_costs[0].dtx_bits == sim._sat_costs[2].dtx_bits
    # stable heterogeneous ring: ONE batched solve for both revolutions
    assert sim.planner.solve_calls == 1
    assert sim.planner.invalidations == 0
    # the cheaper boundary shows up in sat 1's energy accounting
    assert recs[1].e_comm_j < recs[0].e_comm_j
