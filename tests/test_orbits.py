"""Paper eqs. (1)-(5): orbital geometry."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.orbits import (C_LIGHT, OrbitalPlane, PAPER_PLANE, R_EARTH_M)


def test_table1_pass_duration_matches_paper():
    # paper: "T_pass ≈ 3.8 minutes" for Table I (h=550km, eps_min=30°)
    assert PAPER_PLANE.pass_duration_s / 60 == pytest.approx(3.8, abs=0.05)


def test_period_eq1():
    # ISS-like orbit sanity: 550 km -> ~95.5 min period
    assert PAPER_PLANE.period_s / 60 == pytest.approx(95.5, abs=0.2)


def test_slant_range_eq2_bounds():
    p = PAPER_PLANE
    # at zenith the slant range is exactly the altitude
    assert p.slant_range_m(math.pi / 2) == pytest.approx(p.altitude_m, rel=1e-9)
    # at min elevation it is the max distance
    assert p.max_slant_range_m > p.altitude_m


def test_isl_distance_eq5():
    p = PAPER_PLANE
    expected = 2 * (R_EARTH_M + p.altitude_m) * math.sin(math.pi / p.n_sats)
    assert p.isl_distance_m == pytest.approx(expected)
    # 25 sats at 550 km: ~1735 km (paper geometry)
    assert p.isl_distance_m / 1e3 == pytest.approx(1734.9, abs=1.0)


def test_mean_distance_between_min_and_max():
    p = PAPER_PLANE
    d = p.mean_slant_range_m()
    assert p.altitude_m < d < p.max_slant_range_m


def test_prop_delay():
    p = PAPER_PLANE
    assert p.mean_prop_delay_s == pytest.approx(
        p.mean_slant_range_m() / C_LIGHT)


@given(h_km=st.floats(300, 2000), eps_deg=st.floats(5, 80),
       n=st.integers(4, 200))
@settings(max_examples=50, deadline=None)
def test_geometry_invariants(h_km, eps_deg, n):
    p = OrbitalPlane(n_sats=n, altitude_m=h_km * 1e3,
                     min_elevation_rad=math.radians(eps_deg))
    assert p.period_s > 0
    assert 0 < p.pass_central_angle_rad < math.pi
    assert 0 < p.pass_duration_s < p.period_s
    # higher min elevation => shorter pass
    p2 = OrbitalPlane(n_sats=n, altitude_m=h_km * 1e3,
                      min_elevation_rad=math.radians(min(eps_deg + 5, 85)))
    assert p2.pass_duration_s <= p.pass_duration_s + 1e-9
    # more satellites => shorter ISL
    p3 = OrbitalPlane(n_sats=n + 1, altitude_m=h_km * 1e3)
    assert p3.isl_distance_m < p.isl_distance_m
