"""Per-kernel allclose sweeps: Pallas (interpret=True) and the chunked
jnp ops paths against the pure-jnp oracles, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attn import decode_attention as pallas_decode
from repro.kernels.flash_attn import flash_attention_fwd
from repro.kernels.mamba_scan import mamba_chunk_scan
from repro.kernels.mlstm_scan import mlstm_chunk_scan
from repro.kernels.split_quant import quantize_rows as pallas_quant

RNG = np.random.default_rng(42)


def rnd(*s, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(s), dtype)


ATTN_SWEEP = [
    # (B, H, KV, Sq, Skv, D, causal, window)
    (1, 2, 2, 64, 64, 16, True, None),
    (2, 4, 2, 200, 200, 32, True, None),       # GQA + ragged tail
    (2, 4, 4, 128, 128, 64, False, None),      # bidir MHA
    (1, 8, 2, 96, 96, 32, True, 48),           # sliding window
    (2, 3, 1, 65, 130, 16, False, None),       # cross-attn Sq != Skv
]


@pytest.mark.parametrize("B,H,KV,Sq,Skv,D,causal,window", ATTN_SWEEP)
def test_flash_attn_pallas_vs_ref(B, H, KV, Sq, Skv, D, causal, window):
    q, k, v = rnd(B, H, Sq, D), rnd(B, KV, Skv, D), rnd(B, KV, Skv, D)
    r = ref.attention(q, k, v, causal=causal, window=window)
    p = flash_attention_fwd(q, k, v, causal=causal, window=window,
                            block_q=64, block_k=64)
    np.testing.assert_allclose(p, r, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,KV,Sq,Skv,D,causal,window", ATTN_SWEEP)
def test_flash_attn_chunked_vs_ref(B, H, KV, Sq, Skv, D, causal, window):
    q, k, v = rnd(B, H, Sq, D), rnd(B, KV, Skv, D), rnd(B, KV, Skv, D)
    r = ref.attention(q, k, v, causal=causal, window=window)
    c = ops.flash_attention(q, k, v, causal=causal, window=window,
                            block_q=48, block_k=32, use_pallas=False)
    np.testing.assert_allclose(c, r, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attn_dtypes(dtype):
    q = rnd(1, 4, 64, 32, dtype=dtype)
    k = rnd(1, 2, 64, 32, dtype=dtype)
    v = rnd(1, 2, 64, 32, dtype=dtype)
    r = ref.attention(q, k, v, causal=True)
    p = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_k=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(p.astype(jnp.float32),
                               r.astype(jnp.float32), atol=tol, rtol=tol)
    assert p.dtype == dtype


def test_flash_attn_grads_vs_ref():
    B, H, KV, S, D = 1, 4, 2, 96, 16
    q, k, v = rnd(B, H, S, D), rnd(B, KV, S, D), rnd(B, KV, S, D)

    def f_ref(q, k, v):
        return (ref.attention(q, k, v, causal=True) ** 2).sum()

    def f_chk(q, k, v):
        return (ops.flash_attention(q, k, v, causal=True, block_q=32,
                                    block_k=32, use_pallas=False) ** 2).sum()

    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(f_chk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gc):
        np.testing.assert_allclose(b, a, atol=5e-4, rtol=5e-4)


def test_flash_attn_grads_windowed():
    B, H, KV, S, D = 1, 2, 2, 80, 16
    q, k, v = rnd(B, H, S, D), rnd(B, KV, S, D), rnd(B, KV, S, D)

    def f_ref(q, k, v):
        return (ref.attention(q, k, v, causal=True, window=32) ** 2).sum()

    def f_chk(q, k, v):
        return (ops.flash_attention(q, k, v, causal=True, window=32,
                                    block_q=16, block_k=16,
                                    use_pallas=False) ** 2).sum()

    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(f_chk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gc):
        np.testing.assert_allclose(b, a, atol=5e-4, rtol=5e-4)


DECODE_SWEEP = [
    (3, 8, 2, 130, 32, [130, 64, 1]),
    (1, 4, 4, 512, 64, [300]),
    (2, 2, 1, 64, 128, [64, 17]),
]


@pytest.mark.parametrize("B,H,KV,S,D,lens", DECODE_SWEEP)
def test_decode_attn(B, H, KV, S, D, lens):
    q = rnd(B, H, 1, D)
    k, v = rnd(B, KV, S, D), rnd(B, KV, S, D)
    lengths = jnp.asarray(lens, jnp.int32)
    r = ref.attention(q, k, v, causal=False, kv_len=lengths)
    p = pallas_decode(q, k, v, lengths, block_k=64)
    c = ops.decode_attention(q, k, v, lengths, use_pallas=False)
    np.testing.assert_allclose(p, r, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(c, r, atol=2e-5, rtol=2e-5)


MAMBA_SWEEP = [(1, 64, 2, 8, 4, 32), (2, 100, 3, 16, 8, 32),
               (1, 257, 4, 32, 16, 64)]


@pytest.mark.parametrize("B,S,H,P,N,chunk", MAMBA_SWEEP)
def test_mamba_scan(B, S, H, P, N, chunk):
    x = rnd(B, S, H, P)
    dt = jax.nn.softplus(rnd(B, S, H))
    alog = rnd(H) * 0.5
    b, c = rnd(B, S, N), rnd(B, S, N)
    yr, hr = ref.mamba_ssd(x, dt, alog, b, c)
    yp, hp = mamba_chunk_scan(x, dt, alog, b, c, chunk=chunk)
    yj, hj = ops.mamba_scan(x, dt, alog, b, c, chunk=chunk, use_pallas=False)
    np.testing.assert_allclose(yp, yr, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(hp, hr, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(yj, yr, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(hj, hr, atol=5e-4, rtol=5e-4)


def test_mamba_decode_step_matches_scan():
    B, S, H, P, N = 2, 33, 2, 8, 4
    x = rnd(B, S, H, P)
    dt = jax.nn.softplus(rnd(B, S, H))
    alog = rnd(H) * 0.5
    b, c = rnd(B, S, N), rnd(B, S, N)
    y_all, h_all = ref.mamba_ssd(x, dt, alog, b, c)
    # run scan on first S-1, then one decode step
    y0, h0 = ops.mamba_scan(x[:, :-1], dt[:, :-1], alog, b[:, :-1],
                            c[:, :-1], chunk=16, use_pallas=False)
    y1, h1 = ops.mamba_decode_step(h0, x[:, -1], dt[:, -1], alog,
                                   b[:, -1], c[:, -1])
    np.testing.assert_allclose(y1, y_all[:, -1], atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(h1, h_all, atol=5e-4, rtol=5e-4)


MLSTM_SWEEP = [(1, 64, 2, 8, 16), (2, 100, 2, 16, 32), (1, 130, 1, 32, 64)]


@pytest.mark.parametrize("B,S,H,P,chunk", MLSTM_SWEEP)
def test_mlstm_scan(B, S, H, P, chunk):
    q, k, v = rnd(B, S, H, P), rnd(B, S, H, P), rnd(B, S, H, P)
    ip, fp = rnd(B, S, H), rnd(B, S, H) + 1.0
    hr, (Cr, nr, mr) = ref.mlstm(q, k, v, ip, fp)
    hp, (Cp, np_, mp) = mlstm_chunk_scan(q, k, v, ip, fp, chunk=chunk)
    hj, (Cj, nj, mj) = ops.mlstm_scan(q, k, v, ip, fp, chunk=chunk,
                                      use_pallas=False)
    np.testing.assert_allclose(hp, hr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(Cp, Cr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np_[..., 0], nr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(hj, hr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(Cj, Cr, atol=1e-4, rtol=1e-4)


def test_mlstm_decode_step_matches_scan():
    B, S, H, P = 1, 17, 2, 8
    q, k, v = rnd(B, S, H, P), rnd(B, S, H, P), rnd(B, S, H, P)
    ip, fp = rnd(B, S, H), rnd(B, S, H)
    h_all, (C_all, n_all, m_all) = ref.mlstm(q, k, v, ip, fp)
    _, st = ops.mlstm_scan(q[:, :-1], k[:, :-1], v[:, :-1], ip[:, :-1],
                           fp[:, :-1], chunk=8, use_pallas=False)
    h1, (C1, n1, m1) = ops.mlstm_decode_step(
        st, q[:, -1], k[:, -1], v[:, -1], ip[:, -1], fp[:, -1])
    np.testing.assert_allclose(h1, h_all[:, -1], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(C1, C_all, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(m1, m_all, atol=1e-5)


@pytest.mark.parametrize("rows,d,block", [(16, 32, 8), (37, 64, 16),
                                          (5, 128, 256)])
def test_split_quant(rows, d, block):
    x = rnd(rows, d) * 7.3
    qq, ss = pallas_quant(x, block_rows=block)
    qr, sr = ref.quantize_rows(x)
    np.testing.assert_array_equal(np.asarray(qq), np.asarray(qr))
    np.testing.assert_allclose(ss, sr, rtol=1e-6)
    # dequant error bounded by scale/2 per element
    deq = ops.dequantize_boundary(qq, ss)
    assert float(jnp.max(jnp.abs(deq - x))) <= float(jnp.max(ss)) * 0.51


def test_ste_quantize_grad_passthrough():
    x = rnd(8, 16)
    g = jax.grad(lambda t: (ops.ste_quantize(t) * 3.0).sum())(x)
    np.testing.assert_allclose(g, jnp.full_like(x, 3.0))


def test_inner_unroll_equivalence():
    """The dry-run cost mode (unrolled inner scans) is numerically
    identical to the streaming mode."""
    q, k, v = rnd(1, 4, 96, 16), rnd(1, 2, 96, 16), rnd(1, 2, 96, 16)
    base = ops.flash_attention(q, k, v, causal=True, block_q=32,
                               block_k=32, use_pallas=False)
    ops.set_inner_unroll(True)
    try:
        unrolled = ops.flash_attention(q, k, v, causal=True, block_q=32,
                                       block_k=32, use_pallas=False)
    finally:
        ops.set_inner_unroll(False)
    np.testing.assert_allclose(base, unrolled, atol=1e-6)
