"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).
When it is installed, this module re-exports the real ``given`` /
``settings`` / ``strategies``.  When it is absent, it exports stand-ins
whose ``@given`` decorator replaces the test body with a
``pytest.importorskip("hypothesis")`` call — so property-based tests
report as SKIPPED instead of breaking collection of the whole module,
and every plain test in the same file still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """st.floats(...)/st.builds(...) placeholders; never executed."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def decorate(fn):
            # NOTE: varargs-only signature on purpose — pytest must not
            # try to resolve the wrapped test's parameters as fixtures.
            def skipper(*a, **k):
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn
