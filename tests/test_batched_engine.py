"""Batched pass engine: solve_batch parity vs the scalar reference
solver, best_split_batch vs the legacy sweep, and make_sl_pass parity
vs sequential make_sl_step + sgd_update calls."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import resource_opt as ro
from repro.core.energy import PassBudget, SplitCosts, direct_download_costs
from repro.core.sl_step import (autoencoder_adapter, boundary_bits,
                                make_sl_pass, make_sl_step)
from repro.core.train_state import SLTrainState
from repro.data.synthetic import ImageryShards
from repro.train.optimizer import sgd, sgd_init, sgd_update


def _sgd_state(pa, pb, lr=1e-2):
    return SLTrainState.create(pa, pb, sgd(lr=lr))

BUDGET = PassBudget()


def _grid_costs():
    """Deterministic instance grid: feasible, comm-heavy, proc-heavy,
    phase-absent, and infeasible (shedding-regime) cases."""
    w_max = BUDGET.sat_device.peak_flops * BUDGET.plane.pass_duration_s \
        / BUDGET.n_items
    cases = [
        SplitCosts(1e9, 1e9, 1e4, 1e6),              # easy feasible
        SplitCosts(3e11, 1e11, 1e6, 1e8),            # paper-scale
        SplitCosts(0.0, 1e9, 1e5, 0.0),              # no sat segment
        SplitCosts(1e9, 1e9, 0.0, 1e6),              # no comm phases
        SplitCosts(0.0, 1e6, 0.0, 0.0),              # gs-proc only
        SplitCosts(w_max * 0.9, 1e6, 1e3, 0.0),      # near the deadline
        SplitCosts(w_max * 1000, 1e6, 1e3, 0.0),     # infeasible (shed)
        SplitCosts(1e9, 1e9, 5e9, 1e6),              # comm-infeasible
        direct_download_costs(1.605e6, 3.4e9),       # fig-3 baseline
        # Lambert-W branch-point regression: tiny payloads push λ·g̃
        # below float eps, where W((λg̃−1)/e) alone returns NaN
        SplitCosts(0.0, 0.0, 1.0, 0.0),
        SplitCosts(0.0, 0.0, 1e-3, 0.0),
        SplitCosts(1e9, 1e9, 1.0, 1e6),
    ]
    rng = np.random.default_rng(7)
    for _ in range(24):
        cases.append(SplitCosts(
            w1_flops=float(rng.uniform(1e8, 3e11)),
            w2_flops=float(rng.uniform(1e8, 3e11)),
            dtx_bits=float(rng.uniform(1e3, 1e7)),
            d_isl_bits=float(rng.uniform(0, 1e9))))
    return cases


def test_solve_batch_matches_scalar_reference():
    costs = _grid_costs()
    batch = ro.solve_batch(BUDGET, costs)
    assert batch.n == len(costs)
    for i, c in enumerate(costs):
        ref = ro.solve_reference(BUDGET, c)
        assert bool(batch.feasible[i]) == ref.allocation.feasible, c
        e_ref, e_b = ref.allocation.e_total, batch.e_total[i]
        t_ref, t_b = ref.allocation.t_total, batch.t_total[i]
        assert e_b == pytest.approx(e_ref, rel=1e-6, abs=1e-12), c
        assert t_b == pytest.approx(t_ref, rel=1e-6, abs=1e-12), c
        if ref.allocation.feasible:
            assert batch.kkt_residual[i] < 1e-6


def test_solve_wrapper_equals_batch_element():
    costs = _grid_costs()[:6]
    batch = ro.solve_batch(BUDGET, costs)
    for i, c in enumerate(costs):
        rep = ro.solve(BUDGET, c)
        # identical path, but the lockstep bisection takes a different
        # iteration count per batch composition -> convergence-level noise
        assert rep.allocation.e_total == pytest.approx(
            float(batch.e_total[i]), rel=1e-9, abs=1e-15)
        assert rep.allocation.feasible == bool(batch.feasible[i])


def test_solve_batch_broadcast_and_length_check():
    costs = SplitCosts(1e9, 1e9, 1e4, 1e6)
    budgets = [PassBudget(n_items=100.0 * (j + 1)) for j in range(5)]
    rep = ro.solve_batch(budgets, costs)
    assert rep.n == 5
    # more items => more energy (monotone sanity across the broadcast)
    assert np.all(np.diff(rep.e_total) > 0)
    with pytest.raises(ValueError):
        ro.solve_batch(budgets, [costs, costs])


def test_solve_batch_vs_scipy():
    scipy_opt = pytest.importorskip("scipy.optimize")
    costs = [c for c in _grid_costs()[:6]]
    rep = ro.solve_batch(BUDGET, costs)
    for i, c in enumerate(costs):
        if not rep.feasible[i]:
            continue
        phases = [p for p in ro._build_phases(BUDGET, c) if p is not None]
        if len(phases) < 2:
            continue
        T = BUDGET.time_budget_s(c)
        x0 = np.array([T / len(phases)] * len(phases))
        res = scipy_opt.minimize(
            lambda x: sum(p.energy(t) for p, t in zip(phases, x)), x0,
            bounds=[(p.t_min, None) for p in phases],
            constraints=[{"type": "ineq", "fun": lambda x: T - x.sum()}],
            method="SLSQP", options={"maxiter": 800, "ftol": 1e-16})
        e_var = rep.e_total[i] - rep.e_isl[i]
        assert e_var <= res.fun * (1 + 1e-4) + 1e-12

@given(w1=st.floats(0, 5e12), w2=st.floats(1e6, 5e12),
       dtx=st.floats(1e2, 5e9), disl=st.floats(0, 1e9))
@settings(max_examples=40, deadline=None)
def test_solve_batch_matches_reference_property(w1, w2, dtx, disl):
    c = SplitCosts(w1_flops=w1, w2_flops=w2, dtx_bits=dtx, d_isl_bits=disl)
    ref = ro.solve_reference(BUDGET, c)
    batch = ro.solve_batch(BUDGET, [c])
    assert bool(batch.feasible[0]) == ref.allocation.feasible
    if np.isfinite(ref.allocation.e_total):
        assert batch.e_total[0] == pytest.approx(ref.allocation.e_total,
                                                 rel=1e-6, abs=1e-12)


def test_best_split_batch_matches_scalar_sweep():
    from repro.core.splitting import resnet18_plan
    cands = resnet18_plan().enumerate_cuts()

    # legacy scalar sweep (what best_split did before the batch path)
    best = None
    for c in cands:
        rep = ro.solve_reference(BUDGET, c)
        if not rep.allocation.feasible:
            continue
        if best is None or rep.allocation.e_total < best[1].allocation.e_total:
            best = (c, rep)
    cb, rb = ro.best_split_batch(BUDGET, cands)
    assert cb.name == best[0].name
    assert rb.allocation.e_total == pytest.approx(
        best[1].allocation.e_total, rel=1e-6)


def test_best_split_batch_infeasible_falls_back_to_shedding():
    w_max = BUDGET.sat_device.peak_flops * BUDGET.plane.pass_duration_s \
        / BUDGET.n_items
    cands = [SplitCosts(w_max * 100, 1e6, 1e3, 0.0, name="c100"),
             SplitCosts(w_max * 2, 1e6, 1e3, 0.0, name="c2")]
    c, rep = ro.best_split_batch(BUDGET, cands)
    assert c.name == "c2"          # sheds the least
    assert rep.allocation.feasible


def test_report_at_consistent_with_arrays():
    costs = _grid_costs()
    batch = ro.solve_batch(BUDGET, costs)
    for i in (0, 1, 6, 8):
        rep = batch.report_at(i)
        assert rep.allocation.e_total == pytest.approx(
            float(batch.e_total[i]), rel=1e-9, abs=1e-15)
        assert rep.allocation.feasible == bool(batch.feasible[i])


# --------------------------------------------------------------------------
# make_sl_pass vs sequential make_sl_step
# --------------------------------------------------------------------------

SHARDS = ImageryShards(img=32, batch=4)


def _batches(k, shard=0):
    return [jax.tree.map(jnp.asarray, SHARDS.batch_at(shard, i))
            for i in range(k)]


def _sequential(adapter, pa, pb, batches, lr=1e-2, quantize=False):
    step = make_sl_step(adapter, quantize_boundary=quantize)
    oa, ob = sgd_init(pa), sgd_init(pb)
    losses = []
    for bt in batches:
        r = step(pa, pb, bt)
        pa, oa, _ = sgd_update(r.grads_a, oa, pa, lr=lr)
        pb, ob, _ = sgd_update(r.grads_b, ob, pb, lr=lr)
        losses.append(float(r.loss))
    return np.asarray(losses), pa, pb, r


def test_bucket_schedule():
    from repro.core.sl_step import _bucket_size
    assert [_bucket_size(k) for k in (1, 2, 3, 5, 16)] == [1, 2, 4, 8, 16]
    # above 16: 1/8-octave granularity, padding bounded at 25%
    for k in range(17, 400):
        kb = _bucket_size(k)
        assert kb >= k
        assert (kb - k) / k <= 0.25


@pytest.mark.parametrize("k", [1, 4, 5, 17])
def test_sl_pass_matches_sequential_steps(k):
    """k fused scan steps == k sequential step+update calls; k=5 and
    k=17 also exercise the bucketing (padded steps must be no-ops)."""
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    batches = _batches(k)

    losses_ref, pa_ref, pb_ref, last = _sequential(ad, pa, pb, batches)
    res = make_sl_pass(ad, lr=1e-2)(_sgd_state(pa, pb), batches)
    assert res.n_steps == k
    assert res.losses.shape == (k,)
    np.testing.assert_allclose(np.asarray(res.losses), losses_ref,
                               rtol=1e-5, atol=1e-6)
    for got, ref in zip(jax.tree.leaves(res.params_a),
                        jax.tree.leaves(pa_ref)):
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    for got, ref in zip(jax.tree.leaves(res.params_b),
                        jax.tree.leaves(pb_ref)):
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    # measured boundary payload matches the probe-step measurement
    assert res.dtx_bits_down == last.dtx_bits_down
    assert res.dtx_bits_down == boundary_bits(ad, batches[0])


def test_sl_pass_quantized_boundary_parity():
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(2))
    batches = _batches(3, shard=1)
    losses_ref, _, _, last = _sequential(ad, pa, pb, batches, quantize=True)
    res = make_sl_pass(ad, quantize_boundary=True, lr=1e-2)(
        _sgd_state(pa, pb), batches)
    np.testing.assert_allclose(np.asarray(res.losses), losses_ref,
                               rtol=1e-5, atol=1e-6)
    assert res.dtx_bits_down == last.dtx_bits_down   # int8: 4x smaller


def test_sl_pass_ragged_batches_match_sequential():
    """A partial final batch (real datasets) must not crash the stack:
    same-shape groups are scanned and chained, matching sequential."""
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(3))
    full = _batches(3, shard=2)
    partial = jax.tree.map(lambda x: x[:2], _batches(4, shard=2)[3])
    batches = full + [partial]

    losses_ref, pa_ref, _, _ = _sequential(ad, pa, pb, batches)
    res = make_sl_pass(ad, lr=1e-2)(_sgd_state(pa, pb), batches)
    assert res.n_steps == 4
    np.testing.assert_allclose(np.asarray(res.losses), losses_ref,
                               rtol=1e-5, atol=1e-6)
    for got, ref in zip(jax.tree.leaves(res.params_a),
                        jax.tree.leaves(pa_ref)):
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


def test_constellation_streams_chunks():
    """pass_chunk_steps smaller than n_steps: the pass runs in several
    chained scans and still consumes every allocated batch."""
    from repro.core.constellation import (ConstellationConfig,
                                          ConstellationSim)

    def data(s, i):
        return jax.tree.map(jnp.asarray, SHARDS.batch_at(s, i))

    ad = autoencoder_adapter(cut=5, img=32)
    sim = ConstellationSim(ad, PassBudget(n_items=40.0), data,
                           ConstellationConfig(n_passes=1, batch_size=4,
                                               pass_chunk_steps=4))
    recs = sim.run()
    assert recs[0].action == "trained"
    assert sim._batch_idx == 10        # 40 items / batch 4, chunks of 4


def test_sl_pass_accepts_prestacked_batches():
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    batches = _batches(2)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    # donate=False: the default donates the param buffers to the jitted
    # call, so the same arrays cannot feed two separate passes.
    state = _sgd_state(pa, pb)
    r_list = make_sl_pass(ad, donate=False)(state, batches)
    r_stk = make_sl_pass(ad, donate=False)(state, stacked)
    np.testing.assert_allclose(np.asarray(r_list.losses),
                               np.asarray(r_stk.losses), rtol=1e-6)


def test_sl_pass_rejects_legacy_4_tuple_call():
    """The PR-2 deprecation shim is gone: the 4-tuple call raises."""
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    with pytest.raises(TypeError, match="SLTrainState"):
        make_sl_pass(ad)(pa, _batches(1))


def test_constellation_runs_beyond_old_16_step_cap():
    """96 items / batch 4 = 24 fused steps — more than the removed cap."""
    from repro.core.constellation import (ConstellationConfig,
                                          ConstellationSim)

    def data(s, i):
        return jax.tree.map(jnp.asarray, SHARDS.batch_at(s, i))

    ad = autoencoder_adapter(cut=5, img=32)
    sim = ConstellationSim(ad, PassBudget(n_items=96.0), data,
                           ConstellationConfig(n_passes=1, batch_size=4))
    recs = sim.run()
    assert recs[0].action == "trained"
    assert recs[0].n_items == pytest.approx(96.0)
    assert sim._batch_idx == 24        # all 24 steps consumed, one pass
