"""Serving correctness: token-by-token decode against the KV cache must
reproduce the full-sequence forward logits (per architecture family),
and prefill->decode must agree with decode-from-scratch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.models.layers import Ctx

PARITY_ARCHS = ["granite_3_2b", "llama3_8b", "mixtral_8x7b", "xlstm_1_3b",
                "zamba2_1_2b", "qwen2_vl_7b", "phi35_moe"]


def _decode_all(cfg, params, tokens, s_max, ctx):
    B, S = tokens.shape
    cache = lm.init_cache(cfg, B, s_max, jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = lm.decode_step(
            cfg, params, cache, tokens[:, t:t + 1],
            jnp.full((B,), t, jnp.int32), ctx=ctx)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), cache


@pytest.mark.parametrize("name", PARITY_ARCHS)
def test_decode_matches_forward(name):
    cfg = configs.get_smoke(name)
    if cfg.window is not None:
        cfg = dataclasses.replace(cfg, window=8)
    if cfg.n_experts:
        # capacity dropping is order-dependent by design (GShard); use
        # ample capacity so the parity check is exact
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = lm.init(cfg, jax.random.key(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    ctx = Ctx(cfg=cfg, act_dtype=jnp.float32)

    full, _, _ = lm.forward(cfg, params, tokens, ctx=ctx)
    dctx = dataclasses.replace(ctx, mode="decode")
    dec, _ = _decode_all(cfg, params, tokens, s_max=S + 4, ctx=dctx)
    np.testing.assert_allclose(dec, full, atol=2e-3, rtol=2e-3)


def test_swa_ring_cache_matches_forward_beyond_window():
    """Sequence longer than the SWA window: the ring buffer must agree
    with the full windowed forward."""
    cfg = dataclasses.replace(configs.get_smoke("mixtral_8x7b"), window=6,
                              capacity_factor=16.0)
    params = lm.init(cfg, jax.random.key(0))
    B, S = 1, 15
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    ctx = Ctx(cfg=cfg, act_dtype=jnp.float32)
    full, _, _ = lm.forward(cfg, params, tokens, ctx=ctx)
    dctx = dataclasses.replace(ctx, mode="decode")
    dec, _ = _decode_all(cfg, params, tokens, s_max=64, ctx=dctx)
    np.testing.assert_allclose(dec, full, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("name", ["granite_3_2b", "zamba2_1_2b",
                                  "whisper_small", "mixtral_8x7b"])
def test_prefill_then_decode(name):
    """prefill(0..T0) -> cache_from_prefill -> decode(T0..S) must equal
    the full forward on the suffix."""
    cfg = configs.get_smoke(name)
    if cfg.window is not None:
        cfg = dataclasses.replace(cfg, window=8)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = lm.init(cfg, jax.random.key(0))
    B, S, T0 = 2, 14, 9
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.frontend == "audio":
        kw["enc_frames"] = 0.01 * jnp.ones(
            (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    ctx = Ctx(cfg=cfg, act_dtype=jnp.float32)

    full, _, _ = lm.forward(cfg, params, tokens, ctx=ctx, **kw)

    pctx = dataclasses.replace(ctx, mode="prefill")
    _, _, caches = lm.forward(cfg, params, tokens[:, :T0], ctx=pctx, **kw)
    s_max = S + 4
    cache = lm.cache_from_prefill(cfg, caches, s_max, jnp.float32)
    dctx = dataclasses.replace(ctx, mode="decode")
    for t in range(T0, S):
        logits, cache = lm.decode_step(
            cfg, params, cache, tokens[:, t:t + 1],
            jnp.full((B,), t, jnp.int32), ctx=dctx)
        np.testing.assert_allclose(logits[:, 0], full[:, t],
                                   atol=2e-3, rtol=2e-3, err_msg=f"t={t}")


def test_long_context_cells_use_subquadratic_archs_only():
    from repro.configs.shapes import SHAPES, applicable
    long = SHAPES["long_500k"]
    ok = {a for a in configs.ASSIGNED if applicable(configs.get(a), long)}
    assert ok == {"xlstm_1_3b", "zamba2_1_2b", "mixtral_8x7b"}
