"""ISL comms subsystem (repro.isl): contact-window arithmetic, codec
bit metering, exchange configuration, device-vs-host-oracle bit parity
for async gossip and sync codec exchange, beyond-horizon contact
continuation on chained runs, and the problem-(13) plan feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.energy import PassBudget
from repro.core.linkbudget import ISLConfig, LinkConfig
from repro.core.orbits import OrbitalPlane
from repro.core.sl_step import autoencoder_adapter
from repro.fleet import FleetConfig, FleetEngine, oracle_actions
from repro.isl import (CodecConfig, ContactConfig, ExchangeConfig,
                       codec_label, delta_payload_bits, encode_delta,
                       exchange_events, oracle_exchange, residual_init,
                       staleness_weight)
from repro.obs.ring import EV_EXCHANGE
from repro.sim.data import DeviceImageryShards
from repro.sim.device_sim import ACTION_TRAINED

SHARDS = DeviceImageryShards(img=32, batch=4)
ADAPTER = autoencoder_adapter(cut=5, img=32)


def _budget(n_sats=4, **kw):
    return PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=4e6, **kw)


def _fleet(budget, **cfg_kw):
    kw = dict(n_planes=2, n_revolutions=2, max_steps_per_pass=2, seed=0)
    kw.update(cfg_kw)
    return FleetEngine(ADAPTER, budget, SHARDS, FleetConfig(**kw))


# ------------------------------------------------------- contact model

def test_contact_config_schedule_arithmetic():
    """open/offset/partner are pure modular arithmetic — Python ints,
    NumPy arrays and traced scalars agree, beyond any horizon."""
    cc = ContactConfig(period=3, phase=1, offsets=(1, 2))
    opens = [bool(cc.open_at(k)) for k in range(7)]
    assert opens == [False, False, True, False, False, True, False]
    # contact 1 at k=2 uses offsets[1 % 2]=2, contact 2 at k=5 offset 1
    assert int(cc.offset_at(2)) == 2 and int(cc.offset_at(5)) == 1
    assert int(cc.partner(3, 2, n_planes=4)) == (3 + 2) % 4
    assert cc.contacts_in(7) == 2 and cc.contacts_in(7, start=7) == 2
    # traced: the same expression inside jit
    assert bool(jax.jit(lambda k: cc.open_at(k))(5))
    assert int(jax.jit(lambda k: cc.offset_at(k, xp=jnp))(5)) == 1
    with pytest.raises(ValueError, match="period"):
        ContactConfig(period=0)
    with pytest.raises(ValueError, match="window"):
        ContactConfig(window_s=0.0)
    with pytest.raises(ValueError, match="offset"):
        ContactConfig(offsets=())


def test_contact_rates_capacity_energy():
    isl = ISLConfig(rate_bps=1e6, tx_power_w=2.0)
    cc = ContactConfig(window_s=0.5)
    assert cc.rate_bps(isl) == 1e6
    assert cc.capacity_bits(isl) == 5e5
    # E = pw * bits / rate
    assert cc.tx_energy_j(1e6, isl) == pytest.approx(2.0)
    # with a distance + LinkConfig, the eq.-(8) Shannon rate applies
    link = LinkConfig()
    cs = ContactConfig(window_s=0.5, distance_m=1e6)
    assert cs.rate_bps(isl, link) == pytest.approx(
        link.rate_bps(2.0, 1e6))
    assert cs.rate_bps(isl, None) == 1e6   # no link model -> fixed rate


# -------------------------------------------------------------- codec

def test_codec_labels_and_monotone_bits():
    tree = {"w": jnp.zeros((32, 32)), "b": jnp.zeros((32,))}
    cs = [CodecConfig("none"), CodecConfig("int8"),
          CodecConfig("topk", topk_ratio=0.10),
          CodecConfig("topk", topk_ratio=0.01)]
    assert [codec_label(c) for c in cs] == \
        ["none", "int8", "topk10pc", "topk1pc"]
    bits = [delta_payload_bits(tree, c) for c in cs]
    assert bits == sorted(bits, reverse=True) and bits[-1] > 0
    with pytest.raises(ValueError, match="scheme"):
        CodecConfig("fft")
    with pytest.raises(ValueError, match="ratio"):
        CodecConfig("topk", topk_ratio=0.0)


def test_encode_delta_none_is_exact_and_ef_accumulates():
    params = {"w": jnp.arange(8.0)}
    anchor = {"w": jnp.zeros((8,))}
    resid = residual_init(params)
    kept, r2 = encode_delta(params, anchor, resid, CodecConfig("none"))
    np.testing.assert_array_equal(np.asarray(kept["w"]),
                                  np.arange(8.0, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(r2["w"]), np.zeros(8))
    # top-k at 1/8 keeps the largest entry; the rest rides the residual
    kept, r2 = encode_delta(params, anchor, resid,
                            CodecConfig("topk", topk_ratio=1 / 8))
    assert int((np.asarray(kept["w"]) != 0).sum()) == 1
    np.testing.assert_allclose(np.asarray(kept["w"] + r2["w"]),
                               np.arange(8.0), rtol=1e-7)


def test_exchange_config_validation_and_amortization():
    with pytest.raises(ValueError, match="mode"):
        ExchangeConfig(mode="carrier_pigeon")
    with pytest.raises(ValueError, match="mix"):
        ExchangeConfig(mix=0.0)
    with pytest.raises(ValueError, match="staleness"):
        ExchangeConfig(staleness_lam=-1.0)
    a = ExchangeConfig(mode="async", contact=ContactConfig(period=4))
    assert a.mean_contacts_per_pass(8, 1) == pytest.approx(0.25)
    s = ExchangeConfig(mode="sync")
    assert s.mean_contacts_per_pass(8, 2) == pytest.approx(1 / 16)
    assert s.mean_contacts_per_pass(8, 0) == 0.0
    # staleness weight: mix at s=0, discounted hyperbolically after
    assert staleness_weight(0, 0.5, 0.1) == np.float32(0.5)
    assert staleness_weight(10.0, 0.5, 0.1) == pytest.approx(0.25)


# ------------------------------------------- device-vs-oracle parity

def test_async_int8_gossip_matches_host_oracles():
    """Every action and every contact row (t / paying slot / bits /
    joules / staleness / merge weight) of an async int8 gossip fleet
    replays bit-exactly on the host — the repro.isl analogue of the
    degraded-ops action oracle."""
    fleet = _fleet(_budget(), avg_every=0, exchange=ExchangeConfig(
        mode="async", codec=CodecConfig("int8"),
        contact=ContactConfig(period=2, offsets=(1,)),
        mix=0.4, staleness_lam=0.2))
    assert fleet._ex_on and fleet._ex_bits > 0
    expect_act = oracle_actions(fleet)
    expect_ex = oracle_exchange(fleet)
    res = fleet.run()
    np.testing.assert_array_equal(res.action, expect_act)
    got = exchange_events(fleet.recorder)
    assert got["t"].size == expect_ex["t"].size > 0
    for col in ("t", "aggregate", "slot", "bits", "e_isl_j",
                "staleness", "weight"):
        np.testing.assert_array_equal(got[col], expect_ex[col], col)
    # the meter moved, training stayed finite, sync contract held
    assert float(res.isl_bits.sum()) > 0
    assert float(res.isl_e_j.sum()) > 0
    finite = res.loss[np.isfinite(res.loss)]
    assert finite.size and np.isfinite(finite).all()
    assert fleet.traces == 1
    assert fleet.host_syncs <= fleet.cfg.n_revolutions


def test_exchange_payload_flows_into_timeline():
    """EV_EXCHANGE rows carry {bits, e_isl_j, staleness} through the
    flight recorder into the chrome trace and the text summary."""
    from repro.obs.timeline import timeline_summary, to_chrome_trace

    fleet = _fleet(_budget(), n_revolutions=1, avg_every=1,
                   exchange=ExchangeConfig(mode="sync"))
    fleet.run()
    ev = fleet.recorder.events()
    assert int((ev["kind"] == EV_EXCHANGE).sum()) > 0
    trace = to_chrome_trace(ev)
    ex = [e for e in trace["traceEvents"]
          if e.get("cat") == "exchange" and e["ph"] == "i"]
    assert ex and all(e["args"]["bits"] > 0 for e in ex)
    assert "bits" in timeline_summary(ev)


def test_beyond_horizon_contacts_continue_on_chained_runs():
    """Chained runs past the precomputed horizon keep exchanging on
    schedule — the contact model is arithmetic on the absolute pass
    index, not a precomputed table (mirrors the fold_in refresh
    contract of failures/epidemics)."""
    fleet = _fleet(_budget(), n_revolutions=1, avg_every=0,
                   exchange=ExchangeConfig(
                       mode="async",
                       codec=CodecConfig("topk", topk_ratio=0.01),
                       contact=ContactConfig(period=2)))
    K = fleet.n_passes          # == the precomputed schedule horizon
    assert K == fleet.schedule.n_passes
    per_run = fleet.exchange.contact.contacts_in(K)
    res1 = fleet.run()
    assert int(res1.isl_contacts.sum()) == per_run * fleet.n_planes
    res2 = fleet.run()          # passes [K, 2K): beyond the horizon
    assert int(res2.isl_contacts.sum()) == \
        (per_run + fleet.exchange.contact.contacts_in(K, start=K)) \
        * fleet.n_planes
    # same compiled program, one sync per dispatch
    assert fleet.traces == 1 and fleet.host_syncs <= 2
    # recorded contact times include the beyond-horizon opens, on
    # schedule (the ring may have rotated out first-run events)
    ev = fleet.recorder.events()
    t_ex = set(np.unique(ev["t"][ev["kind"] == EV_EXCHANGE]).tolist())
    beyond = {k for k in range(K, 2 * K)
              if fleet.exchange.contact.open_at(k)}
    assert beyond and beyond <= t_ex
    # and training kept advancing out there
    finite = res2.loss[np.isfinite(res2.loss)]
    assert finite.size and np.isfinite(finite).all()


# ----------------------------------------- problem-(13) plan feedback

def test_plans_differ_across_compression_levels():
    """The charged ISL bit volume is a planner input: compression level
    changes the problem-(13) allocation, not just a counter
    (acceptance criterion (c) at unit level)."""
    plans = {}
    for codec in (CodecConfig("none"),
                  CodecConfig("topk", topk_ratio=0.01)):
        f = _fleet(_budget(), n_revolutions=1, avg_every=0,
                   exchange=ExchangeConfig(mode="async", codec=codec,
                                           contact=ContactConfig()))
        plans[codec.scheme] = f.plan
    d_none = np.asarray(plans["none"].d_isl_bits)
    d_topk = np.asarray(plans["topk"].d_isl_bits)
    assert (d_none > d_topk).all()
    e_none = np.asarray(plans["none"].e_isl_j)
    e_topk = np.asarray(plans["topk"].e_isl_j)
    assert (e_none > e_topk).all()
    # time moves the same way (the per-pass ISL seconds can round away
    # at f32 against a ~200 s pass, so non-strict)
    assert (np.asarray(plans["none"].t_total_s)
            >= np.asarray(plans["topk"].t_total_s)).all()


def test_sync_topk_full_ratio_tracks_legacy_barrier():
    """Top-k at ratio 1.0 keeps every entry, so the sync codec exchange
    reduces to the legacy mean barrier up to reconstruction rounding
    (anchor + (params - anchor) vs params)."""
    legacy = _fleet(_budget(), n_revolutions=1, avg_every=1)
    res_l = legacy.run()
    f = _fleet(_budget(), n_revolutions=1, avg_every=1,
               exchange=ExchangeConfig(
                   mode="sync", codec=CodecConfig("topk",
                                                  topk_ratio=1.0)))
    res_s = f.run()
    np.testing.assert_array_equal(res_l.action, res_s.action)
    for a, b in zip(jax.tree.leaves((res_l.state.params_a,
                                     res_l.state.params_b)),
                    jax.tree.leaves((res_s.state.params_a,
                                     res_s.state.params_b))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    assert float(res_s.isl_bits.sum()) > float(res_l.summary()
                                               ["ISL_exchange_bits"])


def test_over_capacity_payload_never_transfers():
    """A payload larger than rate * window_s does not cross the link:
    the exchange is disabled outright (bandwidth-limited, not merely
    priced), and the oracle agrees there is nothing to replay."""
    fleet = _fleet(_budget(), n_revolutions=1, avg_every=0,
                   exchange=ExchangeConfig(
                       mode="async", contact=ContactConfig(window_s=1e-6)))
    assert not fleet._ex_on and fleet._ex_bits > fleet._ex_cap_bits
    assert oracle_exchange(fleet)["t"].size == 0
    res = fleet.run()
    ev = fleet.recorder.events()
    assert int((ev["kind"] == EV_EXCHANGE).sum()) == 0
    assert float(res.isl_bits.sum()) == 0.0
    assert (res.action == ACTION_TRAINED).any()
