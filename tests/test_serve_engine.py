"""Serving engine: continuous batching, greedy parity with forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm
from repro.models.layers import Ctx
from repro.serve.engine import DecodeEngine, Request


def test_engine_serves_all_requests():
    cfg = configs.get_smoke("granite_3_2b")
    params = lm.init(cfg, jax.random.key(0))
    eng = DecodeEngine(cfg, params, n_slots=2, s_max=48,
                       act_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5)
                    .astype(np.int32), max_new_tokens=6) for i in range(5)]
    out = eng.submit_and_run(reqs)
    assert set(out) == {0, 1, 2, 3, 4}          # continuous batching refilled
    assert all(len(v) == 6 for v in out.values())


def test_engine_greedy_matches_forward_argmax():
    """Single-slot generation must equal greedy decoding computed by
    repeatedly running the full forward (the O(S^2) oracle)."""
    cfg = configs.get_smoke("granite_3_2b")
    params = lm.init(cfg, jax.random.key(0))
    prompt = np.array([3, 7, 11, 2], np.int32)
    new = 5

    # oracle: greedy via full forward
    ctx = Ctx(cfg=cfg, act_dtype=jnp.float32)
    seq = list(prompt)
    oracle = []
    for _ in range(new):
        logits, _, _ = lm.forward(cfg, params,
                                  jnp.asarray([seq], jnp.int32), ctx=ctx)
        nxt = int(jnp.argmax(logits[0, -1]))
        oracle.append(nxt)
        seq.append(nxt)

    eng = DecodeEngine(cfg, params, n_slots=1, s_max=32,
                       act_dtype=jnp.float32)
    out = eng.submit_and_run([Request(rid=0, prompt=prompt,
                                      max_new_tokens=new)])
    # engine records the token *consumed* at each step: first entry is
    # the model's continuation of the prompt, etc.
    assert out[0] == oracle
