"""Serving engine: continuous batching, greedy parity with forward,
bulk-vs-loop prefill, Pallas decode routing, and edge cases."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.models.layers import Ctx
from repro.serve.engine import DecodeEngine, Request


def _granite():
    cfg = configs.get_smoke("granite_3_2b")
    return cfg, lm.init(cfg, jax.random.key(0))


def _requests(cfg, n, prompt_len=5, new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, prompt_len)
                    .astype(np.int32), max_new_tokens=new)
            for i in range(n)]


def test_engine_serves_all_requests():
    cfg = configs.get_smoke("granite_3_2b")
    params = lm.init(cfg, jax.random.key(0))
    eng = DecodeEngine(cfg, params, n_slots=2, s_max=48,
                       act_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5)
                    .astype(np.int32), max_new_tokens=6) for i in range(5)]
    out = eng.submit_and_run(reqs)
    assert set(out) == {0, 1, 2, 3, 4}          # continuous batching refilled
    assert all(len(v) == 6 for v in out.values())


def test_engine_greedy_matches_forward_argmax():
    """Single-slot generation must equal greedy decoding computed by
    repeatedly running the full forward (the O(S^2) oracle)."""
    cfg = configs.get_smoke("granite_3_2b")
    params = lm.init(cfg, jax.random.key(0))
    prompt = np.array([3, 7, 11, 2], np.int32)
    new = 5

    # oracle: greedy via full forward
    ctx = Ctx(cfg=cfg, act_dtype=jnp.float32)
    seq = list(prompt)
    oracle = []
    for _ in range(new):
        logits, _, _ = lm.forward(cfg, params,
                                  jnp.asarray([seq], jnp.int32), ctx=ctx)
        nxt = int(jnp.argmax(logits[0, -1]))
        oracle.append(nxt)
        seq.append(nxt)

    eng = DecodeEngine(cfg, params, n_slots=1, s_max=32,
                       act_dtype=jnp.float32)
    out = eng.submit_and_run([Request(rid=0, prompt=prompt,
                                      max_new_tokens=new)])
    # engine records the token *consumed* at each step: first entry is
    # the model's continuation of the prompt, etc.
    assert out[0] == oracle


# --------------------------------------------------------------------------
# Bulk prefill (the _prefill_into_slot fix) and Pallas decode routing.
# --------------------------------------------------------------------------

def test_bulk_prefill_matches_loop_reference():
    """The bulk prefill path (forward in prefill mode + cache splice)
    must generate the same greedy tokens as the legacy token-by-token
    loop on an attention-only arch (where the loop's zero-token writes
    into other slots are overwritten and thus merely wasteful)."""
    cfg, params = _granite()

    def run(mode):
        eng = DecodeEngine(cfg, params, n_slots=2, s_max=48,
                           act_dtype=jnp.float32, prefill=mode)
        return eng.submit_and_run(_requests(cfg, 4))

    assert run("bulk") == run("loop")


def test_bulk_prefill_isolates_recurrent_slots():
    """On an arch with recurrent state (zamba2: mamba2 blocks) the loop
    prefill corrupted every OTHER live slot's state by pushing zero
    tokens through the full batch; bulk prefill must leave concurrent
    slots untouched, so multi-slot output == one-request-at-a-time
    output."""
    cfg = configs.get_smoke("zamba2_1_2b")
    params = lm.init(cfg, jax.random.key(1))
    reqs = _requests(cfg, 3, new=4)

    solo = {}
    for r in reqs:
        eng = DecodeEngine(cfg, params, n_slots=1, s_max=32,
                           act_dtype=jnp.float32)
        solo.update(eng.submit_and_run(
            [dataclasses.replace(r, out_tokens=None)]))

    eng = DecodeEngine(cfg, params, n_slots=3, s_max=32,
                       act_dtype=jnp.float32)
    batched = eng.submit_and_run(
        [dataclasses.replace(r, out_tokens=None) for r in reqs])
    assert batched == solo


def test_engine_pallas_decode_parity():
    """use_pallas=True routes decode attention through the Pallas
    flash-decode kernel (interpret mode on CPU); greedy outputs must
    match the reference jnp path exactly."""
    cfg, params = _granite()

    def run(flag):
        eng = DecodeEngine(cfg, params, n_slots=2, s_max=32,
                           act_dtype=jnp.float32, use_pallas=flag)
        return eng.submit_and_run(_requests(cfg, 3, new=4))

    assert run(False) == run(True)


# --------------------------------------------------------------------------
# Edge cases.
# --------------------------------------------------------------------------

def test_zero_new_tokens_completes_immediately():
    cfg, params = _granite()
    eng = DecodeEngine(cfg, params, n_slots=2, s_max=32,
                       act_dtype=jnp.float32)
    reqs = _requests(cfg, 3)
    reqs[1] = dataclasses.replace(reqs[1], max_new_tokens=0)
    out = eng.submit_and_run(reqs)
    assert out[1] == []
    assert len(out[0]) == 6 and len(out[2]) == 6


def test_all_zero_budget_requests():
    cfg, params = _granite()
    eng = DecodeEngine(cfg, params, n_slots=2, s_max=32,
                       act_dtype=jnp.float32)
    out = eng.submit_and_run([
        dataclasses.replace(r, max_new_tokens=0)
        for r in _requests(cfg, 2)])
    assert out == {0: [], 1: []}


def test_prompt_at_least_s_max_raises():
    cfg, params = _granite()
    eng = DecodeEngine(cfg, params, n_slots=1, s_max=8,
                       act_dtype=jnp.float32)
    with pytest.raises(ValueError, match="s_max"):
        eng.submit_and_run(_requests(cfg, 1, prompt_len=8))


def test_empty_request_list():
    cfg, params = _granite()
    eng = DecodeEngine(cfg, params, n_slots=2, s_max=32,
                       act_dtype=jnp.float32)
    assert eng.submit_and_run([]) == {}


def test_more_requests_than_slots_fifo_refill():
    """With 1 slot, 4 requests: slots must be (re)filled in submission
    order and every request still gets its own continuation."""
    cfg, params = _granite()

    filled = []

    class Tracing(DecodeEngine):
        def _prefill_into_slot(self, slot, req):
            filled.append(req.rid)
            super()._prefill_into_slot(slot, req)

    eng = Tracing(cfg, params, n_slots=1, s_max=32,
                  act_dtype=jnp.float32)
    reqs = _requests(cfg, 4, new=3)
    out = eng.submit_and_run(reqs)
    assert filled == [0, 1, 2, 3]                # FIFO refill order
    # each request's output equals its solo greedy continuation
    for r in reqs:
        solo = DecodeEngine(cfg, params, n_slots=1, s_max=32,
                            act_dtype=jnp.float32)
        assert solo.submit_and_run(
            [dataclasses.replace(r, out_tokens=None)])[r.rid] == out[r.rid]
