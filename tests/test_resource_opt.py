"""Problem (13): exact solver vs scipy, KKT, shedding, pipelining."""
import dataclasses
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.energy import (PassBudget, SplitCosts, direct_download_costs,
                               evaluate_raw)
from repro.core.resource_opt import (_build_phases, best_split, solve,
                                     solve_pipelined, solve_with_shedding)

BUDGET = PassBudget()


def _scipy_solve(budget, costs):
    from scipy.optimize import minimize
    phases = [p for p in _build_phases(budget, costs) if p is not None]
    T = budget.time_budget_s(costs)
    x0 = np.array([T / len(phases)] * len(phases))
    res = minimize(
        lambda x: sum(p.energy(t) for p, t in zip(phases, x)), x0,
        bounds=[(p.t_min, None) for p in phases],
        constraints=[{"type": "ineq", "fun": lambda x: T - x.sum()}],
        method="SLSQP", options={"maxiter": 800, "ftol": 1e-16})
    return res.fun


COSTS = st.builds(
    SplitCosts,
    w1_flops=st.floats(0, 5e12),
    w2_flops=st.floats(1e6, 5e12),
    dtx_bits=st.floats(1e2, 5e9),
    d_isl_bits=st.floats(0, 1e9),
)


@given(costs=COSTS)
@settings(max_examples=30, deadline=None)
def test_solver_matches_scipy(costs):
    rep = solve(BUDGET, costs)
    if not rep.allocation.feasible:
        return
    e_scipy = _scipy_solve(BUDGET, costs)
    # compare the variable part only (E_ISL is a constant outside (13));
    # our dual bisection may be *better* than SLSQP, never worse.
    e_var = rep.allocation.e_total - rep.allocation.e_isl
    assert e_var <= e_scipy * (1 + 1e-4) + 1e-12
    assert e_var >= e_scipy * (1 - 1e-2) - 1e-12


@given(costs=COSTS)
@settings(max_examples=50, deadline=None)
def test_kkt_and_deadline(costs):
    rep = solve(BUDGET, costs)
    if not rep.allocation.feasible:
        return
    # deadline binds (energy decreasing in every t)
    assert rep.allocation.t_total == pytest.approx(
        BUDGET.plane.pass_duration_s, rel=1e-6)
    # equalized marginals among interior phases
    assert rep.kkt_residual < 1e-6


@given(costs=COSTS)
@settings(max_examples=30, deadline=None)
def test_solution_consistent_with_raw_eval(costs):
    """Time-domain solution, re-evaluated through the paper's raw (f, p)
    formulation (eqs. 6-9), must give the same energy/time."""
    rep = solve(BUDGET, costs)
    a = rep.allocation
    if not a.feasible:
        return
    raw = evaluate_raw(BUDGET, costs, a.f_sat_hz, a.f_gs_hz,
                       a.p_down_w, a.p_up_w)
    assert raw.e_total == pytest.approx(a.e_total, rel=1e-6)
    assert raw.t_total == pytest.approx(a.t_total, rel=1e-6)


def test_box_constraints_respected():
    costs = SplitCosts(w1_flops=1e13, w2_flops=1e13, dtx_bits=1e9,
                       d_isl_bits=1e8)
    rep = solve(BUDGET, costs)
    a = rep.allocation
    if a.feasible:
        assert a.f_sat_hz <= BUDGET.sat_device.f_max_hz * (1 + 1e-9)
        assert a.f_gs_hz <= BUDGET.gs_device.f_max_hz * (1 + 1e-9)
        assert a.p_down_w <= BUDGET.link.max_tx_power_w * (1 + 1e-9)
        assert a.p_up_w <= BUDGET.link.max_tx_power_w * (1 + 1e-9)


def test_infeasible_detected_and_shed():
    # 1000x the max processable work in a pass
    w_max = BUDGET.sat_device.peak_flops * BUDGET.plane.pass_duration_s \
        / BUDGET.n_items
    costs = SplitCosts(w1_flops=w_max * 1000, w2_flops=1e6,
                       dtx_bits=1e3, d_isl_bits=0)
    rep = solve(BUDGET, costs)
    assert not rep.allocation.feasible
    # 1000x over budget: even the 5% floor is infeasible -> floor returned
    shed = solve_with_shedding(BUDGET, costs)
    assert shed.kept_fraction == pytest.approx(0.05)
    assert not shed.report.allocation.feasible
    # 2x over budget: sheds to just under half and becomes feasible
    costs2 = SplitCosts(w1_flops=w_max * 2, w2_flops=1e6,
                        dtx_bits=1e3, d_isl_bits=0)
    shed2 = solve_with_shedding(BUDGET, costs2)
    assert 0.3 < shed2.kept_fraction < 0.51
    assert shed2.report.allocation.feasible


def test_shedding_noop_when_feasible():
    costs = SplitCosts(w1_flops=1e9, w2_flops=1e9, dtx_bits=1e4,
                       d_isl_bits=1e6)
    shed = solve_with_shedding(BUDGET, costs)
    assert shed.kept_fraction == 1.0


def test_pipelined_never_worse():
    costs = SplitCosts(w1_flops=3e11, w2_flops=1e11, dtx_bits=1e6,
                       d_isl_bits=1e8)
    seq = solve(BUDGET, costs)
    pipe = solve_pipelined(BUDGET, costs, n_microbatches=8)
    assert pipe.allocation.e_total <= seq.allocation.e_total * (1 + 1e-9)


def test_best_split_picks_minimum():
    from repro.core.splitting import resnet18_plan
    plan = resnet18_plan()
    cands = plan.enumerate_cuts()
    c, rep = best_split(BUDGET, cands)
    for other in cands:
        r = solve(BUDGET, other)
        if r.allocation.feasible:
            assert rep.allocation.e_total <= r.allocation.e_total * (1 + 1e-9)


def test_quasiconvexity_along_boundary_scaling():
    """Energy is monotone in payload size and in work (sanity of (13))."""
    base = SplitCosts(w1_flops=1e11, w2_flops=1e11, dtx_bits=1e6,
                      d_isl_bits=1e7)
    e_prev = 0.0
    for scale in [0.5, 1.0, 2.0, 4.0]:
        c = dataclasses.replace(base, dtx_bits=base.dtx_bits * scale)
        e = solve(BUDGET, c).allocation.e_total
        assert e >= e_prev - 1e-12
        e_prev = e


def test_gather_coeff_arrays_vectorized_parity():
    """The vectorized coefficient gather equals the per-instance
    reference loop bit-for-bit across mixed scenarios (different planes
    => different geometry constants) and heterogeneous costs."""
    from repro.core.orbits import OrbitalPlane
    from repro.core.resource_opt import (_gather_coeff_arrays,
                                         _gather_coeff_arrays_reference)

    rng = np.random.default_rng(0)
    planes = [OrbitalPlane(n_sats=n) for n in (10, 25, 400)]
    blist, clist = [], []
    for i in range(96):
        blist.append(PassBudget(plane=planes[i % len(planes)],
                                n_items=float(rng.uniform(1, 5e4))))
        clist.append(SplitCosts(
            w1_flops=float(rng.uniform(0, 1e12)),
            w2_flops=float(rng.uniform(1e6, 1e12)),
            dtx_bits=float(rng.choice([0.0, rng.uniform(1e2, 1e9)])),
            d_isl_bits=float(rng.uniform(0, 1e9))))
    ref = _gather_coeff_arrays_reference(blist, clist)
    vec = _gather_coeff_arrays(blist, clist)
    assert set(vec) == set(ref)
    for key in ref:
        np.testing.assert_allclose(vec[key], ref[key], rtol=1e-13, atol=0.0,
                                   err_msg=key)
