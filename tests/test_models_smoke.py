"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
asserting output shapes and no NaNs (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm, vision
from repro.models.layers import Ctx
from repro.models.param import init_params
from repro.utils.treeutil import tree_count_params


def _batch_for(cfg, B=2, S=24):
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.frontend == "vision":
        kw["frontend_embed"] = 0.01 * jnp.ones(
            (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio":
        kw["enc_frames"] = 0.01 * jnp.ones(
            (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    return tokens, labels, kw


@pytest.mark.parametrize("name", configs.ASSIGNED)
def test_arch_smoke_forward_and_train_step(name):
    cfg = configs.get_smoke(name)
    params = lm.init(cfg, jax.random.key(0))
    B, S = 2, 24
    tokens, labels, kw = _batch_for(cfg, B, S)
    ctx = Ctx(cfg=cfg, act_dtype=jnp.float32)

    logits, aux, _ = lm.forward(cfg, params, tokens, ctx=ctx, **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    (lv, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss(cfg, p, tokens, labels, ctx=ctx, **kw),
        has_aux=True)(params)
    assert np.isfinite(float(lv))
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", configs.ASSIGNED)
def test_arch_full_config_matches_assignment(name):
    cfg = configs.get(name)
    spec = {
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "granite_3_2b": (40, 2048, 32, 8, 8192, 49155),
        "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
        "smollm_360m": (32, 960, 15, 5, 2560, 49152),
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
        "phi35_moe": (32, 4096, 32, 8, 6400, 32064),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "zamba2_1_2b": (36, 2048, 32, 32, 8192, 32000),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
    }[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == spec


def test_moe_active_vs_total_params():
    cfg = configs.get("phi35_moe")
    assert cfg.n_experts == 16 and cfg.top_k == 2
    # 42B total / 6.6B active ballpark
    assert 35e9 < cfg.param_count() < 48e9
    assert 5e9 < cfg.active_param_count() < 8.5e9


def test_param_count_formulas_match_real_trees():
    for name in ["granite_3_2b", "llama3_8b", "mixtral_8x7b"]:
        cfg = configs.get_smoke(name)
        params = lm.init(cfg, jax.random.key(0))
        real = tree_count_params(params)
        pred = cfg.param_count()
        assert abs(real - pred) / real < 0.05, (name, real, pred)


def test_llama3_8b_param_count():
    assert configs.get("llama3_8b").param_count() == pytest.approx(
        8.03e9, rel=0.02)


def test_resnet18_matches_torchvision_count():
    p = init_params(vision.resnet18_abstract_params(1000), jax.random.key(0))
    # torchvision resnet18: 11,689,512 (BN); ours with GN ~ +3k
    assert abs(tree_count_params(p) - 11_689_512) / 11_689_512 < 0.001


def test_autoencoder_latent_is_paper_dtx():
    p = init_params(vision.ae_abstract_params(), jax.random.key(0))
    x = jnp.zeros((1, 224, 224, 3), jnp.float32)
    z = vision.ae_apply_range(p, x, 0, 5)
    assert z.shape == (1, 7, 7, 3)
    assert z.size * 32 == pytest.approx(4.7e3, rel=0.01)  # 4.7 kbit

    recon = vision.ae_apply_range(p, z, 5, 10)
    assert recon.shape == x.shape


def test_moe_dispatch_matches_dense_when_capacity_ample():
    """With top_k == n_experts and generous capacity the MoE layer must
    equal the gate-weighted sum of all experts (oracle)."""
    from repro.models import layers as L
    cfg = dataclasses.replace(configs.get_smoke("mixtral_8x7b"),
                              n_experts=2, top_k=2, capacity_factor=2.0)
    spec = L.spec_moe(cfg)
    p = init_params(spec, jax.random.key(0))
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    ctx = Ctx(cfg=cfg, act_dtype=jnp.float32)
    y, aux = L.apply_moe(p, x, ctx)

    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    outs = []
    for e in range(cfg.n_experts):
        h = x.reshape(-1, cfg.d_model) @ p["wi"][e]
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g) * u
        outs.append(h @ p["wo"][e])
    dense = sum(gates[:, e:e + 1] * outs[e] for e in range(cfg.n_experts))
    np.testing.assert_allclose(y.reshape(-1, cfg.d_model), dense,
                               atol=1e-4, rtol=1e-4)
