"""Constellation scheduler: training progress, faults, skips, handoffs,
shedding, elastic membership."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.constellation import ConstellationConfig, ConstellationSim
from repro.core.energy import PassBudget
from repro.core.sl_step import autoencoder_adapter
from repro.data.synthetic import ImageryShards

SHARDS = ImageryShards(img=32, batch=4)


def _data(s, i):
    return jax.tree.map(jnp.asarray, SHARDS.batch_at(s, i))


def _sim(**kw):
    ad = autoencoder_adapter(cut=5, img=32)
    cfg = ConstellationConfig(batch_size=4, **kw)
    return ConstellationSim(ad, PassBudget(n_items=16), _data, cfg)


def test_online_learning_progress():
    sim = _sim(n_passes=10)
    recs = sim.run()
    s = sim.summary()
    assert s["trained"] == 10
    assert s["loss_last"] < s["loss_first"]
    # energy accounted every trained pass
    assert all(r.e_total_j > 0 for r in recs)


def test_energy_skip_policy():
    # battery below reserve and negligible recharge => skips
    sim = _sim(n_passes=6, battery_j=10.0, recharge_w=0.0, reserve_j=50.0)
    recs = sim.run()
    assert all(r.action == "skipped_energy" for r in recs)
    # the segment still moves around the ring (handoff bits recorded)
    assert all(r.d_isl_bits > 0 for r in recs)


def test_failures_dont_stop_the_ring(tmp_path):
    sim = _sim(n_passes=15, fail_prob=0.3, handoff_dir=str(tmp_path),
               seed=3)
    recs = sim.run()
    s = sim.summary()
    assert s["failed"] > 0
    assert s["trained"] > 0
    assert len(recs) == 15


def test_handoff_checkpoint_roundtrip(tmp_path):
    from repro import ckpt
    sim = _sim(n_passes=3, handoff_dir=str(tmp_path))
    sim.run()
    restored, meta, idx = ckpt.restore_handoff(str(tmp_path),
                                               sim.state.params_a)
    assert idx == 2
    for a, b in zip(jax.tree.leaves(restored),
                    jax.tree.leaves(sim.state.params_a)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta["payload_bytes"] > 0


def test_elastic_membership():
    sim = _sim(n_passes=8, join_events={2: 3}, leave_events={4: 0})
    sim.run()
    assert len(sim.sats) == 25 + 3
    assert not sim.sats[0].alive
    # ring keeps serving after leave
    assert sim.summary()["trained"] == 8


def test_straggler_shedding_activates():
    """Give each pass far more items than the compute budget allows:
    the optimizer sheds to the feasible fraction instead of failing."""
    ad = autoencoder_adapter(cut=5, img=32)
    # inflate per-item work via the measured-costs path: huge n_items
    budget = PassBudget(n_items=4e8)
    sim = ConstellationSim(ad, budget, _data,
                           ConstellationConfig(n_passes=1, batch_size=4))
    recs = sim.run()
    assert recs[0].action == "shed"
    assert recs[0].kept_fraction < 1.0


def test_battery_never_negative_and_clamped():
    """The shared clamp policy: charge lives in [0, battery_j] even when
    a pass's allocation would overdraw the battery (energy *accounting*
    still records the full cost)."""
    ad = autoencoder_adapter(cut=5, img=32)
    budget = PassBudget(n_items=4e8)          # huge drain => shed + overdraw
    sim = ConstellationSim(ad, budget, _data,
                           ConstellationConfig(n_passes=3, batch_size=4,
                                               battery_j=50.0,
                                               reserve_j=1.0,
                                               recharge_w=0.0))
    recs = sim.run()
    assert any(r.action in ("trained", "shed") for r in recs)
    for s in sim.sats:
        assert 0.0 <= s.battery_j <= sim.cfg.battery_j
    trained = [r for r in recs if r.action in ("trained", "shed")]
    assert all(r.e_total_j > 0 for r in trained)


def test_join_recharge_only_from_membership():
    """A satellite joining mid-run recharges only for passes it was a
    ring member of; a satellite that left stops recharging (its battery
    freezes at the value it left with)."""
    ad = autoencoder_adapter(cut=5, img=32)
    budget = PassBudget(n_items=16)
    dt = budget.plane.pass_duration_s
    # recharge small enough that a served satellite never re-caps, so
    # every recharge interval is visible in the final battery value
    recharge_w = 1e-8

    def run(**events):
        cfg = ConstellationConfig(n_passes=8, batch_size=4,
                                  battery_j=1000.0, recharge_w=recharge_w,
                                  join_battery_frac=0.25, **events)
        sim = ConstellationSim(ad, budget, _data, cfg)
        sim.run()
        return sim

    sim = run(join_events={5: 1}, leave_events={4: 1})
    joiner = sim.sats[-1]
    assert joiner.joined_pass == 5 and joiner.passes_served == 0
    # joined at pass 5 with 25% charge; member for passes 5..7 => exactly
    # 3 recharge intervals, not 8 (it never served: ring slot not hit)
    np.testing.assert_allclose(
        joiner.battery_j, 0.25 * 1000.0 + 3 * recharge_w * dt, rtol=1e-12)

    # sat 1 served pass 1 then left at pass 4: recharges for passes
    # 1..3 only (3 intervals post-serve).  vs the no-leave reference
    # (7 post-serve intervals) its battery is short exactly 4 intervals.
    ref = run()
    leaver = sim.sats[1]
    assert not leaver.alive and leaver.passes_served == 1
    assert ref.sats[1].battery_j < 1000.0        # never re-capped
    np.testing.assert_allclose(
        ref.sats[1].battery_j - leaver.battery_j,
        4 * recharge_w * dt, rtol=1e-6)
