"""Sharded elastic fleet engine: event-schedule semantics, host-vs-fleet
parity for join/leave/seeded-failure runs, inter-plane checkpoint
averaging, the <=1-sync-per-revolution contract, and plane sharding on a
multi-CPU-device mesh (subprocess)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.constellation import ConstellationConfig, ConstellationSim
from repro.core.energy import PassBudget
from repro.core.orbits import OrbitalPlane
from repro.core.sl_step import autoencoder_adapter
from repro.core.train_state import SLTrainState
from repro.fleet import (FleetConfig, FleetEngine, average_planes,
                         build_event_schedule)
from repro.sim.data import DeviceImageryShards
from repro.sim.device_sim import (ACTION_NAMES, DeviceConstellationSim,
                                  DeviceSimConfig, plan_ring_passes)
from repro.train.optimizer import resolve_optimizer

SHARDS = DeviceImageryShards(img=32, batch=4)
ADAPTER = autoencoder_adapter(cut=5, img=32)

# the standard elastic scenario: one join, one leave, seeded failures,
# batteries tight enough that reserve-policy skips appear
ELASTIC = dict(join_events={2: 1}, leave_events={5: 0}, fail_prob=0.3)
ENERGY = dict(battery_j=200.0, recharge_w=0.01, reserve_j=150.0,
              max_steps_per_pass=2)


def _budget(n_sats=4, n_items=16.0):
    return PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=n_items)


def _host_sim(budget, seed=0, data=None, **cfg_kw):
    sim = ConstellationSim(ADAPTER, budget, data or SHARDS,
                           ConstellationConfig(batch_size=4, seed=seed,
                                               **cfg_kw))
    # pin the model init to seed 0 regardless of the failure seed, so a
    # per-plane oracle (seed + p) still trains the fleet's shared init
    sim.state = SLTrainState.create(
        *ADAPTER.init(jax.random.key(0)), sim.optimizer)
    return sim


def _assert_plane_parity(host, res, p):
    """One plane of a FleetResult against its host oracle's records."""
    assert [r.action for r in host.records] == \
        [ACTION_NAMES[int(a)] for a in res.action[p]]
    assert [r.sat_id for r in host.records] == list(res.sat[p])
    for hr, dl, db in zip(host.records, res.loss[p], res.battery_j[p]):
        if hr.loss is None:
            assert not np.isfinite(dl)
        else:
            np.testing.assert_allclose(dl, hr.loss, rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(db, hr.battery_j, rtol=1e-5, atol=0.05)


# ---------------------------------------------------------------- events

def test_event_schedule_matches_host_semantics():
    """The precomputed schedule replays the host's join/leave rules:
    joins append slots (id = current total) before a leave resolves its
    ``sid % len(sats)`` at that pass."""
    sched = build_event_schedule(
        3, 10, join_events={2: 2, 4: 1}, leave_events={1: 4, 4: 5})
    assert sched.n_slots == 6
    assert list(sched.join_pass) == [0, 0, 0, 2, 2, 4]
    # pass 1: 3 sats -> 4 % 3 = slot 1; pass 4: joins first (6 sats),
    # then 5 % 6 = slot 5 (the just-joined sat leaves immediately)
    assert sched.leave_pass[1] == 1
    assert sched.leave_pass[5] == 4
    member = sched.member_at(4)
    assert list(member) == [True, False, True, True, True, False]
    # failure stream == the host oracle's own numpy draws
    sched = build_event_schedule(3, 8, fail_prob=0.4, n_planes=2, seed=7)
    for p in range(2):
        rng = np.random.default_rng(7 + p)
        host_draws = np.array([rng.random() < 0.4 for _ in range(8)])
        assert (sched.fail_mask[p] == host_draws).all()


# ------------------------------------------- host-vs-fleet parity (P=1)

def test_seeded_failure_parity_via_delegation():
    """The ISSUE acceptance scenario: a host ``fail_prob`` run vs the
    device aliveness-mask run with the same event schedule produces
    identical action sequences and battery trajectories (and the
    elastic delegation guards are gone — ``run(engine="device")`` now
    executes join/leave/failure runs on device)."""
    budget = _budget(n_items=4e6)

    def mk():
        return _host_sim(budget, n_passes=12, **ELASTIC, **ENERGY)

    host, dev = mk(), mk()
    host.run()
    dev.run(engine="device")

    assert [r.action for r in host.records] == \
        [r.action for r in dev.records]
    assert [r.sat_id for r in host.records] == \
        [r.sat_id for r in dev.records]
    actions = [r.action for r in host.records]
    assert "failed" in actions and "skipped_energy" in actions \
        and "trained" in actions
    for h, d in zip(host.records, dev.records):
        if h.loss is None:
            assert d.loss is None
        else:
            np.testing.assert_allclose(d.loss, h.loss, rtol=2e-4,
                                       atol=2e-5)
        np.testing.assert_allclose(d.battery_j, h.battery_j, rtol=1e-5,
                                   atol=0.05)
        np.testing.assert_allclose(d.e_total_j, h.e_total_j, rtol=1e-5,
                                   atol=1e-9)
    hs, ds = host.summary(), dev.summary()
    for key in ("passes", "trained", "skipped", "failed"):
        assert hs[key] == ds[key], key
    assert ds["failed"] > 0
    np.testing.assert_allclose(ds["E_total_J"], hs["E_total_J"],
                               rtol=1e-5)
    # fleet slot state folded back onto the host SatelliteStates
    # (joiners appended, failed/left sats dead, batteries carried over)
    assert len(dev.sats) == len(host.sats) > 4
    for hsat, dsat in zip(host.sats, dev.sats):
        assert dsat.alive == hsat.alive
        assert dsat.passes_served == hsat.passes_served
        np.testing.assert_allclose(dsat.battery_j, hsat.battery_j,
                                   rtol=1e-5, atol=0.05)
    assert dev._batch_idx == host._batch_idx
    eng = dev.device_engine
    assert eng.traces == 1 and eng.host_syncs <= 3  # <= 1 per revolution


def test_chained_elastic_delegation():
    """Two chained elastic device runs equal two chained host runs: the
    second delegation's ring already carries the first run's joiners
    and casualties (slot layout follows the schedule, not the
    configured plane), and the failure stream keeps consuming the
    sim's one live generator across segments."""
    budget = _budget()

    def mk():
        return _host_sim(budget, n_passes=6, join_events={1: 1},
                         fail_prob=0.3, max_steps_per_pass=4)

    host, dev = mk(), mk()
    host.run()
    host.run()
    dev.run(engine="device")
    dev.run(engine="device")
    assert [(r.action, r.sat_id) for r in host.records] == \
        [(r.action, r.sat_id) for r in dev.records]
    assert len(host.records) == 12 and len(dev.sats) == len(host.sats)
    for hsat, dsat in zip(host.sats, dev.sats):
        assert dsat.alive == hsat.alive
        np.testing.assert_allclose(dsat.battery_j, hsat.battery_j,
                                   rtol=1e-5, atol=0.05)
    assert dev._batch_idx == host._batch_idx


def test_ragged_elastic_delegation():
    """Elastic runs need not be whole revolutions: a 7-pass fail run
    delegates as one chunk and still matches the host oracle."""
    budget = _budget()
    host = _host_sim(budget, n_passes=7, fail_prob=0.4,
                     max_steps_per_pass=4)
    dev = _host_sim(budget, n_passes=7, fail_prob=0.4,
                    max_steps_per_pass=4)
    host.run()
    dev.run(engine="device")
    assert [r.action for r in host.records] == \
        [r.action for r in dev.records]
    assert dev.device_engine.host_syncs == 1


# --------------------------------------------- multi-plane fleet parity

def test_two_plane_fleet_matches_per_plane_host_oracles():
    """2 planes x (4+1) slots with joins, leaves and per-plane seeded
    failures (averaging off): every plane's action/sat/loss/battery
    timeline equals a host oracle running the same schedule with its
    data ids offset to the plane's global range."""
    budget = _budget(n_sats=4, n_items=4e6)
    cfg = FleetConfig(n_planes=2, n_revolutions=3, seed=0, avg_every=0,
                      **ELASTIC, **ENERGY)
    fleet = FleetEngine(ADAPTER, budget, SHARDS, cfg)
    M, K = fleet.n_slots, fleet.n_passes
    res = fleet.run(stream_telemetry=True)
    assert fleet.traces == 1
    assert fleet.host_syncs == 3          # exactly one per revolution
    assert res.action.shape == (2, K)

    failures = 0
    for p in range(2):
        host = _host_sim(budget, seed=cfg.seed + p,
                         data=lambda s, i, p=p: SHARDS(p * M + s, i),
                         n_passes=K, **ELASTIC, **ENERGY)
        host.run()
        _assert_plane_parity(host, res, p)
        failures += sum(r.action == "failed" for r in host.records)
    assert failures > 0
    assert res.summary()["failed"] == failures


def test_interplane_averaging_matches_manual_reference():
    """avg_every=1 equals P independent single-ring device engines with
    explicit checkpoint averaging between revolutions — the fleet's
    all-reduce is exactly the paper's inter-plane ISL exchange."""
    N, P, R = 4, 2, 2
    budget = _budget(n_sats=N)
    cfg = FleetConfig(n_planes=P, n_revolutions=R, max_steps_per_pass=8,
                      avg_every=1, seed=0)
    fleet = FleetEngine(ADAPTER, budget, SHARDS, cfg)
    M = fleet.n_slots
    res = fleet.run(stream_telemetry=True)

    opt = resolve_optimizer("sgd", lr=cfg.lr)
    init = SLTrainState.create(*ADAPTER.init(jax.random.key(0)), opt)
    engines = [DeviceConstellationSim(
        ADAPTER, budget, lambda s, i, p=p: SHARDS(p * M + s, i),
        DeviceSimConfig(max_steps_per_pass=8, seed=0),
        state=jax.tree.map(jnp.copy, init)) for p in range(P)]
    ref = [[] for _ in range(P)]
    for _ in range(R):
        for p, eng in enumerate(engines):
            ref[p].extend(eng.run(1, stream_telemetry=True).loss[0])
        avg = average_planes(jax.tree.map(lambda *xs: jnp.stack(xs),
                                          *[e.state for e in engines]))
        for p, eng in enumerate(engines):
            eng.state = jax.tree.map(lambda x: x[p], avg)
    np.testing.assert_allclose(res.loss, np.asarray(ref), rtol=2e-4,
                               atol=2e-5)
    # averaging actually coupled the planes: the final segment params
    # are identical across the plane axis
    pa = jax.tree.leaves(res.state.params_a)[0]
    np.testing.assert_allclose(np.asarray(pa[0]), np.asarray(pa[1]),
                               rtol=1e-6)


def test_averaging_off_keeps_planes_independent():
    N, P = 4, 2
    budget = _budget(n_sats=N)
    cfg = FleetConfig(n_planes=P, n_revolutions=1, max_steps_per_pass=4,
                      avg_every=0, seed=0)
    res = FleetEngine(ADAPTER, budget, SHARDS, cfg).run()
    pa = jax.tree.leaves(res.state.params_a)[0]
    assert not np.allclose(np.asarray(pa[0]), np.asarray(pa[1]))


# ----------------------------------------------- planning / integration

def test_fleet_plan_heterogeneous_rows():
    """All P x M problem-(13) instances solve in ONE device call, with
    per-satellite dtx rows planning mixed payloads."""
    budget = _budget()
    dtx = np.array([[1e4, 2e4, 3e4, 4e4], [4e4, 3e4, 2e4, 1e4]])
    plan = plan_ring_passes(budget, ADAPTER.costs(), batch_size=4,
                            n_sats=(2, 4), ring_n=4, dtx_bits=dtx,
                            max_steps_per_pass=8)
    e = np.asarray(plan.e_total_j)
    assert e.shape == (2, 4)
    assert (np.diff(e[0]) > 0).all()      # heavier payloads cost more
    np.testing.assert_allclose(e[1], e[0, ::-1], rtol=1e-6)


def test_delegation_threads_measured_per_sat_dtx():
    """ROADMAP open item 2, host half: ``as_device_sim`` feeds the
    device planner a measured per-satellite (N,) payload array (the
    ``sl_step.ring_boundary_bits`` feed), not slot 0's scalar."""
    budget = _budget()
    sim = ConstellationSim(ADAPTER, budget, SHARDS,
                           ConstellationConfig(batch_size=4, n_passes=4))
    eng = sim.as_device_sim(n_revolutions=1)
    assert isinstance(eng.dtx_bits, np.ndarray)
    assert eng.dtx_bits.shape == (4,)
    # the measured array equals each slot's metered payload per item
    from repro.core.sl_step import ring_boundary_bits
    batches = [SHARDS(s, 0) for s in range(4)]
    expect = ring_boundary_bits(ADAPTER, batches) / 4.0
    np.testing.assert_allclose(eng.dtx_bits, expect)


def test_sweep_cell_feeds_fleet():
    """A planned sweep cell broadcasts into a (P, N) fleet plan the
    engine executes directly (mission -> fleet bridge)."""
    from repro.core.mission import sweep_revolutions
    from repro.sim.device_sim import ACTION_TRAINED

    budget = _budget()
    cfg = FleetConfig(n_planes=2, n_revolutions=1, max_steps_per_pass=8,
                      seed=0)
    fleet = FleetEngine(ADAPTER, budget, SHARDS, cfg)
    sweep = sweep_revolutions([4], [fleet.costs], [16.0], budget=budget)
    plan = sweep.fleet_plan(4, 2, cut=0, max_steps_per_pass=8)
    for field in plan._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(plan, field)),
            np.asarray(getattr(fleet.plan, field)),
            rtol=1e-6, atol=1e-12, err_msg=field)
    fleet2 = FleetEngine(ADAPTER, budget, SHARDS, cfg, plan=plan)
    res = fleet2.run()
    assert (res.action == ACTION_TRAINED).all()
    assert np.isfinite(res.loss).all()


def test_fleet_chaining_and_counters():
    budget = _budget()
    cfg = FleetConfig(n_planes=2, n_revolutions=2, max_steps_per_pass=4,
                      seed=0)
    fleet = FleetEngine(ADAPTER, budget, SHARDS, cfg)
    res = fleet.run(stream_telemetry=True)
    assert fleet.traces == 1
    assert fleet.device_calls == 2 and fleet.host_syncs == 2
    res2 = fleet.run(1, stream_telemetry=True)
    assert fleet.traces == 1              # same program, reused
    # beyond the precomputed horizon membership persists and, with
    # fail_prob=0, no failure stream exists (fail_prob>0 refreshes from
    # jax.random past the horizon — tests/test_scenarios.py): every
    # chained pass still serves and trains
    assert np.isfinite(res2.loss).all()
    assert (res2.sat >= 0).all()
    # training continued from where the first run stopped
    assert res2.loss[0, 0] < res.loss[0, -1]
    assert int(np.asarray(fleet._pass_idx)) == 12


# ------------------------------------------------- multi-device sharding

def test_fleet_accepts_host_mesh_data_axis():
    """Any mesh with a suitable axis shards the plane dimension —
    ``make_host_mesh``'s data axis serves CPU-device tests."""
    from repro.launch.mesh import make_host_mesh

    budget = _budget()
    cfg = FleetConfig(n_planes=2, n_revolutions=1, max_steps_per_pass=2,
                      seed=0)
    with pytest.raises(ValueError, match="planes"):
        FleetEngine(ADAPTER, budget, SHARDS, cfg,
                    schedule=build_event_schedule(4, 4, n_planes=1))
    fleet = FleetEngine(ADAPTER, budget, SHARDS, cfg,
                        mesh=make_host_mesh(), plane_axis="data")
    res = fleet.run()
    assert np.isfinite(res.loss).all()


def test_fleet_on_two_cpu_devices_subprocess():
    """The acceptance scenario end to end: a 2-plane fleet with join,
    leave and seeded-failure events runs on >= 2 CPU host devices,
    sharded over the plane mesh axis, with <= 1 host sync per
    revolution and host-oracle parity — in a subprocess because the
    device count must be forced before jax initializes."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               REPRO_FLEET_SMOKE_SATS="4", REPRO_FLEET_SMOKE_PLANES="2",
               REPRO_FLEET_SMOKE_REVS="2",
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.fleet"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "on 2 device(s)" in proc.stdout, proc.stdout
    assert "'plane': 2" in proc.stdout, proc.stdout
    assert "parity OK" in proc.stdout, proc.stdout
