import os
import sys

# Tests see the real (single) CPU device — only launch/dryrun.py forces
# the 512-device placeholder topology.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "float32")
