"""Mission API: SLTrainState semantics, pluggable optimizers, the
revolution planner, and vectorized shedding — plus parity of the
redesigned stack against the pre-redesign 4-tuple/scalar-solve path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import resource_opt as ro
from repro.core.constellation import ConstellationConfig, ConstellationSim
from repro.core.energy import PassBudget, SplitCosts
from repro.core.mission import PlanEntry, RevolutionPlanner
from repro.core.sl_step import (autoencoder_adapter, lm_adapter,
                                make_sl_pass, make_sl_step)
from repro.core.train_state import SLTrainState
from repro.data.synthetic import ImageryShards, TokenShards
from repro.train.optimizer import (AdamWConfig, Optimizer, adamw,
                                   adamw_init, adamw_update,
                                   resolve_optimizer, sgd, sgd_init,
                                   sgd_update)

BUDGET = PassBudget()
SHARDS = ImageryShards(img=32, batch=4)


def _data(s, i):
    return jax.tree.map(jnp.asarray, SHARDS.batch_at(s, i))


def _batches(k, shard=0):
    return [_data(shard, i) for i in range(k)]


def _state(adapter, opt, seed=0):
    pa, pb = adapter.init(jax.random.key(seed))
    return SLTrainState.create(pa, pb, opt)


# --------------------------------------------------------------------------
# SLTrainState: pytree round-trip + donation safety
# --------------------------------------------------------------------------

def test_train_state_pytree_roundtrip():
    ad = autoencoder_adapter(cut=5, img=32)
    state = _state(ad, sgd(lr=1e-2))
    leaves, treedef = jax.tree_util.tree_flatten(state)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, SLTrainState)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # survives a jit boundary as one object
    bumped = jax.jit(lambda s: s.replace(step=s.step + 1))(state)
    assert int(bumped.step) == 1
    assert not bumped.consumed


def test_train_state_apply_updates_matches_raw_sgd():
    ad = autoencoder_adapter(cut=5, img=32)
    opt = sgd(lr=1e-2)
    state = _state(ad, opt)
    step = make_sl_step(ad)
    res = step(state.params_a, state.params_b, _data(0, 0))
    new = state.apply_updates(res.grads_a, res.grads_b, opt)

    pa_ref, _, _ = sgd_update(res.grads_a, sgd_init(state.params_a),
                              state.params_a, lr=1e-2)
    for got, ref in zip(jax.tree.leaves(new.params_a),
                        jax.tree.leaves(pa_ref)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert int(new.step) == 1


def test_train_state_donation_safety():
    ad = autoencoder_adapter(cut=5, img=32)
    sl_pass = make_sl_pass(ad, optimizer=sgd(lr=1e-2))   # donate=True
    state = _state(ad, sgd(lr=1e-2))
    res = sl_pass(state, _batches(2))
    assert state.consumed
    assert not res.state.consumed
    # every reuse path raises instead of touching freed buffers
    with pytest.raises(ValueError, match="consumed"):
        sl_pass(state, _batches(2))
    with pytest.raises(ValueError, match="consumed"):
        state.replace(step=0)
    with pytest.raises(ValueError, match="consumed"):
        state.apply_updates(None, None, sgd())
    with pytest.raises(ValueError, match="consumed"):
        state.donate()
    # the returned state chains forward normally
    res2 = sl_pass(res.state, _batches(2))
    assert np.isfinite(np.asarray(res2.losses)).all()


def test_train_state_explicit_donate_marks_original():
    ad = autoencoder_adapter(cut=5, img=32)
    state = _state(ad, sgd())
    alias = state.donate()
    assert state.consumed and not alias.consumed
    res = make_sl_pass(ad, optimizer=sgd())(alias, _batches(1))
    assert alias.consumed
    assert res.n_steps == 1


def test_non_donating_pass_keeps_state_live():
    ad = autoencoder_adapter(cut=5, img=32)
    sl_pass = make_sl_pass(ad, optimizer=sgd(lr=1e-2), donate=False)
    state = _state(ad, sgd(lr=1e-2))
    r1 = sl_pass(state, _batches(2))
    r2 = sl_pass(state, _batches(2))          # same live state, legal
    assert not state.consumed
    np.testing.assert_allclose(np.asarray(r1.losses),
                               np.asarray(r2.losses), rtol=1e-6)


def test_consumed_state_rejected_even_without_donation():
    """A state consumed by a donating pass must raise the documented
    ValueError from a donate=False executor too (its buffers may be
    freed — the raw deleted-buffer crash is exactly what the guard
    exists to prevent)."""
    ad = autoencoder_adapter(cut=5, img=32)
    state = _state(ad, sgd(lr=1e-2))
    make_sl_pass(ad, optimizer=sgd(lr=1e-2))(state, _batches(1))
    assert state.consumed
    no_donate = make_sl_pass(ad, optimizer=sgd(lr=1e-2), donate=False)
    with pytest.raises(ValueError, match="consumed"):
        no_donate(state, _batches(1))


# --------------------------------------------------------------------------
# Optimizer protocol
# --------------------------------------------------------------------------

def test_resolve_optimizer():
    assert resolve_optimizer("sgd").name == "sgd"
    assert resolve_optimizer("adamw", lr=1e-3).name == "adamw"
    inst = sgd(lr=5e-4)
    assert resolve_optimizer(inst) is inst
    assert resolve_optimizer(None).name == "sgd"
    with pytest.raises(ValueError, match="unknown optimizer"):
        resolve_optimizer("rmsprop")


def test_sl_pass_sgd_parity_with_pre_redesign_loop():
    """The state-API SGD pass must equal the pre-redesign sequential
    make_sl_step + sgd_update loop loss-for-loss and weight-for-weight."""
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    batches = _batches(5)

    step = make_sl_step(ad)
    p_a, p_b = pa, pb
    oa, ob = sgd_init(pa), sgd_init(pb)
    losses_ref = []
    for bt in batches:
        r = step(p_a, p_b, bt)
        p_a, oa, _ = sgd_update(r.grads_a, oa, p_a, lr=1e-2)
        p_b, ob, _ = sgd_update(r.grads_b, ob, p_b, lr=1e-2)
        losses_ref.append(float(r.loss))

    res = make_sl_pass(ad, optimizer=sgd(lr=1e-2))(
        SLTrainState.create(pa, pb, sgd(lr=1e-2)), batches)
    np.testing.assert_allclose(np.asarray(res.losses),
                               np.asarray(losses_ref), rtol=1e-5, atol=1e-6)
    for got, ref in zip(jax.tree.leaves(res.state.params_a),
                        jax.tree.leaves(p_a)):
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    assert int(res.state.step) == 5


def test_sl_pass_adamw_parity_with_sequential_updates():
    """AdamW (incl. lr schedule + bias correction riding the scan carry)
    must equal sequential adamw_update calls."""
    cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10,
                      weight_decay=0.01)
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(1))
    batches = _batches(4, shard=1)

    step = make_sl_step(ad)
    p_a, p_b = pa, pb
    oa, ob = adamw_init(pa), adamw_init(pb)
    losses_ref = []
    for bt in batches:
        r = step(p_a, p_b, bt)
        p_a, oa, _ = adamw_update(cfg, r.grads_a, oa, p_a)
        p_b, ob, _ = adamw_update(cfg, r.grads_b, ob, p_b)
        losses_ref.append(float(r.loss))

    opt = adamw(cfg)
    res = make_sl_pass(ad, optimizer=opt)(
        SLTrainState.create(pa, pb, opt), batches)
    np.testing.assert_allclose(np.asarray(res.losses),
                               np.asarray(losses_ref), rtol=1e-5, atol=1e-6)
    for got, ref in zip(jax.tree.leaves(res.state.params_a),
                        jax.tree.leaves(p_a)):
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-6)
    # AdamW's own step counter advanced inside the scan
    assert int(res.state.opt_a.step) == 4


# --------------------------------------------------------------------------
# Vectorized shedding
# --------------------------------------------------------------------------

def _shed_reference(budget, costs, min_fraction=0.05, tol=1e-4):
    """The pre-redesign scalar algorithm (bisection of _feasible_at)."""
    rep = ro.solve(budget, costs)
    if rep.allocation.feasible:
        return 1.0, rep
    lo, hi = min_fraction, 1.0
    if not ro._feasible_at(budget, costs, lo):
        return lo, ro.solve(
            dataclasses.replace(budget, n_items=budget.n_items * lo), costs)
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if ro._feasible_at(budget, costs, mid):
            lo = mid
        else:
            hi = mid
    return lo, ro.solve(
        dataclasses.replace(budget, n_items=budget.n_items * lo), costs)


def test_shedding_batch_matches_scalar_reference():
    w_max = BUDGET.sat_device.peak_flops * BUDGET.plane.pass_duration_s \
        / BUDGET.n_items
    grid = [
        SplitCosts(1e9, 1e9, 1e4, 1e6),              # feasible, no shed
        SplitCosts(w_max * 2, 1e6, 1e3, 0.0),        # sheds to ~0.5
        SplitCosts(w_max * 10, 1e6, 1e3, 0.0),       # sheds to ~0.1
        SplitCosts(w_max * 1000, 1e6, 1e3, 0.0),     # floor (0.05)
        SplitCosts(1e9, 1e9, 5e9, 1e6),              # comm-driven shed
        SplitCosts(0.0, 1e6, 0.0, 0.0),              # gs-proc only
    ]
    batch = ro.solve_with_shedding_batch(BUDGET, grid)
    assert batch.n == len(grid)
    for i, c in enumerate(grid):
        frac_ref, rep_ref = _shed_reference(BUDGET, c)
        assert batch.kept_fraction[i] == pytest.approx(frac_ref, abs=2e-4)
        shed = batch.at(i)
        assert shed.kept_fraction == pytest.approx(frac_ref, abs=2e-4)
        if rep_ref.allocation.feasible:
            assert shed.report.allocation.e_total == pytest.approx(
                rep_ref.allocation.e_total, rel=1e-2)
        assert shed.report.allocation.feasible == rep_ref.allocation.feasible


def test_shedding_scalar_wrapper_delegates_to_batch():
    w_max = BUDGET.sat_device.peak_flops * BUDGET.plane.pass_duration_s \
        / BUDGET.n_items
    c = SplitCosts(w_max * 2, 1e6, 1e3, 0.0)
    shed = ro.solve_with_shedding(BUDGET, c)
    batch = ro.solve_with_shedding_batch(BUDGET, [c])
    assert shed.kept_fraction == pytest.approx(float(batch.kept_fraction[0]))
    assert shed.n_items_kept == pytest.approx(float(batch.n_items_kept[0]))


# --------------------------------------------------------------------------
# RevolutionPlanner: one batched solve per revolution + cache invalidation
# --------------------------------------------------------------------------

COSTS_OK = SplitCosts(1e9, 1e9, 1e4, 1e6)


def test_planner_one_solve_per_revolution():
    planner = RevolutionPlanner()
    ring = (0, 1, 2, 3)
    for k in range(8):                       # two full revolutions
        e = planner.entry_for(ring[k % 4], ring, BUDGET, COSTS_OK)
        assert isinstance(e, PlanEntry)
        assert e.sat_id == ring[k % 4]
        assert e.allocation.feasible
    assert planner.solve_calls == 1
    assert planner.invalidations == 0


def test_planner_invalidates_on_membership_change():
    planner = RevolutionPlanner()
    ring = (0, 1, 2)
    planner.entry_for(0, ring, BUDGET, COSTS_OK)
    planner.entry_for(1, ring, BUDGET, COSTS_OK)
    assert planner.solve_calls == 1
    # a join re-shapes the ring => exactly one replan
    ring2 = (0, 1, 2, 3)
    planner.entry_for(3, ring2, BUDGET, COSTS_OK)
    assert planner.solve_calls == 2
    assert planner.invalidations == 1
    # a leave does too
    ring3 = (0, 2, 3)
    planner.entry_for(2, ring3, BUDGET, COSTS_OK)
    assert planner.solve_calls == 3
    # unknown satellite is an error, not a silent scalar solve
    with pytest.raises(KeyError):
        planner.entry_for(99, ring3, BUDGET, COSTS_OK)


def test_planner_invalidates_on_boundary_shape_change():
    planner = RevolutionPlanner()
    ring = (0, 1)
    planner.entry_for(0, ring, BUDGET, COSTS_OK)
    # same numbers, different name: no replan
    planner.entry_for(1, ring, BUDGET,
                      dataclasses.replace(COSTS_OK, name="renamed"))
    assert planner.solve_calls == 1
    # doubled boundary payload: replan
    planner.entry_for(1, ring, BUDGET,
                      dataclasses.replace(COSTS_OK,
                                          dtx_bits=2 * COSTS_OK.dtx_bits))
    assert planner.solve_calls == 2


def test_planner_per_satellite_instances():
    planner = RevolutionPlanner()
    ring = [0, 1, 2]
    budgets = [PassBudget(n_items=100.0 * (i + 1)) for i in range(3)]
    entries = planner.plan_revolution(ring, budgets, COSTS_OK)
    e = [entries[s].allocation.e_total for s in ring]
    assert e[0] < e[1] < e[2]            # more items => more energy


def test_plan_revolution_updates_cache_key():
    """A direct plan_revolution call must own the cache: entry_for with
    the same instances reuses it, with different instances replans
    (regression: stale key served the wrong plan)."""
    planner = RevolutionPlanner()
    ring = (0, 1)
    c2 = dataclasses.replace(COSTS_OK, w1_flops=5e10)
    planner.entry_for(0, ring, BUDGET, COSTS_OK)
    e1 = planner.entry_for(0, ring, BUDGET, COSTS_OK).allocation.e_total
    planner.plan_revolution(ring, BUDGET, c2)
    assert planner.planned
    assert planner.solve_calls == 2
    # matching inputs hit the direct plan's cache...
    e2 = planner.entry_for(0, ring, BUDGET, c2).allocation.e_total
    assert planner.solve_calls == 2
    assert e2 != pytest.approx(e1)
    # ...and the original costs correctly replan instead of serving c2's
    e1_again = planner.entry_for(0, ring, BUDGET, COSTS_OK).allocation.e_total
    assert planner.solve_calls == 3
    assert e1_again == pytest.approx(e1)


def test_planner_heterogeneous_ring_stays_cached():
    """Per-satellite cost instances: a stable heterogeneous ring plans
    once, not once per pass (regression: single-costs keying thrashed
    the cache into one N-instance solve per pass)."""
    planner = RevolutionPlanner()
    ring = (0, 1, 2)
    per_sat = [dataclasses.replace(COSTS_OK, dtx_bits=1e4 * (s + 1))
               for s in ring]
    for k in range(6):                       # two revolutions
        e = planner.entry_for(ring[k % 3], ring, BUDGET, per_sat)
        assert e.sat_id == ring[k % 3]
    assert planner.solve_calls == 1
    assert planner.invalidations == 0


def test_planner_shedding_for_infeasible_passes():
    w_max = BUDGET.sat_device.peak_flops * BUDGET.plane.pass_duration_s \
        / BUDGET.n_items
    planner = RevolutionPlanner()
    entries = planner.plan_revolution(
        [0, 1], BUDGET,
        [COSTS_OK, SplitCosts(w_max * 2, 1e6, 1e3, 0.0)])
    assert entries[0].shed.kept_fraction == 1.0
    assert entries[1].shed.kept_fraction < 0.51
    assert entries[1].allocation.feasible
    assert planner.solve_calls == 1


# --------------------------------------------------------------------------
# ConstellationSim end-to-end on the mission API
# --------------------------------------------------------------------------

def _sim(adapter=None, n_items=16.0, **kw):
    ad = adapter or autoencoder_adapter(cut=5, img=32)
    cfg = ConstellationConfig(batch_size=4, **kw)
    return ConstellationSim(ad, PassBudget(n_items=n_items), _data, cfg)


def test_config_default_not_shared():
    """Mutable-default footgun: two sims must not alias one config."""
    ad = autoencoder_adapter(cut=5, img=32)
    s1 = ConstellationSim(ad, PassBudget(n_items=16), _data)
    s2 = ConstellationSim(ad, PassBudget(n_items=16), _data)
    assert s1.cfg is not s2.cfg
    s1.cfg.join_events[3] = 1
    assert 3 not in s2.cfg.join_events


def test_constellation_sgd_end_to_end_single_planner_solve():
    sim = _sim(n_passes=8, optimizer="sgd")
    recs = sim.run()
    s = sim.summary()
    assert s["trained"] == 8
    assert s["loss_last"] < s["loss_first"]
    # steady ring + constant shapes: ONE batched solve covers every pass
    assert sim.planner.solve_calls == 1
    assert sim.planner.invalidations == 0
    assert all(r.e_total_j > 0 for r in recs)


def test_constellation_adamw_end_to_end():
    sim = _sim(n_passes=6, optimizer="adamw", lr=1e-3)
    recs = sim.run()
    s = sim.summary()
    assert s["trained"] == 6
    assert s["loss_last"] < s["loss_first"]
    assert sim.optimizer.name == "adamw"
    # AdamW state advanced through the fused passes
    assert int(sim.state.opt_a.step) == int(sim.state.step) > 0
    assert sim.planner.solve_calls == 1


def test_constellation_custom_optimizer_instance():
    opt = adamw(AdamWConfig(lr=5e-4, warmup_steps=1, total_steps=50))
    sim = _sim(n_passes=2, optimizer=opt)
    sim.run()
    assert sim.optimizer is opt
    assert sim.summary()["trained"] == 2


def test_constellation_lm_adapter_adamw():
    """The LM split-training track through the same constellation loop."""
    from repro import configs
    cfg = configs.get_smoke("smollm_360m")
    ad = lm_adapter(cfg, cut_units=1, seq_len=16)
    shards = TokenShards(vocab=cfg.vocab, seq_len=16, batch=2)

    def data(s, i):
        return jax.tree.map(jnp.asarray, shards.batch_at(s, i))

    sim = ConstellationSim(
        ad, PassBudget(n_items=4.0), data,
        ConstellationConfig(n_passes=2, batch_size=2, optimizer="adamw",
                            lr=1e-3))
    recs = sim.run()
    assert all(r.action in ("trained", "shed") for r in recs)
    assert all(np.isfinite(r.loss) for r in recs)
    assert sim.planner.solve_calls == 1


def test_constellation_join_event_invalidates_plan():
    sim = _sim(n_passes=6, join_events={3: 2})
    sim.run()
    # one plan for the initial ring, one replan after the join
    assert sim.planner.solve_calls == 2
    assert sim.planner.invalidations == 1
    assert sim.summary()["trained"] == 6


def test_constellation_sgd_parity_with_pre_redesign_path():
    """Full-pass parity: the planner + state + optimizer stack must
    reproduce the pre-redesign scheduler (scalar solve_with_shedding +
    sequential step/update loop) loss-for-loss on SGD."""
    seed, lr, n_in_batch = 0, 1e-2, 4
    sim = _sim(n_passes=2, optimizer="sgd", lr=lr, seed=seed)
    recs = sim.run()

    # --- replicate pass 0 and 1 the pre-redesign way -------------------
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(seed))
    oa, ob = sgd_init(pa), sgd_init(pb)
    step = make_sl_step(ad)
    from repro.core.sl_step import boundary_bits
    from repro.utils.treeutil import tree_bytes
    batch_idx = 0
    for k in range(2):
        batch = _data(k, batch_idx)          # sat k serves pass k
        dtx = boundary_bits(ad, batch) / n_in_batch
        costs = dataclasses.replace(ad.costs(), dtx_bits=dtx,
                                    d_isl_bits=8.0 * tree_bytes(pa))
        frac_ref, rep_ref = _shed_reference(PassBudget(n_items=16.0), costs)
        n_steps = max(1, int(round(16.0 * frac_ref / n_in_batch)))
        losses = []
        for j in range(n_steps):
            bt = _data(k, batch_idx + j)
            r = step(pa, pb, bt)
            pa, oa, _ = sgd_update(r.grads_a, oa, pa, lr=lr)
            pb, ob, _ = sgd_update(r.grads_b, ob, pb, lr=lr)
            losses.append(float(r.loss))
        batch_idx += n_steps
        assert recs[k].loss == pytest.approx(float(np.mean(losses)),
                                             rel=1e-5)
        assert recs[k].e_total_j == pytest.approx(
            rep_ref.allocation.e_total, rel=1e-6)
    for got, ref in zip(jax.tree.leaves(sim.state.params_a),
                        jax.tree.leaves(pa)):
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
