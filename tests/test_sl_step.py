"""Split-learning step: exactness vs monolithic training + payloads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.sl_step import (autoencoder_adapter, lm_adapter, make_sl_step,
                                resnet18_adapter)
from repro.data.synthetic import ImageryShards, TokenShards


def _flat_err(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return max(float(jnp.max(jnp.abs(x - y)))
               / (float(jnp.max(jnp.abs(y))) + 1e-8)
               for x, y in zip(la, lb))


def test_ae_split_grads_equal_monolithic():
    from repro.models import vision
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, ImageryShards(img=32, batch=4)
                         .batch_at(0, 0))
    res = make_sl_step(ad)(pa, pb, batch)
    g_full = jax.grad(vision.ae_loss)({**pa, **pb}, batch["images"])
    ga_ref = {k: g_full[k] for k in pa}
    gb_ref = {k: g_full[k] for k in pb}
    assert _flat_err(res.grads_a, ga_ref) < 1e-5
    assert _flat_err(res.grads_b, gb_ref) < 1e-5


@pytest.mark.parametrize("cut", [3, 5, 7])
def test_resnet_split_grads_equal_monolithic(cut):
    from repro.models import vision
    ad = resnet18_adapter(cut=cut, img=32, n_classes=10)
    pa, pb = ad.init(jax.random.key(1))
    batch = jax.tree.map(jnp.asarray, ImageryShards(img=32, batch=4)
                         .batch_at(1, 0))
    res = make_sl_step(ad)(pa, pb, batch)
    g_full = jax.grad(vision.resnet18_loss)({**pa, **pb}, batch["images"],
                                            batch["labels"])
    assert _flat_err(res.grads_a, {k: g_full[k] for k in pa}) < 1e-5
    assert _flat_err(res.grads_b, {k: g_full[k] for k in pb}) < 1e-5


def test_lm_split_runs_and_boundary_size():
    cfg = configs.get_smoke("smollm_360m")
    ad = lm_adapter(cfg, cut_units=1, seq_len=16)
    pa, pb = ad.init(jax.random.key(0))
    shards = TokenShards(vocab=cfg.vocab, seq_len=16, batch=2)
    batch = jax.tree.map(jnp.asarray, shards.batch_at(0, 0))
    res = make_sl_step(ad)(pa, pb, batch)
    assert np.isfinite(float(res.loss))
    # boundary = B * S * d_model * 32 bits
    assert res.dtx_bits_down == 2 * 16 * cfg.d_model * 32


def test_quantized_boundary_is_4x_smaller_and_close():
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, ImageryShards(img=32, batch=4)
                         .batch_at(0, 0))
    res = make_sl_step(ad)(pa, pb, batch)
    resq = make_sl_step(ad, quantize_boundary=True)(pa, pb, batch)
    assert res.dtx_bits_down == 4 * resq.dtx_bits_down
    # int8 boundary shouldn't change the loss much at init
    assert abs(float(res.loss) - float(resq.loss)) < 0.05 * abs(
        float(res.loss)) + 1e-3


def test_split_costs_consistent_with_plan():
    ad = resnet18_adapter(cut=5, img=224, n_classes=1000)
    c = ad.costs()
    # Table II l2 W1: 3.006 GMACs * 2 FLOPs (our convention counts 2/MAC)
    assert c.w1_flops / 2 == pytest.approx(3.006e9, rel=0.08)
    assert c.dtx_bits == pytest.approx(3.211e6, rel=0.01)
