"""Device-resident constellation engine: host-vs-device closed-loop
parity (pass records, skip decisions, battery trajectories, losses),
swept-plan execution, and the zero-per-pass-host-transfer contract."""
import dataclasses

import numpy as np
import pytest

from repro.core.constellation import ConstellationConfig, ConstellationSim
from repro.core.energy import PassBudget
from repro.core.orbits import OrbitalPlane
from repro.core.sl_step import autoencoder_adapter
from repro.sim.data import DeviceImageryShards
from repro.sim.device_sim import (ACTION_TRAINED, DeviceConstellationSim,
                                  DeviceSimConfig, plan_ring_passes)

SHARDS = DeviceImageryShards(img=32, batch=4)
ADAPTER = autoencoder_adapter(cut=5, img=32)


def _budget(n_sats=4, n_items=16.0):
    return PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=n_items)


def _pair(budget, **cfg_kw):
    """Two identically-configured sims sharing the traceable provider."""
    def make():
        return ConstellationSim(ADAPTER, budget, SHARDS,
                                ConstellationConfig(batch_size=4, **cfg_kw))
    return make(), make()


def _assert_record_parity(host_recs, dev_recs, *, loss_rtol=2e-4,
                          e_rtol=1e-5):
    """``e_rtol`` loosens for shed scenarios: the host bisects the kept
    fraction to 1e-4 while the device uses the closed form, and energy
    scales ~cubically in the kept item count."""
    assert [r.action for r in host_recs] == [r.action for r in dev_recs]
    assert [r.sat_id for r in host_recs] == [r.sat_id for r in dev_recs]
    for h, d in zip(host_recs, dev_recs):
        if h.loss is None:
            assert d.loss is None
        else:
            np.testing.assert_allclose(d.loss, h.loss, rtol=loss_rtol,
                                       atol=1e-5)
        np.testing.assert_allclose(d.battery_j, h.battery_j, rtol=1e-5,
                                   atol=0.05)
        np.testing.assert_allclose(d.e_total_j, h.e_total_j, rtol=e_rtol,
                                   atol=1e-9)
        np.testing.assert_allclose(d.kept_fraction, h.kept_fraction,
                                   rtol=5e-4)
        np.testing.assert_allclose(d.d_isl_bits, h.d_isl_bits, rtol=1e-6)


def test_closed_loop_parity_with_energy_skips():
    """3 revolutions on a 4-sat ring where the ~48 J/pass satellite drain
    pushes batteries below reserve: action sequence (incl. every
    skip-below-reserve decision), battery trajectories, per-pass losses
    and the energy summary must match the host oracle within float32
    tolerance."""
    budget = _budget(n_items=4e6)
    host, dev = _pair(budget, n_passes=12, battery_j=200.0,
                      recharge_w=0.01, reserve_j=150.0,
                      max_steps_per_pass=4)
    host.run()
    dev.run(engine="device")

    assert len(dev.records) == 12
    actions = [r.action for r in host.records]
    assert "trained" in actions and "skipped_energy" in actions
    _assert_record_parity(host.records, dev.records)

    hs, ds = host.summary(), dev.summary()
    assert ds["trained"] == hs["trained"]
    assert ds["skipped"] == hs["skipped"] > 0
    np.testing.assert_allclose(ds["loss_last"], hs["loss_last"],
                               rtol=2e-4, atol=1e-5)
    for key in ("E_total_J", "E_comm_J", "E_proc_J", "E_isl_J"):
        np.testing.assert_allclose(ds[key], hs[key], rtol=1e-5)
    # fleet state folded back onto the host SatelliteStates
    for hsat, dsat in zip(host.sats, dev.sats):
        np.testing.assert_allclose(dsat.battery_j, hsat.battery_j,
                                   rtol=1e-5, atol=0.05)
        assert dsat.passes_served == hsat.passes_served


def test_loss_parity_two_clean_revolutions():
    """No skips, no shedding: pure training parity over >=2 revolutions
    (same samples, same shared step kernel, same optimizer updates)."""
    host, dev = _pair(_budget(), n_passes=8, max_steps_per_pass=8)
    host.run()
    dev.run(engine="device")
    _assert_record_parity(host.records, dev.records)
    hl = np.array([r.loss for r in host.records])
    assert hl[-1] < hl[0]          # still actually learning
    eng = dev.device_engine
    assert eng.traces == 1
    assert eng.host_syncs <= 2     # <= one per revolution


def test_shedding_parity():
    """Infeasible budgets shed on both engines: same action, kept
    fraction within the host bisection tolerance."""
    host, dev = _pair(_budget(n_items=4e7), n_passes=4,
                      max_steps_per_pass=4)
    host.run()
    dev.run(engine="device")
    assert all(r.action == "shed" for r in host.records)
    _assert_record_parity(host.records, dev.records, e_rtol=2e-3)


def test_streamed_telemetry_one_sync_per_revolution():
    budget = _budget()
    eng = DeviceConstellationSim(
        ADAPTER, budget, SHARDS,
        DeviceSimConfig(n_revolutions=3, max_steps_per_pass=4))
    res = eng.run(stream_telemetry=True)
    assert res.action.shape == (3, 4)
    assert eng.traces == 1         # one revolution program, reused
    assert eng.device_calls == 3
    assert eng.host_syncs == 3     # exactly one per revolution
    # chaining: a further run reuses the same trace and the train state
    res2 = eng.run(1, stream_telemetry=True)
    assert eng.traces == 1
    assert np.isfinite(res2.loss).all()
    # training continued from where the first run stopped
    assert res2.loss[0, 0] < res.loss[-1, -1]


def test_engine_plan_matches_host_planner():
    """The engine's on-device plan equals the host RevolutionPlanner's
    batched solve for the same measured costs."""
    budget = _budget(n_items=400.0)
    host = ConstellationSim(ADAPTER, budget, SHARDS,
                            ConstellationConfig(batch_size=4, n_passes=1))
    host.run()                     # populates planner with measured costs
    entry = host.planner.entry_for(
        0, [0, 1, 2, 3], budget,
        [host._costs_for(s) for s in range(4)])
    eng = host.as_device_sim(n_revolutions=1)
    plan = eng.plan
    alloc = entry.shed.report.allocation
    np.testing.assert_allclose(
        np.asarray(plan.e_total_j)[0], alloc.e_total, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(plan.drain_j)[0],
        alloc.e_proc_sat + alloc.e_comm_down + alloc.e_isl, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(plan.t_total_s)[0], alloc.t_total, rtol=1e-5)


def test_sweep_cell_feeds_whole_revolution():
    """A planned (ring x cut x budget) grid cell broadcasts into a
    DevicePassPlan identical to the engine's own plan and drives a full
    closed-loop revolution (ROADMAP: planned grids feed whole-revolution
    execution)."""
    from repro.core.mission import sweep_revolutions

    budget = _budget()
    eng = DeviceConstellationSim(ADAPTER, budget, SHARDS,
                                 DeviceSimConfig(max_steps_per_pass=8))
    sweep = sweep_revolutions([4], [eng.costs], [16.0], budget=budget)
    plan = sweep.revolution_plan(batch_size=4, cut=0,
                                 max_steps_per_pass=8)
    for field in plan._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(plan, field)),
            np.asarray(getattr(eng.plan, field)),
            rtol=1e-6, atol=1e-12, err_msg=field)
    eng2 = DeviceConstellationSim(ADAPTER, budget, SHARDS,
                                  DeviceSimConfig(max_steps_per_pass=8),
                                  plan=plan)
    res = eng2.run()
    assert (res.action == ACTION_TRAINED).all()
    assert np.isfinite(res.loss).all()


def test_delegation_guards():
    """Elastic membership and random failures are no longer blockers
    (they delegate to the fleet engine, tests/test_fleet.py); the
    remaining host-only features are non-traceable providers, handoff
    persistence, and ragged static revolutions."""
    budget = _budget()
    sim = ConstellationSim(ADAPTER, budget, lambda s, i: SHARDS(s, i),
                           ConstellationConfig(n_passes=8))
    with pytest.raises(ValueError, match="traceable"):
        sim.run(engine="device")
    sim = ConstellationSim(ADAPTER, budget, lambda s, i: SHARDS(s, i),
                           ConstellationConfig(n_passes=8, fail_prob=0.5))
    with pytest.raises(ValueError, match="traceable"):
        sim.run(engine="device")
    sim = ConstellationSim(ADAPTER, budget, SHARDS,
                           ConstellationConfig(n_passes=8, fail_prob=0.5,
                                               handoff_dir="/tmp/x"))
    with pytest.raises(ValueError, match="handoff"):
        sim.run(engine="device")
    sim = ConstellationSim(ADAPTER, budget, SHARDS,
                           ConstellationConfig(n_passes=7))
    with pytest.raises(ValueError, match="whole number of revolutions"):
        sim.run(engine="device")
    with pytest.raises(ValueError, match="unknown engine"):
        sim.run(engine="tpu")


def test_1000_sat_revolution_no_per_pass_host_transfers():
    """The scale target: a 1000-satellite ring runs a full closed-loop
    revolution (planning + masked fused passes + battery/recharge/skip
    policy) as ONE compiled program — one jit trace, one dispatch, one
    telemetry sync; no per-pass host boundary crossings."""
    shards = DeviceImageryShards(img=32, batch=2)
    budget = PassBudget(plane=OrbitalPlane(n_sats=1000), n_items=2.0)
    eng = DeviceConstellationSim(
        ADAPTER, budget, shards,
        DeviceSimConfig(n_revolutions=1, max_steps_per_pass=1))
    assert int(np.asarray(eng.plan.n_steps).max()) == 1
    res = eng.run()
    assert eng.traces == 1          # the whole loop compiled once
    assert eng.device_calls == 1    # ... dispatched once
    assert eng.host_syncs == 1      # ... synced once (telemetry)
    assert res.action.shape == (1, 1000)
    assert (res.action == ACTION_TRAINED).all()
    assert np.isfinite(res.loss).all()
    assert (res.energy.passes_served == 1).all()
    assert (res.energy.battery_j >= 0).all()
    # the train state advanced exactly 1000 fused steps, all on device
    assert int(np.asarray(res.state.step)) == 1000


def test_plan_ring_passes_per_sat_heterogeneous():
    """Per-satellite measured payloads plan as (N,) instances."""
    budget = _budget()
    costs = ADAPTER.costs()
    costs = dataclasses.replace(costs, d_isl_bits=1e6)
    dtx = np.array([1e4, 2e4, 3e4, 4e4])
    plan = plan_ring_passes(budget, costs, batch_size=4, dtx_bits=dtx,
                            max_steps_per_pass=8)
    e = np.asarray(plan.e_total_j)
    assert e.shape == (4,)
    assert (np.diff(e) > 0).all()   # heavier payloads cost more energy


def test_chained_delegation_resumes_data_cursor():
    """Two chained device runs equal one long host run: the engine
    inherits the host's batch index and folds it back, so no satellite
    ever retrains on samples it already consumed."""
    host, dev = _pair(_budget(), n_passes=8, max_steps_per_pass=8)
    host.run()
    dev.cfg.n_passes = 4
    dev.run(engine="device")
    dev.run(engine="device")
    assert dev._batch_idx == host._batch_idx
    _assert_record_parity(host.records, dev.records)
