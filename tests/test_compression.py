"""Gradient/delta compression (train/compression.py): error-feedback
conservation, exact top-k sparsity, int8 round-trips, exact payload-bit
metering, scheme validation, and a compressed-vs-uncompressed SGD
convergence check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.compression import (ErrorFeedbackState, SCHEMES,
                                     VALUE_BITS, compress, ef_init,
                                     index_bits, int8_compress,
                                     int8_payload_bits, payload_bits,
                                     topk_compress, topk_payload_bits)


def _grads(seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"w": jax.random.normal(k1, (8, 16)),
            "b": jax.random.normal(k2, (16,))}


# ------------------------------------------------------ error feedback

@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_error_feedback_conserves_mass(scheme):
    """kept + residual == grads + prev_residual: compression error is
    carried, never lost (the Stich et al. memory invariant)."""
    grads = _grads()
    ef = ef_init(grads)
    # seed a nonzero prior residual so the accumulate path is exercised
    ef = ErrorFeedbackState(jax.tree.map(lambda r: r + 0.25, ef.residual))
    kept, ef2, metrics = compress(grads, ef, scheme=scheme,
                                  topk_ratio=0.1)
    acc = jax.tree.map(lambda g, r: g + r, grads, ef.residual)
    total = jax.tree.map(lambda k_, r: k_ + r, kept, ef2.residual)
    for a, t in zip(jax.tree.leaves(acc), jax.tree.leaves(total)):
        np.testing.assert_allclose(np.asarray(t), np.asarray(a),
                                   rtol=1e-6, atol=1e-6)
    assert float(metrics["compress_residual_norm"]) >= 0.0


def test_topk_keeps_exactly_k_entries():
    grads = _grads(1)
    kept, _, _ = topk_compress(grads, ef_init(grads), ratio=0.1)
    for g, k_ in zip(jax.tree.leaves(grads), jax.tree.leaves(kept)):
        k_expect = max(1, int(g.size * 0.1))
        assert int((np.asarray(k_) != 0).sum()) == k_expect


def test_int8_round_trip_tolerance():
    """Symmetric per-row int8: error bounded by half a quantization
    step per row."""
    grads = _grads(2)
    deq, ef2, _ = int8_compress(grads, ef_init(grads))
    for g, d in zip(jax.tree.leaves(grads), jax.tree.leaves(deq)):
        g, d = np.asarray(g), np.asarray(d)
        step = np.abs(g).max() / 127.0
        assert np.abs(g - d).max() <= step * 1.01


def test_unknown_scheme_raises():
    grads = _grads()
    with pytest.raises(ValueError):
        compress(grads, ef_init(grads), scheme="fft")
    with pytest.raises(ValueError):
        payload_bits(grads, "fft")
    assert set(SCHEMES) == {"none", "topk", "int8"}


# ------------------------------------------------------- bit metering

def test_payload_bits_by_hand():
    """The exact wire formulas on a known tree: top-k
    ``k*(value+index)``, int8 ``numel*8 + rows*32``, none dense."""
    tree = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    # none: dense fp32
    assert payload_bits(tree, "none") == (128 + 16) * VALUE_BITS
    # topk at 10%: w keeps 12 of 128 (7 index bits), b 1 of 16 (4 bits)
    assert index_bits(128) == 7 and index_bits(16) == 4
    assert topk_payload_bits(tree, 0.1) == 12 * (32 + 7) + 1 * (32 + 4)
    assert payload_bits(tree, "topk", topk_ratio=0.1) == \
        topk_payload_bits(tree, 0.1)
    # int8: one fp32 scale per row; rank-1 tensors quantize as one row
    assert int8_payload_bits(tree) == (128 * 8 + 8 * 32) + (16 * 8 + 32)
    # degenerate shapes
    assert index_bits(1) == 1
    assert topk_payload_bits({"s": jnp.zeros(())}, 0.5) == 1 * (32 + 1)


def test_compressor_metrics_are_uniform_and_exact():
    """Both schemes surface the same metrics keys, and the metered bits
    equal the shape-only formula — the satellite-task fix for int8's
    formerly empty metrics dict."""
    grads = _grads(3)
    for scheme, expect in [("topk", topk_payload_bits(grads, 0.05)),
                           ("int8", int8_payload_bits(grads))]:
        _, _, m = compress(grads, ef_init(grads), scheme=scheme,
                           topk_ratio=0.05)
        assert set(m) == {"compress_kept_norm", "compress_residual_norm",
                          "compress_payload_bits"}
        assert float(m["compress_payload_bits"]) == float(expect)
        assert float(m["compress_kept_norm"]) > 0.0


# ----------------------------------------------------- SGD convergence

def test_compressed_sgd_converges_like_uncompressed():
    """Top-k 30% with error feedback on a least-squares problem lands
    within a modest factor of plain SGD (and both actually descend)."""
    key = jax.random.key(0)
    X = jax.random.normal(key, (64, 10))
    w_true = jnp.linspace(-1.0, 1.0, 10)
    y = X @ w_true

    def loss_fn(w):
        return jnp.mean((X @ w - y) ** 2)

    grad = jax.grad(loss_fn)

    def train(scheme):
        w = jnp.zeros((10,))
        ef = ef_init({"w": w})
        for _ in range(120):
            g = {"w": grad(w)}
            g, ef, _ = compress(g, ef, scheme=scheme, topk_ratio=0.3)
            w = w - 0.05 * g["w"]
        return float(loss_fn(w))

    l0 = float(loss_fn(jnp.zeros((10,))))
    plain, topk = train("none"), train("topk")
    assert plain < 0.05 * l0
    assert topk < 0.10 * l0
    assert topk < 4.0 * plain + 1e-6
