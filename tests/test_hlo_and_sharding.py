"""HLO collective parser + logical-axis sharding resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.param import ParamSpec, ShardingRules
from repro.utils import hlo

SAMPLE = """
HloModule test
ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %p0), replica_groups={}
  %ag = f32[256,256]{1,0} all-gather(f32[128,256]{1,0} %ar), dimensions={0}
  %rs = f32[64,256]{1,0} reduce-scatter(f32[128,256]{1,0} %p0), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(f32[128,256]{1,0} %p0), source_target_pairs={{0,1}}
  %a2a = f32[128,256]{1,0} all-to-all(f32[128,256]{1,0} %p0), dimensions={0}
  ROOT %out = f32[128,256]{1,0} add(f32[128,256]{1,0} %ar, f32[128,256]{1,0} %cp)
}
"""


def test_collective_census_counts_and_bytes():
    stats = hlo.collective_stats(SAMPLE)
    b = 128 * 256 * 4
    assert stats["all-reduce"] == {"count": 1, "bytes": b}
    assert stats["all-gather"] == {"count": 1, "bytes": 2 * b}   # result
    assert stats["reduce-scatter"] == {"count": 1, "bytes": b}   # operand
    assert stats["collective-permute"]["count"] == 1
    assert stats["all-to-all"]["count"] == 1
    assert hlo.collective_bytes(SAMPLE) == pytest.approx(6 * b)


def test_async_start_counted_once():
    text = """
  %ags = (f32[16,4]{1,0}, f32[32,4]{1,0}) all-gather-start(f32[16,4]{1,0} %x), dimensions={0}
  %agd = f32[32,4]{1,0} all-gather-done((f32[16,4], f32[32,4]) %ags)
"""
    stats = hlo.collective_stats(text)
    assert stats["all-gather"]["count"] == 1


def test_real_compiled_collectives_on_host_mesh():
    """A 1-device mesh compiles with zero collectives; the parser must
    return zeros (no false positives on fusion metadata)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        f = jax.jit(lambda x: (x @ x.T).sum())
        txt = f.lower(jnp.ones((8, 8))).compile().as_text()
    assert hlo.collective_bytes(txt) == 0


# ----------------------------------------------------------------- sharding

class _FakeMesh:
    def __init__(self, shape, names):
        import numpy as _np
        self.devices = _np.empty(shape)
        self.axis_names = names


def test_rules_resolve_basic():
    rules = ShardingRules()
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    spec = rules.resolve(("batch", None, "mlp"), mesh, (256, 4096, 8192))
    assert spec == P(("pod", "data"), None, "model")


def test_rules_drop_indivisible():
    rules = ShardingRules()
    mesh = _FakeMesh((16, 16), ("data", "model"))
    # 15 heads don't divide the 16-way model axis -> replicated
    spec = rules.resolve(("batch", None, "heads", None), mesh,
                         (256, 4096, 15, 64))
    assert spec == P("data", None, None, None)
    # granite's 49155-row vocab stays replicated too
    spec = rules.resolve(("vocab", "embed"), mesh, (49155, 2048))
    assert spec == P(None, None)


def test_rules_single_pod_drops_pod_axis():
    rules = ShardingRules()
    mesh = _FakeMesh((16, 16), ("data", "model"))
    spec = rules.resolve(("batch",), mesh, (256,))
    assert spec == P("data")


def test_no_double_axis_use():
    rules = ShardingRules(seq="model")
    mesh = _FakeMesh((16, 16), ("data", "model"))
    # heads wants model, seq wants model: first come first served
    spec = rules.resolve(("batch", "seq", "heads"), mesh, (256, 4096, 32))
    assert spec == P("data", "model", None)
