"""Benchmarks reproducing the paper's tables/figures.

One function per artifact; each prints a CSV-ish block and returns a
dict so tests can assert the claims:

  table1  — constellation geometry (T_pass ≈ 3.8 min check)
  table2  — ResNet-18 split points (ours vs paper; both D_ISL conventions)
  fig3_top — autoencoder SL vs direct download energy (97% claim)
  fig3_bottom — ResNet split-point energy sweep
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.energy import (PassBudget, SplitCosts,
                               direct_download_costs)
from repro.core.orbits import PAPER_PLANE
from repro.core.resource_opt import (best_split_batch, solve, solve_batch,
                                     solve_pipelined)
from repro.core.splitting import (RESNET18_PAPER_CUTS, autoencoder_plan,
                                  resnet18_plan)

# Paper-published numbers (§V-A, Table II).
PAPER_AE = dict(w1=302e9, w2=39e6, dtx=4.7e3, d_isl=168.8e3)
PAPER_TABLE2 = {
    "l1": dict(w1=1.765e9, w2=3.714e9, dtx=6.423e6, d_isl=369.056e6),
    "l2": dict(w1=3.006e9, w2=2.474e9, dtx=3.211e6, d_isl=352.224e6),
    "l3": dict(w1=4.243e9, w2=1.237e9, dtx=1.605e6, d_isl=285.024e6),
}
RAW_IMAGE_BITS = 1.605e6           # Table I "average image size D"


def table1() -> Dict:
    s = PAPER_PLANE.summary()
    print("== Table 1 / constellation geometry ==")
    for k, v in s.items():
        print(f"  {k:24s} {v:.4f}" if isinstance(v, float) else
              f"  {k:24s} {v}")
    print(f"  paper claim: T_pass ~ 3.8 min -> ours "
          f"{s['pass_duration_min']:.3f} min "
          f"(eq. 4 erratum: /(2*pi), see DESIGN.md)")
    return s


def table2() -> Dict:
    """ResNet-18 split costs: our analytic model vs the paper's values."""
    plan = resnet18_plan(img=224, n_classes=1000)
    total_param_bits = 8.0 * (sum(l.param_bytes for l in plan.layers))
    print("== Table 2 / ResNet-18 split points ==")
    print("cut, W1_ours_GF, W1_paper_GF, W2_ours_GF, W2_paper_GF, "
          "Dtx_ours_Mb, Dtx_paper_Mb, Disl_segA_Mb, Disl_paper(segB)_Mb")
    out = {}
    for name, cut in RESNET18_PAPER_CUTS.items():
        c = plan.costs_at(cut)
        p = PAPER_TABLE2[name]
        # The paper counts W in GMAC-units (fvcore counts MACs): W_paper =
        # 3 x GMACs. Our fwd_flops are 2 FLOPs/MAC, so ours/2 x 3 = theirs.
        w1_ours = c.w1_flops / 2.0
        w2_ours = c.w2_flops / 2.0
        disl_segb = total_param_bits + PAPER_AE["d_isl"] * 0 \
            - (c.d_isl_bits)
        row = dict(w1_ours=w1_ours, w2_ours=w2_ours,
                   dtx_ours=c.dtx_bits, d_isl_segA=c.d_isl_bits,
                   d_isl_segB=disl_segb, **{f"{k}_paper": v
                                            for k, v in p.items()})
        out[name] = row
        print(f"{name}, {w1_ours/1e9:.3f}, {p['w1']/1e9:.3f}, "
              f"{w2_ours/1e9:.3f}, {p['w2']/1e9:.3f}, "
              f"{c.dtx_bits/1e6:.3f}, {p['dtx']/1e6:.3f}, "
              f"{c.d_isl_bits/1e6:.1f}, {p['d_isl']/1e6:.1f}")
    print("  NOTE (erratum #2, DESIGN.md): the paper's D_ISL column matches "
          "the GROUND segment's parameter bytes (total - segA); the handoff "
          "the architecture ships is segment A. Both reported.")
    return out


def _budget(n_items=400.0) -> PassBudget:
    return PassBudget(n_items=n_items)


def fig3_top() -> Dict:
    """Autoencoder: SL vs direct download, two W interpretations."""
    print("== Fig. 3 (top) / autoencoder SL vs direct download ==")
    out = {}

    for label, scale in [("paper_W_per_image", 1.0),
                         ("W_as_total(/400)", 1.0 / 400.0)]:
        sl = SplitCosts(w1_flops=PAPER_AE["w1"] * scale,
                        w2_flops=PAPER_AE["w2"] * scale,
                        dtx_bits=PAPER_AE["dtx"],
                        d_isl_bits=PAPER_AE["d_isl"], name="ae-sl")
        dd = direct_download_costs(
            RAW_IMAGE_BITS, (PAPER_AE["w1"] + PAPER_AE["w2"]) * scale)
        b = _budget()
        r_sl = solve(b, sl)
        r_dd = solve(b, dd)
        e_sl, e_dd = r_sl.allocation.e_total, r_dd.allocation.e_total
        sav = 100.0 * (1.0 - e_sl / e_dd)
        out[label] = dict(
            e_sl=e_sl, e_dd=e_dd, savings_pct=sav,
            sl=r_sl.allocation.summary(), dd=r_dd.allocation.summary())
        print(f"  [{label}] E_SL={e_sl:.4g} J (proc "
              f"{r_sl.allocation.e_proc_sat + r_sl.allocation.e_proc_gs:.3g}"
              f" / comm {r_sl.allocation.e_comm_down + r_sl.allocation.e_comm_up + r_sl.allocation.e_isl:.3g})"
              f"  E_DD={e_dd:.4g} J  savings={sav:.1f}%")
    print("  paper claim: ~97% savings — reproduced in the comm-dominated "
          "regime (W-as-total row); with W per-image the processing term "
          "dominates both systems and savings shrink (DESIGN.md erratum #3).")
    return out


def fig3_bottom() -> Dict:
    """ResNet-18 energy at the three split points (+ direct download).

    The whole sweep is one :func:`solve_batch` call — the same batched
    path constellation-scale cut × pass sweeps use.
    """
    print("== Fig. 3 (bottom) / ResNet-18 split-point sweep ==")
    plan = resnet18_plan(img=224, n_classes=1000)
    b = _budget()
    names = list(RESNET18_PAPER_CUTS)
    cands = [plan.costs_at(RESNET18_PAPER_CUTS[nm]) for nm in names]
    cands.append(direct_download_costs(
        RAW_IMAGE_BITS, plan.costs_at(0).w2_flops / 3.0 * 3.0))
    rep = solve_batch(b, cands)
    out = {}
    for i, name in enumerate(names):
        a = rep.report_at(i).allocation
        out[name] = dict(e_total=a.e_total, e_comm=a.e_comm_down
                         + a.e_comm_up + a.e_isl,
                         e_proc=a.e_proc_sat + a.e_proc_gs,
                         feasible=a.feasible)
        print(f"  {name}: E={a.e_total:.4g} J (comm "
              f"{out[name]['e_comm']:.3g}, proc {out[name]['e_proc']:.3g}) "
              f"Dtx={cands[i].dtx_bits/1e6:.2f} Mb")
    out["direct"] = dict(e_total=float(rep.e_total[len(names)]))
    print(f"  direct download: E={out['direct']['e_total']:.4g} J")
    order = [out[k]["e_total"] for k in ("l1", "l2", "l3")]
    print(f"  paper claim: deeper split (l3) wins -> ours "
          f"{'monotone decreasing OK' if order[0] > order[1] > order[2] else order}")
    return out


def beyond_paper() -> Dict:
    """Beyond-paper rows: int8 boundary, pipelining, auto split search."""
    print("== beyond-paper optimizations (energy model) ==")
    plan = resnet18_plan(img=224, n_classes=1000)
    b = _budget()
    base = solve(b, plan.costs_at(5))                       # l2
    q = solve(b, plan.with_boundary_compression(0.25).costs_at(5))
    pipe = solve_pipelined(b, plan.costs_at(5), n_microbatches=8)
    cbest, rbest = best_split_batch(b, plan.enumerate_cuts())
    out = dict(
        base=base.allocation.e_total,
        int8=q.allocation.e_total,
        pipelined=pipe.allocation.e_total,
        auto_split=dict(cut=cbest.name, e=rbest.allocation.e_total))
    print(f"  l2 baseline            E={out['base']:.4g} J")
    print(f"  + int8 boundary (4x)   E={out['int8']:.4g} J "
          f"({100*(1-out['int8']/out['base']):.1f}% vs base)")
    print(f"  + microbatch pipeline  E={out['pipelined']:.4g} J "
          f"({100*(1-out['pipelined']/out['base']):.1f}% vs base)")
    print(f"  auto split search      {cbest.name} "
          f"E={rbest.allocation.e_total:.4g} J")
    return out


def run_all() -> Dict:
    return {
        "table1": table1(),
        "table2": table2(),
        "fig3_top": fig3_top(),
        "fig3_bottom": fig3_bottom(),
        "beyond_paper": beyond_paper(),
    }


if __name__ == "__main__":
    run_all()
