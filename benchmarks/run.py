"""Benchmark entry point: one block per paper table/figure + the
beyond-paper rows + a micro-benchmark of the SL step and kernels.

Usage:  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import os
import time


def _timeit(fn, *args, n=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args, **kw)
    return (time.time() - t0) / n * 1e6, out      # us/call


def micro_benchmarks():
    """us/call for the SL step + each kernel's jnp path (CPU; the numbers
    are for regression tracking, not TPU performance claims)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.sl_step import autoencoder_adapter, make_sl_step
    from repro.data.synthetic import ImageryShards
    from repro.kernels import ops

    print("== micro-benchmarks (CPU reference timings) ==")
    print("name,us_per_call,derived")
    rng = np.random.default_rng(0)

    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, ImageryShards(img=32, batch=4)
                         .batch_at(0, 0))
    step = make_sl_step(ad)
    us, _ = _timeit(lambda: step(pa, pb, batch))
    print(f"sl_step_autoencoder,{us:.0f},loss+both-grads")

    q = jnp.asarray(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(f(q, k, v)))
    flops = 4 * 8 * 512 * 512 / 2 * 64
    print(f"flash_attention_512,{us:.0f},{flops/us/1e3:.1f}GFLOP/s")

    x = jnp.asarray(rng.standard_normal((1, 512, 4, 64)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((1, 512, 4))))
    alog = jnp.asarray(rng.standard_normal(4)) * 0.5
    b = jnp.asarray(rng.standard_normal((1, 512, 16)), jnp.float32)
    g = jax.jit(lambda *a: ops.mamba_scan(*a, chunk=128, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(g(x, dt, alog, b, b)[0]))
    print(f"mamba_scan_512,{us:.0f},chunked-ssd")

    xq = jnp.asarray(rng.standard_normal((4096, 512)), jnp.float32)
    h = jax.jit(lambda t: ops.quantize_boundary(t, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(h(xq)[0]))
    print(f"split_quant_4096x512,{us:.0f},{xq.nbytes/us/1e3:.2f}GB/s")


def main() -> None:
    from benchmarks import paper_tables

    t0 = time.time()
    results = paper_tables.run_all()
    micro_benchmarks()

    os.makedirs("results", exist_ok=True)

    def _clean(o):
        if isinstance(o, dict):
            return {k: _clean(v) for k, v in o.items()}
        if isinstance(o, (list, tuple)):
            return [_clean(v) for v in o]
        if isinstance(o, (float, int, str, bool)) or o is None:
            return o
        return float(o) if hasattr(o, "__float__") else str(o)

    with open("results/bench.json", "w") as f:
        json.dump(_clean(results), f, indent=1)
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s "
          f"-> results/bench.json")


if __name__ == "__main__":
    main()
