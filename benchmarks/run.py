"""Benchmark entry point: one block per paper table/figure + the
beyond-paper rows + micro-benchmarks of the SL step, the batched pass
engine (before/after rows for the vectorized problem-(13) solver and the
scan-fused pass executor), and each kernel's jnp path.

Usage:  PYTHONPATH=src python -m benchmarks.run

Alongside the stdout tables the run emits machine-readable JSON to
``results/BENCH_<rev>.json`` (``<rev>`` = current git short hash, "dev"
outside a checkout) so the perf trajectory is tracked across PRs, plus
``results/bench.json`` as a stable latest-run alias.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import subprocess
import time


def _timeit(fn, *args, n=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args, **kw)
    return (time.time() - t0) / n * 1e6, out      # us/call


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "dev"
    except Exception:
        return "dev"


def engine_benchmarks():
    """Before/after rows for the batched pass engine (the tentpole):

    * problem-(13): loop of the scalar reference solver vs one
      ``solve_batch`` call over the same >=256-instance cut x pass sweep;
    * SL pass execution: 16 Python-loop ``make_sl_step`` + eager SGD
      calls vs ONE jitted ``make_sl_pass`` scan of the same 16 steps;
    * revolution planning: a per-pass scalar ``solve_with_shedding``
      loop (the pre-planner scheduler) vs one ``RevolutionPlanner``
      batched solve for the same ring revolution.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import resource_opt
    from repro.core.energy import PassBudget
    from repro.core.mission import RevolutionPlanner
    from repro.core.sl_step import autoencoder_adapter, make_sl_pass, \
        make_sl_step
    from repro.core.splitting import resnet18_plan
    from repro.core.train_state import SLTrainState
    from repro.data.synthetic import ImageryShards
    from repro.train.optimizer import sgd, sgd_init, sgd_update

    print("== pass-engine benchmarks (batched solver + fused SL pass) ==")
    print("name,us_per_call,derived")
    out = {}

    # --- problem (13): 32 n_items variants x every ResNet-18 cut --------
    plan = resnet18_plan(img=224, n_classes=1000)
    cuts = plan.enumerate_cuts()
    budgets, costs = [], []
    for j in range(32):
        b = PassBudget(n_items=50.0 * (j + 1))
        for c in cuts:
            budgets.append(b)
            costs.append(c)
    n_inst = len(costs)
    assert n_inst >= 256, n_inst

    def scalar_loop():
        return [resource_opt.solve_reference(b, c)
                for b, c in zip(budgets, costs)]

    def batched():
        return resource_opt.solve_batch(budgets, costs)

    us_loop, _ = _timeit(scalar_loop, n=1, warmup=0)   # pure python: no jit
    us_batch, rep = _timeit(batched, n=3, warmup=1)
    speedup = us_loop / us_batch
    out["solve_scalar_loop"] = dict(us=us_loop, n_instances=n_inst)
    out["solve_batch"] = dict(us=us_batch, n_instances=n_inst,
                              speedup_vs_scalar=speedup,
                              feasible=int(rep.feasible.sum()))
    print(f"solve13_scalar_loop_{n_inst},{us_loop:.0f},"
          f"{us_loop/n_inst:.0f}us/instance")
    print(f"solve13_batch_{n_inst},{us_batch:.0f},{speedup:.1f}x-speedup")

    # --- SL pass: 16 steps, python loop vs one fused scan ---------------
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    shards = ImageryShards(img=32, batch=4)
    batches = [jax.tree.map(jnp.asarray, shards.batch_at(0, i))
               for i in range(16)]
    step = make_sl_step(ad)
    opt = sgd(lr=1e-2)
    sl_pass = make_sl_pass(ad, optimizer=opt, donate=False)

    def step_loop():
        p_a, p_b = pa, pb
        oa, ob = sgd_init(pa), sgd_init(pb)
        for bt in batches:
            r = step(p_a, p_b, bt)
            p_a, oa, _ = sgd_update(r.grads_a, oa, p_a, lr=1e-2)
            p_b, ob, _ = sgd_update(r.grads_b, ob, p_b, lr=1e-2)
        return jax.block_until_ready(p_a)

    def fused_pass():
        r = sl_pass(SLTrainState.create(pa, pb, opt), batches)
        return jax.block_until_ready(r.params_a)

    us_steps, _ = _timeit(step_loop, n=3, warmup=1)
    us_pass, _ = _timeit(fused_pass, n=3, warmup=1)
    speedup = us_steps / us_pass
    out["sl_step_loop_16"] = dict(us=us_steps)
    out["sl_pass_16"] = dict(us=us_pass, speedup_vs_step_loop=speedup)
    print(f"sl_step_loop_16,{us_steps:.0f},16-python-dispatches")
    print(f"sl_pass_16,{us_pass:.0f},{speedup:.2f}x-speedup-one-scan")

    # --- revolution planning: per-pass scalar solves vs one planner -----
    # 64-sat ring, work spread so some rows shed: the pre-planner
    # scheduler paid one scalar solve_with_shedding per pass.
    ring_ids = list(range(64))
    w_max = PassBudget().sat_device.peak_flops \
        * PassBudget().plane.pass_duration_s / PassBudget().n_items
    rev_budgets = [PassBudget(n_items=200.0 + 25.0 * s) for s in ring_ids]
    rev_costs = [dataclasses.replace(cuts[s % len(cuts)],
                                     w1_flops=w_max * (0.02 * s))
                 for s in ring_ids]

    def per_pass_loop():
        return [resource_opt.solve_with_shedding(b, c)
                for b, c in zip(rev_budgets, rev_costs)]

    def planner_call():
        return RevolutionPlanner().plan_revolution(ring_ids, rev_budgets,
                                                   rev_costs)

    us_scalar, _ = _timeit(per_pass_loop, n=1, warmup=0)
    us_planner, entries = _timeit(planner_call, n=3, warmup=1)
    speedup = us_scalar / us_planner
    out["revolution_scalar_loop_64"] = dict(us=us_scalar)
    out["revolution_planner_64"] = dict(us=us_planner,
                                        speedup_vs_scalar=speedup,
                                        n_sats=len(entries))
    print(f"revolution_scalar_loop_64,{us_scalar:.0f},64-scalar-sheds")
    print(f"revolution_planner_64,{us_planner:.0f},"
          f"{speedup:.1f}x-speedup-one-batched-solve")
    return out


def micro_benchmarks():
    """us/call for the SL step + each kernel's jnp path (CPU; the numbers
    are for regression tracking, not TPU performance claims)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.sl_step import autoencoder_adapter, make_sl_step
    from repro.data.synthetic import ImageryShards
    from repro.kernels import ops

    print("== micro-benchmarks (CPU reference timings) ==")
    print("name,us_per_call,derived")
    rng = np.random.default_rng(0)
    out = {}

    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, ImageryShards(img=32, batch=4)
                         .batch_at(0, 0))
    step = make_sl_step(ad)
    us, _ = _timeit(lambda: step(pa, pb, batch))
    out["sl_step_autoencoder"] = us
    print(f"sl_step_autoencoder,{us:.0f},loss+both-grads")

    q = jnp.asarray(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(f(q, k, v)))
    flops = 4 * 8 * 512 * 512 / 2 * 64
    out["flash_attention_512"] = us
    print(f"flash_attention_512,{us:.0f},{flops/us/1e3:.1f}GFLOP/s")

    x = jnp.asarray(rng.standard_normal((1, 512, 4, 64)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((1, 512, 4))))
    alog = jnp.asarray(rng.standard_normal(4)) * 0.5
    b = jnp.asarray(rng.standard_normal((1, 512, 16)), jnp.float32)
    g = jax.jit(lambda *a: ops.mamba_scan(*a, chunk=128, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(g(x, dt, alog, b, b)[0]))
    out["mamba_scan_512"] = us
    print(f"mamba_scan_512,{us:.0f},chunked-ssd")

    xq = jnp.asarray(rng.standard_normal((4096, 512)), jnp.float32)
    h = jax.jit(lambda t: ops.quantize_boundary(t, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(h(xq)[0]))
    out["split_quant_4096x512"] = us
    print(f"split_quant_4096x512,{us:.0f},{xq.nbytes/us/1e3:.2f}GB/s")
    return out


def _flatten_metrics(obj, prefix=""):
    """Dotted-path -> float map of every numeric leaf in a results dict."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten_metrics(v, f"{prefix}{k}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix.rstrip(".")] = float(obj)
    return out


def trend_report(results_dir: str, current: dict, rev: str,
                 threshold: float = 0.20) -> dict:
    """Compare this run against the previous ``BENCH_<rev>.json``.

    Timing metrics (dotted paths ending in ``.us`` or named ``us_*``)
    regress when they grow; each >``threshold`` change is flagged.  The
    report is printed and returned so it lands inside the current JSON.
    """
    prev_path, prev = None, None
    candidates = []
    for p in glob.glob(os.path.join(results_dir, "BENCH_*.json")):
        if os.path.basename(p) == f"BENCH_{rev}.json":
            continue
        try:
            with open(p) as f:
                data = json.load(f)
            candidates.append((data.get("meta", {}).get("unix_time", 0.0),
                               p, data))
        except (json.JSONDecodeError, OSError):
            continue
    if candidates:
        _, prev_path, prev = max(candidates, key=lambda t: t[0])

    report = {"baseline": prev_path and os.path.basename(prev_path),
              "threshold": threshold, "regressions": [],
              "improvements": []}
    if prev is None:
        print("\n== trend report: no previous BENCH_<rev>.json — baseline "
              "run ==")
        return report

    cur_m = _flatten_metrics(current)
    prev_m = _flatten_metrics(prev)
    # timing metrics: engine rows expose an `us` field; micro rows are
    # bare us/call floats.  Table values (losses, energies) are not
    # regressions in the timing sense and are left out.
    timing = {k for k in cur_m if k.endswith(".us") or k.startswith("micro.")}
    for k in sorted(timing & prev_m.keys()):
        if prev_m[k] <= 0.0:
            continue
        delta = cur_m[k] / prev_m[k] - 1.0
        row = {"metric": k, "prev_us": prev_m[k], "cur_us": cur_m[k],
               "delta_pct": 100.0 * delta}
        if delta > threshold:
            report["regressions"].append(row)
        elif delta < -threshold:
            report["improvements"].append(row)

    base = report["baseline"]
    print(f"\n== trend report vs {base} "
          f"(flagging >{threshold:.0%} timing changes) ==")
    if not report["regressions"] and not report["improvements"]:
        print(f"  all {len(timing & prev_m.keys())} shared timing metrics "
              f"within {threshold:.0%}")
    for row in report["regressions"]:
        print(f"  REGRESSION {row['metric']}: {row['prev_us']:.0f}us -> "
              f"{row['cur_us']:.0f}us (+{row['delta_pct']:.0f}%)")
    for row in report["improvements"]:
        print(f"  improved   {row['metric']}: {row['prev_us']:.0f}us -> "
              f"{row['cur_us']:.0f}us ({row['delta_pct']:.0f}%)")
    return report


def main() -> None:
    from benchmarks import paper_tables

    t0 = time.time()
    results = paper_tables.run_all()
    results["engine"] = engine_benchmarks()
    results["micro"] = micro_benchmarks()
    rev = _git_rev()
    results["meta"] = {"rev": rev, "wall_s": time.time() - t0,
                       "unix_time": time.time()}

    os.makedirs("results", exist_ok=True)
    results["trend"] = trend_report("results", results, rev)

    def _clean(o):
        if isinstance(o, dict):
            return {k: _clean(v) for k, v in o.items()}
        if isinstance(o, (list, tuple)):
            return [_clean(v) for v in o]
        if isinstance(o, (float, int, str, bool)) or o is None:
            return o
        return float(o) if hasattr(o, "__float__") else str(o)

    cleaned = _clean(results)
    bench_path = os.path.join("results", f"BENCH_{rev}.json")
    for path in (bench_path, os.path.join("results", "bench.json")):
        with open(path, "w") as f:
            json.dump(cleaned, f, indent=1)
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s "
          f"-> {bench_path} (+ results/bench.json)")


if __name__ == "__main__":
    main()
