"""Benchmark entry point: one block per paper table/figure + the
beyond-paper rows + micro-benchmarks of the SL step, the batched pass
engine (before/after rows for the vectorized problem-(13) solver and the
scan-fused pass executor), the solver backends (NumPy lockstep vs the
jit+vmap JAX engine), the on-device revolution sweep, and each kernel's
jnp path.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick]

``--quick`` is the CI smoke mode (scripts/check.sh): small solver grids,
no 1000-sat sweep, paper tables skipped, results written to
``results/bench_quick.json`` only — fast enough to catch a regression in
the jitted solver without a full sweep.

Alongside the stdout tables a full run emits machine-readable JSON to
``results/BENCH_<rev>.json`` (``<rev>`` = current git short hash, "dev"
outside a checkout) so the perf trajectory is tracked across PRs, plus
``results/bench.json`` as a stable latest-run alias.
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import subprocess
import time
import traceback


def _timeit(fn, *args, n=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args, **kw)
    return (time.time() - t0) / n * 1e6, out      # us/call


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "dev"
    except Exception:
        return "dev"


def run_header(quick: bool) -> dict:
    """The shared run header emitted into every BENCH JSON: everything
    needed to judge whether two trend rows are comparable (same jax,
    same device topology, same mode) across machines."""
    import platform

    import jax

    devices = jax.devices()
    try:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
        mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    except Exception:                              # pragma: no cover
        mesh_shape = None
    return {
        "rev": _git_rev(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": len(devices),
        "device_kind": devices[0].device_kind if devices else None,
        "mesh_shape": mesh_shape,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "quick": quick,
    }


def engine_benchmarks():
    """Before/after rows for the batched pass engine (the tentpole):

    * problem-(13): loop of the scalar reference solver vs one
      ``solve_batch`` call over the same >=256-instance cut x pass sweep;
    * SL pass execution: 16 Python-loop ``make_sl_step`` + eager SGD
      calls vs ONE jitted ``make_sl_pass`` scan of the same 16 steps;
    * revolution planning: a per-pass scalar ``solve_with_shedding``
      loop (the pre-planner scheduler) vs one ``RevolutionPlanner``
      batched solve for the same ring revolution.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import resource_opt
    from repro.core.energy import PassBudget
    from repro.core.mission import RevolutionPlanner
    from repro.core.sl_step import autoencoder_adapter, make_sl_pass, \
        make_sl_step
    from repro.core.splitting import resnet18_plan
    from repro.core.train_state import SLTrainState
    from repro.data.synthetic import ImageryShards
    from repro.train.optimizer import sgd, sgd_init, sgd_update

    print("== pass-engine benchmarks (batched solver + fused SL pass) ==")
    print("name,us_per_call,derived")
    out = {}

    # --- problem (13): 32 n_items variants x every ResNet-18 cut --------
    plan = resnet18_plan(img=224, n_classes=1000)
    cuts = plan.enumerate_cuts()
    budgets, costs = [], []
    for j in range(32):
        b = PassBudget(n_items=50.0 * (j + 1))
        for c in cuts:
            budgets.append(b)
            costs.append(c)
    n_inst = len(costs)
    assert n_inst >= 256, n_inst

    def scalar_loop():
        return [resource_opt.solve_reference(b, c)
                for b, c in zip(budgets, costs)]

    def batched():
        return resource_opt.solve_batch(budgets, costs)

    us_loop, _ = _timeit(scalar_loop, n=1, warmup=0)   # pure python: no jit
    us_batch, rep = _timeit(batched, n=3, warmup=1)
    speedup = us_loop / us_batch
    out["solve_scalar_loop"] = dict(us=us_loop, n_instances=n_inst)
    out["solve_batch"] = dict(us=us_batch, n_instances=n_inst,
                              speedup_vs_scalar=speedup,
                              feasible=int(rep.feasible.sum()))
    print(f"solve13_scalar_loop_{n_inst},{us_loop:.0f},"
          f"{us_loop/n_inst:.0f}us/instance")
    print(f"solve13_batch_{n_inst},{us_batch:.0f},{speedup:.1f}x-speedup")

    # --- SL pass: 16 steps, python loop vs one fused scan ---------------
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    shards = ImageryShards(img=32, batch=4)
    batches = [jax.tree.map(jnp.asarray, shards.batch_at(0, i))
               for i in range(16)]
    step = make_sl_step(ad)
    opt = sgd(lr=1e-2)
    sl_pass = make_sl_pass(ad, optimizer=opt, donate=False)

    def step_loop():
        p_a, p_b = pa, pb
        oa, ob = sgd_init(pa), sgd_init(pb)
        for bt in batches:
            r = step(p_a, p_b, bt)
            p_a, oa, _ = sgd_update(r.grads_a, oa, p_a, lr=1e-2)
            p_b, ob, _ = sgd_update(r.grads_b, ob, p_b, lr=1e-2)
        return jax.block_until_ready(p_a)

    def fused_pass():
        r = sl_pass(SLTrainState.create(pa, pb, opt), batches)
        return jax.block_until_ready(r.params_a)

    us_steps, _ = _timeit(step_loop, n=3, warmup=1)
    us_pass, _ = _timeit(fused_pass, n=3, warmup=1)
    speedup = us_steps / us_pass
    out["sl_step_loop_16"] = dict(us=us_steps)
    out["sl_pass_16"] = dict(us=us_pass, speedup_vs_step_loop=speedup)
    print(f"sl_step_loop_16,{us_steps:.0f},16-python-dispatches")
    print(f"sl_pass_16,{us_pass:.0f},{speedup:.2f}x-speedup-one-scan")

    # --- revolution planning: per-pass scalar solves vs one planner -----
    # 64-sat ring, work spread so some rows shed: the pre-planner
    # scheduler paid one scalar solve_with_shedding per pass.
    ring_ids = list(range(64))
    w_max = PassBudget().sat_device.peak_flops \
        * PassBudget().plane.pass_duration_s / PassBudget().n_items
    rev_budgets = [PassBudget(n_items=200.0 + 25.0 * s) for s in ring_ids]
    rev_costs = [dataclasses.replace(cuts[s % len(cuts)],
                                     w1_flops=w_max * (0.02 * s))
                 for s in ring_ids]

    def per_pass_loop():
        return [resource_opt.solve_with_shedding(b, c)
                for b, c in zip(rev_budgets, rev_costs)]

    def planner_call():
        return RevolutionPlanner().plan_revolution(ring_ids, rev_budgets,
                                                   rev_costs)

    us_scalar, _ = _timeit(per_pass_loop, n=1, warmup=0)
    us_planner, entries = _timeit(planner_call, n=3, warmup=1)
    speedup = us_scalar / us_planner
    out["revolution_scalar_loop_64"] = dict(us=us_scalar)
    out["revolution_planner_64"] = dict(us=us_planner,
                                        speedup_vs_scalar=speedup,
                                        n_sats=len(entries))
    print(f"revolution_scalar_loop_64,{us_scalar:.0f},64-scalar-sheds")
    print(f"revolution_planner_64,{us_planner:.0f},"
          f"{speedup:.1f}x-speedup-one-batched-solve")
    return out


def solver_backend_benchmarks(quick: bool = False):
    """Backend rows for the problem-(13) solver (the device tentpole):

    * ``solve13_numpy_<B>``: the lockstep NumPy ``solve_batch`` over a
      >=4096-instance (cut x n_items) grid (full call incl. the host
      coefficient gather, i.e. what any consumer pays);
    * ``solve13_jax_<B>``: ``solve_batch_jax`` post-compile, same grid,
      same full-call accounting;
    * ``solve13_jax_device_<B>``: the device-resident core
      (``solve_coeffs`` on pre-staged CoeffArrays) — the number a
      zero-host-transfer pipeline (sweep_revolutions) actually sees.
    """
    import jax
    from repro.core import resource_opt, resource_opt_jax
    from repro.core.energy import PassBudget
    from repro.core.splitting import resnet18_plan

    print("== solver-backend benchmarks (numpy vs jit+vmap jax) ==")
    print("name,us_per_call,derived")
    out = {}
    if not resource_opt_jax.available():           # pragma: no cover
        print("solver_backend,skipped,jax-unavailable")
        return out

    plan = resnet18_plan(img=224, n_classes=1000)
    cuts = plan.enumerate_cuts()
    n_variants = 36 if quick else 512
    budgets, costs = [], []
    for j in range(n_variants):
        b = PassBudget(n_items=50.0 * (j + 1))
        for c in cuts:
            budgets.append(b)
            costs.append(c)
    n_inst = len(costs)
    if not quick:
        assert n_inst >= 4096, n_inst

    def np_call():
        return resource_opt.solve_batch(budgets, costs, backend="numpy")

    def jax_call():
        return resource_opt.solve_batch(budgets, costs, backend="jax")

    us_np, rep_np = _timeit(np_call, n=3, warmup=1)
    us_jax, rep_jax = _timeit(jax_call, n=3, warmup=1)   # warmup compiles

    blist, clist = resource_opt._broadcast_instances(budgets, costs)
    with resource_opt_jax.x64_scope():
        coeffs = resource_opt_jax._coeffs_from_instances(blist, clist)

        def device_call():
            return jax.block_until_ready(
                resource_opt_jax.solve_coeffs(coeffs).phase_times)

        us_dev, _ = _timeit(device_call, n=3, warmup=1)

    import numpy as np
    agree = bool(np.allclose(rep_np.e_total, rep_jax.e_total, rtol=1e-8))
    out["solve13_numpy"] = dict(us=us_np, n_instances=n_inst)
    out["solve13_jax"] = dict(us=us_jax, n_instances=n_inst,
                              speedup_vs_numpy=us_np / us_jax,
                              parity_vs_numpy=agree)
    out["solve13_jax_device"] = dict(us=us_dev, n_instances=n_inst,
                                     speedup_vs_numpy=us_np / us_dev)
    print(f"solve13_numpy_{n_inst},{us_np:.0f},host-lockstep")
    print(f"solve13_jax_{n_inst},{us_jax:.0f},"
          f"{us_np / us_jax:.2f}x-vs-numpy,parity={agree}")
    print(f"solve13_jax_device_{n_inst},{us_dev:.0f},"
          f"{us_np / us_dev:.2f}x-vs-numpy-device-resident")
    return out


def sweep_benchmarks(quick: bool = False):
    """The on-device revolution sweep: a (ring x cut x budget) grid —
    including the 1000-sat ring in full mode — planned (coefficients,
    shedding, dual bisection) in ONE jitted call with zero host
    transfers, then chained into a fused SL pass via a device-side step
    count (``steps_for`` -> ``n_valid``) without ever syncing the plan.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import resource_opt_jax
    from repro.core.mission import sweep_revolutions
    from repro.core.sl_step import autoencoder_adapter, make_sl_pass
    from repro.core.splitting import resnet18_plan
    from repro.core.train_state import SLTrainState
    from repro.data.synthetic import ImageryShards
    from repro.train.optimizer import sgd

    print("== revolution-sweep benchmarks (on-device planning) ==")
    print("name,us_per_call,derived")
    out = {}
    if not resource_opt_jax.available():           # pragma: no cover
        print("sweep_revolutions,skipped,jax-unavailable")
        return out

    cuts = resnet18_plan(img=224, n_classes=1000).enumerate_cuts()
    ring_sizes = [25, 100] if quick else [25, 100, 1000]
    n_items = [100.0 * (j + 1) for j in range(4 if quick else 32)]

    def sweep_call():
        sw = sweep_revolutions(ring_sizes, cuts, n_items)
        jax.block_until_ready(sw.e_pass)
        return sw

    us_sweep, sw = _timeit(sweep_call, n=3, warmup=1)
    r, c, b = sw.shape
    n_cells = r * c * b
    host = sw.to_host()
    out["sweep_revolutions"] = dict(
        us=us_sweep, ring_sizes=list(map(int, ring_sizes)),
        n_cells=n_cells, us_per_cell=us_sweep / n_cells,
        feasible_cells=int(host["feasible"].sum()),
        max_ring=int(max(ring_sizes)))
    print(f"sweep_revolutions_{n_cells},{us_sweep:.0f},"
          f"rings={ring_sizes}-x-{c}cuts-x-{b}budgets,"
          f"{us_sweep / n_cells:.1f}us/cell")

    # plan -> train with no host sync: the planned step count reaches the
    # fused pass as a device scalar (n_valid); time the chained call.
    ad = autoencoder_adapter(cut=5, img=32)
    shards = ImageryShards(img=32, batch=4)
    batches = [jax.tree.map(jnp.asarray, shards.batch_at(0, i))
               for i in range(8)]
    opt = sgd(lr=1e-2)
    sl_pass = make_sl_pass(ad, optimizer=opt, donate=False)
    plan_sweep = sweep_revolutions([25], [ad.costs()], [24.0])
    n_valid = plan_sweep.steps_for(4)[0, 0, 0]     # 6 of 8 steps, on device

    def planned_pass():
        r = sl_pass(SLTrainState.create(*ad.init(jax.random.key(0)), opt),
                    batches, n_valid=n_valid)
        return jax.block_until_ready(r.losses)

    us_pass, losses = _timeit(planned_pass, n=3, warmup=1)
    n_ran = int(np.isfinite(np.asarray(losses)).sum())
    out["sweep_planned_pass"] = dict(us=us_pass, steps_planned=n_ran,
                                     steps_offered=len(batches))
    print(f"sweep_planned_pass,{us_pass:.0f},"
          f"{n_ran}/{len(batches)}-steps-device-masked")
    return out


def device_sim_benchmarks(quick: bool = False):
    """Closed-loop rows: the host Python scheduler
    (``ConstellationSim.run()``) vs the device-resident engine
    (``repro.sim.device_sim``) running the SAME steady-state scenario —
    planning + reserve-skip policy + masked fused passes +
    battery/recharge accounting — on identical data (the traceable
    provider serves both).  Quick mode: a 16-sat ring × 2 revolutions;
    full mode adds the 64-sat and 1000-sat rings the ISSUE/ROADMAP
    target.  Parity of trained/skipped counts is asserted per row.
    """
    from repro.core.constellation import (ConstellationConfig,
                                          ConstellationSim)
    from repro.core.energy import PassBudget
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.sim.data import DeviceImageryShards

    print("== closed-loop benchmarks (host scheduler vs device engine) ==")
    print("name,us_per_call,derived")
    out = {}
    shards = DeviceImageryShards(img=32, batch=2)
    adapter = autoencoder_adapter(cut=5, img=32)
    # (ring size, revolutions, fused steps per pass): the 1000-sat row
    # runs 1 step/pass so the host baseline stays affordable on CPU
    scenarios = [(16, 2, 2)] if quick else [(64, 2, 2), (1000, 1, 1)]
    for n_sats, n_rev, k_steps in scenarios:
        budget = PassBudget(plane=OrbitalPlane(n_sats=n_sats), n_items=4e6)
        cfg = ConstellationConfig(
            batch_size=2, n_passes=n_rev * n_sats, battery_j=200.0,
            recharge_w=1e-4, reserve_j=150.0,
            max_steps_per_pass=k_steps)

        # both cold rows are symmetric end-to-end accounting (fresh sim,
        # jit compiles included — what a consumer pays once); the
        # post-compile row re-dispatches the SAME engine, i.e. the
        # steady-state cost of every further revolution batch.
        def host_run():
            sim = ConstellationSim(adapter, budget, shards, cfg)
            sim.run()
            return sim.summary()

        us_host, hs = _timeit(host_run, n=1, warmup=0)
        engine = ConstellationSim(adapter, budget, shards,
                                  cfg).as_device_sim()
        us_cold, res = _timeit(engine.run, n=1, warmup=0)
        ds = res.summary()
        us_warm, _ = _timeit(engine.run, n=1, warmup=0)
        parity = (hs["trained"] == ds["trained"]
                  and hs["skipped"] == ds["skipped"])
        assert parity, (f"host/device closed-loop divergence at "
                        f"{n_sats} sats: host {hs} vs device {ds}")
        n_passes = n_rev * n_sats
        out[f"closed_loop_host_{n_sats}"] = dict(
            us=us_host, n_passes=n_passes, us_per_pass=us_host / n_passes)
        out[f"closed_loop_device_{n_sats}"] = dict(
            us=us_cold, n_passes=n_passes, us_per_pass=us_cold / n_passes,
            speedup_vs_host=us_host / us_cold, parity_vs_host=parity)
        out[f"closed_loop_device_warm_{n_sats}"] = dict(
            us=us_warm, n_passes=n_passes, us_per_pass=us_warm / n_passes,
            speedup_vs_host=us_host / us_warm)
        print(f"closed_loop_host_{n_sats},{us_host:.0f},"
              f"{n_passes}-python-dispatched-passes-cold")
        print(f"closed_loop_device_{n_sats},{us_cold:.0f},"
              f"{us_host / us_cold:.1f}x-vs-host-cold-incl-compile,"
              f"parity={parity}")
        print(f"closed_loop_device_warm_{n_sats},{us_warm:.0f},"
              f"{us_host / us_warm:.1f}x-vs-host-post-compile")
    return out


def fleet_benchmarks(quick: bool = False):
    """Sharded fleet rows: the P-plane elastic engine
    (``repro.fleet.FleetEngine`` — one jitted scan, vmapped over planes,
    plane axis sharded over the host mesh, inter-plane checkpoint
    averaging every revolution) vs the *per-plane loop* of P single-ring
    device engines with explicit averaging between revolutions.  Quick
    mode runs a 2x16 fleet; full mode the 2x64 and 4x256 fleets the
    ISSUE targets.  Parity (action sequences + losses vs the per-plane
    reference) is asserted per row.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.energy import PassBudget
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.core.train_state import SLTrainState
    from repro.fleet import FleetConfig, FleetEngine, average_planes
    from repro.sim.data import DeviceImageryShards
    from repro.sim.device_sim import (DeviceConstellationSim,
                                      DeviceSimConfig)
    from repro.train.optimizer import resolve_optimizer

    print("== fleet benchmarks (P-plane sharded engine vs per-plane "
          "loop) ==")
    print("name,us_per_call,derived")
    out = {}
    shards = DeviceImageryShards(img=32, batch=2)
    adapter = autoencoder_adapter(cut=5, img=32)
    energy = dict(battery_j=200.0, recharge_w=1e-4, reserve_j=150.0,
                  max_steps_per_pass=1, seed=0)
    scenarios = [(2, 16, 2)] if quick else [(2, 64, 2), (4, 256, 1)]
    for P, N, R in scenarios:
        budget = PassBudget(plane=OrbitalPlane(n_sats=N), n_items=4e6)
        cfg = FleetConfig(n_planes=P, n_revolutions=R, avg_every=1,
                          **energy)

        def fleet_run():
            eng = FleetEngine(adapter, budget, shards, cfg)
            return eng, eng.run()

        us_cold, (eng, res) = _timeit(fleet_run, n=1, warmup=0)
        cold_syncs = eng.host_syncs           # before the warm re-run
        us_warm, _ = _timeit(eng.run, n=1, warmup=0)
        M = eng.n_slots

        # the pre-fleet workflow: P independent single-ring engines,
        # checkpoints averaged on the host loop between revolutions
        opt = resolve_optimizer("sgd", lr=cfg.lr)
        init = SLTrainState.create(*adapter.init(jax.random.key(0)), opt)

        def plane_loop():
            engines = [DeviceConstellationSim(
                adapter, budget, lambda s, i, p=p: shards(p * M + s, i),
                DeviceSimConfig(**energy),
                state=jax.tree.map(jnp.copy, init)) for p in range(P)]
            acts, losses = [], []
            for _ in range(R):
                rr = [e.run(1, stream_telemetry=True) for e in engines]
                acts.append(np.stack([r.action[0] for r in rr]))
                losses.append(np.stack([r.loss[0] for r in rr]))
                avg = average_planes(jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[e.state for e in engines]))
                for p, e in enumerate(engines):
                    e.state = jax.tree.map(lambda x: x[p], avg)
            return np.concatenate(acts, 1), np.concatenate(losses, 1)

        us_ref, (ref_act, ref_loss) = _timeit(plane_loop, n=1, warmup=0)
        parity = bool((res.action == ref_act).all()
                      and np.allclose(np.nan_to_num(res.loss),
                                      np.nan_to_num(ref_loss),
                                      rtol=2e-4, atol=2e-5))
        assert parity, (f"fleet/per-plane divergence at {P}x{N}: "
                        f"{res.summary()}")
        n_passes = P * R * N
        name = f"closed_loop_fleet_{P}x{N}"
        # both cold rows are end-to-end incl. construction + compiles
        # (the reference pays P of them); the warm row re-dispatches the
        # SAME fleet program — the steady-state per-revolution cost
        out[name] = dict(
            us=us_cold, n_passes=n_passes, n_planes=P,
            us_per_pass=us_cold / n_passes, parity_vs_plane_loop=parity,
            speedup_vs_plane_loop=us_ref / us_cold,
            host_syncs=cold_syncs)
        out[f"{name}_warm"] = dict(us=us_warm, n_passes=n_passes,
                                   us_per_pass=us_warm / n_passes)
        out[f"closed_loop_plane_loop_{P}x{N}"] = dict(us=us_ref,
                                                      n_passes=n_passes)
        print(f"{name},{us_cold:.0f},"
              f"{us_ref / us_cold:.1f}x-vs-per-plane-loop-cold,"
              f"parity={parity}")
        print(f"{name}_warm,{us_warm:.0f},"
              f"{us_warm / n_passes:.0f}us/pass-post-compile")
        print(f"closed_loop_plane_loop_{P}x{N},{us_ref:.0f},"
              f"{P}-engines-host-averaged-cold")
    return out


def degraded_ops_benchmarks(quick: bool = False):
    """Degraded-ops scenario rows (``repro.fleet.scenarios``):

    * Byzantine recovery (full mode) — the ISSUE acceptance scenario: a
      4-plane fleet with one whole plane sign-flipping its updates
      (scale 8).  Plain ``mean`` aggregation lets the corrupted plane
      poison the inter-plane exchange (final loss blows up orders of
      magnitude); ``trimmed_mean`` / ``median`` drop the outlier
      coordinate-wise and land within a few percent of the fault-free
      run.  Final loss = mean of the honest planes' last finite loss.
    * Eclipse duty sweep — the same fleet energy envelope under 0%,
      50% and 100% orbital shadow: trained/skipped counts show the
      shadow reaching the reserve-skip policy through the battery.
    """
    import numpy as np
    from repro.core.energy import PassBudget
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.fleet import (ByzantineConfig, EclipseConfig, FleetConfig,
                             FleetEngine, ScenarioConfig)
    from repro.sim.data import DeviceImageryShards

    print("== degraded-ops benchmarks (byzantine planes + eclipse) ==")
    print("name,us_per_call,derived")
    out = {}
    shards = DeviceImageryShards(img=32, batch=4)
    adapter = autoencoder_adapter(cut=5, img=32)

    if not quick:
        # --- Byzantine recovery: 1 of 4 planes lies, scale 8 ----------
        P, N, R = 4, 4, 6
        budget = PassBudget(plane=OrbitalPlane(n_sats=N), n_items=4e6)
        byz = ScenarioConfig(byzantine=ByzantineConfig(
            planes=(3,), mode="sign_flip", scale=8.0))

        def final_loss(res):
            last = [row[np.isfinite(row)][-1] for row in res.loss[:3]]
            return float(np.mean(last))

        losses = {}
        for tag, scn, agg in (("fault_free", None, "mean"),
                              ("byzantine_mean", byz, "mean"),
                              ("byzantine_trimmed", byz, "trimmed_mean"),
                              ("byzantine_median", byz, "median")):
            cfg = FleetConfig(n_planes=P, n_revolutions=R,
                              battery_j=5000.0, recharge_w=20.0,
                              reserve_j=100.0, max_steps_per_pass=4,
                              seed=0, avg_every=1, scenario=scn,
                              aggregate=agg)

            def degraded_run(cfg=cfg):
                eng = FleetEngine(adapter, budget, shards, cfg)
                return eng, eng.run()

            us, (eng, res) = _timeit(degraded_run, n=1, warmup=0)
            losses[tag] = final_loss(res)
            name = f"degraded_ops_{tag}_{P}x{N}"
            out[name] = dict(us=us, n_passes=P * R * N, aggregate=agg,
                             final_loss=losses[tag],
                             host_syncs=eng.host_syncs)
            print(f"{name},{us:.0f},aggregate={agg},"
                  f"final_loss={losses[tag]:.4g}")
        clean = losses["fault_free"]
        out["degraded_ops_recovery"] = dict(
            loss_fault_free=clean,
            loss_byzantine_mean=losses["byzantine_mean"],
            loss_byzantine_trimmed=losses["byzantine_trimmed"],
            loss_byzantine_median=losses["byzantine_median"],
            mean_blowup=losses["byzantine_mean"] / clean,
            trimmed_gap_pct=100.0
            * abs(losses["byzantine_trimmed"] - clean) / clean,
            median_gap_pct=100.0
            * abs(losses["byzantine_median"] - clean) / clean)
        print(f"degraded_ops_recovery,-,"
              f"mean-blowup={losses['byzantine_mean'] / clean:.0f}x,"
              f"trimmed-gap="
              f"{out['degraded_ops_recovery']['trimmed_gap_pct']:.1f}%")

    # --- eclipse duty sweep: shadow -> battery -> reserve skips -------
    ecl_budget = PassBudget(plane=OrbitalPlane(n_sats=4), n_items=4e6)
    for duty in (0.0, 0.5, 1.0):
        scn = (None if duty == 0.0 else ScenarioConfig(
            eclipse=EclipseConfig(period=4, duty=duty, stagger=1)))
        cfg = FleetConfig(n_planes=2, n_revolutions=3, battery_j=200.0,
                          recharge_w=0.05, reserve_j=180.0,
                          max_steps_per_pass=2, seed=0, avg_every=1,
                          scenario=scn)

        def eclipse_run(cfg=cfg):
            eng = FleetEngine(adapter, ecl_budget, shards, cfg)
            return eng.run()

        us, res = _timeit(eclipse_run, n=1, warmup=0)
        s = res.summary()
        name = f"degraded_ops_eclipse_duty{int(duty * 100):03d}"
        out[name] = dict(
            us=us, n_passes=int(res.action.size), trained=s["trained"],
            skipped=s["skipped"],
            energy_spent_j=float(
                np.asarray(res.energy.energy_spent_j).sum()))
        print(f"{name},{us:.0f},trained={s['trained']},"
              f"skipped={s['skipped']}")
    return out


def isl_frontier_benchmarks(quick: bool = False):
    """ISL exchange frontier (``repro.isl``): what compressed,
    bandwidth-limited inter-plane exchange buys and costs.

    Sweeps the codec grid {none, int8, top-k 10%, top-k 1%} across both
    exchange modes on a 2x16 fleet, entirely on device: ``sync`` is the
    revolution-boundary aggregation routed through the codec + meter
    (``none`` = the metered legacy barrier), ``async`` is contact-window
    gossip with staleness-discounted merges and no barrier at all.
    Each row reports the final loss, the actual wire bits / ISL joules
    drained from the batteries, and the *planned* per-pass
    ``d_isl_bits`` — the problem-(13) feedback that makes compression a
    resource-allocation decision rather than a counter.

    Asserts the acceptance frontier: (a) async top-k 1% lands within
    50% of the full-float sync barrier's final loss; (b) wire bits
    shrink monotonically with compression in both modes; (c) the
    planned allocation differs between compression levels.
    """
    import numpy as np
    from repro.core.energy import PassBudget
    from repro.core.orbits import OrbitalPlane
    from repro.core.sl_step import autoencoder_adapter
    from repro.fleet import FleetConfig, FleetEngine
    from repro.isl import (CodecConfig, ContactConfig, ExchangeConfig,
                           codec_label)
    from repro.sim.data import DeviceImageryShards

    P, N = 2, 16
    R = 2 if quick else 6
    print(f"== isl exchange frontier (codec x mode, {P}x{N} fleet) ==")
    print("name,us_per_call,derived")
    out = {}
    shards = DeviceImageryShards(img=32, batch=4)
    adapter = autoencoder_adapter(cut=5, img=32)
    budget = PassBudget(plane=OrbitalPlane(n_sats=N), n_items=4e6)
    codecs = [CodecConfig("none"), CodecConfig("int8"),
              CodecConfig("topk", topk_ratio=0.10),
              CodecConfig("topk", topk_ratio=0.01)]

    def final_loss(res):
        last = [row[np.isfinite(row)][-1] for row in res.loss]
        return float(np.mean(last))

    rows = {}
    for mode in ("sync", "async"):
        for codec in codecs:
            if mode == "sync":
                cfg = FleetConfig(
                    n_planes=P, n_revolutions=R, max_steps_per_pass=2,
                    seed=0, avg_every=1,
                    exchange=ExchangeConfig(mode="sync", codec=codec))
            else:
                cfg = FleetConfig(
                    n_planes=P, n_revolutions=R, max_steps_per_pass=2,
                    seed=0, avg_every=0,
                    exchange=ExchangeConfig(
                        mode="async", codec=codec,
                        contact=ContactConfig(period=2), mix=0.5,
                        staleness_lam=0.1))

            def frontier_run(cfg=cfg):
                eng = FleetEngine(adapter, budget, shards, cfg)
                return eng, eng.run()

            us, (eng, res) = _timeit(frontier_run, n=1, warmup=0)
            s = res.summary()
            row = dict(
                us=us, n_passes=P * R * N, final_loss=final_loss(res),
                isl_bits=float(s["ISL_exchange_bits"]),
                isl_j=float(s["ISL_exchange_J"]),
                contacts=int(np.asarray(res.isl_contacts).sum()),
                plan_d_isl_bits=float(
                    np.asarray(eng.plan.d_isl_bits).mean()),
                host_syncs=eng.host_syncs)
            rows[(mode, codec_label(codec))] = row
            name = f"isl_frontier_{mode}_{codec_label(codec)}"
            out[name] = row
            print(f"{name},{us:.0f},loss={row['final_loss']:.4g},"
                  f"bits={row['isl_bits']:.3g},"
                  f"isl_J={row['isl_j']:.3g},"
                  f"plan_d_isl={row['plan_d_isl_bits']:.4g}")

    # -- the acceptance frontier ------------------------------------------
    order = ("none", "int8", "topk10pc", "topk1pc")
    for mode in ("sync", "async"):
        bits = [rows[(mode, c)]["isl_bits"] for c in order]
        assert bits == sorted(bits, reverse=True) and bits[-1] > 0, (
            "wire bits must shrink monotonically with compression",
            mode, dict(zip(order, bits)))
        plans = [rows[(mode, c)]["plan_d_isl_bits"] for c in order]
        assert len(set(plans)) == len(plans), (
            "planned d_isl_bits must differ between compression levels",
            mode, dict(zip(order, plans)))
    ref = rows[("sync", "none")]["final_loss"]
    got = rows[("async", "topk1pc")]["final_loss"]
    gap = abs(got - ref) / ref
    assert gap <= 0.5, (
        "async top-k 1% must land within 50% of the full-float sync "
        "barrier", got, ref)
    out["isl_frontier_acceptance"] = dict(
        sync_none_loss=ref, async_topk1pc_loss=got, rel_gap=gap,
        tolerance=0.5, bits_monotone=True, plans_differ=True)
    print(f"isl_frontier_acceptance,-,async-topk1pc-gap={gap * 100:.1f}%"
          f"-of-sync-full-float,bits-monotone,plans-differ")
    return out


def serve_fleet_benchmarks(quick: bool = False):
    """Serving-fleet rows (``repro.serve_fleet``): the constellation as
    an inference fleet.

    * ``serve_split_decode`` — one satellite's sustained generated
      tokens/sec, measured wall-clock on the real split-model
      continuous-batching engine (ground-half bulk prefill, satellite
      half + boundary downlink + ground half per decode step).
    * ``serve_fleet_PxM`` — constellation-scale pass-window serving at
      >= 1M offered users/day: Poisson arrivals with a diurnal profile,
      routed to the satellite overhead, FIFO backlog carry-over along
      the ring.  Reports sustained tokens/sec and FIFO p99 latency;
      the NumPy host oracle asserts bit-exact f32 energy parity per
      row.  Capacity scales with the number of planes (one terminal
      serves one overhead satellite at a time — the paper's geometry),
      so 1x64 vs 4x256 is the constellation-size comparison.
    * ``serve_fleet_contention`` — the same offered load with a
      concurrent planned training pass per window on ONE shared
      battery: trained-pass count with vs without serving drain (the
      reserve-skip gate reads the post-serve battery).  Uses a fixed
      ServeCost so the row is measurement-noise-free for the trend
      report.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.models import lm
    from repro.serve_fleet import (FleetServeEngine, ServeCost,
                                   ServeFleetConfig, SplitDecodeEngine,
                                   TrafficConfig, TrainLoad,
                                   assert_host_parity,
                                   measure_decode_rate, serve_cost)

    print("== serve-fleet benchmarks (constellation as an inference "
          "fleet) ==")
    print("name,us_per_call,derived")
    out = {}

    # -- per-satellite split-decode rate (real engine, wall-clock) --------
    cfg = configs.get_smoke("smollm_360m")
    params = lm.init(cfg, jax.random.key(0))
    cut = max(1, cfg.n_units // 2)
    eng = SplitDecodeEngine(cfg, params, cut_units=cut, n_slots=8,
                            s_max=64, act_dtype=jnp.float32)
    rate = measure_decode_rate(eng, n_requests=8 if quick else 48,
                               prompt_len=6, new_tokens=12)
    cost = serve_cost(cfg, params, cut, tokens_per_s=rate)
    out["serve_split_decode"] = dict(
        arch=cfg.name, cut_units=cut, n_slots=8, tokens_per_s=rate,
        e_token_j=cost.e_token_j, dtx_bits_token=cost.dtx_bits_token)
    print(f"serve_split_decode,,{rate:.1f}tok/s,"
          f"e_token={cost.e_token_j:.2e}J")

    # -- constellation-size rows at >= 1M users/day -----------------------
    # offered load = 2x ONE satellite's measured capacity (>= 1.5M
    # users/day): a single plane saturates (one sat overhead at a time),
    # four planes = four terminals serve the same load comfortably —
    # capacity scales with planes, and the p99 gap shows it
    decode_len = 12
    users = max(1.5e6, 2.0 * rate * 86_400.0 / decode_len)
    traffic = TrafficConfig(users_per_day=users, prompt_len=6,
                            decode_len=decode_len)
    scenarios = [(1, 8, 16)] if quick else [(1, 64, 192), (4, 256, 192)]
    for P, M, K in scenarios:
        scfg = ServeFleetConfig(n_planes=P, n_sats=M, n_windows=K,
                                battery_j=5000.0, recharge_w=25.0,
                                reserve_serve_j=100.0)
        fleet = FleetServeEngine(scfg, traffic, cost)
        us, res = _timeit(fleet.run, n=1, warmup=0)
        assert_host_parity(res, None)            # f32 energy parity
        s = res.summary()
        name = f"serve_fleet_{P}x{M}"
        out[name] = dict(us=us, host_syncs=fleet.host_syncs,
                         energy_parity=True, **s)
        print(f"{name},{us:.0f},"
              f"{s['sustained_tokens_per_s']:.0f}tok/s,"
              f"p99={s['p99_latency_s']:.0f}s,"
              f"backlog={s['final_backlog_requests']:.0f}")

    # -- train-vs-serve contention on one battery -------------------------
    M, K = (8, 32) if quick else (16, 192)
    fixed = ServeCost(tokens_per_s=2000.0, e_token_j=5e-3,
                      dtx_bits_token=cost.dtx_bits_token)
    scfg = ServeFleetConfig(n_planes=1, n_sats=M, n_windows=K,
                            battery_j=1000.0, recharge_w=0.15,
                            reserve_serve_j=50.0, reserve_train_j=600.0)
    train = TrainLoad(drain_j=500.0, e_total_j=700.0)

    def contention(users):
        fleet = FleetServeEngine(
            scfg, dataclasses.replace(traffic, users_per_day=users),
            fixed, train=train)
        res = fleet.run()
        assert_host_parity(res, train)
        return res.summary()

    us, s_with = _timeit(lambda: contention(1.5e6), n=1, warmup=0)
    _, s_without = _timeit(lambda: contention(0.0), n=1, warmup=0)
    assert s_with["trained_passes"] < s_without["trained_passes"], (
        "serving drain must cost trained passes", s_with, s_without)
    out["serve_fleet_contention"] = dict(
        us=us, n_windows=K, n_sats=M,
        trained_with_serve=s_with["trained_passes"],
        skipped_with_serve=s_with["skipped_passes"],
        trained_without_serve=s_without["trained_passes"],
        skipped_without_serve=s_without["skipped_passes"],
        serve_energy_spent_j=s_with["serve_energy_spent_j"])
    print(f"serve_fleet_contention,{us:.0f},"
          f"trained {s_with['trained_passes']} (serving) vs "
          f"{s_without['trained_passes']} (idle) of {K}")
    return out


def micro_benchmarks():
    """us/call for the SL step + each kernel's jnp path (CPU; the numbers
    are for regression tracking, not TPU performance claims)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.sl_step import autoencoder_adapter, make_sl_step
    from repro.data.synthetic import ImageryShards
    from repro.kernels import ops

    print("== micro-benchmarks (CPU reference timings) ==")
    print("name,us_per_call,derived")
    rng = np.random.default_rng(0)
    out = {}

    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, ImageryShards(img=32, batch=4)
                         .batch_at(0, 0))
    step = make_sl_step(ad)
    us, _ = _timeit(lambda: step(pa, pb, batch))
    out["sl_step_autoencoder"] = us
    print(f"sl_step_autoencoder,{us:.0f},loss+both-grads")

    q = jnp.asarray(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(f(q, k, v)))
    flops = 4 * 8 * 512 * 512 / 2 * 64
    out["flash_attention_512"] = us
    print(f"flash_attention_512,{us:.0f},{flops/us/1e3:.1f}GFLOP/s")

    x = jnp.asarray(rng.standard_normal((1, 512, 4, 64)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((1, 512, 4))))
    alog = jnp.asarray(rng.standard_normal(4)) * 0.5
    b = jnp.asarray(rng.standard_normal((1, 512, 16)), jnp.float32)
    g = jax.jit(lambda *a: ops.mamba_scan(*a, chunk=128, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(g(x, dt, alog, b, b)[0]))
    out["mamba_scan_512"] = us
    print(f"mamba_scan_512,{us:.0f},chunked-ssd")

    xq = jnp.asarray(rng.standard_normal((4096, 512)), jnp.float32)
    h = jax.jit(lambda t: ops.quantize_boundary(t, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(h(xq)[0]))
    out["split_quant_4096x512"] = us
    print(f"split_quant_4096x512,{us:.0f},{xq.nbytes/us/1e3:.2f}GB/s")
    return out


def _flatten_metrics(obj, prefix=""):
    """Dotted-path -> float map of every numeric leaf in a results dict."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten_metrics(v, f"{prefix}{k}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix.rstrip(".")] = float(obj)
    return out


def trend_report(results_dir: str, current: dict, rev: str,
                 threshold: float = 0.20) -> dict:
    """Compare this run against the previous ``BENCH_<rev>.json``.

    Timing metrics (dotted paths ending in ``.us`` or named ``us_*``)
    regress when they grow; each >``threshold`` change is flagged.  The
    report is printed and returned so it lands inside the current JSON.
    """
    prev_path, prev = None, None
    candidates = []
    for p in glob.glob(os.path.join(results_dir, "BENCH_*.json")):
        if os.path.basename(p) == f"BENCH_{rev}.json":
            continue
        try:
            with open(p) as f:
                data = json.load(f)
            candidates.append((data.get("meta", {}).get("unix_time", 0.0),
                               p, data))
        except (json.JSONDecodeError, OSError):
            continue
    if candidates:
        _, prev_path, prev = max(candidates, key=lambda t: t[0])

    # errored sections (benchmark code raised; see their recorded
    # traceback in this run's JSON) are flagged up front — their rows
    # carry no metrics, so silence here would read as "no regression"
    errored = sorted(k for k, v in current.items()
                     if isinstance(v, dict) and v.get("status") == "error")
    report = {"baseline": prev_path and os.path.basename(prev_path),
              "threshold": threshold, "regressions": [],
              "improvements": [], "errored_sections": errored}
    for name in errored:
        print(f"  ERRORED section '{name}': benchmark raised — metrics "
              f"missing this run (traceback recorded in JSON)")
    if prev is None:
        print("\n== trend report: no previous BENCH_<rev>.json — baseline "
              "run ==")
        return report

    cur_m = _flatten_metrics(current)
    prev_m = _flatten_metrics(prev)
    # timing metrics: engine rows expose an `us` field; micro rows are
    # bare us/call floats.  Table values (losses, energies) are not
    # regressions in the timing sense and are left out.
    timing = {k for k in cur_m if k.endswith(".us") or k.startswith("micro.")}
    for k in sorted(timing & prev_m.keys()):
        if prev_m[k] <= 0.0:
            continue
        delta = cur_m[k] / prev_m[k] - 1.0
        row = {"metric": k, "prev_us": prev_m[k], "cur_us": cur_m[k],
               "delta_pct": 100.0 * delta}
        if delta > threshold:
            report["regressions"].append(row)
        elif delta < -threshold:
            report["improvements"].append(row)

    base = report["baseline"]
    print(f"\n== trend report vs {base} "
          f"(flagging >{threshold:.0%} timing changes) ==")
    if not report["regressions"] and not report["improvements"]:
        print(f"  all {len(timing & prev_m.keys())} shared timing metrics "
              f"within {threshold:.0%}")
    for row in report["regressions"]:
        print(f"  REGRESSION {row['metric']}: {row['prev_us']:.0f}us -> "
              f"{row['cur_us']:.0f}us (+{row['delta_pct']:.0f}%)")
    for row in report["improvements"]:
        print(f"  improved   {row['metric']}: {row['prev_us']:.0f}us -> "
              f"{row['cur_us']:.0f}us ({row['delta_pct']:.0f}%)")
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small solver grids, no 1000-sat "
                         "sweep, paper tables skipped, no BENCH_<rev> "
                         "emission (results/bench_quick.json only)")
    args = ap.parse_args(argv)

    t0 = time.time()
    # a fresh global metrics registry: every engine any section builds
    # parents to it, and its aggregate snapshot becomes the BENCH
    # "metrics" block below
    from repro.obs.metrics import global_registry, reset_global

    reset_global()
    results = {}
    results["header"] = run_header(args.quick)
    print("== run header ==")
    for k, v in results["header"].items():
        print(f"  {k}: {v}")

    def section(name, fn, *a, **kw):
        # one failing section must not take the whole run (and its
        # BENCH_<rev>.json history entry) down with it: record the
        # failure as a row so the trend report can flag it
        try:
            results[name] = fn(*a, **kw)
        except Exception as exc:                  # noqa: BLE001
            tb = traceback.format_exc()
            print(f"!! benchmark section '{name}' FAILED: {exc!r}")
            print(tb)
            results[name] = {"status": "error", "error": repr(exc),
                             "traceback": tb}

    if not args.quick:
        from benchmarks import paper_tables

        try:
            results.update(paper_tables.run_all())
        except Exception as exc:                  # noqa: BLE001
            tb = traceback.format_exc()
            print(f"!! paper tables FAILED: {exc!r}")
            print(tb)
            results["paper_tables"] = {"status": "error",
                                       "error": repr(exc), "traceback": tb}
    section("engine", engine_benchmarks)
    section("solver_backend", solver_backend_benchmarks, quick=args.quick)
    section("sweep", sweep_benchmarks, quick=args.quick)
    section("device_sim", device_sim_benchmarks, quick=args.quick)
    section("fleet", fleet_benchmarks, quick=args.quick)
    section("degraded_ops", degraded_ops_benchmarks, quick=args.quick)
    section("isl_frontier", isl_frontier_benchmarks, quick=args.quick)
    section("serve_fleet", serve_fleet_benchmarks, quick=args.quick)
    section("micro", micro_benchmarks)
    errored = sorted(k for k, v in results.items()
                     if isinstance(v, dict) and v.get("status") == "error")
    rev = _git_rev()
    results["meta"] = {"rev": rev, "wall_s": time.time() - t0,
                       "unix_time": time.time(), "quick": args.quick,
                       "errored_sections": errored}
    # aggregate registry snapshot across every engine the sections
    # built: sim.*/fleet.*/serve_fleet.* traces / device_calls /
    # host_syncs / events_recorded counters + dispatch_s histograms
    results["metrics"] = global_registry().to_dict()

    os.makedirs("results", exist_ok=True)
    if not args.quick:
        # quick runs never enter the trend history: their small grids
        # would read as huge spurious "improvements" next full run
        results["trend"] = trend_report("results", results, rev)

    def _clean(o):
        if isinstance(o, dict):
            return {k: _clean(v) for k, v in o.items()}
        if isinstance(o, (list, tuple)):
            return [_clean(v) for v in o]
        if isinstance(o, (float, int, str, bool)) or o is None:
            return o
        return float(o) if hasattr(o, "__float__") else str(o)

    cleaned = _clean(results)
    if args.quick:
        path = os.path.join("results", "bench_quick.json")
        with open(path, "w") as f:
            json.dump(cleaned, f, indent=1)
        print(f"\nquick benchmarks done in {time.time()-t0:.1f}s -> {path}")
        return
    bench_path = os.path.join("results", f"BENCH_{rev}.json")
    for path in (bench_path, os.path.join("results", "bench.json")):
        with open(path, "w") as f:
            json.dump(cleaned, f, indent=1)
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s "
          f"-> {bench_path} (+ results/bench.json)")


if __name__ == "__main__":
    main()
