"""Benchmark entry point: one block per paper table/figure + the
beyond-paper rows + micro-benchmarks of the SL step, the batched pass
engine (before/after rows for the vectorized problem-(13) solver and the
scan-fused pass executor), and each kernel's jnp path.

Usage:  PYTHONPATH=src python -m benchmarks.run

Alongside the stdout tables the run emits machine-readable JSON to
``results/BENCH_<rev>.json`` (``<rev>`` = current git short hash, "dev"
outside a checkout) so the perf trajectory is tracked across PRs, plus
``results/bench.json`` as a stable latest-run alias.
"""
from __future__ import annotations

import json
import os
import subprocess
import time


def _timeit(fn, *args, n=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args, **kw)
    return (time.time() - t0) / n * 1e6, out      # us/call


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "dev"
    except Exception:
        return "dev"


def engine_benchmarks():
    """Before/after rows for the batched pass engine (the tentpole):

    * problem-(13): loop of the scalar reference solver vs one
      ``solve_batch`` call over the same >=256-instance cut x pass sweep;
    * SL pass execution: 16 Python-loop ``make_sl_step`` + eager SGD
      calls vs ONE jitted ``make_sl_pass`` scan of the same 16 steps.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import resource_opt
    from repro.core.energy import PassBudget
    from repro.core.sl_step import autoencoder_adapter, make_sl_pass, \
        make_sl_step
    from repro.core.splitting import resnet18_plan
    from repro.data.synthetic import ImageryShards
    from repro.train.optimizer import sgd_init, sgd_update

    print("== pass-engine benchmarks (batched solver + fused SL pass) ==")
    print("name,us_per_call,derived")
    out = {}

    # --- problem (13): 32 n_items variants x every ResNet-18 cut --------
    plan = resnet18_plan(img=224, n_classes=1000)
    cuts = plan.enumerate_cuts()
    budgets, costs = [], []
    for j in range(32):
        b = PassBudget(n_items=50.0 * (j + 1))
        for c in cuts:
            budgets.append(b)
            costs.append(c)
    n_inst = len(costs)
    assert n_inst >= 256, n_inst

    def scalar_loop():
        return [resource_opt.solve_reference(b, c)
                for b, c in zip(budgets, costs)]

    def batched():
        return resource_opt.solve_batch(budgets, costs)

    us_loop, _ = _timeit(scalar_loop, n=1, warmup=0)   # pure python: no jit
    us_batch, rep = _timeit(batched, n=3, warmup=1)
    speedup = us_loop / us_batch
    out["solve_scalar_loop"] = dict(us=us_loop, n_instances=n_inst)
    out["solve_batch"] = dict(us=us_batch, n_instances=n_inst,
                              speedup_vs_scalar=speedup,
                              feasible=int(rep.feasible.sum()))
    print(f"solve13_scalar_loop_{n_inst},{us_loop:.0f},"
          f"{us_loop/n_inst:.0f}us/instance")
    print(f"solve13_batch_{n_inst},{us_batch:.0f},{speedup:.1f}x-speedup")

    # --- SL pass: 16 steps, python loop vs one fused scan ---------------
    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    shards = ImageryShards(img=32, batch=4)
    batches = [jax.tree.map(jnp.asarray, shards.batch_at(0, i))
               for i in range(16)]
    step = make_sl_step(ad)
    sl_pass = make_sl_pass(ad, lr=1e-2, donate=False)

    def step_loop():
        p_a, p_b = pa, pb
        oa, ob = sgd_init(pa), sgd_init(pb)
        for bt in batches:
            r = step(p_a, p_b, bt)
            p_a, oa, _ = sgd_update(r.grads_a, oa, p_a, lr=1e-2)
            p_b, ob, _ = sgd_update(r.grads_b, ob, p_b, lr=1e-2)
        return jax.block_until_ready(p_a)

    def fused_pass():
        r = sl_pass(pa, pb, sgd_init(pa), sgd_init(pb), batches)
        return jax.block_until_ready(r.params_a)

    us_steps, _ = _timeit(step_loop, n=3, warmup=1)
    us_pass, _ = _timeit(fused_pass, n=3, warmup=1)
    speedup = us_steps / us_pass
    out["sl_step_loop_16"] = dict(us=us_steps)
    out["sl_pass_16"] = dict(us=us_pass, speedup_vs_step_loop=speedup)
    print(f"sl_step_loop_16,{us_steps:.0f},16-python-dispatches")
    print(f"sl_pass_16,{us_pass:.0f},{speedup:.2f}x-speedup-one-scan")
    return out


def micro_benchmarks():
    """us/call for the SL step + each kernel's jnp path (CPU; the numbers
    are for regression tracking, not TPU performance claims)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.sl_step import autoencoder_adapter, make_sl_step
    from repro.data.synthetic import ImageryShards
    from repro.kernels import ops

    print("== micro-benchmarks (CPU reference timings) ==")
    print("name,us_per_call,derived")
    rng = np.random.default_rng(0)
    out = {}

    ad = autoencoder_adapter(cut=5, img=32)
    pa, pb = ad.init(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, ImageryShards(img=32, batch=4)
                         .batch_at(0, 0))
    step = make_sl_step(ad)
    us, _ = _timeit(lambda: step(pa, pb, batch))
    out["sl_step_autoencoder"] = us
    print(f"sl_step_autoencoder,{us:.0f},loss+both-grads")

    q = jnp.asarray(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(f(q, k, v)))
    flops = 4 * 8 * 512 * 512 / 2 * 64
    out["flash_attention_512"] = us
    print(f"flash_attention_512,{us:.0f},{flops/us/1e3:.1f}GFLOP/s")

    x = jnp.asarray(rng.standard_normal((1, 512, 4, 64)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((1, 512, 4))))
    alog = jnp.asarray(rng.standard_normal(4)) * 0.5
    b = jnp.asarray(rng.standard_normal((1, 512, 16)), jnp.float32)
    g = jax.jit(lambda *a: ops.mamba_scan(*a, chunk=128, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(g(x, dt, alog, b, b)[0]))
    out["mamba_scan_512"] = us
    print(f"mamba_scan_512,{us:.0f},chunked-ssd")

    xq = jnp.asarray(rng.standard_normal((4096, 512)), jnp.float32)
    h = jax.jit(lambda t: ops.quantize_boundary(t, use_pallas=False))
    us, _ = _timeit(lambda: jax.block_until_ready(h(xq)[0]))
    out["split_quant_4096x512"] = us
    print(f"split_quant_4096x512,{us:.0f},{xq.nbytes/us/1e3:.2f}GB/s")
    return out


def main() -> None:
    from benchmarks import paper_tables

    t0 = time.time()
    results = paper_tables.run_all()
    results["engine"] = engine_benchmarks()
    results["micro"] = micro_benchmarks()
    rev = _git_rev()
    results["meta"] = {"rev": rev, "wall_s": time.time() - t0,
                       "unix_time": time.time()}

    os.makedirs("results", exist_ok=True)

    def _clean(o):
        if isinstance(o, dict):
            return {k: _clean(v) for k, v in o.items()}
        if isinstance(o, (list, tuple)):
            return [_clean(v) for v in o]
        if isinstance(o, (float, int, str, bool)) or o is None:
            return o
        return float(o) if hasattr(o, "__float__") else str(o)

    cleaned = _clean(results)
    bench_path = os.path.join("results", f"BENCH_{rev}.json")
    for path in (bench_path, os.path.join("results", "bench.json")):
        with open(path, "w") as f:
            json.dump(cleaned, f, indent=1)
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s "
          f"-> {bench_path} (+ results/bench.json)")


if __name__ == "__main__":
    main()
